"""Per-model sharding policies: parameters, activations, caches, optimizer.

``param_specs`` walks the declarative parameter schema, so the specs can
never drift from the parameters. Cache specs are derived from the concrete
cache structure plus per-family logical-axis annotations; batch/activation
specs shard the batch over ('pod', 'data') and, when the batch is too small
(long_500k has global_batch = 1), fall back to sharding the sequence /
capacity dimension so the 500k-token KV cache and media context still
distribute.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import cache as cache_mod
from repro.models.config import ModelConfig
from repro.models.transformer import param_schema, _map_schema
from repro.sharding.rules import batch_axes, spec_for


# ---------------------------------------------------------------- parameters
def param_specs(cfg: ModelConfig, mesh: Mesh, rules=None) -> Any:
    """Pytree of PartitionSpec congruent with ``init_params(cfg, ...)``."""
    return _map_schema(
        lambda path, e: spec_for(e.shape, e.axes, mesh, rules), param_schema(cfg)
    )


# ------------------------------------------------------------------ batches
def data_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> dict:
    """Specs for a training / prefill batch dict (tokens, labels, [media])."""
    baxes = divisible_batch_axes(mesh, batch)
    tok = P(baxes or None)
    out = {"tokens": tok, "labels": tok}
    if cfg.family in ("vlm", "audio"):
        out["media"] = P(baxes or None, None, None)
    return out


def divisible_batch_axes(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """Largest prefix of the batch mesh axes whose product divides batch."""
    got: list[str] = []
    rem = batch
    for a in batch_axes(mesh):
        size = dict(mesh.shape)[a]
        if rem % size == 0:
            got.append(a)
            rem //= size
    return tuple(got)


# -------------------------------------------------------------------- caches
def _attn_cache_spec(mesh: Mesh, k_shape, baxes, used_batch) -> dict:
    """(L, B, C, KV, hd) ring-cache specs with the heads->capacity ladder."""
    _, b, cap, kv, hd = k_shape
    names = dict(mesh.shape)
    model = names.get("model", 1)
    free_batch = [a for a in ("pod", "data") if names.get(a, 1) > 1 and a not in used_batch]
    kv_spec: Any = None
    cap_spec: Any = None
    hd_spec: Any = None
    if model > 1 and kv % model == 0:
        kv_spec = "model"
    elif model > 1 and cap % model == 0:
        cap_spec = "model"
    elif model > 1 and hd % model == 0:
        hd_spec = "model"
    # leftover batch-ish axes soak into capacity (long-context, tiny batch)
    extra = tuple(a for a in free_batch if cap % names[a] == 0)
    if extra:
        cap_spec = (
            extra if cap_spec is None else ((cap_spec,) + extra)
        )
    return {
        "k": P(None, used_batch or None, cap_spec, kv_spec, hd_spec),
        "v": P(None, used_batch or None, cap_spec, kv_spec, hd_spec),
        "slot_pos": P(),
    }


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int) -> dict:
    """Specs congruent with ``cache_structure(cfg, batch, seq_len)``."""
    struct = cache_mod.cache_structure(cfg, batch, seq_len)
    baxes = divisible_batch_axes(mesh, batch)
    names = dict(mesh.shape)
    model = names.get("model", 1)

    def model_if(dim: int):
        return "model" if model > 1 and dim % model == 0 else None

    out: dict = {"pos": P()}
    fam = cfg.family
    if fam in ("dense", "moe"):
        out["self"] = _attn_cache_spec(mesh, struct["self"]["k"].shape, baxes, baxes)
    elif fam in ("vlm", "audio"):
        out["self"] = _attn_cache_spec(mesh, struct["self"]["k"].shape, baxes, baxes)
        mk = struct["media_k"].shape  # (L, B, M, KV, hd)
        out["media_k"] = P(None, baxes or None, None, model_if(mk[3]), None)
        out["media_v"] = out["media_k"]
    elif fam == "hybrid":
        ssm = struct["ssm"].shape  # (L, B, nh, hp, st)
        out["ssm"] = P(None, baxes or None, model_if(ssm[2]), None, None)
        cv = struct["conv"].shape  # (L, B, K-1, conv_ch)
        out["conv"] = P(None, baxes or None, None, model_if(cv[3]))
        out["shared"] = _attn_cache_spec(
            mesh, struct["shared"]["k"].shape, baxes, baxes
        )
    elif fam == "ssm":
        mc = struct["mlstm"]["c"].shape  # (ng, mpg, B, h, hd, hd)
        hspec = model_if(mc[3])
        hdspec = None if hspec else model_if(mc[4])
        out["mlstm"] = {
            # shard the matrix memory on its OUTPUT dim (q of C[p,q]): the
            # read einsum contracts p, so a p-shard forces an all-gather of
            # the f32 memory every step (+1.4 GB/token observed); a q-shard
            # keeps read and update fully local.
            "c": P(None, None, baxes or None, hspec, None, hdspec),
            "n": P(None, None, baxes or None, hspec, hdspec),
            "m": P(None, None, baxes or None, hspec),
        }
        sc = struct["slstm"]["c"].shape  # (ng, B, h, hd)
        shs = model_if(sc[2])
        shd = None if shs else model_if(sc[3])
        sspec = P(None, baxes or None, shs, shd)
        out["slstm"] = {"c": sspec, "n": sspec, "m": sspec, "h": sspec}
    else:
        raise ValueError(fam)
    return out


# ----------------------------------------------------------------- optimizer
def optimizer_state_specs(state_shape: Any, pspecs: Any) -> Any:
    """Specs for an optimizer state pytree: moments inherit the parameter
    specs (ZeRO — the state is sharded exactly as far as the parameters),
    ring buffers get a leading replicated delay axis, scalars replicate."""
    from repro.optim.delayed import DelayedState
    from repro.optim.optimizers import AdamState, SgdState

    if isinstance(state_shape, AdamState):
        return AdamState(step=P(), mu=pspecs, nu=pspecs)
    if isinstance(state_shape, SgdState):
        mom = state_shape.momentum
        return SgdState(momentum=pspecs if mom != () else ())
    if isinstance(state_shape, DelayedState):
        ring = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))),
            pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
        return DelayedState(
            step=P(),
            ring=ring,
            inner=optimizer_state_specs(state_shape.inner, pspecs),
        )
    if isinstance(state_shape, tuple) and not hasattr(state_shape, "_fields"):
        return tuple(optimizer_state_specs(s, pspecs) for s in state_shape)
    raise TypeError(f"unknown optimizer state node {type(state_shape)}")
