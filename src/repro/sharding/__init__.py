"""Sharding policies: logical-axis rules + per-model specs."""
from repro.sharding.rules import (
    DEFAULT_RULES,
    batch_axes,
    gbdt_data_specs,
    named,
    serving_rules,
    spec_for,
    tree_shardings,
)
from repro.sharding.policy import (
    cache_specs,
    data_specs,
    divisible_batch_axes,
    optimizer_state_specs,
    param_specs,
)

__all__ = [
    "DEFAULT_RULES",
    "batch_axes",
    "gbdt_data_specs",
    "named",
    "serving_rules",
    "spec_for",
    "tree_shardings",
    "cache_specs",
    "data_specs",
    "divisible_batch_axes",
    "optimizer_state_specs",
    "param_specs",
]
