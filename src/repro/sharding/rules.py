"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter in the zoo is declared with logical axis names (see
``repro.models.transformer.param_schema``); this module maps those names to
mesh axes. The policy is megatron-style tensor parallelism on 'model'
(ff / heads / experts / vocab) combined with FSDP-style parameter sharding
on 'data' (+ 'pod' when present) along the embed dimension — XLA SPMD
inserts the use-site all-gathers, which is exactly the ZeRO-3 communication
pattern.

Assignment is greedy per tensor: for each dim (left to right), take every
candidate mesh axis that (a) is present in the mesh, (b) has not been used
by an earlier dim of the same tensor, and (c) divides the remaining dim
size. Candidates that fail any test fall through — a 8-head KV tensor on a
16-way 'model' axis simply stays unsharded on that dim and the next dim
gets its chance (the heads -> head_dim -> replicate ladder emerges from the
rule table, not special cases).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rule table: logical axis -> mesh-axis candidates (in order).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # --- parameters ---
    "vocab": ("model",),
    "ff": ("model",),
    "q_flat": ("model",),
    "kv_flat": ("model",),
    "experts": ("model",),
    "gates": ("model",),  # slstm 4d gate stack
    "inner": ("model",),  # mamba d_inner
    "inner_proj": ("model",),  # mamba fused in_proj output
    "conv_ch": ("model",),
    "head_dim": ("model",),  # only reached when heads were unshardable
    "embed": ("data", "pod"),  # FSDP / ZeRO-3 axis for weights
    "layers": (),  # scan axis — never sharded
    # --- activations / caches ---
    "batch": ("pod", "data"),
    "seq": ("model",),  # long-context fallback: shard positions
    "kv_heads": ("model",),
    "heads": ("model",),
    "capacity": ("model", "data"),  # decode cache ring slots
    "media": (),
    # --- GBDT parameter-server engine (repro.ps) ---
    "samples": ("data",),  # binned rows / labels / targets / weights
    "features": ("feature", "model"),  # feature columns of the binned matrix
}


def gbdt_data_specs(mesh: Mesh, shard_features: bool = False, sparse: bool = False):
    """PartitionSpecs for a ``BinnedData`` pytree on the PS mesh.

    Samples shard over 'data' (each shard builds partial histograms that
    merge with a psum — the engine's worker/server split); feature columns
    shard over the block-distributed 2D mesh's 'feature' axis when the mesh
    has one (DESIGN.md §16), else optionally over 'model' for very wide
    datasets. Bin edges ride with the features; the scalar ``n_bins`` is
    replicated.

    ``sparse=True`` returns the specs for a ``SparseBins``-carrying
    dataset: only the feature-major store shards over the feature axis —
    the row-major store and ``zero_bin`` stay replicated (they route
    samples by global feature id), and the row dim stays UNSHARDED (sparse
    feature-major entries hold global sample ids; see
    ``ps.sharded.make_sharded_builder_2d``).
    """
    from repro.trees.binning import BinnedData, SparseBins  # local: no hard dep

    names = dict(mesh.shape)
    d = "data" if names.get("data", 1) > 1 else None
    if "feature" in names:
        m = "feature"
    else:
        m = "model" if shard_features and names.get("model", 1) > 1 else None
    if sparse:
        bins = SparseBins(
            indices=P(), codes=P(),
            feat_rows=P(m), feat_codes=P(m),
            zero_bin=P(),
        )
        d = None
    else:
        bins = P(d, m)
    return BinnedData(
        bins=bins,
        bin_edges=P(m),
        labels=P(d),
        multiplicity=P(d),
        n_bins=P(),
    )


def serving_rules() -> dict[str, tuple[str, ...]]:
    """Serving-time parameter placement: pure tensor parallelism, params
    REPLICATED over 'data'/'pod'. ZeRO-3's per-step parameter all-gather is
    pure loss at decode time (one token amortizes nothing); whenever the
    TP-sharded parameters fit HBM, dropping the FSDP axis removes the
    all-gather traffic entirely (beyond-paper optimization, §Perf)."""
    rules = dict(DEFAULT_RULES)
    rules["embed"] = ()
    # 2D-shard the FFN contraction dim (model x data) so even 100B-class
    # parameters fit without the FSDP axis; XLA turns the row-parallel
    # matmul into psum over both axes — no parameter gathers at decode.
    rules["ff"] = ("model", "data")
    return rules


def spec_for(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]] | None = None,
    min_ndim: int = 2,
) -> P:
    """PartitionSpec for one tensor under the rule table (see module doc)."""
    rules = DEFAULT_RULES if rules is None else rules
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs axes {axes}")
    if len(shape) < min_ndim:  # replicate small vectors/scalars
        return P()
    used: set[str] = set()
    parts: list = []
    for dim, name in zip(shape, axes):
        got: list[str] = []
        rem = int(dim)
        for cand in rules.get(name, ()) if name else ():
            size = dict(mesh.shape).get(cand, 0)
            if size <= 1 or cand in used or rem % size != 0:
                continue
            got.append(cand)
            used.add(cand)
            rem //= size
        parts.append(tuple(got) if len(got) > 1 else (got[0] if got else None))
    while parts and parts[-1] is None:
        parts.pop()  # trailing Nones are implicit
    return P(*parts)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that carry the global batch ('pod' first when present)."""
    names = dict(mesh.shape)
    return tuple(a for a in ("pod", "data") if names.get(a, 1) > 1)
