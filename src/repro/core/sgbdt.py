"""Stochastic GBDT: shared state + the serial trainer (tau = 0 case).

The functional-space view of the paper: the "parameter" is the prediction
vector F in R^N over the training set; one boosting round is one (projected)
SGD step on E[L_random(F; Q)]. The serial trainer below is both the paper's
baseline and the degenerate case of ``async_sgbdt.train_async`` with a zero
delay schedule — tested to be identical.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.objectives import Objective, get_objective
from repro.trees.binning import BinnedData
from repro.trees.forest import Forest, empty_forest
from repro.trees.learner import LearnerConfig


class SGBDTConfig(NamedTuple):
    n_trees: int = 400  # boosting rounds (x n_outputs trees each)
    step_length: float = 0.01  # the paper's v
    sampling_rate: float = 0.8  # uniform R_ij (paper's efficiency setting)
    # DEPRECATED shim: legacy string losses ("logistic" | "mse") resolve
    # through the Objective registry. Prefer ``objective``, which wins
    # whenever set.
    loss: str = "logistic"
    learner: LearnerConfig = LearnerConfig()
    # 'gradient' — the paper's step (leaf = mean sampled gradient; the only
    # one the paper claims is asynchronizable). 'newton' — xgboost-style
    # leaf = -G/(H+lam) with the sampled hessian; used by the ablation that
    # tests the paper's counter-intuitive conclusion 2 ("xgboost cannot be
    # modified into asynch-parallel manner").
    step_kind: str = "gradient"
    # First-class objective: an Objective instance or a registry spec
    # string ("multiclass:3", "quantile:0.9", "lambdarank", ...).
    objective: Objective | str | None = None
    # Staleness-adaptive step length (Keuper & Pfreundt's async-SGD rule /
    # Prop. 1's deflation): > 0 enables scaling each fold's effective step
    # by 1 / (1 + 6 * adaptive_step * tau_j), with tau_j = j - k(j) the
    # staleness OBSERVED at fold time. 0.0 (default) keeps the fixed step.
    # The scale is applied by the server (``engine.scale_push``): staleness
    # is unknowable at build time. tau = 0 scales by exactly 1.0, so serial
    # training is bitwise-unchanged by the flag.
    adaptive_step: float = 0.0

    @property
    def obj(self) -> Objective:
        return get_objective(self.objective if self.objective is not None else self.loss)

    @property
    def n_outputs(self) -> int:
        return self.obj.n_outputs

    @property
    def grad_hess(self) -> Callable:
        return self.obj.grad_hess

    @property
    def loss_fn(self) -> Callable:
        return self.obj.loss


class TrainState(NamedTuple):
    forest: Forest
    f: jax.Array  # (N,) — or (N, K) — current train-set predictions
    step: jax.Array  # () int32 — server update counter j


def init_state(cfg: SGBDTConfig, data: BinnedData) -> TrainState:
    """Server init: the paper's constant tree = the objective's prior.

    ``Objective.init_score`` owns the constant fit: prior log-odds for
    logistic, the multiplicity-weighted label mean for squared error, log
    class priors (K,) for multiclass, the weighted label quantile for
    pinball, zero for ranking.
    """
    obj = cfg.obj
    base = obj.init_score(data.labels, data.multiplicity)
    forest = empty_forest(
        cfg.n_trees, cfg.learner.depth, base_score=base, n_outputs=obj.n_outputs
    )
    if obj.n_outputs == 1:
        f = jnp.full((data.n_samples,), base, jnp.float32)
    else:
        f = jnp.broadcast_to(
            jnp.asarray(base, jnp.float32), (data.n_samples, obj.n_outputs)
        )
    return TrainState(forest=forest, f=f, step=jnp.asarray(0, jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg",))
def sgbdt_round(
    cfg: SGBDTConfig,
    data: BinnedData,
    state: TrainState,
    f_target: jax.Array,  # (N,) the F the *target* is computed from —
    rng: jax.Array,  #      equals state.f serially, stale when async
) -> TrainState:
    """One boosting round: sample Q -> build target -> build tree -> fold in.

    Thin shim over ``repro.ps.engine.round_body`` — the single shared round
    body of every trainer. Splitting ``f_target`` from ``state.f`` is what
    makes the body shared between the serial and asynchronous trainers: the
    tree is built against (possibly stale) ``f_target``, but folded into
    the live server state.
    """
    from repro.ps.engine import round_body  # local import to avoid cycle

    forest, f = round_body(cfg, data, state.forest, state.f, f_target, rng)
    return TrainState(forest=forest, f=f, step=state.step + 1)


def train_serial(
    cfg: SGBDTConfig,
    data: BinnedData,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[TrainState, int], None] | None = None,
) -> TrainState:
    """The paper's serial stochastic GBDT (Fig. 3, 'stochastic GBDT').

    Executed by the PS engine under the zero-staleness schedule: serial
    training IS ``("round_robin", 1)`` (k(j) = j), not a separate loop.
    """
    from repro.ps.engine import train  # local import to avoid cycle

    return train(
        cfg, data, ("round_robin", 1),
        seed=seed, eval_every=eval_every, eval_fn=eval_fn,
    )


def train_loss(cfg: SGBDTConfig, data: BinnedData, state: TrainState) -> jax.Array:
    return cfg.obj.loss(data.labels, state.f, data.multiplicity, qid=data.qid)


def train_metrics(cfg: SGBDTConfig, data: BinnedData, state: TrainState) -> dict:
    """The objective's scalar diagnostics on the training set."""
    return cfg.obj.metrics(data.labels, state.f, data.multiplicity, qid=data.qid)
