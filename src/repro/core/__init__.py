"""The paper's contribution: asynch-SGBDT (Algorithm 3) and its baselines.

- ``sgbdt``: serial stochastic GBDT (the tau = 0 special case) + shared state.
- ``async_sgbdt``: the asynchronous trainer — delayed targets F^{k(j)} via
  delay schedules, exactly the object Proposition 1 reasons about. Includes a
  fully jit/scan form that doubles as the distributed ``gbdt_train_step``.
- ``simulator``: event-driven parameter-server cluster simulator
  (heterogeneous workers, network jitter) producing delay schedules and
  wall-clock estimates; powers the Fig. 10 speedup reproduction.
- ``baselines``: synchronous fork-join SGBDT (LightGBM-style) and
  DimBoost-style centralized aggregation timing models.
"""
from repro.core.sgbdt import SGBDTConfig, TrainState, init_state, train_serial, sgbdt_round
from repro.core.async_sgbdt import (
    constant_delay,
    train_async,
    worker_round_robin,
)
from repro.core.simulator import ClusterSpec, simulate_async, simulate_sync
from repro.core.baselines import (
    speedup_model_async,
    speedup_model_dimboost,
    speedup_model_sync,
)

__all__ = [
    "SGBDTConfig",
    "TrainState",
    "init_state",
    "train_serial",
    "sgbdt_round",
    "constant_delay",
    "worker_round_robin",
    "train_async",
    "ClusterSpec",
    "simulate_async",
    "simulate_sync",
    "speedup_model_async",
    "speedup_model_sync",
    "speedup_model_dimboost",
]
