"""The paper's contribution: asynch-SGBDT (Algorithm 3) and its baselines.

- ``sgbdt``: config/state definitions + the serial trainer (the tau = 0
  special case). All trainers here are thin shims over the parameter-server
  execution engine in ``repro.ps`` — one shared round body, loop and scan
  forms, optional shard_map data-parallel builds.
- ``async_sgbdt``: the asynchronous trainer — delayed targets F^{k(j)} via
  delay schedules, exactly the object Proposition 1 reasons about. Includes a
  fully jit/scan form that doubles as the distributed ``gbdt_train_step``.
- ``simulator``: event-driven parameter-server cluster simulator
  (heterogeneous workers, network jitter) producing delay schedules and
  wall-clock estimates; powers the Fig. 10 speedup reproduction.
- ``baselines``: synchronous fork-join SGBDT (LightGBM-style) and
  DimBoost-style centralized aggregation timing models.
"""
from repro.core.sgbdt import (
    SGBDTConfig,
    TrainState,
    init_state,
    sgbdt_round,
    train_loss,
    train_metrics,
    train_serial,
)
from repro.core.async_sgbdt import (
    constant_delay,
    max_staleness,
    train_async,
    train_async_scan,
    worker_round_robin,
)
from repro.core.simulator import ClusterSpec, simulate_async, simulate_sync
from repro.core.baselines import (
    speedup_model_async,
    speedup_model_dimboost,
    speedup_model_sync,
)

__all__ = [
    "SGBDTConfig",
    "TrainState",
    "init_state",
    "train_serial",
    "train_loss",
    "train_metrics",
    "sgbdt_round",
    "constant_delay",
    "max_staleness",
    "worker_round_robin",
    "train_async",
    "train_async_scan",
    "ClusterSpec",
    "simulate_async",
    "simulate_sync",
    "speedup_model_async",
    "speedup_model_sync",
    "speedup_model_dimboost",
]
