"""Closed-form speedup models (Eq. 13 and the fork-join counterparts).

These are the napkin-math companions to the event simulator: the paper's
scalability upper bound  #workers < T(BuildTree) / T(Comm + BuildTarget)
(Eq. 13) says async speedup is linear until the server saturates, then flat.
The sync models capture Amdahl + barrier + comm growth. The benchmark
harness overlays these curves on the simulated ones.
"""
from __future__ import annotations

import numpy as np


def speedup_model_async(
    workers: np.ndarray, t_build: float, t_comm: float, t_server: float
) -> np.ndarray:
    """Eq. 13: linear until the server pipeline saturates.

    With W workers, trees arrive every t_build/W on average; the server needs
    t_server + t_comm per tree. Throughput = min(W / t_build, 1 / (t_server +
    t_comm)); speedup relative to serial throughput 1 / (t_build + t_server).
    """
    workers = np.asarray(workers, float)
    serial = 1.0 / (t_build + t_server + t_comm)
    cap = 1.0 / max(t_server + t_comm, 1e-12)
    rate = np.minimum(workers / t_build, cap)
    return rate / serial


def max_workers_bound(t_build: float, t_comm: float, t_server: float) -> float:
    """The paper's Eq. 13 bound on useful worker count."""
    return t_build / max(t_comm + t_server, 1e-12)


def speedup_model_sync(
    workers: np.ndarray,
    t_build: float,
    t_comm: float,
    t_server: float,
    parallel_fraction: float = 0.9,
    straggler_factor: float = 0.15,
) -> np.ndarray:
    """LightGBM-style fork-join: Amdahl + log-comm + straggler tax.

    E[max of W lognormals] grows ~ (1 + straggler_factor * log W); the
    barrier pays it every round.
    """
    w = np.asarray(workers, float)
    serial_round = t_build + t_server
    par = t_build * parallel_fraction / w * (1.0 + straggler_factor * np.log(np.maximum(w, 1)))
    rest = t_build * (1 - parallel_fraction) + t_server
    comm = np.where(w > 1, t_comm * np.log2(np.maximum(w, 2)), 0.0)
    return serial_round / (par + rest + comm)


def speedup_model_dimboost(
    workers: np.ndarray,
    t_build: float,
    t_comm: float,
    t_server: float,
    parallel_fraction: float = 0.85,
) -> np.ndarray:
    """DimBoost: centralized PS aggregation — comm cost linear in W."""
    w = np.asarray(workers, float)
    serial_round = t_build + t_server
    par = t_build * parallel_fraction / w
    rest = t_build * (1 - parallel_fraction) + t_server
    comm = np.where(w > 1, t_comm * 0.5 * w, 0.0)
    return serial_round / (par + rest + comm)
