"""Asynch-SGBDT: Algorithm 3 with explicit delay schedules.

On real hardware asynchrony arises from worker timing; algorithmically its
entire effect is *which* server version each pushed tree was built from —
the k(j) map with staleness tau >= j - k(j). The theory (Prop. 1) is stated
directly in terms of k(j), so we execute k(j) exactly: schedules come either
from closed forms (round-robin steady state, constant tau) or from the
event-driven cluster simulator (heterogeneous workers, network jitter).

Two executions of the same semantics:
  * ``train_async`` — Python loop, per-round eval hooks (experiments).
  * ``train_async_scan`` — single ``lax.scan`` program; this is the form the
    multi-pod dry-run lowers (dataset sharded over 'data', features over
    'model'), giving the paper's GBDT a roofline table alongside the zoo.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sgbdt import SGBDTConfig, TrainState, init_state
from repro.data.sampling import bernoulli_weights
from repro.trees.binning import BinnedData
from repro.trees.forest import forest_push
from repro.trees.learner import build_tree
from repro.trees.tree import apply_tree


# ---------------------------------------------------------------- schedules
def constant_delay(n_trees: int, tau: int) -> np.ndarray:
    """k(j) = max(0, j - tau): every tree is exactly tau versions stale."""
    j = np.arange(n_trees)
    return np.maximum(0, j - tau).astype(np.int32)


def worker_round_robin(n_trees: int, n_workers: int) -> np.ndarray:
    """Steady-state schedule of W homogeneous workers (threads-as-workers).

    A worker whose push became update j immediately pulls F^{j+1}; its next
    push lands W updates later => k(j + W) = j + 1, i.e. k(j) = j - W + 1.
    W = 1 is exactly the serial trainer (k(j) = j, zero staleness). The
    first W trees are all built from F^0 (all workers pulled at launch).
    """
    j = np.arange(n_trees)
    return np.maximum(0, j - n_workers + 1).astype(np.int32)


def max_staleness(schedule: np.ndarray) -> int:
    return int(np.max(np.arange(len(schedule)) - schedule))


# ------------------------------------------------------------------ trainers
def _round(cfg, data, forest, f_live, f_target, rng):
    """Shared round body (traced inside loop or scan)."""
    r_sample, r_feat = jax.random.split(rng)
    m_prime, _ = bernoulli_weights(r_sample, cfg.sampling_rate, data.multiplicity)
    g, h = cfg.grad_hess(data.labels, f_target)
    hess_w = m_prime * h if cfg.step_kind == "newton" else m_prime
    tree = build_tree(cfg.learner, data.bins, m_prime * g, hess_w, r_feat)
    delta = apply_tree(tree, data.bins)
    return (
        forest_push(forest, tree, jnp.float32(cfg.step_length)),
        f_live + cfg.step_length * delta,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "ring_size"))
def _async_step(cfg, data, forest, f, ring, j, k_j, rng, ring_size):
    f_target = ring[k_j % ring_size]
    forest, f = _round(cfg, data, forest, f, f_target, rng)
    ring = ring.at[(j + 1) % ring_size].set(f)
    return forest, f, ring


def train_async(
    cfg: SGBDTConfig,
    data: BinnedData,
    schedule: np.ndarray,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[TrainState, int], None] | None = None,
) -> TrainState:
    """Algorithm 3 under an explicit delay schedule (Python-loop form)."""
    assert len(schedule) == cfg.n_trees
    ring_size = max_staleness(schedule) + 1
    state = init_state(cfg, data)
    ring = jnp.broadcast_to(state.f, (ring_size, state.f.shape[0])).copy()
    keys = jax.random.split(jax.random.PRNGKey(seed), cfg.n_trees)
    forest, f = state.forest, state.f
    for j in range(cfg.n_trees):
        forest, f, ring = _async_step(
            cfg, data, forest, f, ring,
            jnp.asarray(j, jnp.int32), jnp.asarray(int(schedule[j]), jnp.int32),
            keys[j], ring_size,
        )
        if eval_fn is not None and eval_every and (j + 1) % eval_every == 0:
            eval_fn(TrainState(forest, f, jnp.asarray(j + 1, jnp.int32)), j + 1)
    return TrainState(forest=forest, f=f, step=jnp.asarray(cfg.n_trees, jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg", "ring_size"))
def train_async_scan(
    cfg: SGBDTConfig,
    data: BinnedData,
    schedule: jax.Array,    # (T,) int32
    rngs: jax.Array,        # (T, 2) keys
    ring_size: int,
) -> tuple[TrainState, jax.Array]:
    """Whole training run as one scan; returns per-round train loss too."""
    state = init_state(cfg, data)
    ring = jnp.broadcast_to(state.f, (ring_size, state.f.shape[0]))

    def body(carry, xs):
        forest, f, ring = carry
        j, k_j, rng = xs
        f_target = ring[k_j % ring_size]
        forest, f = _round(cfg, data, forest, f, f_target, rng)
        ring = jax.lax.dynamic_update_index_in_dim(
            ring, f, (j + 1) % ring_size, 0
        )
        loss = cfg.loss_fn(data.labels, f, data.multiplicity)
        return (forest, f, ring), loss

    (forest, f, _), losses = jax.lax.scan(
        body,
        (state.forest, state.f, ring),
        (jnp.arange(cfg.n_trees, dtype=jnp.int32), schedule, rngs),
    )
    return TrainState(forest, f, jnp.asarray(cfg.n_trees, jnp.int32)), losses
