"""Asynch-SGBDT: Algorithm 3 with explicit delay schedules (legacy names).

On real hardware asynchrony arises from worker timing; algorithmically its
entire effect is *which* server version each pushed tree was built from —
the k(j) map with staleness tau >= j - k(j). The theory (Prop. 1) is stated
directly in terms of k(j), so we execute k(j) exactly.

This module is the stable public surface; the execution engine lives in
``repro.ps``. Both entry points run the SAME shared round body
(``repro.ps.engine.round_body``) under a ``Trainer``:

  * ``train_async`` — Python loop, per-round eval hooks (experiments).
  * ``train_async_scan`` — single ``lax.scan`` program; this is the form
    the multi-pod dry-run lowers (dataset sharded over 'data', features
    over 'model'), giving the paper's GBDT a roofline table alongside the
    zoo.

The schedule closed forms (``constant_delay``, ``worker_round_robin``,
``max_staleness``) are re-exported from ``repro.ps.schedules``.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.core.sgbdt import SGBDTConfig, TrainState
from repro.ps.schedules import (  # noqa: F401  (public re-exports)
    constant_delay,
    max_staleness,
    worker_round_robin,
)
from repro.trees.binning import BinnedData


def train_async(
    cfg: SGBDTConfig,
    data: BinnedData,
    schedule: np.ndarray,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn: Callable[[TrainState, int], None] | None = None,
) -> TrainState:
    """Algorithm 3 under an explicit delay schedule (Python-loop form)."""
    from repro.ps.engine import train

    return train(
        cfg, data, schedule, seed=seed, eval_every=eval_every, eval_fn=eval_fn
    )


def train_async_scan(
    cfg: SGBDTConfig,
    data: BinnedData,
    schedule: jax.Array,  # (T,) int32
    rngs: jax.Array,  # (T, 2) keys
    ring_size: int,
) -> tuple[TrainState, jax.Array]:
    """Whole training run as one scan; returns per-round train loss too."""
    from repro.ps.engine import get_trainer

    return get_trainer(cfg).scan_with(data, schedule, rngs, ring_size)
