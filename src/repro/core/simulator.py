"""Event-driven parameter-server cluster simulator.

The container is a single CPU, so wall-clock asynchrony is *modeled*: a
discrete-event simulation of Algorithm 3's server/worker protocol with
heterogeneous worker speeds, per-build jitter, and network instability — the
three effects the paper blames for fork-join's poor scalability. The
simulator emits (a) the realized delay schedule k(j), which feeds the real
trainer (``train_async``), and (b) makespans, which feed the Fig. 10 speedup
reproduction. Component times are *measured* from the actual jitted
implementation by the benchmark harness, then passed in here.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    n_workers: int
    t_build: float  # mean tree-build time, reference worker (s)
    t_comm: float  # mean pull+push time per tree (s)
    t_server: float  # server: sample + target + fold per update (s)
    build_cv: float = 0.15  # lognormal per-build jitter
    comm_cv: float = 0.5  # network instability
    speed_spread: float = 0.25  # per-worker speed multiplier ~ LogN(0, spread)
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    schedule: np.ndarray  # (n_trees,) k(j)
    makespan: float
    mean_staleness: float
    max_staleness: int
    server_busy_frac: float


def _lognormal(rng: np.random.Generator, mean: float, cv: float) -> float:
    if mean <= 0:
        return 0.0
    if cv <= 0:
        return mean
    sigma = np.sqrt(np.log(1.0 + cv * cv))
    mu = np.log(mean) - 0.5 * sigma * sigma
    return float(rng.lognormal(mu, sigma))


def simulate_async(spec: ClusterSpec, n_trees: int) -> SimResult:
    """Algorithm 3 timing: workers pull/build/push freely; server serializes
    target rebuilds. Returns the realized delay schedule and makespan."""
    rng = np.random.default_rng(spec.seed)
    speed = np.exp(rng.normal(0.0, spec.speed_spread, spec.n_workers))

    # Events: (time, seq, kind, worker, pulled_version). Kinds: 'push'.
    events: list[tuple[float, int, int, int]] = []
    seq = 0
    for w in range(spec.n_workers):
        pull = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        build = _lognormal(rng, spec.t_build, spec.build_cv) * speed[w]
        push = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        heapq.heappush(events, (pull + build + push, seq, w, 0))
        seq += 1

    schedule = np.zeros(n_trees, np.int32)
    server_free = 0.0
    server_busy = 0.0
    j = 0
    while j < n_trees:
        t_arrive, _, w, pulled_version = heapq.heappop(events)
        start = max(t_arrive, server_free)
        t_srv = _lognormal(rng, spec.t_server, spec.build_cv)
        server_free = start + t_srv
        server_busy += t_srv
        schedule[j] = pulled_version
        j += 1
        # Worker pulls the fresh version and starts its next build.
        pull = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        build = _lognormal(rng, spec.t_build, spec.build_cv) * speed[w]
        push = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        heapq.heappush(events, (server_free + pull + build + push, seq, w, j))
        seq += 1

    stale = np.arange(n_trees) - schedule
    return SimResult(
        schedule=schedule,
        makespan=server_free,
        mean_staleness=float(stale.mean()),
        max_staleness=int(stale.max()),
        server_busy_frac=server_busy / server_free,
    )


def staleness_stats(schedule) -> dict:
    """Mean/max staleness + histogram of a realized or simulated k(j)."""
    schedule = np.asarray(schedule)
    stale = np.arange(len(schedule)) - schedule
    taus, counts = np.unique(stale, return_counts=True)
    return {
        "mean_staleness": float(stale.mean()),
        "max_staleness": int(stale.max()),
        "histogram": {int(t): int(c) for t, c in zip(taus, counts)},
    }


def step_scale_stats(schedule, rho: float) -> dict:
    """Effective-step statistics of the adaptive rule on a k(j).

    The staleness-adaptive server deflates fold j's step by
    1 / (1 + 6*rho*tau_j); this summarizes the realized effective step a
    schedule implies — the quantity cross-validated between a threaded
    run's trace and the event model's predicted schedule for the same
    cluster geometry (``crossvalidate_schedule(..., adaptive_rho=...)``).
    """
    from repro.ps.schedules import staleness_scales

    scales = staleness_scales(schedule, rho)
    return {
        "rho": float(rho),
        "mean_scale": float(scales.mean()),
        "min_scale": float(scales.min()),
    }


def simulate_elastic(
    spec: ClusterSpec,
    n_trees: int,
    membership: "Sequence[tuple[int, int]]" = (),
) -> SimResult:
    """``simulate_async`` with worker churn: the event model of the elastic
    runtime.

    ``membership`` is a sequence of ``(at_update, delta)`` pairs: when the
    server has folded ``at_update`` trees, ``delta`` workers join (> 0, new
    worker ids with freshly drawn speeds) or leave (< 0, the most recently
    added live workers stop pulling new work; their in-flight build is
    discarded — crash semantics, matching ``ps.runtime.FaultPlan``).
    Predicts the staleness distribution of a join/leave/crash run so a
    recorded elastic trace has a model to cross-validate against.
    """
    rng = np.random.default_rng(spec.seed)
    membership = sorted((int(j), int(d)) for j, d in membership)
    if any(j < 0 for j, _ in membership):
        raise ValueError("membership events need at_update >= 0")

    def draw_speed():
        return float(np.exp(rng.normal(0.0, spec.speed_spread)))

    def cycle(mean_scale: float) -> float:
        pull = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        build = _lognormal(rng, spec.t_build, spec.build_cv) * mean_scale
        push = _lognormal(rng, spec.t_comm / 2, spec.comm_cv)
        return pull + build + push

    events: list[tuple[float, int, int, int]] = []
    seq = 0
    speed: dict[int, float] = {}
    live: list[int] = []
    next_worker = 0
    for _ in range(spec.n_workers):
        w = next_worker
        next_worker += 1
        speed[w] = draw_speed()
        live.append(w)
        heapq.heappush(events, (cycle(speed[w]), seq, w, 0))
        seq += 1

    schedule = np.zeros(n_trees, np.int32)
    server_free = 0.0
    server_busy = 0.0
    j = 0
    mi = 0
    while j < n_trees:
        if not events:
            raise RuntimeError(
                "no live workers left before the run finished — membership "
                "events removed everyone"
            )
        t_arrive, _, w, pulled_version = heapq.heappop(events)
        if w not in live:  # crashed while building: push discarded
            continue
        start = max(t_arrive, server_free)
        t_srv = _lognormal(rng, spec.t_server, spec.build_cv)
        server_free = start + t_srv
        server_busy += t_srv
        schedule[j] = pulled_version
        j += 1
        while mi < len(membership) and membership[mi][0] <= j:
            _, delta = membership[mi]
            mi += 1
            if delta > 0:
                for _ in range(delta):
                    nw = next_worker
                    next_worker += 1
                    speed[nw] = draw_speed()
                    live.append(nw)
                    heapq.heappush(
                        events, (server_free + cycle(speed[nw]), seq, nw, j)
                    )
                    seq += 1
            else:
                for _ in range(-delta):
                    if live:
                        live.pop()
        if w in live:  # pull fresh version, start next build
            heapq.heappush(
                events, (server_free + cycle(speed[w]), seq, w, j)
            )
            seq += 1

    stale = np.arange(n_trees) - schedule
    return SimResult(
        schedule=schedule,
        makespan=server_free,
        mean_staleness=float(stale.mean()),
        max_staleness=int(stale.max()),
        server_busy_frac=server_busy / max(server_free, 1e-12),
    )


def crossvalidate_schedule(
    schedule,
    spec: ClusterSpec,
    makespan: float | None = None,
    membership: Sequence[tuple[int, int]] = (),
    adaptive_rho: float = 0.0,
) -> dict:
    """Validate the event model against a *measured* run.

    ``schedule`` is a realized k(j) (e.g. ``ps.runtime.RunTrace.schedule``)
    and ``spec`` the cluster geometry measured from the same run; the
    simulator predicts a schedule for that geometry and both staleness
    distributions are reported side by side — the same shape of check
    Block-distributed GBT runs between its communication model and real
    cluster traces. ``membership`` forwards the run's worker churn to
    ``simulate_elastic``; ``adaptive_rho > 0`` adds realized-vs-predicted
    effective-step statistics under the staleness-adaptive rule.
    """
    n = len(np.asarray(schedule))
    sim = (
        simulate_elastic(spec, n, membership)
        if membership
        else simulate_async(spec, n)
    )
    out = {
        "spec": dataclasses.asdict(spec),
        "realized": staleness_stats(schedule),
        "simulated": staleness_stats(sim.schedule),
        "simulated_makespan": float(sim.makespan),
    }
    if adaptive_rho:
        out["realized_step_scale"] = step_scale_stats(schedule, adaptive_rho)
        out["simulated_step_scale"] = step_scale_stats(
            sim.schedule, adaptive_rho
        )
    if makespan is not None:
        out["realized_makespan"] = float(makespan)
        out["makespan_ratio"] = float(makespan) / max(float(sim.makespan), 1e-12)
    return out


def simulate_sync(
    spec: ClusterSpec,
    n_trees: int,
    parallel_fraction: float = 0.9,
    comm_model: str = "allreduce",  # 'allreduce' (LightGBM) | 'central' (DimBoost)
) -> float:
    """Fork-join makespan: every round barriers on the slowest worker.

    ``parallel_fraction`` is the share of the tree build that the framework
    actually parallelizes (LightGBM feature-parallel distributes the
    histogram/feature scan, ~90% of the build; the serial remainder plus
    the per-round barrier is the paper's explanation for its 5-7x ceiling).
    'allreduce' comm grows ~log W; 'central' (parameter-server aggregation,
    DimBoost) grows ~linearly in W — the server-burden bottleneck.
    """
    rng = np.random.default_rng(spec.seed + 1)
    speed = np.exp(rng.normal(0.0, spec.speed_spread, spec.n_workers))
    total = 0.0
    w = spec.n_workers
    for _ in range(n_trees):
        shares = np.array(
            [
                _lognormal(rng, spec.t_build * parallel_fraction / w, spec.build_cv)
                * speed[i]
                for i in range(w)
            ]
        )
        serial = _lognormal(rng, spec.t_build * (1 - parallel_fraction), spec.build_cv)
        if w > 1:
            if comm_model == "allreduce":
                comm = _lognormal(rng, spec.t_comm * np.log2(w), spec.comm_cv)
            else:
                comm = _lognormal(rng, spec.t_comm * 0.5 * w, spec.comm_cv)
        else:
            comm = 0.0
        total += shares.max() + serial + comm + spec.t_server
    return total
