"""Fixed-capacity forests: stacked tree arrays + a fill count.

The server's additive model F(x) = sum_t v * Tree_t(x) lives here. Capacity
is static (the paper always fixes the total tree budget T up front), so the
forest is a pytree that jit/scan can carry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.trees.tree import Tree, tree_num_nodes


class Forest(NamedTuple):
    feature: jax.Array     # (T, 2^d - 1) int32
    threshold: jax.Array   # (T, 2^d - 1) int32
    leaf_value: jax.Array  # (T, 2^d) f32 — already scaled by the step length
    n_trees: jax.Array     # () int32 — how many slots are live
    base_score: jax.Array  # () f32 — the paper's init tree (prior log-odds)

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1


def empty_forest(capacity: int, depth: int, base_score=0.0) -> Forest:
    n_int, n_leaf = tree_num_nodes(depth)
    return Forest(
        feature=jnp.zeros((capacity, n_int), jnp.int32),
        threshold=jnp.full((capacity, n_int), 2**30, jnp.int32),
        leaf_value=jnp.zeros((capacity, n_leaf), jnp.float32),
        n_trees=jnp.asarray(0, jnp.int32),
        base_score=jnp.asarray(base_score, jnp.float32),
    )


def forest_push(forest: Forest, tree: Tree, step_length: jax.Array) -> Forest:
    """Server fold-in: F <- F + v * Tree (Algorithm 3, server step 2)."""
    t = forest.n_trees
    return forest._replace(
        feature=jax.lax.dynamic_update_index_in_dim(forest.feature, tree.feature, t, 0),
        threshold=jax.lax.dynamic_update_index_in_dim(
            forest.threshold, tree.threshold, t, 0
        ),
        leaf_value=jax.lax.dynamic_update_index_in_dim(
            forest.leaf_value, tree.leaf_value * step_length, t, 0
        ),
        n_trees=t + 1,
    )


def forest_predict(forest: Forest, bins: jax.Array, backend: str = "auto") -> jax.Array:
    """F(x) over binned inputs (N, F) -> (N,). Slots >= n_trees predict 0.

    ``backend='auto'`` routes through the fused Pallas traversal kernel on
    TPU and the jnp oracle elsewhere (``kernels.ops.forest_traverse``).
    """
    pred = ops.forest_traverse(
        bins, forest.feature, forest.threshold, forest.leaf_value,
        forest.n_trees, forest.depth, backend=backend,
    )
    return forest.base_score + pred
