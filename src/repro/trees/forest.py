"""Fixed-capacity forests: stacked tree arrays + a fill count.

The server's additive model F(x) = sum_t v * Tree_t(x) lives here. Capacity
is static (the paper always fixes the total tree budget T up front), so the
forest is a pytree that jit/scan can carry.

Multi-output (K > 1) objectives fit one tree per output per boosting
round; the K trees of a round occupy K consecutive slots (round-major,
output-minor: slot = round * K + k), so ``n_trees`` keeps counting *live
slots* and the hot-swap/partial-fill masking contract is unchanged. The
output count is derived from ``base_score``'s shape — a scalar for the
historical single-output layout (bitwise-compatible checkpoints), a (K,)
vector otherwise — so ``Forest`` stays a pure array pytree.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.trees.tree import Tree, tree_num_nodes


class Forest(NamedTuple):
    feature: jax.Array  # (T, 2^d - 1) int32; T = capacity * n_outputs slots
    threshold: jax.Array  # (T, 2^d - 1) int32
    leaf_value: jax.Array  # (T, 2^d) f32 — already scaled by the step length
    n_trees: jax.Array  # () int32 — how many slots are live
    base_score: jax.Array  # () f32 init score, or (K,) for K-output forests

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1

    @property
    def n_outputs(self) -> int:
        return int(self.base_score.shape[-1]) if self.base_score.ndim else 1

    def quantize(self, mode: str = "int8") -> "QuantizedForest":
        """Pack the serving payload into a quantized layout (DESIGN.md §17).

        The traversal kernel's VMEM footprint is dominated by the
        ``threshold``/``leaf_value`` blocks (the forest-size ceiling the
        ROADMAP names); quantizing them cuts the resident bytes 4x (int8)
        or 2x (fp16) with a *documented* score error bound
        (``quantization_atol``). Modes:

        - ``"int8"`` — thresholds are bin ids, exact in int8 (requires
          ``n_bins <= 128``; raises otherwise); leaves store
          ``round(leaf / scale)`` with one f32 ``scale = max|leaf| / 127``
          per tree, so per-sample error is at most ``sum_t scale_t / 2``.
        - ``"fp16"`` — thresholds exact in int16, leaves rounded to
          float16 (error at most ``sum_t max|leaf_t| * 2^-11``).

        Dead slots (>= ``n_trees``) are masked at traversal time, so their
        sentinel thresholds are zeroed rather than range-checked. This is
        a host-side load/hot-swap-time operation, not a jit-traceable one.
        """
        if mode not in ("int8", "fp16"):
            raise ValueError(f"quantize mode must be 'int8' or 'fp16', got {mode!r}")
        slots = self.feature.shape[0]
        live = jnp.arange(slots) < self.n_trees
        thr = jnp.where(live[:, None], self.threshold, 0)
        if mode == "fp16":
            if int(jnp.max(thr)) > 32767:
                raise ValueError("fp16 mode stores thresholds as int16: live "
                                 "bin ids must be <= 32767")
            return QuantizedForest(
                feature=self.feature,
                threshold=thr.astype(jnp.int16),
                leaf_value=self.leaf_value.astype(jnp.float16),
                leaf_scale=jnp.ones((slots,), jnp.float32),
                n_trees=self.n_trees,
                base_score=self.base_score,
            )
        if int(jnp.max(thr)) > 127:
            raise ValueError(
                "int8 mode stores thresholds as int8: live bin ids must be "
                "<= 127 (use n_bins <= 128, or mode='fp16')"
            )
        peak = jnp.max(jnp.abs(self.leaf_value), axis=1)
        scale = jnp.where(peak > 0, peak / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(
            jnp.round(self.leaf_value / scale[:, None]), -127, 127
        ).astype(jnp.int8)
        return QuantizedForest(
            feature=self.feature,
            threshold=thr.astype(jnp.int8),
            leaf_value=q,
            leaf_scale=scale,
            n_trees=self.n_trees,
            base_score=self.base_score,
        )


class QuantizedForest(NamedTuple):
    """A ``Forest`` with quantized traversal payload (``Forest.quantize``).

    Same pytree discipline as ``Forest`` — pure arrays, so it rides as a
    jit argument and hot-swaps without retrace. The mode is derived from
    ``leaf_value.dtype`` (int8 -> per-tree-scaled int8, float16 -> fp16),
    exactly like ``Forest`` derives ``n_outputs`` from ``base_score``.
    """

    feature: jax.Array  # (T, 2^d - 1) int32 — gather indices stay exact
    threshold: jax.Array  # (T, 2^d - 1) int8 (int8 mode) or int16 (fp16)
    leaf_value: jax.Array  # (T, 2^d) int8 or float16
    leaf_scale: jax.Array  # (T,) f32 per-tree dequant scale (ones for fp16)
    n_trees: jax.Array  # () int32 — live slots, same masking contract
    base_score: jax.Array  # () or (K,) f32 — never quantized

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1

    @property
    def n_outputs(self) -> int:
        return int(self.base_score.shape[-1]) if self.base_score.ndim else 1

    @property
    def mode(self) -> str:
        return "int8" if self.leaf_value.dtype == jnp.int8 else "fp16"

    def dequantize(self) -> Forest:
        """The f32 forest the quantized payload encodes (dead-slot
        thresholds come back as 0, which the ``n_trees`` mask makes
        unobservable)."""
        leaf = self.leaf_value.astype(jnp.float32)
        if self.leaf_value.dtype == jnp.int8:
            leaf = leaf * self.leaf_scale[:, None]
        return Forest(
            feature=self.feature,
            threshold=self.threshold.astype(jnp.int32),
            leaf_value=leaf,
            n_trees=self.n_trees,
            base_score=self.base_score,
        )


def quantization_atol(forest: Forest, quantized: QuantizedForest) -> float:
    """The documented parity tolerance: |quantized score - f32 score| per
    sample (any output column) is bounded by the sum over live trees of
    each tree's worst leaf dequantization error — every sample reads
    exactly one leaf per live tree."""
    deq = quantized.dequantize()
    err = jnp.max(jnp.abs(deq.leaf_value - forest.leaf_value), axis=1)
    live = jnp.arange(forest.feature.shape[0]) < forest.n_trees
    return float(jnp.sum(jnp.where(live, err, 0.0)))


def empty_forest(capacity: int, depth: int, base_score=0.0, n_outputs: int = 1) -> Forest:
    """``capacity`` boosting rounds x ``n_outputs`` trees each."""
    n_int, n_leaf = tree_num_nodes(depth)
    base = jnp.asarray(base_score, jnp.float32)
    if n_outputs > 1:
        base = jnp.broadcast_to(base, (n_outputs,))
    slots = capacity * n_outputs
    return Forest(
        feature=jnp.zeros((slots, n_int), jnp.int32),
        threshold=jnp.full((slots, n_int), 2**30, jnp.int32),
        leaf_value=jnp.zeros((slots, n_leaf), jnp.float32),
        n_trees=jnp.asarray(0, jnp.int32),
        base_score=base,
    )


def forest_push(forest: Forest, tree: Tree, step_length: jax.Array) -> Forest:
    """Server fold-in: F <- F + v * Tree (Algorithm 3, server step 2).

    Accepts a single tree ((n_int,) arrays) or a stacked K-output group
    ((K, n_int) arrays) — a group lands in K consecutive slots as one push.
    """
    t = forest.n_trees
    if tree.leaf_value.ndim == 1:
        return forest._replace(
            feature=jax.lax.dynamic_update_index_in_dim(
                forest.feature, tree.feature, t, 0
            ),
            threshold=jax.lax.dynamic_update_index_in_dim(
                forest.threshold, tree.threshold, t, 0
            ),
            leaf_value=jax.lax.dynamic_update_index_in_dim(
                forest.leaf_value, tree.leaf_value * step_length, t, 0
            ),
            n_trees=t + 1,
        )
    k = tree.leaf_value.shape[0]
    return forest._replace(
        feature=jax.lax.dynamic_update_slice_in_dim(forest.feature, tree.feature, t, 0),
        threshold=jax.lax.dynamic_update_slice_in_dim(
            forest.threshold, tree.threshold, t, 0
        ),
        leaf_value=jax.lax.dynamic_update_slice_in_dim(
            forest.leaf_value, tree.leaf_value * step_length, t, 0
        ),
        n_trees=t + k,
    )


def forest_predict(
    forest: Forest | QuantizedForest, bins: jax.Array, backend: str = "auto"
) -> jax.Array:
    """F(x) over binned inputs (N, F) -> (N,), or (N, K) for K-output
    forests. Slots >= n_trees predict 0.

    ``backend='auto'`` routes through the fused Pallas traversal kernel on
    TPU and the jnp oracle elsewhere (``kernels.ops.forest_traverse``).
    Accepts a ``QuantizedForest`` too — the kernel dequantizes in VMEM
    (scores within ``quantization_atol`` of the f32 forest's).
    """
    pred = ops.forest_traverse(
        bins, forest.feature, forest.threshold, forest.leaf_value,
        forest.n_trees, forest.depth, backend=backend,
        n_outputs=forest.n_outputs,
        leaf_scale=getattr(forest, "leaf_scale", None),
    )
    return forest.base_score + pred
