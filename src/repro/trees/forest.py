"""Fixed-capacity forests: stacked tree arrays + a fill count.

The server's additive model F(x) = sum_t v * Tree_t(x) lives here. Capacity
is static (the paper always fixes the total tree budget T up front), so the
forest is a pytree that jit/scan can carry.

Multi-output (K > 1) objectives fit one tree per output per boosting
round; the K trees of a round occupy K consecutive slots (round-major,
output-minor: slot = round * K + k), so ``n_trees`` keeps counting *live
slots* and the hot-swap/partial-fill masking contract is unchanged. The
output count is derived from ``base_score``'s shape — a scalar for the
historical single-output layout (bitwise-compatible checkpoints), a (K,)
vector otherwise — so ``Forest`` stays a pure array pytree.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.trees.tree import Tree, tree_num_nodes


class Forest(NamedTuple):
    feature: jax.Array  # (T, 2^d - 1) int32; T = capacity * n_outputs slots
    threshold: jax.Array  # (T, 2^d - 1) int32
    leaf_value: jax.Array  # (T, 2^d) f32 — already scaled by the step length
    n_trees: jax.Array  # () int32 — how many slots are live
    base_score: jax.Array  # () f32 init score, or (K,) for K-output forests

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1

    @property
    def n_outputs(self) -> int:
        return int(self.base_score.shape[-1]) if self.base_score.ndim else 1


def empty_forest(capacity: int, depth: int, base_score=0.0, n_outputs: int = 1) -> Forest:
    """``capacity`` boosting rounds x ``n_outputs`` trees each."""
    n_int, n_leaf = tree_num_nodes(depth)
    base = jnp.asarray(base_score, jnp.float32)
    if n_outputs > 1:
        base = jnp.broadcast_to(base, (n_outputs,))
    slots = capacity * n_outputs
    return Forest(
        feature=jnp.zeros((slots, n_int), jnp.int32),
        threshold=jnp.full((slots, n_int), 2**30, jnp.int32),
        leaf_value=jnp.zeros((slots, n_leaf), jnp.float32),
        n_trees=jnp.asarray(0, jnp.int32),
        base_score=base,
    )


def forest_push(forest: Forest, tree: Tree, step_length: jax.Array) -> Forest:
    """Server fold-in: F <- F + v * Tree (Algorithm 3, server step 2).

    Accepts a single tree ((n_int,) arrays) or a stacked K-output group
    ((K, n_int) arrays) — a group lands in K consecutive slots as one push.
    """
    t = forest.n_trees
    if tree.leaf_value.ndim == 1:
        return forest._replace(
            feature=jax.lax.dynamic_update_index_in_dim(
                forest.feature, tree.feature, t, 0
            ),
            threshold=jax.lax.dynamic_update_index_in_dim(
                forest.threshold, tree.threshold, t, 0
            ),
            leaf_value=jax.lax.dynamic_update_index_in_dim(
                forest.leaf_value, tree.leaf_value * step_length, t, 0
            ),
            n_trees=t + 1,
        )
    k = tree.leaf_value.shape[0]
    return forest._replace(
        feature=jax.lax.dynamic_update_slice_in_dim(forest.feature, tree.feature, t, 0),
        threshold=jax.lax.dynamic_update_slice_in_dim(
            forest.threshold, tree.threshold, t, 0
        ),
        leaf_value=jax.lax.dynamic_update_slice_in_dim(
            forest.leaf_value, tree.leaf_value * step_length, t, 0
        ),
        n_trees=t + k,
    )


def forest_predict(forest: Forest, bins: jax.Array, backend: str = "auto") -> jax.Array:
    """F(x) over binned inputs (N, F) -> (N,), or (N, K) for K-output
    forests. Slots >= n_trees predict 0.

    ``backend='auto'`` routes through the fused Pallas traversal kernel on
    TPU and the jnp oracle elsewhere (``kernels.ops.forest_traverse``).
    """
    pred = ops.forest_traverse(
        bins, forest.feature, forest.threshold, forest.leaf_value,
        forest.n_trees, forest.depth, backend=backend,
        n_outputs=forest.n_outputs,
    )
    return forest.base_score + pred
