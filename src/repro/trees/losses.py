"""Losses, gradients and hessians in the paper's functional-space convention.

The paper optimizes L(F) = sum_i m_i * l(y_i, F_i) over the prediction vector
F in R^N, with the symmetric logistic link p = e^F / (e^F + e^-F) (Friedman's
two-sided logit — equivalent to sigmoid(2F)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sigmoid2(f: jax.Array) -> jax.Array:
    """p = e^F / (e^F + e^-F) = sigmoid(2F)."""
    return jax.nn.sigmoid(2.0 * f)


def logistic_loss(y: jax.Array, f: jax.Array, weight: jax.Array | None = None) -> jax.Array:
    """Weighted mean logistic loss (the paper's Eq. 1 normalized by sum m_i)."""
    # log(1 + exp(-2 (2y-1) F)) — numerically-stable form of the paper's loss.
    margin = (2.0 * y - 1.0) * f
    per = jnp.logaddexp(0.0, -2.0 * margin)
    if weight is None:
        return jnp.mean(per)
    return jnp.sum(weight * per) / jnp.sum(weight)


def logistic_grad_hess(y: jax.Array, f: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-sample dl/dF and d2l/dF2 for the symmetric logit loss.

    grad = 2 (p - y); hess = 4 p (1 - p). Both are O(1)-bounded, matching the
    paper's bounded-gradient assumption ||l'|| <= phi.
    """
    p = sigmoid2(f)
    return 2.0 * (p - y), 4.0 * p * (1.0 - p)


def mse_loss(y: jax.Array, f: jax.Array, weight: jax.Array | None = None) -> jax.Array:
    per = 0.5 * (f - y) ** 2
    if weight is None:
        return jnp.mean(per)
    return jnp.sum(weight * per) / jnp.sum(weight)


def mse_grad_hess(y: jax.Array, f: jax.Array) -> tuple[jax.Array, jax.Array]:
    return f - y, jnp.ones_like(f)


# DEPRECATED: the string-keyed loss table predates the first-class
# Objective API (``repro.objectives``). ``SGBDTConfig.loss`` strings now
# resolve through ``objectives.get_objective``; this dict remains only for
# external callers of the raw functions.
LOSSES = {
    "logistic": (logistic_loss, logistic_grad_hess),
    "mse": (mse_loss, mse_grad_hess),
}
