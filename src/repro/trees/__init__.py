"""Decision-tree substrate: binning, histogram tree learner, forests.

Everything here is shape-static and jit-able: trees are dense heap-indexed
arrays, growth is level-wise (the paper's "well-grown tree" assumption), and
all control flow is ``jax.lax``.
"""
from repro.trees.binning import BinnedData, make_bins, apply_bins, bin_dataset
from repro.trees.losses import (
    logistic_grad_hess,
    logistic_loss,
    mse_grad_hess,
    mse_loss,
    sigmoid2,
)
from repro.trees.tree import (
    Tree,
    apply_tree,
    apply_tree_stack,
    empty_tree,
    tree_num_nodes,
)
from repro.trees.forest import (
    Forest,
    QuantizedForest,
    empty_forest,
    forest_predict,
    forest_push,
    quantization_atol,
)
from repro.trees.learner import LearnerConfig, build_tree, build_tree_multi

__all__ = [
    "BinnedData",
    "make_bins",
    "apply_bins",
    "bin_dataset",
    "logistic_grad_hess",
    "logistic_loss",
    "mse_grad_hess",
    "mse_loss",
    "sigmoid2",
    "Tree",
    "apply_tree",
    "apply_tree_stack",
    "empty_tree",
    "tree_num_nodes",
    "Forest",
    "QuantizedForest",
    "quantization_atol",
    "empty_forest",
    "forest_predict",
    "forest_push",
    "LearnerConfig",
    "build_tree",
    "build_tree_multi",
]
