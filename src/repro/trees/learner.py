"""Level-wise histogram tree learner — fully jittable, fixed shapes.

One tree build = ``depth`` levels; each level builds per-node grad/hess
histograms (Pallas kernel or jnp oracle), scans them for the best split, and
re-routes samples. Matches the paper's worker-side "building the tree
sub-step": the tree fits the (sampled, importance-weighted) gradient target.

Conventions:
  * Caller supplies per-sample (g_i, h_i). For the paper's plain gradient
    step, g_i = m'_i * l'_i and h_i = m'_i (leaf value = - mean residual).
    For Newton (xgboost-style) steps, g/h are weighted gradient/hessian.
  * Leaf value = -G_leaf / (H_leaf + lam) in both cases.
  * Samples with h_i == 0 (not drawn by the Bernoulli sampler) are inert:
    they contribute to no histogram and no leaf.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.trees.tree import Tree


class LearnerConfig(NamedTuple):
    depth: int = 7  # 2^depth leaves (paper: 100 -> 128, 400 -> 512)
    n_bins: int = 64
    lam: float = 1.0  # L2 on leaf values
    min_child_hess: float = 1e-3
    feature_fraction: float = 0.8  # paper samples 80% of features per tree
    backend: str = "ref"  # 'ref' | 'pallas' | 'auto'
    # Mesh axis samples are sharded over when building under shard_map
    # (repro.ps.sharded): histograms and leaf stats psum across it; the rng
    # must be replicated so every shard draws the same feature mask.
    axis_name: str | None = None


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree(
    cfg: LearnerConfig,
    bins: jax.Array,  # (N, F) int32
    g: jax.Array,  # (N,) f32 — weighted gradient target
    h: jax.Array,  # (N,) f32 — weighted hessian / sample weight
    rng: jax.Array,  # feature-subsampling key
) -> Tree:
    n, n_feat = bins.shape
    depth, n_bins = cfg.depth, cfg.n_bins

    feat_mask = (
        jax.random.uniform(rng, (n_feat,)) < cfg.feature_fraction
        if cfg.feature_fraction < 1.0
        else jnp.ones((n_feat,), bool)
    )

    node = jnp.zeros((n,), jnp.int32)  # heap ids, level-local after offset
    features = []
    thresholds = []

    for level in range(depth):
        n_nodes = 1 << level
        hist = ops.build_histogram(
            bins, node, g, h, n_nodes, n_bins,
            backend=cfg.backend, axis_name=cfg.axis_name,
        )
        gain = ops.split_gain(hist, cfg.lam, cfg.min_child_hess, backend=cfg.backend)
        gain = jnp.where(feat_mask[None, :, None], gain, -jnp.inf)  # (L, F, B)

        flat = gain.reshape(n_nodes, -1)
        idx = jnp.argmax(flat, axis=-1)
        best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
        feat = (idx // n_bins).astype(jnp.int32)
        thr = (idx % n_bins).astype(jnp.int32)

        # Unsplittable node -> pass-through: all samples go left.
        ok = jnp.isfinite(best) & (best > 0.0)
        feat = jnp.where(ok, feat, 0)
        thr = jnp.where(ok, thr, n_bins - 1)

        features.append(feat)
        thresholds.append(thr)

        val = jnp.take_along_axis(bins, jnp.take(feat, node)[:, None], axis=1)[:, 0]
        go_right = (val > jnp.take(thr, node)).astype(jnp.int32)
        node = 2 * node + go_right  # level-local child index

    # Leaf statistics.
    n_leaves = 1 << depth
    leaf_g = jax.ops.segment_sum(g, node, num_segments=n_leaves)
    leaf_h = jax.ops.segment_sum(h, node, num_segments=n_leaves)
    if cfg.axis_name is not None:  # merge leaf stats across data shards
        leaf_g = jax.lax.psum(leaf_g, cfg.axis_name)
        leaf_h = jax.lax.psum(leaf_h, cfg.axis_name)
    leaf_value = -leaf_g / (leaf_h + cfg.lam)
    leaf_value = jnp.where(leaf_h > 0, leaf_value, 0.0)

    return Tree(
        feature=jnp.concatenate(features),
        threshold=jnp.concatenate(thresholds),
        leaf_value=leaf_value.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree_multi(
    cfg: LearnerConfig,
    bins: jax.Array,  # (N, F) int32
    g: jax.Array,  # (N, K) f32 — per-output weighted gradient field
    h: jax.Array,  # (N, K) f32 — per-output weighted hessian / weight
    rng: jax.Array,  # ONE feature-subsampling key shared across outputs
) -> Tree:
    """K trees against the (N, K) gradient field, one vmapped build.

    Returns a stacked ``Tree`` with (K, ...) arrays — the K-output
    boosting round's "one push" payload. Sharing ``rng`` across outputs
    draws one feature mask per round (the multiclass convention: the K
    trees of a round see the same feature subsample). Each lane is
    numerically identical to a standalone ``build_tree`` on its column.
    """
    return jax.vmap(
        lambda gk, hk: build_tree(cfg, bins, gk, hk, rng), in_axes=(1, 1)
    )(g, h)
