"""Level-wise histogram tree learner — fully jittable, fixed shapes.

One tree build = ``depth`` levels; each level builds per-node grad/hess
histograms (Pallas kernel or jnp oracle), scans them for the best split, and
re-routes samples. Matches the paper's worker-side "building the tree
sub-step": the tree fits the (sampled, importance-weighted) gradient target.

Histogram modes (``LearnerConfig.hist_mode``):
  * ``'subtract'`` (default) — the parent-histogram-caching builder: below
    the root, only the SMALLER child of every split is histogrammed
    (per-node hessian mass — the drawn-sample count — picks it) and the
    sibling is derived as ``parent - built``. A level then costs 2^(l-1)
    node-histograms instead of 2^l: a depth-d tree builds 2^(d-1) instead of
    2^d - 1 — ~50% of the rebuild mode's histogram kernel work at depth 7.
    Exact in exact arithmetic (children partition their parent's samples);
    in f32 the derived sibling differs from a rebuilt one by subtraction
    rounding, so the two modes agree to tolerance, not bitwise.
  * ``'rebuild'`` — the historical full-level build: every node of every
    level is histogrammed from its samples. Bitwise-identical to the
    pre-subtraction learner; the exact-parity reference mode.
Either mode is deterministic WITHIN itself: the threaded runtime's
record-and-replay contract (DESIGN.md §11) holds bit-for-bit per mode.

Conventions:
  * Caller supplies per-sample (g_i, h_i). For the paper's plain gradient
    step, g_i = m'_i * l'_i and h_i = m'_i (leaf value = - mean residual).
    For Newton (xgboost-style) steps, g/h are weighted gradient/hessian.
  * Leaf value = -G_leaf / (H_leaf + lam) in both cases.
  * Samples with h_i == 0 (not drawn by the Bernoulli sampler) are inert:
    they contribute to no histogram and no leaf.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.trees.tree import Tree


class LearnerConfig(NamedTuple):
    depth: int = 7  # 2^depth leaves (paper: 100 -> 128, 400 -> 512)
    n_bins: int = 64
    lam: float = 1.0  # L2 on leaf values
    min_child_hess: float = 1e-3
    feature_fraction: float = 0.8  # paper samples 80% of features per tree
    backend: str = "ref"  # 'ref' | 'pallas' | 'auto'
    # Mesh axis samples are sharded over when building under shard_map
    # (repro.ps.sharded): histograms and leaf stats psum across it; the rng
    # must be replicated so every shard draws the same feature mask.
    axis_name: str | None = None
    # 'subtract' — parent-minus-child histogram derivation (the default
    # fast path); 'rebuild' — full per-level histogram builds (the exact
    # pre-subtraction semantics). See the module docstring.
    hist_mode: str = "subtract"


def _level_histogram(
    cfg: LearnerConfig,
    bins: jax.Array,
    node: jax.Array,  # (N,) level-local node ids in [0, 2^level)
    g: jax.Array,
    h: jax.Array,
    level: int,
    parent_hist: jax.Array | None,  # (2, 2^(level-1), F, B) from last level
) -> jax.Array:
    """The (2, 2^level, F, B) histogram of one level, by the config's mode."""
    n_nodes = 1 << level
    if cfg.hist_mode not in ("subtract", "rebuild"):
        raise ValueError(
            f"unknown hist_mode {cfg.hist_mode!r} (want 'subtract'|'rebuild')"
        )
    if cfg.hist_mode == "rebuild" or level == 0:
        return ops.build_histogram(
            bins, node, g, h, n_nodes, n_bins=cfg.n_bins,
            backend=cfg.backend, axis_name=cfg.axis_name,
        )

    # Subtraction mode: histogram only the smaller child of every parent,
    # derive the sibling from the cached parent histogram. Children
    # partition the parent's samples, so parent = left + right exactly;
    # the derived sibling differs from a rebuilt one only by f32 rounding.
    # "Smaller" is by per-node hessian mass — the drawn-sample count in the
    # paper's gradient step (h_i = m'_i) — so inert samples (h == 0) stay
    # inert in the builder's control flow too, not just in its sums.
    counts = jax.ops.segment_sum(h, node, num_segments=n_nodes)
    if cfg.axis_name is not None:
        # Merged counts: every shard must pick the SAME child to build.
        counts = jax.lax.psum(counts, cfg.axis_name)
    parents = jnp.arange(n_nodes // 2, dtype=jnp.int32)
    # Per-node select of the smaller child (2p or 2p+1), statically shaped.
    go_odd = (counts[0::2] > counts[1::2]).astype(jnp.int32)
    active = 2 * parents + go_odd  # (2^(level-1),)
    built = ops.build_histogram_subset(
        bins, node, g, h, active, n_nodes, cfg.n_bins,
        backend=cfg.backend, axis_name=cfg.axis_name,
    )  # (2, 2^(level-1), F, B), already psum'd across shards
    # Expand to the full level by a gather: node n (parent p = n >> 1) is
    # either the built child or the derived sibling. The subtraction runs
    # AFTER the collective — it commutes with the psum (both linear), and
    # subtracting merged values keeps every shard's derived rows identical.
    node_ids = jnp.arange(n_nodes, dtype=jnp.int32)
    par_of = node_ids >> 1
    is_built = node_ids == active[par_of]
    built_rows = built[:, par_of]  # (2, n_nodes, F, B)
    sibling_rows = parent_hist[:, par_of] - built_rows
    return jnp.where(is_built[None, :, None, None], built_rows, sibling_rows)


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree(
    cfg: LearnerConfig,
    bins: jax.Array,  # (N, F) int32
    g: jax.Array,  # (N,) f32 — weighted gradient target
    h: jax.Array,  # (N,) f32 — weighted hessian / sample weight
    rng: jax.Array,  # feature-subsampling key
) -> Tree:
    n, n_feat = bins.shape
    depth, n_bins = cfg.depth, cfg.n_bins

    feat_mask = (
        jax.random.uniform(rng, (n_feat,)) < cfg.feature_fraction
        if cfg.feature_fraction < 1.0
        else jnp.ones((n_feat,), bool)
    )

    node = jnp.zeros((n,), jnp.int32)  # heap ids, level-local after offset
    features = []
    thresholds = []
    hist = None  # the previous level's histograms (the subtraction cache)

    for level in range(depth):
        n_nodes = 1 << level
        hist = _level_histogram(cfg, bins, node, g, h, level, hist)
        gain = ops.split_gain(hist, cfg.lam, cfg.min_child_hess, backend=cfg.backend)
        gain = jnp.where(feat_mask[None, :, None], gain, -jnp.inf)  # (L, F, B)

        flat = gain.reshape(n_nodes, -1)
        idx = jnp.argmax(flat, axis=-1)
        best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
        feat = (idx // n_bins).astype(jnp.int32)
        thr = (idx % n_bins).astype(jnp.int32)

        # Unsplittable node -> pass-through: all samples go left.
        ok = jnp.isfinite(best) & (best > 0.0)
        feat = jnp.where(ok, feat, 0)
        thr = jnp.where(ok, thr, n_bins - 1)

        features.append(feat)
        thresholds.append(thr)

        val = jnp.take_along_axis(bins, jnp.take(feat, node)[:, None], axis=1)[:, 0]
        go_right = (val > jnp.take(thr, node)).astype(jnp.int32)
        node = 2 * node + go_right  # level-local child index

    # Leaf statistics.
    n_leaves = 1 << depth
    leaf_g = jax.ops.segment_sum(g, node, num_segments=n_leaves)
    leaf_h = jax.ops.segment_sum(h, node, num_segments=n_leaves)
    if cfg.axis_name is not None:  # merge leaf stats across data shards
        leaf_g = jax.lax.psum(leaf_g, cfg.axis_name)
        leaf_h = jax.lax.psum(leaf_h, cfg.axis_name)
    leaf_value = -leaf_g / (leaf_h + cfg.lam)
    leaf_value = jnp.where(leaf_h > 0, leaf_value, 0.0)

    return Tree(
        feature=jnp.concatenate(features),
        threshold=jnp.concatenate(thresholds),
        leaf_value=leaf_value.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree_multi(
    cfg: LearnerConfig,
    bins: jax.Array,  # (N, F) int32
    g: jax.Array,  # (N, K) f32 — per-output weighted gradient field
    h: jax.Array,  # (N, K) f32 — per-output weighted hessian / weight
    rng: jax.Array,  # ONE feature-subsampling key shared across outputs
) -> Tree:
    """K trees against the (N, K) gradient field, one vmapped build.

    Returns a stacked ``Tree`` with (K, ...) arrays — the K-output
    boosting round's "one push" payload. Sharing ``rng`` across outputs
    draws one feature mask per round (the multiclass convention: the K
    trees of a round see the same feature subsample). Each lane is
    numerically identical to a standalone ``build_tree`` on its column.
    """
    return jax.vmap(
        lambda gk, hk: build_tree(cfg, bins, gk, hk, rng), in_axes=(1, 1)
    )(g, h)
