"""Level-wise histogram tree learner — fully jittable, fixed shapes.

One tree build = ``depth`` levels; each level builds per-node grad/hess
histograms (Pallas kernel or jnp oracle), scans them for the best split, and
re-routes samples. Matches the paper's worker-side "building the tree
sub-step": the tree fits the (sampled, importance-weighted) gradient target.

Histogram modes (``LearnerConfig.hist_mode``):
  * ``'subtract'`` (default) — the parent-histogram-caching builder: below
    the root, only the SMALLER child of every split is histogrammed
    (per-node hessian mass — the drawn-sample count — picks it) and the
    sibling is derived as ``parent - built``. A level then costs 2^(l-1)
    node-histograms instead of 2^l: a depth-d tree builds 2^(d-1) instead of
    2^d - 1 — ~50% of the rebuild mode's histogram kernel work at depth 7.
    Exact in exact arithmetic (children partition their parent's samples);
    in f32 the derived sibling differs from a rebuilt one by subtraction
    rounding, so the two modes agree to tolerance, not bitwise.
  * ``'rebuild'`` — the historical full-level build: every node of every
    level is histogrammed from its samples. Bitwise-identical to the
    pre-subtraction learner; the exact-parity reference mode.
Either mode is deterministic WITHIN itself: the threaded runtime's
record-and-replay contract (DESIGN.md §11) holds bit-for-bit per mode.

Backends (``LearnerConfig.backend``), resolved through the shared
``kernels.ops.resolve_backend``:
  * ``'ref'`` — pure-jnp oracles (production CPU path);
  * ``'pallas'`` — the STAGED kernel pipeline: histogram kernel, split-gain
    kernel, jnp partition, one HBM round-trip between each;
  * ``'fused'`` — ONE Pallas program per level (``kernels.level_build``):
    histogram accumulation, sibling derivation, gain scan, argmax, and the
    row re-route without staging any surface through HBM. Falls back to the
    staged pallas pipeline per level when the level's resident set exceeds
    the VMEM budget, and entirely under ``shard_map`` (``axis_name`` set):
    the split decision must see the psum-MERGED histograms, so the
    collective seam forces the staged order (see ``ps/sharded.py``);
  * ``'auto'`` — pallas on TPU, ref elsewhere.
The fused program is bit-compatible with the staged pallas path at MATCHED
block shapes (same dot shapes in the same order). In the learner the fused
path takes its blocks from the committed autotuner table
(``kernels/autotune.py``), which may group the accumulation differently
than the staged defaults — cross-backend runs then agree like the hist
modes do: identically wherever gains are decisively separated, with
near-tied deep splits free to flip within f32 tolerance. DESIGN.md §13
documents both contracts.

Conventions:
  * Caller supplies per-sample (g_i, h_i). For the paper's plain gradient
    step, g_i = m'_i * l'_i and h_i = m'_i (leaf value = - mean residual).
    For Newton (xgboost-style) steps, g/h are weighted gradient/hessian.
  * Leaf value = -G_leaf / (H_leaf + lam) in both cases.
  * Samples with h_i == 0 (not drawn by the Bernoulli sampler) are inert:
    they contribute to no histogram and no leaf.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import collectives
from repro.kernels import ops
from repro.trees.binning import SparseBins, gather_feature_bins
from repro.trees.tree import Tree


class LearnerConfig(NamedTuple):
    depth: int = 7  # 2^depth leaves (paper: 100 -> 128, 400 -> 512)
    n_bins: int = 64
    lam: float = 1.0  # L2 on leaf values
    min_child_hess: float = 1e-3
    feature_fraction: float = 0.8  # paper samples 80% of features per tree
    backend: str = "ref"  # 'ref' | 'pallas' | 'fused' | 'auto'
    # Mesh axis samples are sharded over when building under shard_map
    # (repro.ps.sharded): histograms and leaf stats psum across it; the rng
    # must be replicated so every shard draws the same feature mask.
    axis_name: str | None = None
    # 'subtract' — parent-minus-child histogram derivation (the default
    # fast path); 'rebuild' — full per-level histogram builds (the exact
    # pre-subtraction semantics). See the module docstring.
    hist_mode: str = "subtract"
    # Mesh axis FEATURES are sharded over — the block-distributed 2D mesh
    # (DESIGN.md §16). Each shard histograms and scans only its own
    # (L, F/P_f, B) bin block; split decisions merge with the (L,)-sized
    # argmax all-reduce (pmax gain + pmin global index) instead of
    # psumming full histograms, and the dense partition reconstructs the
    # winning bin column with an owner-masked uint8 psum. None = every
    # shard holds every feature (the 1D path, unchanged).
    feature_axis: str | None = None
    # Static feature-shard count. Consulted only on the DENSE 2D path,
    # where the GLOBAL feature count (the feature-mask draw must be global
    # so 1D and 2D runs consume identical rng) is not recoverable from the
    # local bins shape. SparseBins carries the global width in zero_bin.
    feature_shards: int = 1


def _check_hist_mode(cfg: LearnerConfig) -> None:
    if cfg.hist_mode not in ("subtract", "rebuild"):
        raise ValueError(
            f"unknown hist_mode {cfg.hist_mode!r} (want 'subtract'|'rebuild')"
        )


def _smaller_children(
    cfg: LearnerConfig, node: jax.Array, h: jax.Array, n_nodes: int
) -> jax.Array:
    """The subtraction builder's per-parent smaller child, (n_nodes // 2,).

    "Smaller" is by per-node hessian mass — the drawn-sample count in the
    paper's gradient step (h_i = m'_i) — so inert samples (h == 0) stay
    inert in the builder's control flow too, not just in its sums. Under
    shard_map the counts psum first: every shard must pick the SAME child.
    """
    counts = jax.ops.segment_sum(h, node, num_segments=n_nodes)
    if cfg.axis_name is not None:
        counts = collectives.psum(counts, cfg.axis_name)
    parents = jnp.arange(n_nodes // 2, dtype=jnp.int32)
    go_odd = (counts[0::2] > counts[1::2]).astype(jnp.int32)
    return 2 * parents + go_odd


def _level_histogram(
    cfg: LearnerConfig,
    bins: jax.Array,
    node: jax.Array,  # (N,) level-local node ids in [0, 2^level)
    g: jax.Array,
    h: jax.Array,
    level: int,
    parent_hist: jax.Array | None,  # (2, 2^(level-1), F, B) from last level
    backend: str | None = None,
) -> jax.Array:
    """The (2, 2^level, F, B) histogram of one level, by the config's mode."""
    n_nodes = 1 << level
    _check_hist_mode(cfg)
    backend = cfg.backend if backend is None else backend
    if cfg.hist_mode == "rebuild" or level == 0:
        return ops.build_histogram(
            bins, node, g, h, n_nodes, n_bins=cfg.n_bins,
            backend=backend, axis_name=cfg.axis_name,
        )

    # Subtraction mode: histogram only the smaller child of every parent,
    # derive the sibling from the cached parent histogram. Children
    # partition the parent's samples, so parent = left + right exactly;
    # the derived sibling differs from a rebuilt one only by f32 rounding.
    active = _smaller_children(cfg, node, h, n_nodes)
    built = ops.build_histogram_subset(
        bins, node, g, h, active, n_nodes, cfg.n_bins,
        backend=backend, axis_name=cfg.axis_name,
    )  # (2, 2^(level-1), F, B), already psum'd across shards
    # Expand to the full level by a gather: node n (parent p = n >> 1) is
    # either the built child or the derived sibling. The subtraction runs
    # AFTER the collective — it commutes with the psum (both linear), and
    # subtracting merged values keeps every shard's derived rows identical.
    node_ids = jnp.arange(n_nodes, dtype=jnp.int32)
    par_of = node_ids >> 1
    is_built = node_ids == active[par_of]
    built_rows = built[:, par_of]  # (2, n_nodes, F, B)
    sibling_rows = parent_hist[:, par_of] - built_rows
    return jnp.where(is_built[None, :, None, None], built_rows, sibling_rows)


def _staged_level(
    cfg: LearnerConfig,
    backend: str,
    hist_bins,  # histogram view: dense (N, F_loc) or shard-local SparseBins
    route_bins,  # partition view: dense (N, F_loc) or the row-major store
    node: jax.Array,
    g: jax.Array,
    h: jax.Array,
    feat_mask: jax.Array,  # (F_loc,) — the shard's slice of the global mask
    level: int,
    parent_hist: jax.Array | None,
):
    """One level via the staged pipeline (histogram -> gain -> partition),
    each stage round-tripping HBM. Returns (hist, feat, thr, new_node).

    Under feature sharding (``cfg.feature_axis``) the histogram/gain/argmax
    stages see only the shard's own (L, F_loc, B) block; the split decision
    then merges across the feature axis with two (L,)-sized collectives:
    ``pmax`` of the local best gains, then ``pmin`` of the GLOBAL flat
    (feature * B + bin) index among the shards achieving that max. Because
    shard s owns the contiguous global columns [s*F_loc, (s+1)*F_loc), the
    global flat order equals the 1D path's flat order — so the pmin
    reproduces the first-maximum tie-break BITWISE, with (L,) floats + (L,)
    ints on the wire instead of the full (2, L, F, B) histogram psum.
    ``feat`` is returned in GLOBAL feature ids either way.
    """
    n_nodes, n_bins = 1 << level, cfg.n_bins
    hist = _level_histogram(cfg, hist_bins, node, g, h, level, parent_hist, backend)
    gain = ops.split_gain(hist, cfg.lam, cfg.min_child_hess, backend=backend)
    gain = jnp.where(feat_mask[None, :, None], gain, -jnp.inf)  # (L, F_loc, B)

    f_local = gain.shape[1]
    flat = gain.reshape(n_nodes, -1)
    idx = jnp.argmax(flat, axis=-1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]

    if cfg.feature_axis is not None:
        shard = jax.lax.axis_index(cfg.feature_axis)
        gidx = idx.astype(jnp.int32) + shard * (f_local * n_bins)
        best_g = collectives.pmax(best, cfg.feature_axis)
        # Among shards holding the global max, the lowest global flat index
        # wins — all--inf rows tie at shard 0's index 0, exactly like the
        # 1D argmax, and the pass-left fix below overrides them anyway.
        cand = jnp.where(best == best_g, gidx, jnp.iinfo(jnp.int32).max)
        idx = collectives.pmin(cand, cfg.feature_axis)
        best = best_g

    feat = (idx // n_bins).astype(jnp.int32)
    thr = (idx % n_bins).astype(jnp.int32)

    # Unsplittable node -> pass-through: all samples go left.
    ok = jnp.isfinite(best) & (best > 0.0)
    feat = jnp.where(ok, feat, 0)
    thr = jnp.where(ok, thr, n_bins - 1)

    f_of = jnp.take(feat, node)  # (N,) global winning feature per sample
    if cfg.feature_axis is not None and not isinstance(route_bins, SparseBins):
        # Dense 2D partition: only the winning feature's owner shard holds
        # its column, so each shard contributes its owned values and a
        # one-byte-per-sample psum reconstructs the column everywhere
        # (bin ids < n_bins <= 256 — uint8 is exact).
        lo = jax.lax.axis_index(cfg.feature_axis) * f_local
        owned = (f_of >= lo) & (f_of < lo + f_local)
        col = jnp.clip(f_of - lo, 0, f_local - 1)
        v = jnp.take_along_axis(route_bins, col[:, None], axis=1)[:, 0]
        v = jnp.where(owned, v, 0).astype(jnp.uint8)
        val = collectives.psum(v, cfg.feature_axis).astype(jnp.int32)
    else:
        # 1D dense gather, or the sparse row-major store (replicated across
        # feature shards: routing needs no collective at all).
        val = gather_feature_bins(route_bins, f_of)
    go_right = (val > jnp.take(thr, node)).astype(jnp.int32)
    return hist, feat, thr, 2 * node + go_right


def _fused_level(
    cfg: LearnerConfig,
    bins: jax.Array,
    node: jax.Array,
    g: jax.Array,
    h: jax.Array,
    feat_mask: jax.Array,
    level: int,
    parent_hist: jax.Array | None,
):
    """One level as ONE Pallas program (``kernels.level_build``): the level
    histogram never leaves VMEM between build, scan, and partition; only
    the next level's subtraction cache and the (L,)-sized split vectors
    reach HBM. Same returns as ``_staged_level``."""
    n_nodes = 1 << level
    _check_hist_mode(cfg)
    derive = cfg.hist_mode == "subtract" and level > 0
    if derive:
        active = _smaller_children(cfg, node, h, n_nodes)
    else:
        active = jnp.arange(n_nodes, dtype=jnp.int32)
    hist, feat, thr, _, new_node = ops.level_build(
        bins, node, g, h, active, parent_hist if derive else None,
        feat_mask.astype(jnp.float32), cfg.lam, cfg.min_child_hess,
        n_nodes, cfg.n_bins, backend="fused", derive_sibling=derive,
    )
    return hist, feat, thr, new_node


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree(
    cfg: LearnerConfig,
    bins,  # (N, F) int32 dense matrix, or a ``SparseBins``
    g: jax.Array,  # (N,) f32 — weighted gradient target
    h: jax.Array,  # (N,) f32 — weighted hessian / sample weight
    rng: jax.Array,  # feature-subsampling key
) -> Tree:
    from repro.kernels.level_build import fused_level_fits

    depth, n_bins = cfg.depth, cfg.n_bins
    sparse = isinstance(bins, SparseBins)
    feature_sharded = cfg.feature_axis is not None
    if sparse:
        # Under feature sharding only the feature-major store is sharded;
        # the row-major store + zero_bin stay replicated (they route
        # samples through GLOBAL feature ids). The histogram view gets the
        # zero-bin slice matching its local feature block.
        n = bins.n_samples
        f_local = bins.feat_rows.shape[0]
        f_global = bins.n_features
        hist_bins = bins
        if feature_sharded and f_local != f_global:
            lo = jax.lax.axis_index(cfg.feature_axis) * f_local
            zb = jax.lax.dynamic_slice(bins.zero_bin, (lo,), (f_local,))
            hist_bins = bins._replace(zero_bin=zb)
    else:
        n, f_local = bins.shape
        f_global = f_local * (cfg.feature_shards if feature_sharded else 1)
        hist_bins = bins

    backend = ops.resolve_backend(cfg.backend, allow_fused=True)
    # The fused program computes split decisions from the histograms it
    # holds in VMEM — under shard_map those are LOCAL, and the decision
    # must see the psum-merged level (data axis) / argmax-merged decision
    # (feature axis). The collective seam therefore pins the staged order
    # (histogram -> psum -> scan -> merge); see ps/sharded.py. The sparse
    # layout is staged-only too (the fused kernel is the dense program).
    use_fused = (
        backend == "fused"
        and cfg.axis_name is None
        and not feature_sharded
        and not sparse
    )
    if backend == "fused":
        # The staged fallback: matched-block pallas when the fused program
        # is merely over VMEM budget for a level; the platform default
        # under shard_map, where interpret-mode pallas_call has no
        # replication rule (the collective seam, see ps/sharded.py).
        staged = "pallas" if use_fused else ops.resolve_backend("auto")
    else:
        staged = backend

    # The feature mask is drawn over the GLOBAL feature space from the
    # replicated rng — a 2D run consumes the key exactly like its 1D twin
    # — and each shard slices out its own contiguous block.
    feat_mask = (
        jax.random.uniform(rng, (f_global,)) < cfg.feature_fraction
        if cfg.feature_fraction < 1.0
        else jnp.ones((f_global,), bool)
    )
    if feature_sharded and f_local != f_global:
        lo = jax.lax.axis_index(cfg.feature_axis) * f_local
        feat_mask = jax.lax.dynamic_slice(feat_mask, (lo,), (f_local,))

    node = jnp.zeros((n,), jnp.int32)  # heap ids, level-local after offset
    features = []
    thresholds = []
    hist = None  # the previous level's histograms (the subtraction cache)

    for level in range(depth):
        n_nodes = 1 << level
        n_sub = max(n_nodes // 2, 1) if (cfg.hist_mode == "subtract" and level) \
            else n_nodes
        if use_fused and fused_level_fits(n, n_nodes, n_sub, f_local, n_bins):
            hist, feat, thr, node = _fused_level(
                cfg, bins, node, g, h, feat_mask, level, hist
            )
        else:
            hist, feat, thr, node = _staged_level(
                cfg, staged, hist_bins, bins, node, g, h, feat_mask, level, hist
            )
        features.append(feat)
        thresholds.append(thr)

    # Leaf statistics.
    n_leaves = 1 << depth
    leaf_g = jax.ops.segment_sum(g, node, num_segments=n_leaves)
    leaf_h = jax.ops.segment_sum(h, node, num_segments=n_leaves)
    if cfg.axis_name is not None:  # merge leaf stats across data shards
        leaf_g = collectives.psum(leaf_g, cfg.axis_name)
        leaf_h = collectives.psum(leaf_h, cfg.axis_name)
    leaf_value = -leaf_g / (leaf_h + cfg.lam)
    leaf_value = jnp.where(leaf_h > 0, leaf_value, 0.0)

    return Tree(
        feature=jnp.concatenate(features),
        threshold=jnp.concatenate(thresholds),
        leaf_value=leaf_value.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_tree_multi(
    cfg: LearnerConfig,
    bins: jax.Array,  # (N, F) int32
    g: jax.Array,  # (N, K) f32 — per-output weighted gradient field
    h: jax.Array,  # (N, K) f32 — per-output weighted hessian / weight
    rng: jax.Array,  # ONE feature-subsampling key shared across outputs
) -> Tree:
    """K trees against the (N, K) gradient field, one vmapped build.

    Returns a stacked ``Tree`` with (K, ...) arrays — the K-output
    boosting round's "one push" payload. Sharing ``rng`` across outputs
    draws one feature mask per round (the multiclass convention: the K
    trees of a round see the same feature subsample). Each lane is
    numerically identical to a standalone ``build_tree`` on its column.
    """
    return jax.vmap(
        lambda gk, hk: build_tree(cfg, bins, gk, hk, rng), in_axes=(1, 1)
    )(g, h)
