"""Dense heap-indexed decision trees.

A depth-``d`` tree is stored as flat arrays: internal nodes 0..2^d-2 in
level order (children of i are 2i+1 / 2i+2), leaves are the 2^d slots of the
final level. Unsplittable nodes degrade to pass-through splits (everything
routes left); both children inherit the parent statistics so predictions are
identical to an early-stopped tree. Fixed shapes keep every consumer jittable
and make forests stackable into (T, ...) arrays.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.trees.binning import gather_feature_bins


class Tree(NamedTuple):
    """One regression tree over binned features.

    Attributes:
      feature: (2^d - 1,) int32 — split feature per internal node.
      threshold: (2^d - 1,) int32 — split bin; route left iff bin <= threshold.
      leaf_value: (2^d,) float32 — output per leaf.

    Depth is *derived* from shapes (so Tree stays a pure array pytree that
    can cross jit boundaries): depth = log2(len(leaf_value)).
    """

    feature: jax.Array
    threshold: jax.Array
    leaf_value: jax.Array

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1


def tree_num_nodes(depth: int) -> tuple[int, int]:
    """(n_internal, n_leaves) for a full tree of the given depth."""
    return (1 << depth) - 1, 1 << depth


def empty_tree(depth: int) -> Tree:
    n_internal, n_leaves = tree_num_nodes(depth)
    return Tree(
        feature=jnp.zeros((n_internal,), jnp.int32),
        threshold=jnp.full((n_internal,), 2**30, jnp.int32),  # all-left
        leaf_value=jnp.zeros((n_leaves,), jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("depth",))
def _leaf_index(
    bins, feature: jax.Array, threshold: jax.Array, depth: int
) -> jax.Array:
    """Route samples (N, F) to leaf indices (N,) by a depth-step heap walk.

    ``bins`` may be the dense matrix or a ``binning.SparseBins`` — the
    per-step feature lookup goes through ``gather_feature_bins``, so
    training-time partition and serving-time routing read the same values
    on either layout.
    """
    n = bins.shape[0]
    node = jnp.zeros((n,), jnp.int32)

    def step(_, node):
        feat = jnp.take(feature, node)
        thr = jnp.take(threshold, node)
        val = gather_feature_bins(bins, feat)
        go_right = (val > thr).astype(jnp.int32)
        return 2 * node + 1 + go_right

    node = jax.lax.fori_loop(0, depth, step, node)
    n_internal = (1 << depth) - 1
    return node - n_internal


def apply_tree(tree: Tree, bins) -> jax.Array:
    """Predict (N,) float32 for binned inputs (N, F) — dense or sparse."""
    leaf = _leaf_index(bins, tree.feature, tree.threshold, tree.depth)
    return jnp.take(tree.leaf_value, leaf)


def leaf_indices(tree: Tree, bins) -> jax.Array:
    """Expose leaf routing — used by tests and by the projection analysis."""
    return _leaf_index(bins, tree.feature, tree.threshold, tree.depth)


def apply_tree_stack(trees: Tree, bins) -> jax.Array:
    """Predict (N, K) for a stacked tree group (leading K axis per leaf).

    A K-output boosting round produces one tree per output as a single
    ``Tree`` pytree with (K, ...) arrays; this is its batched evaluation.
    """
    return jax.vmap(lambda t: apply_tree(t, bins), out_axes=1)(trees)
