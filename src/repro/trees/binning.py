"""Feature quantization into histogram bins.

GBDT histogram algorithms (LightGBM, DimBoost, this paper's workers) never
split on raw feature values: features are pre-quantized into at most
``n_bins`` integer bins, and split search runs over bin boundaries. Binning
happens once per dataset, outside the training loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SparseBins(NamedTuple):
    """A sparse quantized feature matrix — the explicit-zero-bin layout.

    High-dimensional binned datasets (real-sim, E2006) put almost every
    sample of almost every feature into one dominant bin (the feature's
    quantile-degenerate "zero"). Storing only the entries that DIFFER from
    that bin makes histogram cost scale with nnz instead of N*F (the
    block-distributed GBT representation). Two padded fixed-shape layouts
    of the same entry set are kept so every consumer stays jittable:

      * row-major ELL — ``indices``/``codes`` (N, E): per-sample stored
        columns (pad -1) and their bin codes; drives per-sample feature
        lookups (tree routing, serving).
      * feature-major ELL — ``feat_rows``/``feat_codes`` (F, C): per-
        feature stored sample ids (pad -1) and codes; drives the histogram
        kernel, whose contraction length is then C ≈ N * density per
        feature instead of N.

    ``zero_bin`` (F,) int32 is the bin an ABSENT entry decodes to (the
    per-feature majority bin). Stored codes never equal their feature's
    zero bin, so dense↔sparse round-trips are exact (integer scatter).
    Under feature sharding the feature-major fields are sharded over the
    'feature' mesh axis while ``indices``/``codes``/``zero_bin`` stay
    replicated (the global row view routes samples; see DESIGN.md §16).
    """

    indices: jax.Array  # (N, E) int32, -1 = pad
    codes: jax.Array  # (N, E) int32
    feat_rows: jax.Array  # (F, C) int32, -1 = pad
    feat_codes: jax.Array  # (F, C) int32
    zero_bin: jax.Array  # (F,) int32

    @property
    def shape(self) -> tuple[int, int]:
        """(N, F) of the equivalent dense matrix — F is GLOBAL (zero_bin's
        width) even when the feature-major store is a feature shard."""
        return (self.indices.shape[0], self.zero_bin.shape[0])

    @property
    def n_samples(self) -> int:
        return self.indices.shape[0]

    @property
    def n_features(self) -> int:
        return self.zero_bin.shape[0]

    @property
    def max_nnz_row(self) -> int:
        return self.indices.shape[1]

    @property
    def max_nnz_feature(self) -> int:
        return self.feat_rows.shape[1]


class BinnedData(NamedTuple):
    """A quantized dataset.

    Attributes:
      bins: (N, F) int32 — bin index of every sample/feature, in
        [0, n_bins) — or a ``SparseBins`` holding the same matrix in the
        explicit-zero-bin sparse layout (``bin_dataset`` picks it when the
        density falls under the threshold). Either way ``bins.shape`` is
        (N, F), so shape-derived consumers are representation-blind.
      bin_edges: (F, n_bins - 1) float32 — upper edge of each bin (last bin
        is open-ended); used only to map raw inference inputs onto bins.
      labels: (N,) float32 — {0, 1} for binary classification, class ids
        for multiclass, reals for regression, relevance grades for ranking.
      multiplicity: (N,) float32 — the paper's m_i: how many times each
        *distinct* sample occurs in the logical dataset. Controls diversity.
      n_bins: static int.
      qid: (N,) int32 query ids for ranking objectives, else None.
    """

    bins: jax.Array | SparseBins
    bin_edges: jax.Array
    labels: jax.Array
    multiplicity: jax.Array
    n_bins: int
    qid: jax.Array | None = None

    @property
    def n_samples(self) -> int:
        return self.bins.shape[0]

    @property
    def n_features(self) -> int:
        return self.bins.shape[1]


def make_bins(x: np.ndarray, n_bins: int = 256) -> np.ndarray:
    """Compute per-feature quantile bin edges. Host-side, once per dataset.

    Returns (F, n_bins - 1) edges. Degenerate (constant / ultra-sparse)
    features get repeated edges, which is harmless: all samples land in bin 0
    and the split gain there is 0.
    """
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T.astype(np.float32)  # (F, n_bins-1)
    return np.ascontiguousarray(edges)


@functools.partial(jax.jit, static_argnames=("nan_bin",))
def apply_bins(x: jax.Array, bin_edges: jax.Array, nan_bin: int = 0) -> jax.Array:
    """Map raw features (N, F) onto bin ids (N, F) int32 via searchsorted.

    Finite-values policy (serving sees raw, possibly malformed floats):
      * ``-inf`` clamps to bin 0, ``+inf`` clamps to the last bin — the
        values really are below/above every edge;
      * ``NaN`` routes deterministically to ``nan_bin`` (default 0).
        ``searchsorted`` on NaN is comparison-order-defined and lands in
        the LAST bin, which silently reads as "very large feature" — a
        malformed request must not get a confident extreme-bin prediction.
    """

    def one_feature(col: jax.Array, edges: jax.Array) -> jax.Array:
        # searchsorted already clamps ±inf (below/above every finite edge
        # -> bin 0 / last bin); only NaN needs explicit routing.
        ids = jnp.searchsorted(edges, col, side="left").astype(jnp.int32)
        return jnp.where(jnp.isnan(col), jnp.int32(nan_bin), ids)

    return jax.vmap(one_feature, in_axes=(1, 0), out_axes=1)(x, bin_edges)


# Densities below this default make the sparse layout the win: histogram
# contraction length drops to ~N * density per feature and the row-ELL
# stays narrow. Above it, padding (E = max row nnz) erodes the saving.
SPARSE_DENSITY_THRESHOLD = 0.25


def _zero_bins(b: np.ndarray) -> np.ndarray:
    """Per-feature majority bin — the sparse layout's implicit bin."""
    return np.stack(
        [np.bincount(b[:, f]).argmax() for f in range(b.shape[1])]
    ).astype(np.int32)


def sparse_density(bins: np.ndarray | jax.Array) -> float:
    """nnz / (N * F) under the per-feature majority-bin complement."""
    b = np.asarray(bins)
    zero = _zero_bins(b)
    return float((b != zero[None, :]).mean())


def _ell_pack(mask: np.ndarray, vals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Pack ``vals[mask]`` row-major into (rows, max_row_nnz) ELL arrays:
    (indices int32 pad -1, values int32 pad 0)."""
    rows, cols = mask.shape
    nnz = mask.sum(1)
    width = max(int(nnz.max(initial=0)), 1)
    idx = np.full((rows, width), -1, np.int32)
    out = np.zeros((rows, width), np.int32)
    r, c = np.nonzero(mask)
    pos = np.arange(len(r)) - np.repeat(np.cumsum(nnz) - nnz, nnz)
    idx[r, pos] = c
    out[r, pos] = vals[r, c]
    return idx, out


def to_sparse(bins: np.ndarray | jax.Array) -> SparseBins:
    """Dense (N, F) bin matrix -> the explicit-zero-bin sparse layout.

    Host-side, once per dataset (like ``make_bins``). Stored entries are
    exactly the cells that differ from their feature's majority bin, in
    both row-major and feature-major ELL order; ``to_dense`` inverts this
    bitwise (integers — no rounding anywhere).
    """
    b = np.asarray(bins).astype(np.int32)
    zero = _zero_bins(b)
    mask = b != zero[None, :]
    indices, codes = _ell_pack(mask, b)
    feat_rows, feat_codes = _ell_pack(mask.T, b.T)
    return SparseBins(
        indices=jnp.asarray(indices),
        codes=jnp.asarray(codes),
        feat_rows=jnp.asarray(feat_rows),
        feat_codes=jnp.asarray(feat_codes),
        zero_bin=jnp.asarray(zero),
    )


@jax.jit
def to_dense(sp: SparseBins) -> jax.Array:
    """SparseBins -> the exact dense (N, F) int32 matrix (round-trip is
    bitwise: one stored entry per cell, integer scatter)."""
    n, f = sp.shape
    valid = sp.indices >= 0
    col = jnp.where(valid, sp.indices, 0)
    row = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], col.shape)
    delta = jnp.where(valid, sp.codes - sp.zero_bin[col], 0)
    base = jnp.broadcast_to(sp.zero_bin[None, :], (n, f)).astype(jnp.int32)
    return base.at[row.reshape(-1), col.reshape(-1)].add(delta.reshape(-1))


@jax.jit
def gather_feature_bins(bins: jax.Array | SparseBins, feat: jax.Array) -> jax.Array:
    """Per-sample bin of a chosen feature: (N,) int32 from feat (N,) int32.

    The representation-blind form of ``bins[i, feat[i]]`` — dense gathers
    via ``take_along_axis``; sparse scans the row-ELL store (E compares
    per sample) and falls back to the feature's zero bin when the entry is
    absent. Shared by the tree partition step and the heap routing in
    ``trees.tree`` so training and serving route identically on either
    layout.
    """
    if not isinstance(bins, SparseBins):
        return jnp.take_along_axis(bins, feat[:, None], axis=1)[:, 0]
    hit = bins.indices == feat[:, None]  # pads are -1: never match feat >= 0
    stored = jnp.max(jnp.where(hit, bins.codes, -1), axis=1)
    return jnp.where(stored >= 0, stored, jnp.take(bins.zero_bin, feat))


def bin_dataset(
    x: np.ndarray,
    y: np.ndarray,
    n_bins: int = 256,
    multiplicity: np.ndarray | None = None,
    qid: np.ndarray | None = None,
    sparse: bool | str = False,
    density_threshold: float = SPARSE_DENSITY_THRESHOLD,
) -> BinnedData:
    """One-shot host-side dataset quantization.

    ``sparse``: ``True`` forces the ``SparseBins`` layout, ``'auto'`` goes
    sparse when the majority-bin complement density falls below
    ``density_threshold`` — the real-sim / E2006 regime where
    F ≫ N * density. The default stays ``False`` (dense matrix): sparse is
    an opt-in representation, and every dense consumer keeps its exact
    bytes.
    """
    edges = make_bins(x, n_bins)
    bins = apply_bins(jnp.asarray(x, jnp.float32), jnp.asarray(edges))
    if sparse == "auto":
        sparse = sparse_density(bins) < density_threshold
    if sparse:
        bins = to_sparse(bins)
    if multiplicity is None:
        multiplicity = np.ones(x.shape[0], np.float32)
    return BinnedData(
        bins=bins,
        bin_edges=jnp.asarray(edges),
        labels=jnp.asarray(y, jnp.float32),
        multiplicity=jnp.asarray(multiplicity, jnp.float32),
        n_bins=n_bins,
        qid=None if qid is None else jnp.asarray(qid, jnp.int32),
    )
