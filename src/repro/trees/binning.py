"""Feature quantization into histogram bins.

GBDT histogram algorithms (LightGBM, DimBoost, this paper's workers) never
split on raw feature values: features are pre-quantized into at most
``n_bins`` integer bins, and split search runs over bin boundaries. Binning
happens once per dataset, outside the training loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class BinnedData(NamedTuple):
    """A quantized dataset.

    Attributes:
      bins: (N, F) int32 — bin index of every sample/feature, in [0, n_bins).
      bin_edges: (F, n_bins - 1) float32 — upper edge of each bin (last bin
        is open-ended); used only to map raw inference inputs onto bins.
      labels: (N,) float32 — {0, 1} for binary classification, class ids
        for multiclass, reals for regression, relevance grades for ranking.
      multiplicity: (N,) float32 — the paper's m_i: how many times each
        *distinct* sample occurs in the logical dataset. Controls diversity.
      n_bins: static int.
      qid: (N,) int32 query ids for ranking objectives, else None.
    """

    bins: jax.Array
    bin_edges: jax.Array
    labels: jax.Array
    multiplicity: jax.Array
    n_bins: int
    qid: jax.Array | None = None

    @property
    def n_samples(self) -> int:
        return self.bins.shape[0]

    @property
    def n_features(self) -> int:
        return self.bins.shape[1]


def make_bins(x: np.ndarray, n_bins: int = 256) -> np.ndarray:
    """Compute per-feature quantile bin edges. Host-side, once per dataset.

    Returns (F, n_bins - 1) edges. Degenerate (constant / ultra-sparse)
    features get repeated edges, which is harmless: all samples land in bin 0
    and the split gain there is 0.
    """
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T.astype(np.float32)  # (F, n_bins-1)
    return np.ascontiguousarray(edges)


@functools.partial(jax.jit, static_argnames=("nan_bin",))
def apply_bins(x: jax.Array, bin_edges: jax.Array, nan_bin: int = 0) -> jax.Array:
    """Map raw features (N, F) onto bin ids (N, F) int32 via searchsorted.

    Finite-values policy (serving sees raw, possibly malformed floats):
      * ``-inf`` clamps to bin 0, ``+inf`` clamps to the last bin — the
        values really are below/above every edge;
      * ``NaN`` routes deterministically to ``nan_bin`` (default 0).
        ``searchsorted`` on NaN is comparison-order-defined and lands in
        the LAST bin, which silently reads as "very large feature" — a
        malformed request must not get a confident extreme-bin prediction.
    """

    def one_feature(col: jax.Array, edges: jax.Array) -> jax.Array:
        # searchsorted already clamps ±inf (below/above every finite edge
        # -> bin 0 / last bin); only NaN needs explicit routing.
        ids = jnp.searchsorted(edges, col, side="left").astype(jnp.int32)
        return jnp.where(jnp.isnan(col), jnp.int32(nan_bin), ids)

    return jax.vmap(one_feature, in_axes=(1, 0), out_axes=1)(x, bin_edges)


def bin_dataset(
    x: np.ndarray,
    y: np.ndarray,
    n_bins: int = 256,
    multiplicity: np.ndarray | None = None,
    qid: np.ndarray | None = None,
) -> BinnedData:
    """One-shot host-side dataset quantization."""
    edges = make_bins(x, n_bins)
    bins = apply_bins(jnp.asarray(x, jnp.float32), jnp.asarray(edges))
    if multiplicity is None:
        multiplicity = np.ones(x.shape[0], np.float32)
    return BinnedData(
        bins=bins,
        bin_edges=jnp.asarray(edges),
        labels=jnp.asarray(y, jnp.float32),
        multiplicity=jnp.asarray(multiplicity, jnp.float32),
        n_bins=n_bins,
        qid=None if qid is None else jnp.asarray(qid, jnp.int32),
    )
