"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels must match them to float32
tolerance across the shape/dtype sweeps in tests/test_kernels.py. They are
also the fallback backend on platforms without Pallas lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def histogram_ref(
    bins: jax.Array,  # (N, F) int32 bin ids
    node_ids: jax.Array,  # (N,) int32 current node per sample, -1 = inactive
    grad: jax.Array,  # (N,) f32 weighted gradient  (m'_i * l'_i)
    hess: jax.Array,  # (N,) f32 weighted hessian / count weight
    n_nodes: int,
    n_bins: int,
) -> jax.Array:
    """Gradient/hessian histograms: out[0|1, node, f, b] = sum over samples.

    Scatter-add formulation via segment_sum — the LightGBM semantics.
    Inactive samples (node_id == -1 or sampled out with weight 0) contribute
    nothing.
    """
    n, f = bins.shape
    active = node_ids >= 0
    node = jnp.where(active, node_ids, 0)
    # segment id per (sample, feature): node * F * B + f * B + bin
    seg = (node[:, None] * f + jnp.arange(f)[None, :]) * n_bins + bins
    gmat = jnp.where(active, grad, 0.0)[:, None] * jnp.ones((1, f), grad.dtype)
    hmat = jnp.where(active, hess, 0.0)[:, None] * jnp.ones((1, f), hess.dtype)
    num = n_nodes * f * n_bins
    hg = jax.ops.segment_sum(gmat.reshape(-1), seg.reshape(-1), num_segments=num)
    hh = jax.ops.segment_sum(hmat.reshape(-1), seg.reshape(-1), num_segments=num)
    out = jnp.stack([hg, hh]).reshape(2, n_nodes, f, n_bins)
    return out.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def histogram_subset_ref(
    bins: jax.Array,  # (N, F) int32 bin ids
    node_ids: jax.Array,  # (N,) int32 current node per sample, -1 = inactive
    grad: jax.Array,  # (N,) f32 weighted gradient
    hess: jax.Array,  # (N,) f32 weighted hessian / count weight
    active_nodes: jax.Array,  # (n_sub,) int32 — node ids to histogram
    n_nodes: int,  # static bound on node ids (inverse-map size)
    n_bins: int,
) -> jax.Array:
    """Node-subset histograms: out[0|1, r, f, b] sums samples on node
    ``active_nodes[r]`` only — the oracle for the subtraction builder's
    smaller-child build (``trees.learner`` ``hist_mode='subtract'``).

    Samples whose node is not in ``active_nodes`` (or is -1) contribute
    nothing; each active row is bit-identical to the matching row of
    ``histogram_ref`` (same scatter order over the same samples).
    """
    n, f = bins.shape
    n_sub = active_nodes.shape[0]
    # Inverse map node id -> subset row (-1 = not built this level).
    inv = jnp.full((n_nodes,), -1, jnp.int32)
    inv = inv.at[active_nodes].set(jnp.arange(n_sub, dtype=jnp.int32))
    row = jnp.where(node_ids >= 0, inv[jnp.clip(node_ids, 0, n_nodes - 1)], -1)
    active = row >= 0
    rowc = jnp.where(active, row, 0)
    seg = (rowc[:, None] * f + jnp.arange(f)[None, :]) * n_bins + bins
    gmat = jnp.where(active, grad, 0.0)[:, None] * jnp.ones((1, f), grad.dtype)
    hmat = jnp.where(active, hess, 0.0)[:, None] * jnp.ones((1, f), hess.dtype)
    num = n_sub * f * n_bins
    hg = jax.ops.segment_sum(gmat.reshape(-1), seg.reshape(-1), num_segments=num)
    hh = jax.ops.segment_sum(hmat.reshape(-1), seg.reshape(-1), num_segments=num)
    out = jnp.stack([hg, hh]).reshape(2, n_sub, f, n_bins)
    return out.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "derive_sibling"))
def level_build_ref(
    bins: jax.Array,  # (N, F) int32 bin ids
    node_ids: jax.Array,  # (N,) int32 level-local node per sample, -1 inactive
    grad: jax.Array,  # (N,) f32
    hess: jax.Array,  # (N,) f32
    active_nodes: jax.Array,  # (L_sub,) int32 node ids to histogram
    parent_hist: jax.Array | None,  # (2, L_sub, F, B) previous-level cache
    feat_mask: jax.Array,  # (F,) bool/f32 — available features
    lam: jax.Array,
    min_child_hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    derive_sibling: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """The fused level-build oracle: (hist (2, L, F, B), best_feature (L,),
    best_bin (L,), best_gain (L,), new_node (N,)).

    The staged ``trees.learner`` level body as one function — histogram
    (subset + sibling derivation in subtract mode), gain scan, feature
    mask, argmax with the first-maximum tie-break, the unsplittable
    pass-left fix (feature 0, threshold ``n_bins - 1``), and the
    ``2 * node + go_right`` re-route. ``kernels.level_build`` must match
    this to f32 tolerance (bitwise at a single sample block).
    """
    built = histogram_subset_ref(
        bins, node_ids, grad, hess, active_nodes, n_nodes, n_bins
    )
    if derive_sibling:
        node_iota = jnp.arange(n_nodes, dtype=jnp.int32)
        par_of = node_iota >> 1
        is_built = node_iota == active_nodes[par_of]
        built_rows = built[:, par_of]
        hist = jnp.where(
            is_built[None, :, None, None],
            built_rows,
            parent_hist[:, par_of] - built_rows,
        )
    else:
        hist = built  # active_nodes must enumerate 0..n_nodes-1 in order

    g, h = hist[0], hist[1]
    gl = jnp.cumsum(g, axis=-1)
    hl = jnp.cumsum(h, axis=-1)
    gt, ht = gl[..., -1:], hl[..., -1:]
    gr, hr = gt - gl, ht - hl
    gain = gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam)
    valid = (hl >= min_child_hess) & (hr >= min_child_hess)
    valid = valid.at[..., -1].set(False)
    gain = jnp.where(valid, gain, -jnp.inf)
    gain = jnp.where(feat_mask[None, :, None] > 0, gain, -jnp.inf)

    flat = gain.reshape(n_nodes, -1)
    idx = jnp.argmax(flat, axis=-1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
    ok = jnp.isfinite(best) & (best > 0.0)
    feat = jnp.where(ok, idx // n_bins, 0).astype(jnp.int32)
    thr = jnp.where(ok, idx % n_bins, n_bins - 1).astype(jnp.int32)

    node_c = jnp.clip(node_ids, 0, n_nodes - 1)
    val = jnp.take_along_axis(bins, jnp.take(feat, node_c)[:, None], axis=1)[:, 0]
    go_right = (val > jnp.take(thr, node_c)).astype(jnp.int32)
    new_node = jnp.where(node_ids >= 0, 2 * node_ids + go_right, 2 * node_ids)
    return hist, feat, thr, best, new_node


def histogram_sparse_ref(
    sp,  # trees.binning.SparseBins
    node_ids: jax.Array,  # (N,) int32, -1 = inactive
    grad: jax.Array,  # (N,) f32
    hess: jax.Array,  # (N,) f32
    n_nodes: int,
    n_bins: int,
) -> jax.Array:
    """Sparse-layout histogram oracle: densify, then ``histogram_ref``.

    The explicit-zero-bin round trip is exact integers, so this is
    BITWISE-identical to the dense path on the same data — the parity
    contract ``tests/test_sparse.py`` pins. The Pallas sparse kernel
    (nnz-scaling stored-entry contraction + zero-bin complement) must
    match this to f32 tolerance, exactly like the dense kernel vs its
    oracle.
    """
    from repro.trees import binning  # lazy: trees.learner imports kernels

    return histogram_ref(binning.to_dense(sp), node_ids, grad, hess, n_nodes, n_bins)


def histogram_sparse_subset_ref(
    sp,  # trees.binning.SparseBins
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    active_nodes: jax.Array,  # (n_sub,) int32
    n_nodes: int,
    n_bins: int,
) -> jax.Array:
    """Node-subset sparse oracle — densify + ``histogram_subset_ref``."""
    from repro.trees import binning

    return histogram_subset_ref(
        binning.to_dense(sp), node_ids, grad, hess, active_nodes, n_nodes, n_bins
    )


@jax.jit
def split_scan_ref(
    hist: jax.Array,  # (2, L, F, B) f32 grad/hess histograms
    lam: jax.Array,  # scalar L2 regularizer
    min_child_hess: jax.Array,  # scalar: both children need >= this hessian mass
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Best split per node from histograms.

    Returns (best_gain (L,), best_feature (L,) int32, best_bin (L,) int32).
    gain = GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam); splitting at bin b
    sends bins <= b left. The last bin is not a valid split point.
    """
    g, h = hist[0], hist[1]  # (L, F, B)
    gl = jnp.cumsum(g, axis=-1)  # left sums, inclusive
    hl = jnp.cumsum(h, axis=-1)
    gt = gl[..., -1:]  # totals (L, F, 1)
    ht = hl[..., -1:]
    gr = gt - gl
    hr = ht - hl
    parent = gt**2 / (ht + lam)
    gain = gl**2 / (hl + lam) + gr**2 / (hr + lam) - parent  # (L, F, B)
    valid = (hl >= min_child_hess) & (hr >= min_child_hess)
    valid = valid.at[..., -1].set(False)
    gain = jnp.where(valid, gain, -jnp.inf)
    flat = gain.reshape(gain.shape[0], -1)  # (L, F*B)
    idx = jnp.argmax(flat, axis=-1)
    best_gain = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
    nb = hist.shape[-1]
    return best_gain, (idx // nb).astype(jnp.int32), (idx % nb).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("causal", "group"))
def flash_attention_ref(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BKV, Sk, d)
    v: jax.Array,
    causal: bool = True,
    group: int = 1,
) -> jax.Array:
    """Plain softmax attention — the oracle for the flash kernel."""
    bh, sq, d = q.shape
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _tree_leaf_values(
    bins: jax.Array, feat: jax.Array, thr: jax.Array, leaves: jax.Array, depth: int
) -> jax.Array:
    """One tree's leaf value per sample, (N,) — the shared heap descent."""
    node = jnp.zeros((bins.shape[0],), jnp.int32)

    def step(_, node):
        f = jnp.take(feat, node)
        t = jnp.take(thr, node)
        v = jnp.take_along_axis(bins, f[:, None], axis=1)[:, 0]
        return 2 * node + 1 + (v > t).astype(jnp.int32)

    node = jax.lax.fori_loop(0, depth, step, node)
    return jnp.take(leaves, node - ((1 << depth) - 1))


def _dequantize_forest(
    threshold: jax.Array, leaf_value: jax.Array, leaf_scale: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Quantized-layout prologue shared by both traversal oracles.

    The reference semantics of the kernel's dequantize-in-VMEM epilogue:
    int8 leaves scale back through the per-tree f32 ``leaf_scale``, fp16
    leaves cast exactly, quantized thresholds widen to int32. On f32/int32
    inputs both converts are same-dtype no-ops, so the unquantized path
    stays BITWISE-identical to the historical one.
    """
    leaf = leaf_value.astype(jnp.float32)
    if leaf_value.dtype == jnp.int8:
        if leaf_scale is None:
            raise ValueError("int8 leaf_value needs a per-tree leaf_scale")
        leaf = leaf * leaf_scale[:, None]
    return threshold.astype(jnp.int32), leaf


@functools.partial(jax.jit, static_argnames=("depth", "n_outputs"))
def forest_traverse_ref(
    bins: jax.Array,  # (N, F) int32
    feature: jax.Array,  # (T, 2^d - 1) int32
    threshold: jax.Array,  # (T, 2^d - 1) int32 — or int8/int16 quantized
    leaf_value: jax.Array,  # (T, 2^d) f32 — or int8/fp16 quantized
    n_trees: jax.Array,  # () int32 — live slots
    depth: int,
    n_outputs: int = 1,
    leaf_scale: jax.Array | None = None,  # (T,) f32, int8 mode only
) -> jax.Array:
    """Masked forest sum, (N,) f32 — the traversal kernel's oracle.

    Unlike ``apply_forest_ref`` this masks slots >= ``n_trees``, so a
    partially-filled forest predicts correctly even when dead slots hold
    stale (nonzero) trees — the hot-swap serving contract. Reduction shape
    mirrors the kernel (per-tree values, one reduce over the tree axis):
    interpret-mode parity is bitwise. It materializes a transient (T, N)
    buffer; for large train-set evaluation use ``apply_forest_ref`` with
    ``n_trees``, the O(N)-memory scan form of the same sum.

    With ``n_outputs`` = K > 1, slot t belongs to output t % K (the
    forest's round-major/output-minor layout) and the result is (N, K).

    Quantized forests (``Forest.quantize``) pass their packed
    threshold/leaf arrays plus ``leaf_scale``; the oracle dequantizes up
    front (``_dequantize_forest``), which is the reference for the
    kernel's in-VMEM epilogue — interpret-mode parity stays bitwise.
    """
    threshold, leaf_value = _dequantize_forest(threshold, leaf_value, leaf_scale)
    per_tree = jax.vmap(
        lambda feat, thr, leaves: _tree_leaf_values(bins, feat, thr, leaves, depth)
    )(feature, threshold, leaf_value)  # (T, N)
    live = jnp.arange(feature.shape[0])[:, None] < n_trees
    masked = jnp.where(live, per_tree, 0.0)
    if n_outputs == 1:
        return jnp.sum(masked, axis=0).astype(jnp.float32)
    out_k = jnp.arange(feature.shape[0]) % n_outputs
    per_out = jax.ops.segment_sum(masked, out_k, num_segments=n_outputs)
    return per_out.T.astype(jnp.float32)  # (N, K)


@functools.partial(jax.jit, static_argnames=("depth", "n_outputs"))
def apply_forest_ref(
    bins: jax.Array,  # (N, F) int32
    feature: jax.Array,  # (T, 2^d - 1) int32
    threshold: jax.Array,  # (T, 2^d - 1) int32 — or int8/int16 quantized
    leaf_value: jax.Array,  # (T, 2^d) f32 — or int8/fp16 quantized
    depth: int,
    n_trees: jax.Array | None = None,  # () int32; None = all slots live
    n_outputs: int = 1,
    leaf_scale: jax.Array | None = None,  # (T,) f32, int8 mode only
) -> jax.Array:
    """Sum of per-tree predictions, (N,) f32 — the forest F(x) evaluation.

    Scan-accumulated: O(N) live memory regardless of T (the right form for
    full-train-set evaluation). With ``n_trees``, slots past the live count
    contribute exactly 0 (same masking contract as ``forest_traverse_ref``;
    on zero-padded training forests the two agree either way). With
    ``n_outputs`` = K > 1, slot t accumulates into output column t % K
    and the result is (N, K). Quantized forests dequantize up front
    (outside the scan), same as ``forest_traverse_ref``.
    """
    threshold, leaf_value = _dequantize_forest(threshold, leaf_value, leaf_scale)

    def one_tree(carry, tree):
        total, idx = carry
        feat, thr, leaves = tree
        vals = _tree_leaf_values(bins, feat, thr, leaves, depth)
        if n_trees is not None:
            vals = jnp.where(idx < n_trees, vals, 0.0)
        if n_outputs == 1:
            total = total + vals
        else:
            total = total.at[:, idx % n_outputs].add(vals)
        return (total, idx + 1), None

    shape = (bins.shape[0],) if n_outputs == 1 else (bins.shape[0], n_outputs)
    (total, _), _ = jax.lax.scan(
        one_tree,
        (jnp.zeros(shape, jnp.float32), jnp.asarray(0, jnp.int32)),
        (feature, threshold, leaf_value),
    )
    return total
