"""Pallas TPU kernel: fused cumulative-sum + split-gain over histograms.

After histograms are built, split search scans every (node, feature) bin row:
left sums are prefix sums over bins, and the gain formula touches each bin a
handful of times. Unfused, XLA materializes four (L, F, B) temporaries in
HBM (cumsum-g, cumsum-h, gain, validity). The kernel fuses the whole
pipeline per VMEM tile so each histogram element is read from HBM exactly
once and only the (L, F, B) gain surface is written back.

Grid: (node_blocks, feature_blocks); each program owns a (L_blk, F_blk, B)
tile — the bin axis is never split because the prefix sum runs along it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _split_kernel(g_ref, h_ref, params_ref, gain_ref):
    g = g_ref[...]  # (L_blk, F_blk, B)
    h = h_ref[...]
    # Scalars ride in SMEM via scalar prefetch — available before the tile
    # DMA lands, and never occupying a (1, 1) vector tile like the old
    # ``pl.ANY`` placement did.
    lam = params_ref[0]
    min_h = params_ref[1]

    gl = jnp.cumsum(g, axis=-1)
    hl = jnp.cumsum(h, axis=-1)
    gt = gl[..., -1:]
    ht = hl[..., -1:]
    gr = gt - gl
    hr = ht - hl
    parent = gt * gt / (ht + lam)
    gain = gl * gl / (hl + lam) + gr * gr / (hr + lam) - parent

    nb = g.shape[-1]
    bin_pos = jax.lax.broadcasted_iota(jnp.int32, g.shape, 2)
    valid = (hl >= min_h) & (hr >= min_h) & (bin_pos < nb - 1)
    gain_ref[...] = jnp.where(valid, gain, -jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("node_block", "feature_block", "interpret")
)
def split_gain_pallas(
    hist: jax.Array,  # (2, L, F, B) f32
    lam: jax.Array,  # scalar
    min_child_hess: jax.Array,
    node_block: int = 8,
    feature_block: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Gain surface (L, F, B); invalid split points are -inf.

    ``interpret=None`` auto-detects (Mosaic on TPU, interpreter elsewhere).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _, l, f, b = hist.shape
    assert l % node_block == 0 and f % feature_block == 0
    params = jnp.stack([
        jnp.asarray(lam, jnp.float32),
        jnp.asarray(min_child_hess, jnp.float32),
    ])  # (2,) SMEM-resident scalars

    return pl.pallas_call(
        _split_kernel,
        grid=(l // node_block, f // feature_block),
        in_specs=[
            pl.BlockSpec((node_block, feature_block, b), lambda lb, fb: (lb, fb, 0)),
            pl.BlockSpec((node_block, feature_block, b), lambda lb, fb: (lb, fb, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(
            (node_block, feature_block, b), lambda lb, fb: (lb, fb, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((l, f, b), jnp.float32),
        interpret=interpret,
    )(hist[0], hist[1], params)
