"""Pallas TPU kernel: fused (flash) attention — online softmax, O(S) memory.

Why it exists here: the roofline analysis (EXPERIMENTS.md §Perf C4) shows
the train/prefill memory term is dominated by unfused softmax traffic —
XLA materializes the (q_chunk x S_kv) score tensor in f32 and re-reads it
for max/sub/exp/sum/div. This kernel keeps one (block_q x block_k) tile in
VMEM, carries the running max m and normalizer l per query row, and never
writes scores to HBM: HBM traffic drops from O(S^2) to O(S·d) per head.

Layout: q (BH, Sq, d), k/v (BKV, Sk, d) with GQA folded into the grid's
head axis (index_map h -> h // group for k/v — no repeated KV in memory).
Grid (BH, nq, nk); the kv axis is innermost and accumulates into VMEM
scratch (acc, m, l); the final kv step normalizes and writes the output
block. MXU alignment: block_q/block_k default 128, d padded by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, bq, d)
    k_ref,  # (1, bk, d)
    v_ref,  # (1, bk, d)
    o_ref,  # (1, bq, d)
    lse_ref,  # (1, bq) f32 — per-row logsumexp, saved for the backward
    acc_ref,  # VMEM scratch (bq, d) f32
    m_ref,  # VMEM scratch (bq,) f32
    l_ref,  # VMEM scratch (bq,) f32
    *,
    causal: bool,
    block_q: int,
    block_k: int,
    sm_scale: float,
    seq_k: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    def compute():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale  # (bq, bk)
        valid = k_pos < seq_k
        if causal:
            valid &= q_pos >= k_pos
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)  # (bq,)
        p = jnp.exp(s - m_new[:, None])  # (bq, bk)
        p = jnp.where(valid, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # whole block above the diagonal -> nothing to do
        @pl.when(iq * block_q + block_q - 1 >= ik * block_k)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "block_q", "block_k", "group", "interpret", "seq_k"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, d) — batch*heads flattened
    k: jax.Array,  # (BKV, Sk, d) — batch*kv_heads flattened
    v: jax.Array,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    group: int = 1,  # q heads per kv head (GQA); BH = BKV * group
    interpret: bool | None = None,  # None: Mosaic on TPU, interpreter elsewhere
    seq_k: int | None = None,  # true (pre-padding) kv length for masking
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh == bkv * group
    assert sq % block_q == 0 and sk % block_k == 0, "wrapper must pad"
    nq, nk = sq // block_q, sk // block_k
    sm_scale = 1.0 / (d ** 0.5)
    if seq_k is None:
        seq_k = sk

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, block_q=block_q, block_k=block_k,
            sm_scale=sm_scale, seq_k=seq_k,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, iq, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_q), lambda h, iq, ik: (h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ------------------------------------------------------------------ backward
# Standard flash backward (Dao et al.):
#   P_ij  = exp(s_ij - L_i),       s = scale * q k^T
#   D_i   = sum_d do_id * o_id
#   dS    = P * (do v^T - D)
#   dq_i  = scale * sum_j dS_ij k_j        (kernel 1: grid over q blocks)
#   dk_j  = scale * sum_i dS_ij q_i        (kernel 2: grid over kv blocks)
#   dv_j  =         sum_i P_ij  do_i
# Two kernels so each output block has a single writer (no atomics on TPU);
# both recompute P from (q, k, L) — nothing quadratic is ever stored.


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref,
    acc_ref,
    *, causal, block_q, block_k, sm_scale, seq_k, seq_q,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        valid = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            valid &= q_pos >= k_pos
        p = jnp.where(valid, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dp = jax.lax.dot_general(
            do_ref[0], v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta_ref[0][:, None])
        acc_ref[...] += sm_scale * jax.lax.dot(
            ds.astype(k.dtype), k, preferred_element_type=jnp.float32
        )

    if causal:
        @pl.when(iq * block_q + block_q - 1 >= ik * block_k)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == pl.num_programs(2) - 1)
    def _done():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, causal, block_q, block_k, sm_scale, seq_k, seq_q, group,
):
    # grid: (BKV_head, nk, nq * group) — innermost axis walks all q blocks
    # of every q-head in this kv head's group, accumulating dk/dv.
    inner = pl.program_id(2)
    ik = pl.program_id(1)
    nq = pl.num_programs(2) // group
    iq = inner % nq

    @pl.when(inner == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        valid = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            valid &= q_pos >= k_pos
        p = jnp.where(valid, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        do = do_ref[0]
        dv_acc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dp = jax.lax.dot_general(
            do, v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta_ref[0][:, None])
        dk_acc[...] += sm_scale * jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if causal:
        @pl.when(iq * block_q + block_q - 1 >= ik * block_k)
        def _():
            compute()
    else:
        compute()

    @pl.when(inner == pl.num_programs(2) - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "block_q", "block_k", "group", "interpret", "seq_k",
        "seq_q",
    ),
)
def flash_attention_bwd_pallas(
    q, k, v, o, lse, do,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    group: int = 1,
    interpret: bool | None = None,  # None: Mosaic on TPU, interpreter elsewhere
    seq_k: int | None = None,
    seq_q: int | None = None,
):
    """-> (dq, dk, dv). Shapes as the forward; lse (BH, Sq) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    nq, nk = sq // block_q, sk // block_k
    sm_scale = 1.0 / (d ** 0.5)
    if seq_k is None:
        seq_k = sk
    if seq_q is None:
        seq_q = sq
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (BH, Sq)

    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, causal=causal, block_q=block_q,
            block_k=block_k, sm_scale=sm_scale, seq_k=seq_k, seq_q=seq_q,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_q), lambda h, iq, ik: (h, iq)),
            pl.BlockSpec((1, block_q), lambda h, iq, ik: (h, iq)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv: one kv-head per grid row; inner axis = (q-head in group, q block)
    def _qh(h, inner, nq_=nq, g=group):
        return h * g + inner // nq_

    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, causal=causal, block_q=block_q,
            block_k=block_k, sm_scale=sm_scale, seq_k=seq_k, seq_q=seq_q,
            group=group,
        ),
        grid=(bkv, nk, nq * group),
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda h, ik, inner: (_qh(h, inner), inner % nq, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, ik, inner: (h, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, ik, inner: (h, ik, 0)),
            pl.BlockSpec((1, block_q, d),
                         lambda h, ik, inner: (_qh(h, inner), inner % nq, 0)),
            pl.BlockSpec((1, block_q),
                         lambda h, ik, inner: (_qh(h, inner), inner % nq)),
            pl.BlockSpec((1, block_q),
                         lambda h, ik, inner: (_qh(h, inner), inner % nq)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda h, ik, inner: (h, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, ik, inner: (h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, sk, d), k.dtype),
            jax.ShapeDtypeStruct((bkv, sk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
