"""Pallas TPU kernel: ONE fused program per tree level.

The staged build pays three HBM round-trips per level: the histogram kernel
writes the (2, L, F, B) level histogram, ``split_scan`` reads it back and
writes the (L, F, B) gain surface, and the learner reads THAT back for the
argmax before a fourth pass re-routes every sample. This kernel fuses the
whole level:

  phase A (grid steps 0..ns-1) — stream sample blocks from HBM (the grid
      pipeline double-buffers the DMA) and accumulate the built nodes'
      grad/hess histogram in a VMEM scratch via the same one-hot MXU
      contraction as ``histogram.py`` — identical dot shapes, identical
      accumulation order, so single-shard results stay bit-compatible with
      the staged kernel;
  phase B (first step of the partition sweep) — derive the sibling rows
      from the cached parent histogram (subtract mode), run the cumulative
      split-gain scan IN REGISTERS over the full (L, F, B) block, apply
      the feature mask, argmax, and fix unsplittable nodes to the
      pass-left convention — only the (2, L, F, B) level histogram (the
      next level's subtraction cache) and three (L,)-sized split vectors
      ever reach HBM, never a gain surface;
  phase C (grid steps ns..2*ns-1) — second sample sweep: gather each
      sample's node's winning (feature, threshold) from the VMEM-resident
      split tables and emit the new row -> node map.

Grid: (2 * ns,) — ns sample-block steps of histogram accumulation, then ns
steps of partition. Scalars (lam, min_child_hess) ride in SMEM; everything
data-dependent (the active-node subset, the feature mask) is an operand so
one compiled program serves a whole training run.

The row -> node semantics, the gain formula, the validity mask, and the
argmax tie-break (first maximum in (f * B + b) row-major order) all match
``ref.level_build_ref`` / the staged ``trees.learner`` path exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _level_kernel(
    bins_ref,  # (S_blk, F_pad) int32
    node_ref,  # (S_blk, 1) int32, -1 = inactive
    grad_ref,  # (S_blk, 1) f32
    hess_ref,  # (S_blk, 1) f32
    rowmap_ref,  # (2 * L_sub, 1) int32 — node id each GH row selects
    parent_ref,  # (2, L_par, FB_pad) f32 — parent cache (zeros in full mode)
    mask_ref,  # (1, F_pad) f32 — 1.0 = feature in this tree's subsample
    params_ref,  # (2,) f32 in SMEM — [lam, min_child_hess]
    hist_ref,  # out (2, L, FB_pad) f32 — the full level histogram
    split_ref,  # out (2, L) int32 — [best_feature; best_bin] per node
    gain_ref,  # out (1, L) f32 — best gain per node (pre pass-left fix)
    node_out_ref,  # out (S_blk, 1) int32 — new row -> node map
    acc_ref,  # scratch (2 * L_sub, FB_pad) f32 — built-row accumulator
    *,
    ns: int,
    n_bins: int,
    feature_block: int,
    n_nodes: int,
    derive_sibling: bool,
):
    t = pl.program_id(0)
    s_blk, f_pad = bins_ref.shape
    rows = acc_ref.shape[0]  # 2 * L_sub
    l_sub = rows // 2
    l = n_nodes
    n_chunks = f_pad // feature_block

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(t < ns)
    def _accumulate():
        # Same GH factor as histogram.py: row 2r carries grad, 2r+1 hess,
        # both masked to samples currently on node rowmap[2r]. One dot of
        # identical shape per (feature chunk, sample block) keeps the
        # per-cell f32 accumulation order bit-compatible with the staged
        # kernel's (feature_blocks, sample_blocks) grid.
        node = node_ref[:, 0]
        grad = grad_ref[:, 0]
        hess = hess_ref[:, 0]
        row_node = rowmap_ref[:, 0]
        row_is_h = jax.lax.broadcasted_iota(jnp.int32, (rows, s_blk), 0) % 2
        gh_val = jnp.where(row_is_h == 0, grad[None, :], hess[None, :])
        gh = jnp.where(row_node[:, None] == node[None, :], gh_val, 0.0)
        for c in range(n_chunks):
            blk = bins_ref[:, c * feature_block : (c + 1) * feature_block]
            bin_iota = jax.lax.broadcasted_iota(
                jnp.int32, (s_blk, feature_block, n_bins), 2
            )
            onehot = (blk[..., None] == bin_iota).astype(jnp.float32)
            onehot = onehot.reshape(s_blk, feature_block * n_bins)
            lo, hi = c * feature_block * n_bins, (c + 1) * feature_block * n_bins
            acc_ref[:, lo:hi] += jax.lax.dot(
                gh, onehot, preferred_element_type=jnp.float32
            )

    @pl.when(t == ns)
    def _decide():
        fb = f_pad * n_bins
        acc = acc_ref[...].reshape(l_sub, 2, f_pad, n_bins)
        g_built, h_built = acc[:, 0], acc[:, 1]  # (L_sub, F_pad, B)
        if derive_sibling:
            # Node n (parent p = n >> 1) is either the built child or the
            # derived sibling ``parent - built`` — the subtraction runs on
            # the already-merged parent cache, so under shard_map the
            # learner keeps the collective BEFORE this kernel (see
            # ps/sharded.py); single-shard, this is the same arithmetic as
            # the staged learner's post-psum gather.
            par = parent_ref[...].reshape(2, l_sub, f_pad, n_bins)
            built2 = jnp.repeat(  # row p -> nodes 2p, 2p+1
                jnp.stack([g_built, h_built]), 2, axis=1
            )  # (2, L, F_pad, B)
            par2 = jnp.repeat(par, 2, axis=1)
            built_ids = rowmap_ref[:, 0].reshape(l_sub, 2)[:, 0]  # (L_sub,)
            is_built = (
                jax.lax.broadcasted_iota(jnp.int32, (l,), 0)
                == jnp.repeat(built_ids, 2)
            )
            full = jnp.where(is_built[None, :, None, None], built2, par2 - built2)
            g_full, h_full = full[0], full[1]
        else:
            g_full, h_full = g_built, h_built  # L_sub == L
        hist_ref[0] = g_full.reshape(l, fb)
        hist_ref[1] = h_full.reshape(l, fb)

        # In-register split scan — the exact split_scan.py / ref formula.
        lam = params_ref[0]
        min_h = params_ref[1]
        gl = jnp.cumsum(g_full, axis=-1)
        hl = jnp.cumsum(h_full, axis=-1)
        gt, ht = gl[..., -1:], hl[..., -1:]
        gr, hr = gt - gl, ht - hl
        gain = gl * gl / (hl + lam) + gr * gr / (hr + lam) - gt * gt / (ht + lam)
        bin_pos = jax.lax.broadcasted_iota(jnp.int32, gain.shape, 2)
        valid = (hl >= min_h) & (hr >= min_h) & (bin_pos < n_bins - 1)
        valid = valid & (mask_ref[0, :][None, :, None] > 0.0)
        gain = jnp.where(valid, gain, -jnp.inf)

        # Argmax with the first-maximum tie-break (== jnp.argmax): max,
        # then the smallest flat index attaining it.
        flat = gain.reshape(l, fb)
        best = jnp.max(flat, axis=-1, keepdims=True)  # (L, 1)
        pos = jax.lax.broadcasted_iota(jnp.int32, (l, fb), 1)
        idx = jnp.min(jnp.where(flat == best, pos, fb), axis=-1)  # (L,)
        best = best[:, 0]
        ok = jnp.isfinite(best) & (best > 0.0)
        feat = jnp.where(ok, idx // n_bins, 0).astype(jnp.int32)
        thr = jnp.where(ok, idx % n_bins, n_bins - 1).astype(jnp.int32)
        split_ref[0, :] = feat
        split_ref[1, :] = thr
        gain_ref[0, :] = best

    @pl.when(t >= ns)
    def _partition():
        # Route every sample: gather its node's winning (feature, bin)
        # from the VMEM-resident split table (one-hot contractions — no
        # TPU gathers), read the sample's bin for that feature, go right
        # iff bin > threshold. Matches the staged learner's
        # ``2 * node + (bins[s, feat[node]] > thr[node])`` update.
        node = node_ref[:, 0]  # (S,)
        onehot_l = (
            node[:, None] == jax.lax.broadcasted_iota(jnp.int32, (s_blk, l), 1)
        ).astype(jnp.float32)
        table = jnp.concatenate(  # (L, 2): feature and threshold columns
            [
                split_ref[0, :][:, None].astype(jnp.float32),
                split_ref[1, :][:, None].astype(jnp.float32),
            ],
            axis=1,
        )
        sel = jax.lax.dot(onehot_l, table, preferred_element_type=jnp.float32)
        feat_s = sel[:, 0].astype(jnp.int32)  # exact: values < F_pad
        thr_s = sel[:, 1]
        f_iota = jax.lax.broadcasted_iota(jnp.int32, (s_blk, f_pad), 1)
        val = jnp.sum(
            jnp.where(f_iota == feat_s[:, None], bins_ref[...], 0), axis=1
        ).astype(jnp.float32)
        go_right = (val > thr_s).astype(jnp.int32)
        node_out_ref[:, 0] = 2 * node + go_right


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_nodes",
        "n_bins",
        "derive_sibling",
        "sample_block",
        "feature_block",
        "interpret",
    ),
)
def level_build_pallas(
    bins: jax.Array,  # (N_pad, F_pad) int32 — wrapper pads both axes
    node_ids: jax.Array,  # (N_pad,) int32, -1 = padding/inactive
    grad: jax.Array,  # (N_pad,) f32
    hess: jax.Array,  # (N_pad,) f32
    active_nodes: jax.Array,  # (L_sub,) int32 node ids to histogram
    parent_hist: jax.Array | None,  # (2, L_sub, F_pad, B) merged parent cache
    feat_mask: jax.Array,  # (F_pad,) f32 — 1.0 = feature available
    lam: jax.Array,
    min_child_hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    derive_sibling: bool = False,
    sample_block: int = 512,
    feature_block: int = 8,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused level: (hist (2, L, F_pad, B), feat (L,), thr (L,),
    best_gain (L,), new_node (N_pad,)). See the module docstring.

    ``derive_sibling=False`` is the full-level build (``active_nodes`` must
    enumerate all ``n_nodes``); ``True`` is the subtraction mode —
    ``active_nodes[p]`` is the smaller child of parent ``p`` and
    ``parent_hist`` the (already psum-merged, when sharded) previous-level
    cache. ``interpret=None`` auto-detects like every kernel here.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f_pad = bins.shape
    assert n % sample_block == 0, "wrapper must pad samples"
    assert f_pad % feature_block == 0, "wrapper must pad features"
    ns = n // sample_block
    l_sub = active_nodes.shape[0]
    if derive_sibling:
        assert parent_hist is not None and 2 * l_sub == n_nodes
    else:
        assert l_sub == n_nodes
        parent_hist = jnp.zeros((2, l_sub, f_pad, n_bins), jnp.float32)
    fb = f_pad * n_bins
    row_map = jnp.repeat(active_nodes.astype(jnp.int32), 2)
    params = jnp.stack(
        [jnp.asarray(lam, jnp.float32), jnp.asarray(min_child_hess, jnp.float32)]
    )

    kernel = functools.partial(
        _level_kernel,
        ns=ns,
        n_bins=n_bins,
        feature_block=feature_block,
        n_nodes=n_nodes,
        derive_sibling=derive_sibling,
    )
    sample_map = lambda t: (jax.lax.rem(t, ns), 0)
    hist, split, gain, new_node = pl.pallas_call(
        kernel,
        grid=(2 * ns,),
        in_specs=[
            pl.BlockSpec((sample_block, f_pad), sample_map),
            pl.BlockSpec((sample_block, 1), sample_map),
            pl.BlockSpec((sample_block, 1), sample_map),
            pl.BlockSpec((sample_block, 1), sample_map),
            pl.BlockSpec((2 * l_sub, 1), lambda t: (0, 0)),
            pl.BlockSpec((2, l_sub, fb), lambda t: (0, 0, 0)),
            pl.BlockSpec((1, f_pad), lambda t: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((2, n_nodes, fb), lambda t: (0, 0, 0)),
            pl.BlockSpec((2, n_nodes), lambda t: (0, 0)),
            pl.BlockSpec((1, n_nodes), lambda t: (0, 0)),
            pl.BlockSpec(
                (sample_block, 1),
                lambda t: (jnp.where(t < ns, 0, t - ns), 0),
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((2, n_nodes, fb), jnp.float32),
            jax.ShapeDtypeStruct((2, n_nodes), jnp.int32),
            jax.ShapeDtypeStruct((1, n_nodes), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((2 * l_sub, fb), jnp.float32)],
        interpret=interpret,
    )(
        bins,
        node_ids[:, None],
        grad[:, None],
        hess[:, None],
        row_map[:, None],
        parent_hist.reshape(2, l_sub, fb),
        feat_mask[None, :].astype(jnp.float32),
        params,
    )
    return (
        hist.reshape(2, n_nodes, f_pad, n_bins),
        split[0],
        split[1],
        gain[0],
        new_node[:, 0],
    )


# Fall back to the staged pipeline when a level's resident set would not
# leave headroom in the ~16 MB/core VMEM (DESIGN.md §13 has the budget
# math). Deep wide levels are exactly where histogram tiling wins anyway.
FUSED_VMEM_BUDGET = 12 * 2**20


def fused_level_fits(
    n: int,
    n_nodes: int,
    n_sub: int,
    n_feat: int,
    n_bins: int,
    budget: int = FUSED_VMEM_BUDGET,
) -> bool:
    """Whether one fused level fits the VMEM budget at its tuned blocks."""
    from repro.kernels import autotune

    blocks = autotune.lookup(n, n_feat, n_bins, n_nodes)
    return (
        fused_level_vmem_bytes(
            n_nodes, n_sub, n_feat, n_bins,
            blocks["sample_block"], blocks["feature_block"],
        )
        <= budget
    )


def fused_level_vmem_bytes(
    n_nodes: int,
    n_sub: int,
    n_feat: int,
    n_bins: int,
    sample_block: int,
    feature_block: int,
) -> int:
    """The fused program's peak VMEM footprint model (DESIGN.md §13).

    Resident blocks: the built-row accumulator (2*L_sub, F, B), the parent
    cache (2, L_sub, F, B), the level-histogram output window
    (2, L, F, B), the (S_blk, F) bins block, and phase B's scan
    temporaries (~3 extra (L, F, B) values for cumsums and the gain).
    The learner falls back to the staged path for any level whose estimate
    exceeds the budget — deep wide levels, where histogram tiling is the
    right call anyway.
    """
    fb = n_feat * n_bins
    acc = 2 * n_sub * fb
    parent = 2 * n_sub * fb
    hist_out = 2 * n_nodes * fb
    bins_blk = sample_block * n_feat
    onehot = sample_block * feature_block * n_bins
    scan_tmp = 3 * n_nodes * fb
    return 4 * (acc + parent + hist_out + bins_blk + onehot + scan_tmp)
