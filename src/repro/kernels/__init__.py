"""Pallas TPU kernels for the GBDT hot spots, with jnp oracles.

- ``histogram``: per-(node, feature, bin) grad/hess sums as one-hot MXU
  matmuls (the TPU adaptation of LightGBM's scatter-add histogram).
- ``split_scan``: fused prefix-sum + gain surface.
- ``forest_traversal``: fused batched forest traversal for serving.
- ``ops``: jit'd wrappers with ref/pallas backend dispatch.
- ``ref``: pure-jnp semantics of record.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.forest_traversal import forest_traverse_pallas
from repro.kernels.histogram import histogram_pallas
from repro.kernels.histogram_sparse import histogram_sparse_pallas
from repro.kernels.split_scan import split_gain_pallas

__all__ = [
    "ops",
    "ref",
    "flash_attention_pallas",
    "forest_traverse_pallas",
    "histogram_pallas",
    "histogram_sparse_pallas",
    "split_gain_pallas",
]
