"""Pallas TPU kernel: gradient/hessian histograms as one-hot MXU matmuls.

LightGBM's (and every GPU GBDT's) hot loop scatter-adds grad/hess into
per-(node, feature, bin) buckets — atomics into shared memory. TPUs have no
atomics and weak scatter throughput, but a 128x128 systolic MXU. We therefore
reformulate the whole level-histogram as a single dense contraction:

    out[r, f*B + b] = sum_s GH[r, s] * onehot[s, f*B + b]

where row r carries (node_of_row[r], grad-or-hess), GH masks each sample's
grad/hess onto its current tree node, and onehot marks the sample's bin for
feature f. Both factor matrices are built on the fly inside VMEM from
integer inputs — nothing of size (N, F*B) ever touches HBM.

The row -> node mapping is an explicit operand (``row_map``), not an iota:
row r selects samples on node ``row_map[r]``. The full-level build passes
``row_map = repeat(arange(n_nodes), 2)``; the histogram-subtraction tree
builder (``trees.learner`` with ``hist_mode='subtract'``) passes the
smaller child of every parent only, halving the GH rows — and therefore
the MXU work — of every level below the root. Kernel cost is linear in
``rows``, so the node subset IS the speedup.

Grid: (feature_blocks, sample_blocks); sample axis is innermost and
accumulates into the same output block (standard Pallas reduce pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(
    bins_ref,  # (S_blk, F_blk) int32
    node_ref,  # (S_blk, 1) int32, -1 = inactive
    grad_ref,  # (S_blk, 1) f32
    hess_ref,  # (S_blk, 1) f32
    rowmap_ref,  # (rows, 1) int32 — node id each GH row selects
    out_ref,  # (rows, F_blk*B) f32
    *,
    n_bins: int,
):
    s_blk, f_blk = bins_ref.shape
    rows = out_ref.shape[0]

    sample_axis = pl.program_id(1)

    @pl.when(sample_axis == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    node = node_ref[:, 0]  # (S,)
    grad = grad_ref[:, 0]
    hess = hess_ref[:, 0]
    row_node = rowmap_ref[:, 0]  # (rows,)

    # GH: (rows, S). Row r selects samples on node row_map[r]; even rows
    # carry grad, odd rows carry hess. Inactive samples (node < 0) never
    # match (row maps hold real node ids >= 0).
    row_is_h = jax.lax.broadcasted_iota(jnp.int32, (rows, s_blk), 0) % 2
    gh_val = jnp.where(row_is_h == 0, grad[None, :], hess[None, :])
    gh = jnp.where(row_node[:, None] == node[None, :], gh_val, 0.0)

    # One-hot: (S, F_blk*B), onehot[s, f*B + b] = 1{bins[s, f] == b}.
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (s_blk, f_blk, n_bins), 2)
    onehot = (bins_ref[...][..., None] == bin_iota).astype(jnp.float32)
    onehot = onehot.reshape(s_blk, f_blk * n_bins)

    out_ref[...] += jax.lax.dot(
        gh, onehot, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "n_bins", "sample_block", "feature_block", "interpret"),
)
def histogram_pallas(
    bins: jax.Array,  # (N, F) int32 — N % sample_block == 0 (wrapper pads)
    node_ids: jax.Array,  # (N,) int32
    grad: jax.Array,  # (N,) f32
    hess: jax.Array,  # (N,) f32
    n_nodes: int,
    n_bins: int,
    sample_block: int = 512,
    feature_block: int = 8,
    interpret: bool | None = None,
    active_nodes: jax.Array | None = None,  # (n_sub,) int32 node subset
) -> jax.Array:
    """Returns (2, R, F, n_bins) f32 histograms. See module docstring.

    ``R = n_nodes`` for the full-level build (``active_nodes=None``), else
    ``R = len(active_nodes)`` and row r histograms node ``active_nodes[r]``
    only — the entry point of the parent-minus-child subtraction builder.
    ``active_nodes`` values must be valid node ids in ``[0, n_nodes)``;
    its length is static (it fixes the kernel's row count).

    ``interpret=None`` auto-detects: compile to Mosaic on TPU, run the
    Pallas interpreter elsewhere — so direct callers (tests, benches) get
    the real kernel on real hardware instead of silently interpreting.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f = bins.shape
    assert n % sample_block == 0, "wrapper must pad samples"
    assert f % feature_block == 0, "wrapper must pad features"
    ns, nf = n // sample_block, f // feature_block
    if active_nodes is None:
        active_nodes = jnp.arange(n_nodes, dtype=jnp.int32)
    n_sub = active_nodes.shape[0]
    rows = 2 * n_sub
    row_map = jnp.repeat(active_nodes.astype(jnp.int32), 2)  # (rows,)

    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=(nf, ns),
        in_specs=[
            pl.BlockSpec((sample_block, feature_block), lambda fb, sb: (sb, fb)),
            pl.BlockSpec((sample_block, 1), lambda fb, sb: (sb, 0)),
            pl.BlockSpec((sample_block, 1), lambda fb, sb: (sb, 0)),
            pl.BlockSpec((sample_block, 1), lambda fb, sb: (sb, 0)),
            pl.BlockSpec((rows, 1), lambda fb, sb: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (rows, feature_block * n_bins), lambda fb, sb: (0, fb)
        ),
        out_shape=jax.ShapeDtypeStruct((rows, f * n_bins), jnp.float32),
        interpret=interpret,
    )(
        bins,
        node_ids[:, None],
        grad[:, None],
        hess[:, None],
        row_map[:, None],
    )
    # rows are (2*row + grad/hess) -> (row, gh, feature, bin) -> (gh, row, f, b)
    return out.reshape(n_sub, 2, f, n_bins).transpose(1, 0, 2, 3)
