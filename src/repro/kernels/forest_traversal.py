"""Pallas TPU kernel: fused batched forest traversal for serving.

Inference cost in GBT deployments is dominated by batched traversal
throughput (Anghel et al., 2018): for every request row, T trees each do a
depth-d heap descent and the T leaf values are summed. Evaluated naively
(one tree at a time, XLA scan like ``kernels.ref.apply_forest_ref``), the
per-tree prediction vector (N,) round-trips HBM T times and nothing of the
tree arrays is reused across samples.

The kernel evaluates a (sample_block, tree_block) tile per grid step with
everything resident in VMEM:

- tree arrays arrive pre-transposed as (n_int, T) / (n_leaf, T) so each
  descent level is two ``take_along_axis`` gathers over VMEM-resident
  blocks — ``feature[t, node]`` then ``bins[s, feature]``;
- the heap descent is unrolled over the static depth (node = 2*node + 1 +
  (bin > threshold)), so there is no per-level control flow;
- leaf values are masked by the live-tree count (partially-filled forests
  serve correctly even if dead slots hold stale trees) and reduced on-chip;
  only the (N,) partial sum is written back, accumulated across tree
  blocks — nothing of size (N, T) ever touches HBM.

Grid: (sample_blocks, tree_blocks); the tree axis is innermost and
accumulates into the same output block (the histogram kernel's reduce
pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _traverse_kernel(
    bins_ref,  # (S_blk, F) int32
    feat_ref,  # (n_int, T_blk) int32 — transposed tree arrays
    thr_ref,  # (n_int, T_blk) int32 — or int8/int16 quantized
    leaf_ref,  # (n_leaf, T_blk) f32 — or int8/fp16 quantized
    *rest,  # [scale_ref (1, T_blk) f32 when qmode='int8'], ntree_ref, out_ref
    depth: int,
    tree_block: int,
    n_outputs: int,
    qmode: str,
):
    if qmode == "int8":
        scale_ref, ntree_ref, out_ref = rest
    else:
        (ntree_ref, out_ref), scale_ref = rest, None
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]
    feat = feat_ref[...]
    thr = thr_ref[...]
    # Dequantize-in-VMEM epilogue (DESIGN.md §17): quantized blocks travel
    # HBM->VMEM packed (4x fewer bytes for int8) and widen on-chip once
    # per block, before the gathers. On the f32/int32 layout both converts
    # are same-dtype no-ops, so that path's program is unchanged.
    if qmode != "none":
        thr = thr.astype(jnp.int32)
    leaf = leaf_ref[...]
    if qmode == "int8":
        leaf = leaf.astype(jnp.float32) * scale_ref[...]  # (n_leaf, T_blk)
    elif qmode == "fp16":
        leaf = leaf.astype(jnp.float32)
    s_blk = bins.shape[0]

    # Depth-unrolled heap descent, all (sample, tree) pairs at once.
    node = jnp.zeros((s_blk, tree_block), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, node, axis=0)  # (S, T) split features
        t = jnp.take_along_axis(thr, node, axis=0)  # (S, T) split bins
        v = jnp.take_along_axis(bins, f, axis=1)  # (S, T) sample bins
        node = 2 * node + 1 + (v > t).astype(jnp.int32)

    leaf_idx = node - ((1 << depth) - 1)
    vals = jnp.take_along_axis(leaf, leaf_idx, axis=0)  # (S, T)
    tree_idx = tb * tree_block + jax.lax.broadcasted_iota(
        jnp.int32, vals.shape, 1
    )
    vals = jnp.where(tree_idx < ntree_ref[0, 0], vals, 0.0)
    if n_outputs == 1:
        out_ref[...] += jnp.sum(vals, axis=1, keepdims=True)
    else:
        # Slot t belongs to output t % K (round-major/output-minor forest
        # layout): K masked on-chip reductions into the (S, K) accumulator.
        out_k = tree_idx % n_outputs
        out_ref[...] += jnp.stack(
            [
                jnp.sum(jnp.where(out_k == k, vals, 0.0), axis=1)
                for k in range(n_outputs)
            ],
            axis=1,
        )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "sample_block", "tree_block", "interpret", "n_outputs"),
)
def forest_traverse_pallas(
    bins: jax.Array,  # (N, F) int32 — N % sample_block == 0 (wrapper pads)
    feature: jax.Array,  # (T, 2^d - 1) int32 — T % tree_block == 0
    threshold: jax.Array,  # (T, 2^d - 1) int32 — or int8/int16 quantized
    leaf_value: jax.Array,  # (T, 2^d) f32 — or int8/fp16 quantized
    n_trees: jax.Array,  # () int32 — live slots; slots >= n_trees add 0
    depth: int,
    sample_block: int = 256,
    tree_block: int = 512,
    interpret: bool | None = None,
    n_outputs: int = 1,
    leaf_scale: jax.Array | None = None,  # (T,) f32 — int8 mode only
) -> jax.Array:
    """Masked forest sum (N,) f32 — or (N, K) with ``n_outputs`` = K > 1,
    where slot t reduces into output column t % K. See module docstring.

    Quantized forests (int8 leaves + ``leaf_scale``, or fp16 leaves) ride
    the same grid with a dequantize-in-VMEM epilogue; the f32 layout lowers
    the exact historical program. ``interpret=None`` auto-detects (Mosaic
    on TPU, interpreter elsewhere).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f = bins.shape
    t, n_int = feature.shape
    n_leaf = leaf_value.shape[1]
    assert n % sample_block == 0, "wrapper must pad samples"
    assert t % tree_block == 0, "wrapper must pad trees"
    ns, nt = n // sample_block, t // tree_block
    if leaf_value.dtype == jnp.int8:
        qmode = "int8"
        assert leaf_scale is not None, "int8 leaves need leaf_scale"
    elif leaf_value.dtype == jnp.float16:
        qmode = "fp16"
    else:
        qmode = "none"

    in_specs = [
        pl.BlockSpec((sample_block, f), lambda sb, tb: (sb, 0)),
        pl.BlockSpec((n_int, tree_block), lambda sb, tb: (0, tb)),
        pl.BlockSpec((n_int, tree_block), lambda sb, tb: (0, tb)),
        pl.BlockSpec((n_leaf, tree_block), lambda sb, tb: (0, tb)),
    ]
    operands = [bins, feature.T, threshold.T, leaf_value.T]
    if qmode == "int8":
        # Per-tree dequant scales ride VMEM next to the leaf block they
        # rescale — (1, tree_block) per grid step, broadcast on-chip.
        in_specs.append(pl.BlockSpec((1, tree_block), lambda sb, tb: (0, tb)))
        operands.append(leaf_scale.reshape(1, t).astype(jnp.float32))
    in_specs.append(
        pl.BlockSpec((1, 1), lambda sb, tb: (0, 0), memory_space=pltpu.SMEM)
    )
    operands.append(jnp.asarray(n_trees, jnp.int32).reshape(1, 1))

    out = pl.pallas_call(
        functools.partial(
            _traverse_kernel,
            depth=depth,
            tree_block=tree_block,
            n_outputs=n_outputs,
            qmode=qmode,
        ),
        grid=(ns, nt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((sample_block, n_outputs), lambda sb, tb: (sb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_outputs), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, 0] if n_outputs == 1 else out
