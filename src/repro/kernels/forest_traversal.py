"""Pallas TPU kernel: fused batched forest traversal for serving.

Inference cost in GBT deployments is dominated by batched traversal
throughput (Anghel et al., 2018): for every request row, T trees each do a
depth-d heap descent and the T leaf values are summed. Evaluated naively
(one tree at a time, XLA scan like ``kernels.ref.apply_forest_ref``), the
per-tree prediction vector (N,) round-trips HBM T times and nothing of the
tree arrays is reused across samples.

The kernel evaluates a (sample_block, tree_block) tile per grid step with
everything resident in VMEM:

- tree arrays arrive pre-transposed as (n_int, T) / (n_leaf, T) so each
  descent level is two ``take_along_axis`` gathers over VMEM-resident
  blocks — ``feature[t, node]`` then ``bins[s, feature]``;
- the heap descent is unrolled over the static depth (node = 2*node + 1 +
  (bin > threshold)), so there is no per-level control flow;
- leaf values are masked by the live-tree count (partially-filled forests
  serve correctly even if dead slots hold stale trees) and reduced on-chip;
  only the (N,) partial sum is written back, accumulated across tree
  blocks — nothing of size (N, T) ever touches HBM.

Grid: (sample_blocks, tree_blocks); the tree axis is innermost and
accumulates into the same output block (the histogram kernel's reduce
pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _traverse_kernel(
    bins_ref,  # (S_blk, F) int32
    feat_ref,  # (n_int, T_blk) int32 — transposed tree arrays
    thr_ref,  # (n_int, T_blk) int32
    leaf_ref,  # (n_leaf, T_blk) f32
    ntree_ref,  # (1, 1) int32 in SMEM — live-slot count
    out_ref,  # (S_blk, n_outputs) f32 — accumulated over tree blocks
    *,
    depth: int,
    tree_block: int,
    n_outputs: int,
):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]
    feat = feat_ref[...]
    thr = thr_ref[...]
    s_blk = bins.shape[0]

    # Depth-unrolled heap descent, all (sample, tree) pairs at once.
    node = jnp.zeros((s_blk, tree_block), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, node, axis=0)  # (S, T) split features
        t = jnp.take_along_axis(thr, node, axis=0)  # (S, T) split bins
        v = jnp.take_along_axis(bins, f, axis=1)  # (S, T) sample bins
        node = 2 * node + 1 + (v > t).astype(jnp.int32)

    leaf = node - ((1 << depth) - 1)
    vals = jnp.take_along_axis(leaf_ref[...], leaf, axis=0)  # (S, T)
    tree_idx = tb * tree_block + jax.lax.broadcasted_iota(
        jnp.int32, vals.shape, 1
    )
    vals = jnp.where(tree_idx < ntree_ref[0, 0], vals, 0.0)
    if n_outputs == 1:
        out_ref[...] += jnp.sum(vals, axis=1, keepdims=True)
    else:
        # Slot t belongs to output t % K (round-major/output-minor forest
        # layout): K masked on-chip reductions into the (S, K) accumulator.
        out_k = tree_idx % n_outputs
        out_ref[...] += jnp.stack(
            [
                jnp.sum(jnp.where(out_k == k, vals, 0.0), axis=1)
                for k in range(n_outputs)
            ],
            axis=1,
        )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "sample_block", "tree_block", "interpret", "n_outputs"),
)
def forest_traverse_pallas(
    bins: jax.Array,  # (N, F) int32 — N % sample_block == 0 (wrapper pads)
    feature: jax.Array,  # (T, 2^d - 1) int32 — T % tree_block == 0
    threshold: jax.Array,  # (T, 2^d - 1) int32
    leaf_value: jax.Array,  # (T, 2^d) f32
    n_trees: jax.Array,  # () int32 — live slots; slots >= n_trees add 0
    depth: int,
    sample_block: int = 256,
    tree_block: int = 512,
    interpret: bool | None = None,
    n_outputs: int = 1,
) -> jax.Array:
    """Masked forest sum (N,) f32 — or (N, K) with ``n_outputs`` = K > 1,
    where slot t reduces into output column t % K. See module docstring.

    ``interpret=None`` auto-detects (Mosaic on TPU, interpreter elsewhere).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f = bins.shape
    t, n_int = feature.shape
    n_leaf = leaf_value.shape[1]
    assert n % sample_block == 0, "wrapper must pad samples"
    assert t % tree_block == 0, "wrapper must pad trees"
    ns, nt = n // sample_block, t // tree_block

    out = pl.pallas_call(
        functools.partial(
            _traverse_kernel,
            depth=depth,
            tree_block=tree_block,
            n_outputs=n_outputs,
        ),
        grid=(ns, nt),
        in_specs=[
            pl.BlockSpec((sample_block, f), lambda sb, tb: (sb, 0)),
            pl.BlockSpec((n_int, tree_block), lambda sb, tb: (0, tb)),
            pl.BlockSpec((n_int, tree_block), lambda sb, tb: (0, tb)),
            pl.BlockSpec((n_leaf, tree_block), lambda sb, tb: (0, tb)),
            pl.BlockSpec((1, 1), lambda sb, tb: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((sample_block, n_outputs), lambda sb, tb: (sb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_outputs), jnp.float32),
        interpret=interpret,
    )(
        bins,
        feature.T,
        threshold.T,
        leaf_value.T,
        jnp.asarray(n_trees, jnp.int32).reshape(1, 1),
    )
    return out[:, 0] if n_outputs == 1 else out
