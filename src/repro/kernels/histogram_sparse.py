"""Pallas TPU kernel: sparse (explicit-zero-bin) grad/hess histograms.

The dense histogram kernel contracts over ALL N samples for every feature
block — cost N * F * B regardless of how many entries actually carry
information. On the high-dimensional sparse datasets this paper's PS
setting targets (real-sim, E2006: F ≫ N * density), almost every
(sample, feature) cell sits in the feature's majority bin. This kernel
contracts only the STORED entries of ``trees.binning.SparseBins``'s
feature-major ELL layout — cost rows * C * B per feature with
C ≈ N * density — so histogram work scales with nnz, not N * F.

Formulation mirrors the dense kernel's one-hot MXU contraction, batched
over the feature lanes of a block:

    out[f, r, b] = sum_c GH[f, r, c] * onehot[f, c, b]

where entry c of feature f carries (sample's node, grad, hess, bin code),
pre-gathered into (F, C) operand arrays by the wrapper; GH masks each
entry's grad/hess onto the GH row whose node it sits on (``row_map``
operand — the same node-subset mechanism as the dense kernel, so the
subtraction builder's smaller-child build works unchanged); onehot marks
the entry's stored bin code. ELL pads carry node -1 and never match a row.

The result is the STORED-entry histogram only. The zero-bin complement —
every absent entry lands at ``zero_bin[f]`` — is a subtraction
(node_total - stored_row_sum) and therefore MUST run after the data-axis
psum (the subtract-after-psum invariant); ``kernels.ops.build_histogram``
owns that step, this kernel never sees ``zero_bin``.

Grid: (feature_blocks, entry_blocks); entry axis is innermost and
accumulates into the same output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_hist_kernel(
    enode_ref,  # (F_blk, C_blk) int32 — node id of each entry's sample, -1 pad
    egrad_ref,  # (F_blk, C_blk) f32
    ehess_ref,  # (F_blk, C_blk) f32
    ecode_ref,  # (F_blk, C_blk) int32 — stored bin code
    rowmap_ref,  # (rows, 1) int32 — node id each GH row selects
    out_ref,  # (F_blk, rows * B) f32
    *,
    n_bins: int,
):
    f_blk, c_blk = enode_ref.shape
    rows = rowmap_ref.shape[0]

    entry_axis = pl.program_id(1)

    @pl.when(entry_axis == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    e_node = enode_ref[...]  # (F, C)
    e_grad = egrad_ref[...]
    e_hess = ehess_ref[...]
    row_node = rowmap_ref[:, 0]  # (rows,)

    # GH: (F, rows, C). Row r selects entries on node row_map[r]; even rows
    # carry grad, odd rows hess. ELL pads (node -1) never match.
    row_is_h = jax.lax.broadcasted_iota(jnp.int32, (1, rows, 1), 1) % 2
    gh_val = jnp.where(row_is_h == 0, e_grad[:, None, :], e_hess[:, None, :])
    gh = jnp.where(e_node[:, None, :] == row_node[None, :, None], gh_val, 0.0)

    # One-hot over stored codes: (F, C, B).
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (f_blk, c_blk, n_bins), 2)
    onehot = (ecode_ref[...][..., None] == bin_iota).astype(jnp.float32)

    # Batched over the feature lanes: (F, rows, C) x (F, C, B) -> (F, rows, B).
    blk = jax.lax.dot_general(
        gh, onehot, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    out_ref[...] += blk.reshape(f_blk, rows * n_bins)


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "n_bins", "entry_block", "feature_block", "interpret"),
)
def histogram_sparse_pallas(
    feat_rows: jax.Array,  # (F, C) int32 sample ids, -1 = pad
    feat_codes: jax.Array,  # (F, C) int32 stored bin codes
    node_ids: jax.Array,  # (N,) int32, -1 = inactive
    grad: jax.Array,  # (N,) f32
    hess: jax.Array,  # (N,) f32
    n_nodes: int,
    n_bins: int,
    entry_block: int = 512,
    feature_block: int = 8,
    interpret: bool | None = None,
    active_nodes: jax.Array | None = None,  # (n_sub,) int32 node subset
) -> jax.Array:
    """Returns (2, R, F, n_bins) f32 STORED-entry histograms.

    ``R`` follows the dense kernel's contract: ``n_nodes`` rows for the
    full-level build, else one row per ``active_nodes`` entry. The caller
    (``kernels.ops``) adds the zero-bin complement after any data-axis
    psum. Operand padding (features to ``feature_block``, entries to
    ``entry_block``) happens here; pad entries carry node -1 and
    contribute exactly 0.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f, c = feat_rows.shape

    # Pre-gather per-entry node/grad/hess once — (F, C) operands so the
    # kernel never touches the (N,) sample arrays.
    valid = feat_rows >= 0
    safe = jnp.where(valid, feat_rows, 0)
    e_node = jnp.where(valid, jnp.take(node_ids, safe), -1).astype(jnp.int32)
    e_grad = jnp.take(grad, safe).astype(jnp.float32)
    e_hess = jnp.take(hess, safe).astype(jnp.float32)
    e_code = feat_codes.astype(jnp.int32)

    fp = -f % feature_block
    cp = -c % entry_block
    if fp or cp:
        pad = ((0, fp), (0, cp))
        e_node = jnp.pad(e_node, pad, constant_values=-1)
        e_grad = jnp.pad(e_grad, pad)
        e_hess = jnp.pad(e_hess, pad)
        e_code = jnp.pad(e_code, pad)
    fpad, cpad = f + fp, c + cp
    nf, nc = fpad // feature_block, cpad // entry_block

    if active_nodes is None:
        active_nodes = jnp.arange(n_nodes, dtype=jnp.int32)
    n_sub = active_nodes.shape[0]
    rows = 2 * n_sub
    row_map = jnp.repeat(active_nodes.astype(jnp.int32), 2)  # (rows,)

    out = pl.pallas_call(
        functools.partial(_sparse_hist_kernel, n_bins=n_bins),
        grid=(nf, nc),
        in_specs=[
            pl.BlockSpec((feature_block, entry_block), lambda fb, cb: (fb, cb)),
            pl.BlockSpec((feature_block, entry_block), lambda fb, cb: (fb, cb)),
            pl.BlockSpec((feature_block, entry_block), lambda fb, cb: (fb, cb)),
            pl.BlockSpec((feature_block, entry_block), lambda fb, cb: (fb, cb)),
            pl.BlockSpec((rows, 1), lambda fb, cb: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (feature_block, rows * n_bins), lambda fb, cb: (fb, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((fpad, rows * n_bins), jnp.float32),
        interpret=interpret,
    )(e_node, e_grad, e_hess, e_code, row_map[:, None])
    # (Fpad, rows*B) -> (rows, F, B) -> (gh, sub, F, B), dropping feature pad
    out = out[:f].reshape(f, rows, n_bins).transpose(1, 0, 2)
    return out.reshape(n_sub, 2, f, n_bins).transpose(1, 0, 2, 3)
