"""Jit'd public entry points for the kernels, with backend dispatch.

``backend='ref'`` runs the pure-jnp oracle (always available, and what a CPU
production deployment would use); ``'pallas'`` runs the TPU kernels. On this
CPU container Pallas executes via ``interpret=True``; on a real TPU the same
call sites compile to Mosaic. ``'auto'`` picks pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import collectives
from repro.kernels import ref as _ref
from repro.kernels.histogram import histogram_pallas
from repro.kernels.histogram_sparse import histogram_sparse_pallas
from repro.kernels.split_scan import split_gain_pallas

BACKENDS = ("auto", "ref", "pallas", "fused")


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def resolve_backend(backend: str, allow_fused: bool = False) -> str:
    """THE backend normalization — learner, ops, and the fused path share it.

    ``'auto'`` resolves to ``'pallas'`` on TPU and ``'ref'`` elsewhere.
    ``'fused'`` (the whole-level program) survives only where a caller can
    actually run it (``allow_fused=True``: the tree learner's level loop
    and ``level_build``); staged kernel entry points degrade it to
    ``'pallas'`` — the fused pipeline IS the pallas kernel family, so a
    staged call inside a fused build stays in the same numerics.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r} (want one of {BACKENDS})")
    if backend == "auto":
        return _default_backend()
    if backend == "fused" and not allow_fused:
        return "pallas"
    return backend


def _pad_to(x: jax.Array, multiple: int, axis: int, fill) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("n_samples",))
def _sparse_local_dense(
    feat_rows: jax.Array,  # (F, C) int32 sample ids, -1 pad
    feat_codes: jax.Array,  # (F, C) int32 stored codes
    zero_bin: jax.Array,  # (F,) int32
    n_samples: int,
) -> jax.Array:
    """Exact dense (N, F) int32 from the feature-major ELL store — the same
    integers as ``binning.to_dense`` (one stored entry per cell, integer
    scatter), but built from the shard-local feature-major view so it works
    on a feature shard where no row-major store exists."""
    f, _ = feat_rows.shape
    valid = feat_rows >= 0
    rows = jnp.where(valid, feat_rows, 0)
    cols = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[:, None], rows.shape)
    delta = jnp.where(valid, feat_codes - zero_bin[:, None], 0)
    base = jnp.broadcast_to(zero_bin[None, :], (n_samples, f)).astype(jnp.int32)
    return base.at[rows.reshape(-1), cols.reshape(-1)].add(delta.reshape(-1))


def _node_totals(
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    active_nodes: jax.Array,  # (n_sub,) int32
    n_nodes: int,
) -> jax.Array:
    """(2, n_sub) grad/hess mass per active node — the zero-bin complement's
    'what the stored entries are missing' term. Row-local (N work); under
    data sharding it psums alongside the stored histogram."""
    n_sub = active_nodes.shape[0]
    inv = jnp.full((n_nodes,), -1, jnp.int32)
    inv = inv.at[active_nodes].set(jnp.arange(n_sub, dtype=jnp.int32))
    row = jnp.where(node_ids >= 0, inv[jnp.clip(node_ids, 0, n_nodes - 1)], -1)
    active = row >= 0
    rowc = jnp.where(active, row, 0)
    tg = jax.ops.segment_sum(
        jnp.where(active, grad, 0.0), rowc, num_segments=n_sub
    )
    th = jax.ops.segment_sum(
        jnp.where(active, hess, 0.0), rowc, num_segments=n_sub
    )
    return jnp.stack([tg, th]).astype(jnp.float32)


def _zero_bin_complement(
    stored: jax.Array,  # (2, R, F, B) stored-entry histograms
    totals: jax.Array,  # (2, R) per-node grad/hess mass
    zero_bin: jax.Array,  # (F,) int32
) -> jax.Array:
    """Add each node's absent-entry mass at the feature's zero bin.

    ``missing = totals - sum_b stored`` is a SUBTRACTION: on a sharded
    build it must consume the psummed stored/totals, never shard-local
    partials (the subtract-after-psum invariant, now per feature shard —
    the determinism checker's taint pass walks exactly this seam).
    """
    row_sum = stored.sum(axis=-1)  # (2, R, F)
    missing = totals[:, :, None] - row_sum
    b_iota = jnp.arange(stored.shape[-1], dtype=jnp.int32)
    onehot = (zero_bin[:, None] == b_iota[None, :]).astype(stored.dtype)  # (F, B)
    return stored + missing[..., None] * onehot[None, None]


def build_histogram_sparse(
    feat_rows: jax.Array,  # (F_local, C) int32
    feat_codes: jax.Array,  # (F_local, C) int32
    zero_bin: jax.Array,  # (F_local,) int32 — SLICED to the local features
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    backend: str = "auto",
    entry_block: int = 512,
    feature_block: int = 8,
    axis_name: str | None = None,
    active_nodes: jax.Array | None = None,
) -> jax.Array:
    """(2, R, F_local, n_bins) histograms from the feature-major sparse store.

    The sparse twin of ``build_histogram``/``build_histogram_subset``:
    operands are the raw feature-major arrays (possibly one feature shard
    of them, with ``zero_bin`` sliced to match). ``backend='ref'``
    densifies the local store exactly and runs the dense oracle — bitwise
    identical to the dense path on the same features. The pallas path runs
    the nnz-scaling stored-entry kernel, psums stored counts AND node
    totals over ``axis_name`` first, and applies the zero-bin complement
    only after the collective (subtract-after-psum, per feature shard).
    """
    backend = resolve_backend(backend)
    n_samples = node_ids.shape[0]
    active = (
        jnp.arange(n_nodes, dtype=jnp.int32)
        if active_nodes is None
        else active_nodes.astype(jnp.int32)
    )
    if backend == "ref":
        dense = _sparse_local_dense(feat_rows, feat_codes, zero_bin, n_samples)
        if active_nodes is None:
            out = _ref.histogram_ref(dense, node_ids, grad, hess, n_nodes, n_bins)
        else:
            out = _ref.histogram_subset_ref(
                dense, node_ids, grad, hess, active, n_nodes, n_bins
            )
        if axis_name is not None:
            out = collectives.psum(out, axis_name)
        return out
    interpret = jax.default_backend() != "tpu"
    fb = min(feature_block, max(feat_rows.shape[0], 1))
    stored = histogram_sparse_pallas(
        feat_rows, feat_codes, node_ids, grad, hess, n_nodes, n_bins,
        entry_block=entry_block, feature_block=fb, interpret=interpret,
        active_nodes=None if active_nodes is None else active,
    )
    totals = _node_totals(node_ids, grad, hess, active, n_nodes)
    if axis_name is not None:
        stored = collectives.psum(stored, axis_name)
        totals = collectives.psum(totals, axis_name)
    return _zero_bin_complement(stored, totals, zero_bin)


def build_histogram(
    bins,
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    backend: str = "auto",
    sample_block: int = 512,
    feature_block: int = 8,
    axis_name: str | None = None,
) -> jax.Array:
    """(2, n_nodes, F, n_bins) grad/hess histograms. See kernels/histogram.py.

    ``bins`` may be the dense (N, F) int32 matrix or a
    ``trees.binning.SparseBins`` — the sparse layout dispatches to the
    nnz-scaling path (``build_histogram_sparse``); on ``backend='ref'``
    the two are bitwise identical.

    ``axis_name``: when running data-parallel under shard_map (samples
    sharded over a mesh axis), each shard builds its local histogram with
    the kernel and the results merge with a psum across the axis — every
    cell is a sum over disjoint sample subsets, so partial sums compose
    exactly (the parameter-server aggregation as an all-reduce).
    """
    from repro.trees.binning import SparseBins  # lazy: trees imports kernels

    if isinstance(bins, SparseBins):
        return build_histogram_sparse(
            bins.feat_rows, bins.feat_codes, bins.zero_bin,
            node_ids, grad, hess, n_nodes, n_bins, backend=backend,
            feature_block=feature_block, axis_name=axis_name,
        )
    backend = resolve_backend(backend)
    if backend == "ref":
        out = _ref.histogram_ref(bins, node_ids, grad, hess, n_nodes, n_bins)
    else:
        interpret = jax.default_backend() != "tpu"
        n_feat = bins.shape[1]
        fb = min(feature_block, n_feat)
        binsp = _pad_to(_pad_to(bins, sample_block, 0, 0), fb, 1, 0)
        nodep = _pad_to(node_ids, sample_block, 0, -1)  # padded samples inactive
        gradp = _pad_to(grad, sample_block, 0, 0.0)
        hessp = _pad_to(hess, sample_block, 0, 0.0)
        out = histogram_pallas(
            binsp, nodep, gradp, hessp, n_nodes, n_bins,
            sample_block=sample_block, feature_block=fb, interpret=interpret,
        )[:, :, :n_feat, :]
    if axis_name is not None:
        out = collectives.psum(out, axis_name)
    return out


def build_histogram_subset(
    bins,
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    active_nodes: jax.Array,  # (n_sub,) int32 node ids to build
    n_nodes: int,
    n_bins: int,
    backend: str = "auto",
    sample_block: int = 512,
    feature_block: int = 8,
    axis_name: str | None = None,
) -> jax.Array:
    """(2, n_sub, F, n_bins) histograms for the ``active_nodes`` subset only.

    The histogram-subtraction builder's entry point: at each level it
    histograms one child per parent and derives the sibling as
    ``parent - built``. Kernel work is linear in the GH row count
    (2 * n_sub vs 2 * n_nodes), so building half the nodes halves the MXU
    contraction per level.

    ``axis_name``: as in ``build_histogram`` — per-shard subset histograms
    merge with a psum across the data axis. The SUBTRACTION does not live
    here: it commutes with the psum (both are linear), and the learner
    subtracts after the collective so every shard derives the sibling from
    identical merged values and stays in lockstep.
    """
    from repro.trees.binning import SparseBins  # lazy: trees imports kernels

    if isinstance(bins, SparseBins):
        return build_histogram_sparse(
            bins.feat_rows, bins.feat_codes, bins.zero_bin,
            node_ids, grad, hess, n_nodes, n_bins, backend=backend,
            feature_block=feature_block, axis_name=axis_name,
            active_nodes=active_nodes.astype(jnp.int32),
        )
    backend = resolve_backend(backend)
    active_nodes = active_nodes.astype(jnp.int32)
    if backend == "ref":
        out = _ref.histogram_subset_ref(
            bins, node_ids, grad, hess, active_nodes, n_nodes, n_bins
        )
    else:
        interpret = jax.default_backend() != "tpu"
        n_feat = bins.shape[1]
        fb = min(feature_block, n_feat)
        binsp = _pad_to(_pad_to(bins, sample_block, 0, 0), fb, 1, 0)
        nodep = _pad_to(node_ids, sample_block, 0, -1)  # padded samples inactive
        gradp = _pad_to(grad, sample_block, 0, 0.0)
        hessp = _pad_to(hess, sample_block, 0, 0.0)
        out = histogram_pallas(
            binsp, nodep, gradp, hessp, n_nodes, n_bins,
            sample_block=sample_block, feature_block=fb, interpret=interpret,
            active_nodes=active_nodes,
        )[:, :, :n_feat, :]
    if axis_name is not None:
        out = collectives.psum(out, axis_name)
    return out


def split_gain(
    hist: jax.Array,
    lam,
    min_child_hess,
    backend: str = "auto",
    node_block: int = 8,
    feature_block: int = 8,
) -> jax.Array:
    """Gain surface (L, F, B), -inf where invalid."""
    backend = resolve_backend(backend)
    lam = jnp.asarray(lam, jnp.float32)
    minh = jnp.asarray(min_child_hess, jnp.float32)
    if backend == "ref":
        return _split_gain_surface_ref(hist, lam, minh)
    interpret = jax.default_backend() != "tpu"
    _, l, f, _ = hist.shape
    lb = min(node_block, l)
    fb = min(feature_block, f)
    histp = _pad_to(_pad_to(hist, lb, 1, 0.0), fb, 2, 0.0)
    out = split_gain_pallas(
        histp, lam, minh, node_block=lb, feature_block=fb, interpret=interpret
    )
    return out[:l, :f, :]


@jax.jit
def _split_gain_surface_ref(hist, lam, min_h):
    """Same surface as the kernel, via jnp (shared with split_scan_ref)."""
    g, h = hist[0], hist[1]
    gl = jnp.cumsum(g, axis=-1)
    hl = jnp.cumsum(h, axis=-1)
    gt, ht = gl[..., -1:], hl[..., -1:]
    gr, hr = gt - gl, ht - hl
    gain = gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam)
    valid = (hl >= min_h) & (hr >= min_h)
    valid = valid.at[..., -1].set(False)
    return jnp.where(valid, gain, -jnp.inf)


def best_split(
    hist: jax.Array, lam, min_child_hess, backend: str = "auto"
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(best_gain (L,), feature (L,), bin (L,)) — argmax over the gain surface."""
    gain = split_gain(hist, lam, min_child_hess, backend=backend)
    nb = gain.shape[-1]
    flat = gain.reshape(gain.shape[0], -1)
    idx = jnp.argmax(flat, axis=-1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
    return best, (idx // nb).astype(jnp.int32), (idx % nb).astype(jnp.int32)


def level_build(
    bins: jax.Array,  # (N, F) int32
    node_ids: jax.Array,  # (N,) int32 level-local node per sample
    grad: jax.Array,  # (N,) f32
    hess: jax.Array,  # (N,) f32
    active_nodes: jax.Array,  # (L_sub,) int32 nodes to histogram
    parent_hist: jax.Array | None,  # (2, L_sub, F, B) cache (subtract mode)
    feat_mask: jax.Array,  # (F,) bool/f32 — the tree's feature subsample
    lam,
    min_child_hess,
    n_nodes: int,
    n_bins: int,
    backend: str = "fused",
    derive_sibling: bool = False,
    sample_block: int | None = None,
    feature_block: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """ONE fused tree level: histogram -> (sibling derive) -> gain scan ->
    argmax -> partition, without staging any surface through HBM.

    Returns ``(hist (2, n_nodes, F, B), best_feature (n_nodes,), best_bin
    (n_nodes,), best_gain (n_nodes,), new_node (N,))`` — everything
    ``trees.learner.build_tree`` needs from a level: the histogram is the
    next level's subtraction cache, feat/thr are final (unsplittable nodes
    already fixed to pass-left), and ``new_node`` is the re-routed
    row -> node map. ``backend='ref'`` is the staged jnp oracle
    (``ref.level_build_ref``); ``'pallas'``/``'fused'`` run the fused
    kernel. Block shapes default to the persistent autotuner table
    (``kernels.autotune``) for the (N, F, B, L) geometry.
    """
    backend = resolve_backend(backend, allow_fused=True)
    if backend == "ref":
        return _ref.level_build_ref(
            bins, node_ids, grad, hess, active_nodes.astype(jnp.int32),
            parent_hist, feat_mask, jnp.asarray(lam, jnp.float32),
            jnp.asarray(min_child_hess, jnp.float32), n_nodes, n_bins,
            derive_sibling=derive_sibling,
        )
    from repro.kernels import autotune
    from repro.kernels.level_build import level_build_pallas

    n, n_feat = bins.shape
    if sample_block is None or feature_block is None:
        tuned = autotune.lookup(n, n_feat, n_bins, n_nodes)
        sample_block = sample_block or tuned["sample_block"]
        feature_block = feature_block or tuned["feature_block"]
    interpret = jax.default_backend() != "tpu"
    sb = min(sample_block, max(n, 1))
    fb = min(feature_block, n_feat)
    binsp = _pad_to(_pad_to(bins, sb, 0, 0), fb, 1, 0)
    nodep = _pad_to(node_ids, sb, 0, -1)  # padded samples inactive
    gradp = _pad_to(grad, sb, 0, 0.0)
    hessp = _pad_to(hess, sb, 0, 0.0)
    maskp = _pad_to(feat_mask.astype(jnp.float32), fb, 0, 0.0)
    parentp = None
    if derive_sibling:
        parentp = _pad_to(parent_hist, fb, 2, 0.0)
    hist, feat, thr, best, new_node = level_build_pallas(
        binsp, nodep, gradp, hessp, active_nodes.astype(jnp.int32), parentp,
        maskp, jnp.asarray(lam, jnp.float32),
        jnp.asarray(min_child_hess, jnp.float32), n_nodes, n_bins,
        derive_sibling=derive_sibling, sample_block=sb, feature_block=fb,
        interpret=interpret,
    )
    return hist[:, :, :n_feat, :], feat, thr, best, new_node[:n]


apply_forest = _ref.apply_forest_ref  # unmasked train-time form (zero-padded slots)


def forest_traverse(
    bins: jax.Array,
    feature: jax.Array,
    threshold: jax.Array,
    leaf_value: jax.Array,
    n_trees,
    depth: int,
    backend: str = "auto",
    sample_block: int = 256,
    tree_block: int = 512,
    n_outputs: int = 1,
    leaf_scale: jax.Array | None = None,
) -> jax.Array:
    """Masked forest sum (N,) f32 — the serving predict. See forest_traversal.py.

    Slots >= ``n_trees`` contribute exactly 0 regardless of their contents,
    so partially-filled and hot-swapped forests serve correctly. The ref
    backend is the O(N)-memory scan (production CPU form); the kernel's
    bitwise oracle is ``ref.forest_traverse_ref``. With ``n_outputs`` =
    K > 1 the result is (N, K): slot t reduces into output column t % K
    (padded tree slots are masked by ``n_trees``, so padding never leaks
    into any output column).

    Quantized layouts (``trees.forest.Forest.quantize``) pass int8/int16
    thresholds and int8/fp16 leaves — int8 with the per-tree ``leaf_scale``.
    Both backends dequantize with identical float ops, and scores stay
    within ``trees.forest.quantization_atol`` of the f32 forest's; with f32
    inputs the dequant converts are no-ops and the path is bitwise-unchanged.
    """
    backend = resolve_backend(backend)
    n_trees = jnp.asarray(n_trees, jnp.int32)
    if backend == "ref":
        return _ref.apply_forest_ref(
            bins, feature, threshold, leaf_value, depth, n_trees,
            n_outputs=n_outputs, leaf_scale=leaf_scale,
        )
    from repro.kernels.forest_traversal import forest_traverse_pallas

    interpret = jax.default_backend() != "tpu"
    n = bins.shape[0]
    t = feature.shape[0]
    sb = min(sample_block, max(n, 1))
    tb = min(tree_block, max(t, 1))
    binsp = _pad_to(bins, sb, 0, 0)
    featp = _pad_to(feature, tb, 0, 0)
    thrp = _pad_to(threshold, tb, 0, 0)
    leafp = _pad_to(leaf_value, tb, 0, 0 if leaf_value.dtype == jnp.int8 else 0.0)
    scalep = None if leaf_scale is None else _pad_to(leaf_scale, tb, 0, 1.0)
    out = forest_traverse_pallas(
        binsp, featp, thrp, leafp, n_trees, depth,
        sample_block=sb, tree_block=tb, interpret=interpret,
        n_outputs=n_outputs, leaf_scale=scalep,
    )
    return out[:n]


def _flash_call(qf, kf, vf, causal, group, block_q, block_k):
    """Pad to blocks, run the forward kernel, return (out, lse) unpadded."""
    from repro.kernels.flash_attention import flash_attention_pallas

    sq, sk = qf.shape[1], kf.shape[1]
    interpret = jax.default_backend() != "tpu"
    bq, bk = min(block_q, sq), min(block_k, sk)
    qp = _pad_to(qf, bq, 1, 0.0)
    kp = _pad_to(kf, bk, 1, 0.0)
    vp = _pad_to(vf, bk, 1, 0.0)
    out, lse = flash_attention_pallas(
        qp, kp, vp, causal=causal, block_q=bq, block_k=bk,
        group=group, interpret=interpret, seq_k=sk,
    )
    return out[:, :sq], lse[:, :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_fwd_only(qf, kf, vf, causal, group, block_q, block_k):
    out, _ = _flash_call(qf, kf, vf, causal, group, block_q, block_k)
    return out


def _flash_vjp_fwd(qf, kf, vf, causal, group, block_q, block_k):
    out, lse = _flash_call(qf, kf, vf, causal, group, block_q, block_k)
    return out, (qf, kf, vf, out, lse)


def _flash_vjp_bwd(causal, group, block_q, block_k, res, g):
    """Fused Pallas backward (dq / dk+dv kernels) — recomputes P tiles from
    (q, k, lse); nothing quadratic ever hits HBM in either direction."""
    from repro.kernels.flash_attention import flash_attention_bwd_pallas

    qf, kf, vf, out, lse = res
    sq, sk = qf.shape[1], kf.shape[1]
    interpret = jax.default_backend() != "tpu"
    bq, bk = min(block_q, sq), min(block_k, sk)
    qp = _pad_to(qf, bq, 1, 0.0)
    kp = _pad_to(kf, bk, 1, 0.0)
    vp = _pad_to(vf, bk, 1, 0.0)
    op = _pad_to(out, bq, 1, 0.0)
    gp = _pad_to(g, bq, 1, 0.0)
    lp = _pad_to(lse, bq, 1, 0.0)
    dq, dk, dv = flash_attention_bwd_pallas(
        qp, kp, vp, op, lp, gp,
        causal=causal, block_q=bq, block_k=bk, group=group,
        interpret=interpret, seq_k=sk, seq_q=sq,
    )
    return dq[:, :sq], dk[:, :sk], dv[:, :sk]


_flash_fwd_only.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    backend: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Fused attention entry point (model-layout in/out). Pads Sq/Sk to the
    block sizes and flattens (B, H) into the kernel's head-grid axis.
    Differentiable: forward is the Pallas kernel (O(S) memory), backward
    recomputes through the jnp oracle (see _flash_vjp_bwd)."""
    backend = resolve_backend(backend)
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    group = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    if backend == "ref":
        out = _ref.flash_attention_ref(qf, kf, vf, causal=causal, group=group)
    else:
        out = _flash_fwd_only(qf, kf, vf, causal, group, block_q, block_k)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
