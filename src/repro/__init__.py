"""Package-wide jax configuration.

``threefry_partitionable`` pins the SHARD-INVARIANT counter-based PRNG
implementation: the bits a seeded draw produces no longer depend on how
XLA's SPMD partitioner happens to shard the surrounding program. Without
it, ``jax.random`` values inside a jitted training step can differ
between device meshes (e.g. a 2-device 1D mesh vs an 8-device 2D mesh
partition the same binomial draw differently), which would break the 2D
block-distributed parity contract: same seed => same forest on a 1D
'data' mesh and the (data x feature) mesh (DESIGN.md §16). The golden
corpus under ``tests/golden/`` is generated under this flag.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
