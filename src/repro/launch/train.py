"""Training driver — runs any assigned architecture end-to-end on the local
device (reduced configs) or a production mesh (full configs on real pods).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 200 --batch 8 --seq 128 [--delay 4] [--sample 0.8]

``--delay`` wraps the optimizer in the paper's DelayedGradient staleness
mechanism; ``--sample`` draws Bernoulli importance weights per batch — the
two halves of asynch-SGBDT applied to NN training.

``--arch gbdt`` instead drives the paper's own model through the
parameter-server engine (``repro.ps``):

    PYTHONPATH=src python -m repro.launch.train --arch gbdt \
        --steps 200 --workers 16 [--sample 0.8] [--scan] \
        [--objective logistic|mse|quantile:0.9|huber|multiclass:5|lambdarank]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.sharding as sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adamw, cosine_schedule, delayed_gradient, staleness_step_scale


def synthetic_batches(cfg, batch: int, seq: int, steps: int, seed: int = 0):
    """Markov-chain token stream: learnable (non-uniform) bigram structure."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    # sparse row-stochastic transition matrix with strong modes
    nxt = rng.integers(0, v, size=(v, 4))
    for i in range(steps):
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, v, size=batch)
        choice = rng.integers(0, 4, size=(batch, seq))
        mix = rng.random((batch, seq)) < 0.1  # 10% noise
        noise = rng.integers(0, v, size=(batch, seq))
        for t in range(seq):
            step_tok = nxt[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(mix[:, t], noise[:, t], step_tok)
        batch_d = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.family in ("vlm", "audio"):
            batch_d["media"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_media_tokens, cfg.d_model)) * 0.02,
                jnp.dtype(cfg.dtype),
            )
        yield batch_d


def gbdt_dataset_for(objective, seed: int, n: int = 4_000):
    """Objective-matched synthetic workload (see data.synthetic).

    The single objective -> workload dispatch, shared by this driver and
    the benchmarks (``benchmarks.fig10_speedup --objective``).
    """
    import repro.data as D
    from repro.objectives import get_objective

    obj = get_objective(objective)
    if obj.name == "lambdarank":
        return obj, D.make_ranking(max(n // 16, 16), 16, 40, seed=seed)
    if obj.n_outputs > 1:
        return obj, D.make_multiclass_classification(n, 60, obj.n_outputs, seed=seed)
    if obj.name in ("mse", "quantile", "huber"):
        return obj, D.make_sparse_regression(n, 1_000, 20, seed=seed)
    return obj, D.make_sparse_classification(n, 1_000, 20, seed=seed)


def run_gbdt(args) -> None:
    """Asynch-SGBDT on the PS engine: round-robin W workers, loop or scan.

    ``--objective`` selects the training objective (and a matched synthetic
    workload): ``logistic`` (default), ``mse``, ``quantile[:a]``,
    ``huber``, ``multiclass:K``, ``lambdarank``.

    ``--runtime threads`` swaps the simulated delay schedule for the REAL
    host-async runtime (``repro.ps.runtime``): W worker threads race the
    server fold loop, the realized k(j) is recorded, and (with
    ``--verify-replay``) the trace is replayed through the deterministic
    engine and checked bit-for-bit against the threaded forest.
    ``--trace-out FILE`` dumps the RunTrace JSON.
    """
    from repro.core.sgbdt import SGBDTConfig, train_loss, train_metrics
    from repro.ps import Trainer
    from repro.trees.learner import LearnerConfig

    obj, data = gbdt_dataset_for(args.objective, args.seed)
    if args.sparse:
        from repro.trees import binning

        data = data._replace(bins=binning.to_sparse(data.bins))
        print(f"sparse bins: {data.bins.max_nnz_row} nnz/row ELL "
              f"(dense round-trip exact)")
    cfg = SGBDTConfig(
        n_trees=args.steps,
        step_length=0.15,
        sampling_rate=args.sample or 0.8,
        objective=args.objective,
        learner=LearnerConfig(
            depth=6, n_bins=64, feature_fraction=0.8, hist_mode=args.hist_mode,
            backend=args.backend,
        ),
    )
    if args.runtime == "threads":
        if args.mesh != "none":
            raise SystemExit(
                "--mesh applies to the simulated PS engine; the threaded "
                "runtime builds on the local device"
            )
        return run_gbdt_threads(args, cfg, data, obj)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_gbdt_mesh

        shape = args.mesh_shape or ("2" if args.mesh == "1d" else "1x2")
        if args.mesh == "1d":
            pd, pf = int(shape.partition("x")[0]), 1
            mesh = jax.make_mesh((pd,), ("data",))
        else:
            pd, _, pf = shape.partition("x")
            pd, pf = int(pd), int(pf or 1)
            mesh = make_gbdt_mesh(pd, pf)
        print(f"mesh: {args.mesh} {dict(mesh.shape)} "
              f"({len(mesh.devices.ravel())} devices)")
    trainer = Trainer(cfg, mesh=mesh)
    cb = trainer.collective_bytes(data)
    if cb is not None:
        # One tree build per round: the realized (wire) bytes of every
        # collective in the sharded build, by primitive kind.
        kinds = ", ".join(
            f"{k}={v:,}B" for k, v in sorted(cb["realized_by_kind"].items())
        )
        print(f"collective bytes/round: {cb['realized_bytes']:,}B "
              f"realized ({kinds})")
    schedule = ("round_robin", args.workers)
    print(f"gbdt[{obj.name}, K={obj.n_outputs}]: {args.steps} rounds, "
          f"{args.workers} PS workers ({'scan' if args.scan else 'loop'} form)")
    t0 = time.time()
    if args.scan:
        state, losses = trainer.train_scan(data, schedule, seed=args.seed)
        print(f"loss {float(losses[0]):.4f} -> {float(losses[-1]):.4f}")
    else:
        def on_eval(st, j):
            print(f"  round {j:4d}: train loss "
                  f"{float(train_loss(cfg, data, st)):.4f}")

        state = trainer.train(
            data, schedule, seed=args.seed,
            eval_every=max(args.log_every, 1) * 5, eval_fn=on_eval,
        )
        metrics = {k: f"{float(v):.4f}"
                   for k, v in train_metrics(cfg, data, state).items()}
        print(f"final {metrics}")
    print(f"trained in {time.time() - t0:.1f}s")
    assert np.isfinite(float(train_loss(cfg, data, state))), "training diverged"


def run_gbdt_threads(args, cfg, data, obj) -> None:
    """The real host-async PS runtime: threads, recorded k(j), elastic
    membership faults, sharded pulls, checkpoints, and bitwise
    replay/resume verification."""
    from repro.core.sgbdt import train_loss
    from repro.ps import AsyncRuntime, FaultPlan, RunTrace

    join_at = {}
    for spec in args.join or ():
        w, _, at = spec.partition(":")
        join_at[int(w)] = int(at)
    faults = FaultPlan(
        crash_tickets=frozenset(args.crash_ticket or ()),
        leave_tickets=frozenset(args.leave_ticket or ()),
        join_at=join_at,
    )
    if args.adaptive_step:
        cfg = cfg._replace(adaptive_step=args.adaptive_step)
    rt = AsyncRuntime(
        cfg, data, n_workers=args.workers,
        faults=faults, shard_pulls=args.shard_pulls,
    )
    print(f"gbdt[{obj.name}, K={obj.n_outputs}]: {cfg.n_trees} rounds, "
          f"{args.workers} REAL worker threads (host-async runtime)")
    run_kw = dict(
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        halt_at_fold=args.halt_at_fold,
        trace_path=args.trace_out,
    )
    if args.checkpoint_every and not args.checkpoint_dir:
        raise SystemExit("--checkpoint-every needs --checkpoint-dir")
    if args.resume_from:
        if not args.checkpoint_dir:
            raise SystemExit("--resume-from needs --checkpoint-dir")
        prefix = RunTrace.load(args.resume_from)
        print(f"resuming from trace prefix {args.resume_from} "
              f"({prefix.n_trees}/{cfg.n_trees} folds) + checkpoints under "
              f"{args.checkpoint_dir}")
        state, trace = rt.resume(prefix, args.checkpoint_dir, **{
            k: v for k, v in run_kw.items() if k != "checkpoint_dir"
        })
    else:
        state, trace = rt.run(seed=args.seed, **run_kw)
    s = trace.summary()
    print(f"makespan {s['makespan_s']:.2f}s  "
          f"staleness mean {s['mean_staleness']:.2f} max {s['max_staleness']}  "
          f"build {s['t_build_mean_s']*1e3:.1f}ms "
          f"queue {s['t_queue_mean_s']*1e3:.1f}ms "
          f"fold {s['t_fold_mean_s']*1e3:.1f}ms")
    print(f"staleness histogram: {trace.staleness_histogram()}")
    if trace.events:
        print(f"membership events ({trace.n_epochs} epochs):")
        for e in trace.events:
            print(f"  fold {e['fold']:4d}: {e['kind']} worker {e['worker']}"
                  + (f" (ticket {e['ticket']})" if e["ticket"] >= 0 else ""))
    if trace.n_parts:
        print(f"sharded pulls (P={trace.n_parts}): "
              f"{s['pull_bytes_mean']:.0f} B/pull vs {s['pull_bytes_full']} B "
              f"full ({100 * s['pull_reduction']:.1f}% reduction)")
    if trace.adaptive_rho:
        print(f"adaptive step (rho={trace.adaptive_rho}): mean scale "
              f"{s['step_scale_mean']:.4f}")
    loss = float(train_loss(cfg, data, state))
    print(f"final train loss {loss:.4f}")
    assert np.isfinite(loss), "training diverged"
    if args.trace_out:
        path = trace.save(args.trace_out)
        print(f"trace -> {path}")
    if args.halt_at_fold is not None:
        print(f"halted at fold {args.halt_at_fold} (simulated crash); "
              f"resume with --resume-from {args.trace_out or '<trace>'}")
        if args.verify_replay:
            raise SystemExit(
                "--verify-replay needs a complete run; a halted prefix "
                "replays only via --resume-from or --verify-resume"
            )
    if args.verify_resume:
        if not args.checkpoint_dir:
            raise SystemExit("--verify-resume needs --checkpoint-dir")
        st_ckpt = rt.replay_from_checkpoint(args.checkpoint_dir, trace)
        identical = (
            np.array_equal(np.asarray(state.f), np.asarray(st_ckpt.f))
            and np.array_equal(
                np.asarray(state.forest.leaf_value),
                np.asarray(st_ckpt.forest.leaf_value),
            )
        )
        print(f"checkpoint + trace-suffix replay identical: {identical}")
        assert identical, "crash-resume replay drifted from the live run"
    if args.verify_replay and args.halt_at_fold is None:
        st_replay, _ = rt.replay(trace)
        identical = (
            np.array_equal(np.asarray(state.f), np.asarray(st_replay.f))
            and np.array_equal(
                np.asarray(state.forest.leaf_value),
                np.asarray(st_replay.forest.leaf_value),
            )
            and np.array_equal(
                np.asarray(state.forest.feature),
                np.asarray(st_replay.forest.feature),
            )
            and np.array_equal(
                np.asarray(state.forest.threshold),
                np.asarray(st_replay.forest.threshold),
            )
        )
        print(f"record-and-replay identical forest: {identical}")
        assert identical, "replay drifted from the threaded run"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--delay", type=int, default=0,
                    help="gradient staleness tau (DelayedGradient wrapper)")
    ap.add_argument("--rho", type=float, default=0.3,
                    help="overlap probability for the Prop.-1 step scaling")
    ap.add_argument("--sample", type=float, default=0.0,
                    help="Bernoulli sampling rate for importance-weighted batches")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=8,
                    help="parameter-server worker count (--arch gbdt)")
    ap.add_argument("--scan", action="store_true",
                    help="run the GBDT trainer in its lax.scan form")
    ap.add_argument("--runtime", choices=("simulated", "threads"),
                    default="simulated",
                    help="PS execution: 'simulated' replays a delay "
                         "schedule; 'threads' runs real worker threads and "
                         "records the realized k(j) (--arch gbdt)")
    ap.add_argument("--trace-out", default=None,
                    help="write the realized RunTrace JSON here "
                         "(--runtime threads)")
    ap.add_argument("--verify-replay", action="store_true",
                    help="replay the recorded trace through the "
                         "deterministic engine and assert the forests are "
                         "bit-identical (--runtime threads)")
    ap.add_argument("--crash-ticket", type=int, action="append",
                    help="crash the worker that first draws this build "
                         "ticket (repeatable; the ticket is re-issued)")
    ap.add_argument("--leave-ticket", type=int, action="append",
                    help="worker gracefully leaves after building this "
                         "ticket (repeatable)")
    ap.add_argument("--join", action="append", metavar="W:J",
                    help="worker W (re)joins when the server reaches fold "
                         "count J (repeatable)")
    ap.add_argument("--shard-pulls", type=int, default=0, metavar="P",
                    help="shard the server leaf table into P partitions; "
                         "workers pull only partitions their sample "
                         "touches (rowwise objectives only)")
    ap.add_argument("--adaptive-step", type=float, default=0.0,
                    metavar="RHO",
                    help="staleness-adaptive server fold: scale each fold "
                         "by 1/(1 + 6*RHO*tau) with tau the observed "
                         "staleness")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="runtime checkpoint directory (--runtime threads)")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="checkpoint the server + in-flight versions every "
                         "K folds")
    ap.add_argument("--halt-at-fold", type=int, default=None, metavar="J",
                    help="simulate a whole-process crash: stop the server "
                         "after J folds and write the prefix trace")
    ap.add_argument("--resume-from", default=None, metavar="TRACE",
                    help="resume a halted run from its prefix trace JSON + "
                         "--checkpoint-dir; unfolded tickets are re-issued")
    ap.add_argument("--verify-resume", action="store_true",
                    help="after the run, rebuild the final state from the "
                         "newest checkpoint + trace suffix and assert it "
                         "matches bitwise")
    ap.add_argument("--hist-mode", choices=("subtract", "rebuild"),
                    default="subtract", dest="hist_mode",
                    help="GBDT level-histogram strategy: 'subtract' derives "
                         "each split's sibling from the cached parent "
                         "histogram (~half the kernel work); 'rebuild' "
                         "re-histograms every node (exact reference mode)")
    ap.add_argument("--backend", choices=("auto", "ref", "pallas", "fused"),
                    default="auto",
                    help="GBDT kernel backend: 'fused' runs one Pallas "
                         "program per tree level (histogram+scan+partition "
                         "without HBM staging); 'pallas' is the staged "
                         "kernel pipeline; 'ref' the jnp oracles; 'auto' "
                         "picks pallas on TPU, ref elsewhere")
    ap.add_argument("--mesh", choices=("none", "1d", "2d"), default="none",
                    help="GBDT build sharding: '1d' shards samples over a "
                         "('data',) mesh (psum-merged histograms); '2d' the "
                         "block-distributed (data x feature) mesh with the "
                         "argmax-merge split search (DESIGN.md §16). Needs "
                         "enough devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--mesh-shape", default=None, metavar="PDxPF",
                    help="mesh shape, e.g. '4' (--mesh 1d) or '2x2' / '1x4' "
                         "(--mesh 2d; sparse bins need Pd=1)")
    ap.add_argument("--sparse", action="store_true",
                    help="convert the binned dataset to the SparseBins "
                         "explicit-zero-bin layout (exact round-trip; "
                         "histogram cost scales with nnz, and feature-"
                         "sharded builds move only the argmax merge)")
    ap.add_argument("--objective", default="logistic",
                    help="GBDT objective registry spec: logistic | mse | "
                         "quantile[:a] | huber | multiclass:K | lambdarank")
    args = ap.parse_args()

    if args.arch == "gbdt":
        return run_gbdt(args)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    baxes = sharding.batch_axes(mesh)

    lr = args.lr
    if args.delay:
        lr *= staleness_step_scale(args.delay, args.rho)
        print(f"delay={args.delay}: scaling lr by Prop. 1 -> {lr:.2e}")
    opt = adamw(
        cosine_schedule(lr, max(args.steps // 20, 1), args.steps),
        weight_decay=0.01, max_grad_norm=1.0,
    )
    if args.delay:
        opt = delayed_gradient(opt, args.delay)

    step_fn = jax.jit(make_train_step(
        cfg, opt, mesh, baxes, accum=args.accum, sampling_rate=args.sample
    ))

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = opt.init(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.2f}M params, family={cfg.family}")

    t0 = time.time()
    losses = []
    for i, batch in enumerate(
        synthetic_batches(cfg, args.batch, args.seq, args.steps, args.seed)
    ):
        key, sub = jax.random.split(key)
        params, opt_state, metrics = step_fn(params, opt_state, batch, sub)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            rate = args.batch * args.seq * args.log_every / (time.time() - t0)
            print(f"step {i+1:5d} loss={losses[-1]:.4f} tok/s={rate:,.0f}")
            t0 = time.time()
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert np.isfinite(losses[-1]), "training diverged"


if __name__ == "__main__":
    main()
