"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init)."""
from __future__ import annotations

import jax


def _mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across versions: ``axis_types`` (and AxisType itself)
    only exist on newer jax; Auto is the default there anyway."""
    if hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over the local device — smoke tests / CPU runs."""
    return _mesh((1, 1), ("data", "model"))


def make_gbdt_mesh(n_data: int = 1, n_feature: int = 1) -> jax.sharding.Mesh:
    """The block-distributed GBDT training mesh: rows × feature columns.

    ``(n_data, 1)`` is the classic 1D data-parallel shape re-expressed in
    2D; ``(1, n_feature)`` is the sparse/high-dimensional regime where the
    full-histogram psum disappears in favor of the (L,)-sized argmax merge
    (DESIGN.md §16). Requires ``n_data * n_feature`` visible devices.
    """
    return _mesh((n_data, n_feature), ("data", "feature"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
