"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over the local device — smoke tests / CPU runs."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
