"""Step builders: the jit-able train / prefill / decode programs.

``make_train_step`` builds the full production step — microbatched gradient
accumulation (f32 accumulators), optional Bernoulli importance weights (the
paper's sampled objective), optimizer update — as one pure function of
(params, opt_state, batch, rng).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward_train, prefill
from repro.optim.optimizers import Optimizer, apply_updates

PyTree = Any


def make_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    mesh=None,
    batch_axes: tuple[str, ...] = ("data",),
    accum: int = 1,
    sampling_rate: float = 0.0,  # > 0: draw Bernoulli weights per microbatch
    grad_specs=None,  # PartitionSpec pytree for the f32 grad
                                  # accumulator (pin to the param specs so
                                  # per-microbatch grad sync lowers to
                                  # reduce-scatter, not all-reduce — §Perf)
) -> Callable:
    """Returns train_step(params, opt_state, batch, rng) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, mb):
        return forward_train(params, cfg, mb, mesh, batch_axes)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if mesh is not None and grad_specs is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        _gshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), grad_specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )

        def pin(g):
            return jax.tree.map(jax.lax.with_sharding_constraint, g, _gshard)
    else:
        def pin(g):
            return g

    def add_weights(mb, rng):
        if sampling_rate <= 0.0:
            return mb
        b = mb["tokens"].shape[0]
        keep = jax.random.bernoulli(rng, sampling_rate, (b,))
        # importance weights Q_i / R_i — unbiased for the unweighted mean
        mb = dict(mb)
        mb["weights"] = keep.astype(jnp.float32) / sampling_rate
        return mb

    def train_step(params, opt_state, batch, rng):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, add_weights(batch, rng))
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            mbs = {k: split(v) for k, v in batch.items()}
            rngs = jax.random.split(rng, accum)
            g0 = pin(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )

            def body(carry, xs):
                gacc, lacc, aacc = carry
                mb, r = xs
                (l, m), g = grad_fn(params, add_weights(mb, r))
                gacc = pin(jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gacc, g
                ))
                return (gacc, lacc + m["ce"], aacc + m["aux"]), None

            (grads, ce, aux), _ = jax.lax.scan(
                body, (g0, jnp.float32(0.0), jnp.float32(0.0)), (mbs, rngs)
            )
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = ce / accum
            metrics = {"ce": ce / accum, "aux": aux / accum}

        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(
    cfg: ModelConfig,
    mesh=None,
    batch_axes: tuple[str, ...] = ("data",),
    max_len: int | None = None,
) -> Callable:
    """prefill_step(params, batch) -> (next_token (B,), logits, cache)."""

    def prefill_step(params, batch):
        logits, cache = prefill(
            params, cfg, batch, mesh, batch_axes, max_len=max_len
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache

    return prefill_step


def make_decode_step(
    cfg: ModelConfig,
    mesh=None,
    batch_axes: tuple[str, ...] = ("data",),
) -> Callable:
    """serve_step(params, tokens (B,1), cache) -> (next_token (B,), cache').

    The MoE body runs with batch_axes=() at decode time: replicating the
    handful of decode tokens over 'data' (KBs) is far cheaper than
    gathering the expert weights over 'data' (GBs) every token — see the
    2D expert sharding note in ``moe_ffn``.
    """

    def serve_step(params, tokens, cache):
        logits, cache = decode_step(params, cfg, tokens, cache, mesh, ())
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
