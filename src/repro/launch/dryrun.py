import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) and emit
the roofline inputs.

The two lines above MUST run before any jax import — jax locks the device
count at first init. Everything below is ordinary code.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun

Artifacts: one JSON per combination with memory_analysis, cost_analysis,
loop-aware HLO stats (dot flops / HBM proxy / collective bytes per kind),
and the analytic MODEL_FLOPS for the utilization ratio.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
import repro.sharding as sharding
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (
    SHAPES,
    ShapeSpec,
    batch_inputs,
    decode_inputs,
    shape_skip_reason,
)
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import abstract_params
from repro.models.config import ModelConfig
from repro.optim import adamw, cosine_schedule

# Per-arch gradient-accumulation defaults: keeps per-device activation
# memory bounded at train_4k's 1M-token global batch. The big-d_model archs
# need microbatch 16 (one sequence per data shard).
TRAIN_ACCUM = 8
TRAIN_ACCUM_BY_ARCH = {
    "llama-3.2-vision-90b": 16,
    "dbrx-132b": 16,
}


def _shardings(mesh, specs):
    return sharding.tree_shardings(mesh, specs)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N*D forward-only, N = active."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_tok = 6 * n if shape.kind == "train" else 2 * n
    return float(per_tok) * tokens


def lower_one(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    accum: int = TRAIN_ACCUM,
    donate: bool = True,
):
    """Build + lower the right step for (cfg, shape) on ``mesh``.
    Returns (lowered, meta) — compile is the caller's business."""
    baxes = sharding.batch_axes(mesh)
    pspecs = sharding.param_specs(cfg, mesh)
    pshard = _shardings(mesh, pspecs)
    params_abs = abstract_params(cfg)

    if shape.kind == "train":
        # microbatch must stay divisible by the data-parallel degree
        dp = 1
        for a in baxes:
            dp *= dict(mesh.shape)[a]
        accum = min(accum, max(1, shape.global_batch // dp))
        opt = adamw(
            cosine_schedule(3e-4, 100, 10_000), weight_decay=0.1, max_grad_norm=1.0
        )
        ostate_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = sharding.optimizer_state_specs(ostate_abs, pspecs)
        oshard = _shardings(mesh, ospecs)
        batch_abs = batch_inputs(cfg, shape)
        bshard = _shardings(
            mesh, sharding.data_specs(cfg, mesh, shape.global_batch)
        )
        # weights spec: replicated-over-model, batch over data axes
        bshard["weights"] = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                sharding.divisible_batch_axes(mesh, shape.global_batch)
                or None
            )
        )
        rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
        rshard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        step = make_train_step(
            cfg, opt, mesh, baxes, accum=accum, sampling_rate=0.8,
            grad_specs=pspecs,
        )
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard, rshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = fn.lower(params_abs, ostate_abs, batch_abs, rng_abs)
    elif shape.kind == "prefill":
        batch_abs = batch_inputs(cfg, shape)
        bshard = _shardings(
            mesh, sharding.data_specs(cfg, mesh, shape.global_batch)
        )
        bshard.pop("labels", None)
        cspecs = sharding.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        cshard = _shardings(mesh, cspecs)
        step = make_prefill_step(cfg, mesh, baxes)
        fn = jax.jit(
            step,
            in_shardings=(pshard, bshard),
            out_shardings=(None, None, cshard),
        )
        lowered = fn.lower(params_abs, batch_abs)
    else:  # decode
        # Serving placement: pure TP (+2D ff), params replicated over the
        # batch axes — drops the per-token FSDP all-gather (§Perf).
        pspecs = sharding.param_specs(
            cfg, mesh, rules=sharding.serving_rules()
        )
        pshard = _shardings(mesh, pspecs)
        tok_abs, cache_abs = decode_inputs(cfg, shape)
        cspecs = sharding.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        cshard = _shardings(mesh, cspecs)
        tshard = _shardings(
            mesh,
            jax.sharding.PartitionSpec(
                sharding.divisible_batch_axes(mesh, shape.global_batch)
                or None
            ),
        )
        step = make_decode_step(cfg, mesh, baxes)
        fn = jax.jit(
            step,
            in_shardings=(pshard, tshard, cshard),
            out_shardings=(None, cshard),
            donate_argnums=(2,) if donate else (),
        )
        lowered = fn.lower(params_abs, tok_abs["tokens"], cache_abs)
    return lowered


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
            accum: int | None = None, save_hlo: bool = False) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if accum is None:
        accum = TRAIN_ACCUM_BY_ARCH.get(arch, TRAIN_ACCUM)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "accum": accum if shape.kind == "train" else None,
    }
    skip = shape_skip_reason(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return _save(rec, out_dir)
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.time()
        lowered = lower_one(cfg, shape, mesh, accum=accum)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo_txt = compiled.as_text()
        stats = hlo_analysis.analyze_hlo(hlo_txt)
        if save_hlo:
            (out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt").write_text(
                hlo_txt
            )
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            n_devices=mesh.devices.size,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            cost_analysis={
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
            },
            hlo=stats.to_dict(),
            model_flops=model_flops(cfg, SHAPES[shape_name]),
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
        )
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the signal
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return _save(rec, out_dir)


def _save(rec: dict, out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = list(configs.ALIASES) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind, out_dir,
                              accum=args.accum, save_hlo=args.save_hlo)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    m = rec["memory"]
                    gb = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
                    extra = (f"args+temp={gb:.2f} GiB/dev "
                             f"compile={rec['compile_s']}s "
                             f"coll={rec['hlo']['total_collective_bytes']/1e9:.2f}GB")
                elif tag == "error":
                    extra = rec["error"][:160]
                elif tag == "skipped":
                    extra = rec["reason"][:80]
                print(f"[{tag:7s}] {arch:24s} {shape:12s} {mesh_kind:6s} {extra}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
