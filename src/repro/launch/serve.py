"""Serving driver — batched prefill + greedy decode against the ring cache.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --reduced --batch 4 --prompt-len 64 --gen 32

``--arch gbdt`` instead serves the paper's own model: train an
asynch-SGBDT forest on the PS engine, checkpoint it mid-run and at the
end, then answer batched raw-float prediction requests through the
``ForestServer`` (serve-time binning + fused traversal), hot-swapping to
the newest checkpoint between waves:

    PYTHONPATH=src python -m repro.launch.serve --arch gbdt \
        --trees 60 --requests 12 [--rows 64] [--workers 8] \
        [--objective logistic|multiclass:3|...]

``--engine continuous`` serves the same traffic through the
continuous-batching ``ForestEngine`` instead: the mid-training and final
checkpoints load as two named versions, traffic A/B-splits between them
by uid hash, and per-request p50/p99 queue+compute latency is reported
against ``--slo-ms``. ``--quantize int8|fp16`` packs the served forests
(both engines) with the documented score-error bound.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.sharding as sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params


def run_gbdt(args) -> None:
    """Train -> checkpoint -> serve handoff, with a live hot swap.

    ``--objective`` picks the training objective; the server applies its
    ``link`` inside the jitted predict, so multiclass serves (rows, K)
    softmax probabilities and logistic serves p(y=1).
    """
    from repro.checkpoint import CheckpointManager
    from repro.core.sgbdt import SGBDTConfig
    from repro.objectives import get_objective
    from repro.ps import Trainer
    from repro.serving import (
        ForestEngine,
        ForestServer,
        PredictRequest,
        load_forest_checkpoint,
        percentile_latencies,
    )
    from repro.trees.binning import bin_dataset
    from repro.trees.learner import LearnerConfig

    obj = get_objective(args.objective)
    rng = np.random.default_rng(args.seed)
    n, dim = 2_000, 40
    if obj.n_outputs > 1 or obj.name == "lambdarank":
        # Objectives with structured targets (class ids, query groups) use
        # the shared objective -> workload dispatch.
        from repro.launch.train import gbdt_dataset_for

        _, data = gbdt_dataset_for(args.objective, args.seed, n=n)
        dim = data.n_features
    else:
        # Scalar-target objectives (logistic/mse/quantile/huber) all train
        # on the demo's lightweight dense set — fast enough for CI smokes.
        x = rng.standard_normal((n, dim)).astype(np.float32)
        w = rng.standard_normal(dim).astype(np.float32)
        y = (x @ w + 0.1 * rng.standard_normal(n) > 0).astype(np.float32)
        data = bin_dataset(x, y, n_bins=64)

    cfg = SGBDTConfig(
        n_trees=args.trees,
        step_length=0.15,
        sampling_rate=0.8,
        objective=args.objective,
        learner=LearnerConfig(depth=5, n_bins=64, feature_fraction=0.8),
    )
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="gbdt_serve_")
    ckpt = CheckpointManager(ckpt_dir, save_every=1, keep=4)
    half = max(args.trees // 2, 1)
    print(f"gbdt: training {args.trees} trees ({args.workers} PS workers), "
          f"checkpointing steps {half} and {args.trees} -> {ckpt_dir}")
    trainer = Trainer(cfg)
    state = trainer.train(
        data, ("round_robin", args.workers), seed=args.seed,
        eval_every=half, eval_fn=lambda st, j: ckpt.maybe_save(j, st),
    )
    ckpt.maybe_save(args.trees, state)  # idempotent when half divides trees

    quantize = None if args.quantize == "none" else args.quantize
    reqs = [
        PredictRequest(
            uid=i,
            x=rng.standard_normal((int(rng.integers(1, args.rows // 2 + 1)), dim))
            .astype(np.float32),
        )
        for i in range(args.requests)
    ]

    if args.engine == "continuous":
        # Two checkpoints, two live versions: traffic A/B-splits by uid
        # hash, each result labeled with its version and that version's
        # own model_step.
        eng = ForestEngine(
            data.bin_edges, max_rows=args.rows, slo_s=args.slo_ms / 1e3
        )
        eng.add_version(
            "half", load_forest_checkpoint(ckpt_dir, half),
            model_step=half, objective=obj, quantize=quantize,
        )
        t0 = time.time()
        first = eng.run(reqs[: args.requests // 2])
        eng.add_version(
            "full", load_forest_checkpoint(ckpt_dir, args.trees),
            model_step=args.trees, objective=obj, quantize=quantize,
            weight=3.0,  # ramp the new version to 75% of the split
        )
        second = eng.run(reqs[args.requests // 2:])
        dt = time.time() - t0
        outs = first + second
        rows = sum(len(r.scores) for r in outs)
        split: dict[str, int] = {}
        for r in second:
            split[r.version] = split.get(r.version, 0) + 1
        stats = percentile_latencies(outs)
        print(f"continuous engine: served {len(outs)} requests / {rows} rows "
              f"in {dt:.2f}s (quantize={quantize or 'off'}); "
              f"post-ramp A/B split {split}")
        print(f"  latency p50/p99: queue {stats['queue_p50_ms']:.2f}/"
              f"{stats['queue_p99_ms']:.2f} ms, compute "
              f"{stats['compute_p50_ms']:.2f}/{stats['compute_p99_ms']:.2f} ms,"
              f" end-to-end {stats['latency_p50_ms']:.2f}/"
              f"{stats['latency_p99_ms']:.2f} ms (SLO {args.slo_ms:.0f} ms)")
        for r in outs[:3]:
            print(f"  req {r.uid}: {len(r.scores)} rows, "
                  f"version={r.version}, model_step={r.model_step}, "
                  f"scores[:4]={np.round(r.scores[:4], 4).tolist()}")
        assert {r.model_step for r in first} == {half}
        assert all(
            r.model_step == (half if r.version == "half" else args.trees)
            for r in second
        )
        assert all(np.isfinite(r.scores).all() for r in outs), "non-finite"
        return

    # Serve from the mid-training (partially-filled) checkpoint first; the
    # checkpoint root is attached only after the first batch so the demo
    # shows both model versions answering live traffic.
    server = ForestServer(
        load_forest_checkpoint(ckpt_dir, half),
        data.bin_edges,
        max_rows=args.rows,
        model_step=half,
        objective=obj,
        quantize=quantize,
    )
    t0 = time.time()
    first = server.run(reqs[: args.requests // 2])
    server.ckpt_root = ckpt_dir
    swapped = server.maybe_reload()
    second = server.run(reqs[args.requests // 2:])
    dt = time.time() - t0
    outs = first + second
    rows = sum(len(r.scores) for r in outs)
    print(f"served {len(outs)} requests / {rows} rows in {dt:.2f}s "
          f"({rows / dt:,.0f} rows/s incl. compile) over "
          f"{server.waves_served} waves (quantize={quantize or 'off'})")
    step_before = first[-1].model_step if first else half
    print(f"hot swap: step {step_before} -> {server.model_step} "
          f"(reloaded={swapped})")
    for r in outs[:3]:
        print(f"  req {r.uid}: {len(r.scores)} rows, model_step={r.model_step}, "
              f"scores[:4]={np.round(r.scores[:4], 4).tolist()}")
    assert swapped and server.model_step == args.trees
    assert all(np.isfinite(r.scores).all() for r in outs), "non-finite scores"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trees", type=int, default=60,
                    help="forest size to train then serve (--arch gbdt)")
    ap.add_argument("--workers", type=int, default=8,
                    help="PS worker count for the training phase (--arch gbdt)")
    ap.add_argument("--requests", type=int, default=12,
                    help="prediction requests to serve (--arch gbdt)")
    ap.add_argument("--rows", type=int, default=64,
                    help="wave capacity in rows (--arch gbdt)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: fresh tempdir)")
    ap.add_argument("--objective", default="logistic",
                    help="GBDT objective spec; served outputs go through "
                         "its link (e.g. multiclass:3 -> softmax rows)")
    ap.add_argument("--engine", default="wave",
                    choices=["wave", "continuous"],
                    help="wave: drain-the-queue ForestServer demo; "
                         "continuous: multi-version SLO-cutting ForestEngine")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "int8", "fp16"],
                    help="serve a quantized forest payload (documented "
                         "score-error bound, 4x/2x smaller VMEM blocks)")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="latency SLO for continuous-engine wave cutting")
    args = ap.parse_args()

    if args.arch == "gbdt":
        return run_gbdt(args)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    baxes = sharding.batch_axes(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prefill_fn = jax.jit(make_prefill_step(cfg, mesh, baxes, max_len=max_len))
    decode_fn = jax.jit(make_decode_step(cfg, mesh, baxes))

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = {"tokens": prompts}
    if cfg.family in ("vlm", "audio"):
        batch["media"] = (
            jax.random.normal(
                key, (args.batch, cfg.n_media_tokens, cfg.d_model)
            ) * 0.02
        ).astype(jnp.dtype(cfg.dtype))

    t0 = time.time()
    next_tok, logits, cache = prefill_fn(params, batch)
    next_tok.block_until_ready()
    t1 = time.time()
    out = [np.asarray(next_tok)]
    tok = next_tok[:, None]
    for _ in range(args.gen - 1):
        tok_next, cache = decode_fn(params, tok, cache)
        out.append(np.asarray(tok_next))
        tok = tok_next[:, None]
    jax.block_until_ready(tok)
    t2 = time.time()

    gen = np.stack(out, axis=1)
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decoded {args.gen} tokens in {t2-t1:.2f}s "
          f"({args.batch*args.gen/(t2-t1):,.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())
    assert gen.min() >= 0 and gen.max() < cfg.vocab_size


if __name__ == "__main__":
    main()
