"""Serving driver — batched prefill + greedy decode against the ring cache.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --reduced --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.sharding as sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    baxes = sharding.batch_axes(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prefill_fn = jax.jit(make_prefill_step(cfg, mesh, baxes, max_len=max_len))
    decode_fn = jax.jit(make_decode_step(cfg, mesh, baxes))

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = {"tokens": prompts}
    if cfg.family in ("vlm", "audio"):
        batch["media"] = (
            jax.random.normal(
                key, (args.batch, cfg.n_media_tokens, cfg.d_model)
            ) * 0.02
        ).astype(jnp.dtype(cfg.dtype))

    t0 = time.time()
    next_tok, logits, cache = prefill_fn(params, batch)
    next_tok.block_until_ready()
    t1 = time.time()
    out = [np.asarray(next_tok)]
    tok = next_tok[:, None]
    for _ in range(args.gen - 1):
        tok_next, cache = decode_fn(params, tok, cache)
        out.append(np.asarray(tok_next))
        tok = tok_next[:, None]
    jax.block_until_ready(tok)
    t2 = time.time()

    gen = np.stack(out, axis=1)
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decoded {args.gen} tokens in {t2-t1:.2f}s "
          f"({args.batch*args.gen/(t2-t1):,.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())
    assert gen.min() >= 0 and gen.max() < cfg.vocab_size


if __name__ == "__main__":
    main()
