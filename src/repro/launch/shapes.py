"""The assigned input shapes + abstract input builders for the dry-run.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input — no device allocation, shardable, exactly what
``jax.jit(...).lower()`` needs. Decode shapes build the (abstract) KV /
state cache for a ``seq_len`` context and feed ONE new token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import cache as cache_mod
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """Documented skips (DESIGN.md): whisper has no 500k decoding horizon;
    full-attention archs run long_500k only via their SWA opt-in."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return "enc-dec audio: 448-token decode horizon, no sub-quadratic variant"
        sub_quadratic = (
            cfg.family in ("hybrid", "ssm")
            or cfg.sliding_window > 0
            or cfg.long_context_window > 0
        )
        if not sub_quadratic:
            return "pure full attention cannot serve 524288 tokens"
    return None


def batch_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch for train/prefill kinds."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["weights"] = jax.ShapeDtypeStruct((b,), jnp.float32)
    if cfg.family in ("vlm", "audio"):
        out["media"] = jax.ShapeDtypeStruct((b, cfg.n_media_tokens, cfg.d_model), dt)
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec) -> tuple[dict, dict]:
    """(tokens, cache) abstract inputs for the decode kinds."""
    b, s = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache = cache_mod.abstract_cache(cfg, b, s)
    return {"tokens": tokens}, cache
