"""Launch substrate: meshes, input shapes, step builders, dry-run."""
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_host_mesh,
    make_production_mesh,
)
from repro.launch.shapes import (
    SHAPES,
    ShapeSpec,
    batch_inputs,
    decode_inputs,
    shape_skip_reason,
)
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "HBM_BW",
    "ICI_BW",
    "PEAK_FLOPS_BF16",
    "make_host_mesh",
    "make_production_mesh",
    "SHAPES",
    "ShapeSpec",
    "batch_inputs",
    "decode_inputs",
    "shape_skip_reason",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
]
