"""Loop-aware roofline statistics from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so for
scanned layer stacks (and microbatch accumulation loops) its flops/bytes
understate the true work by ~n_layers. This module re-derives the three
roofline inputs directly from the optimized HLO:

- ``dot_flops``   — 2 * |out| * contraction for every dot, times the
                    executing computation's loop multiplicity (taken from
                    XLA's ``known_trip_count`` backend_config).
- ``hbm_bytes``   — sum of (result + operand) sizes over top-level ops
                    (post-fusion, so fused temporaries are not counted —
                    the standard HBM-traffic proxy), times multiplicity.
- ``collectives`` — per-kind byte counts (all-gather / all-reduce /
                    reduce-scatter / all-to-all / collective-permute),
                    times multiplicity.

All sizes are PER DEVICE (the HLO is the SPMD-partitioned program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[^\s]+)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: dict[str, float]
    collective_count: dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": dict(self.collective_count),
            "total_collective_bytes": self.total_collective_bytes,
        }


def _parse(hlo: str):
    """-> (computations: {name: [line, ...]}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _walk_multiplicity(comps, entry):
    """-> (mult: {comp: times executed}, toplevel: set of comps whose op
    results/operands count as HBM traffic)."""
    mult: dict[str, int] = {entry: 1}
    toplevel: set[str] = {entry}
    stack = [entry]
    seen: set[str] = set()
    while stack:
        cur = stack.pop()
        if cur in seen:
            continue
        seen.add(cur)
        base = mult.get(cur, 1)
        for ln in comps.get(cur, []):
            om = _OP_RE.match(ln)
            opcode = om.group(3) if om else ""
            trip = 1
            tm = _TRIP_RE.search(ln)
            if tm:
                trip = int(tm.group(1))
            refs: list[tuple[str, bool, int]] = []  # (name, is_toplevel, factor)
            for mm in re.finditer(r"body=%?([\w.\-]+)", ln):
                refs.append((mm.group(1), True, trip))
            for mm in re.finditer(r"condition=%?([\w.\-]+)", ln):
                refs.append((mm.group(1), False, trip))
            for mm in re.finditer(r"branch_computations=\{([^}]*)\}", ln):
                for nm in mm.group(1).split(","):
                    refs.append((nm.strip().lstrip("%"), True, 1))
            for mm in re.finditer(r"calls=%?([\w.\-]+)", ln):
                refs.append((mm.group(1), False, 1))  # fusion body: inlined
            for mm in re.finditer(r"to_apply=%?([\w.\-]+)", ln):
                top = opcode == "call"
                refs.append((mm.group(1), top, 1))
            for name, top, factor in refs:
                nm_ = base * factor
                if nm_ > mult.get(name, 0):
                    mult[name] = nm_
                    seen.discard(name)
                if top:
                    toplevel.add(name)
                stack.append(name)
    return mult, toplevel


def analyze_hlo(hlo: str) -> HloStats:
    comps, entry = _parse(hlo)
    mult, toplevel = _walk_multiplicity(comps, entry)

    # global symbol table: op name -> result-shape string
    shape_of: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            om = _OP_RE.match(ln)
            if om:
                shape_of[om.group(1)] = om.group(2)
    # parameters appear in the signature; resolve lazily via operand shape
    # annotations when present (optimized HLO usually names them %param.N
    # and their shapes are recoverable from defining lines only).

    dot_flops = 0.0
    hbm = 0.0
    coll_b: dict[str, float] = {}
    coll_n: dict[str, int] = {}

    for cname, lines in comps.items():
        k = mult.get(cname, 1)
        count_bytes = cname in toplevel
        for ln in lines:
            om = _OP_RE.match(ln)
            if not om:
                continue
            name, shape_str, opcode = om.groups()
            if opcode == "dot":
                out_dims = _shape_dims(shape_str)
                out_n = 1
                for d in out_dims:
                    out_n *= d
                contr = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                ops_m = re.search(r"dot\(([^)]*)\)", ln)
                if cm and ops_m:
                    args = ops_m.group(1)
                    # Depending on the XLA version, operands print either as
                    # bare %names or with inline shape annotations
                    # ("f32[128,256]{1,0} %arg"); the first inline shape IS
                    # the lhs, otherwise resolve the name in the symbol table.
                    sm = _SHAPE_RE.search(args)
                    if sm:
                        lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                    else:
                        lhs_name = args.split(",")[0].strip().lstrip("%")
                        lhs_dims = _shape_dims(shape_of.get(lhs_name, ""))
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            contr *= lhs_dims[int(ci)]
                dot_flops += 2.0 * out_n * contr * k
            if opcode in COLLECTIVE_KINDS or any(
                opcode == f"{kk}-start" for kk in COLLECTIVE_KINDS
            ):
                kind = opcode.removesuffix("-start")
                b = _shape_bytes(shape_str) * k
                # XLA's CPU pipeline PROMOTES bf16 all-reduces to f32 (the
                # reducer computation gets a "_promoted" suffix) because the
                # CPU runtime lacks bf16 reduction. TPUs reduce bf16
                # natively, so count promoted ops at their pre-promotion
                # width for a TPU-faithful byte count.
                if "promoted" in ln and "f32" in shape_str:
                    b //= 2
                coll_b[kind] = coll_b.get(kind, 0.0) + b
                coll_n[kind] = coll_n.get(kind, 0) + k
            if count_bytes and opcode not in ("tuple", "get-tuple-element",
                                              "parameter", "constant", "bitcast"):
                b = _shape_bytes(shape_str)
                ops_m = re.search(rf"{opcode}\(([^)]*)\)", ln)
                if ops_m:
                    args = ops_m.group(1)
                    if _SHAPE_RE.search(args):  # inline operand shapes
                        b += _shape_bytes(args)
                    else:  # bare %names: symbol table
                        for operand in args.split(","):
                            operand = operand.strip().lstrip("%")
                            b += _shape_bytes(shape_of.get(operand, ""))
                hbm += b * k

    return HloStats(
        dot_flops=dot_flops,
        hbm_bytes=hbm,
        collective_bytes=coll_b,
        collective_count=coll_n,
    )
