"""Pairwise learning-to-rank over query groups (LambdaRank-style).

Queries are identified by ``BinnedData.qid`` (int32 per sample); only
pairs within the same query with different relevance labels contribute.
For a pair where i is more relevant than j, the pair loss is the RankNet
logistic ``log(1 + exp(-sigma (F_i - F_j)))``, optionally weighted by the
|Delta DCG| of swapping the pair at the current ranking (LambdaRank).
The weights are ``stop_gradient``-ed, so ``grad_hess`` is exactly the
autodiff gradient/diagonal-hessian of ``loss_sum`` in both modes — the
same parity contract as every other objective.

The pairwise field is computed dense-masked (O(N^2)); fine for the
synthetic ranking workloads here, where N is a few thousand.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.objectives.base import Objective
from repro.objectives.registry import register


@register("lambdarank", "ranknet")
@dataclasses.dataclass(frozen=True)
class LambdaRank(Objective):
    """Pairwise logistic ranking; ``ndcg_weight`` enables |Delta DCG| pair
    weights (unnormalized — no per-query maxDCG division)."""

    sigma: float = 1.0
    ndcg_weight: bool = True
    name = "lambdarank"
    rowwise = False  # pair gradients mix rows within a query group

    def _pair_weights(self, y, f, qid):
        if qid is None:
            raise ValueError(
                "lambdarank needs per-sample query ids: build the dataset "
                "with BinnedData.qid (e.g. data.make_ranking)"
            )
        same = qid[:, None] == qid[None, :]
        pref = same & (y[:, None] > y[None, :])  # i preferred over j
        w = pref.astype(jnp.float32)
        if self.ndcg_weight:
            # Current 0-based rank of each doc within its query (descending
            # score, ties broken by index so equal-score docs still occupy
            # distinct ranks — otherwise the all-equal init state has zero
            # |Delta DCG| everywhere and training cannot start); swap cost
            # |gain_i - gain_j| * |disc_i - disc_j|.
            idx = jnp.arange(f.shape[0])
            beats = (f[None, :] > f[:, None]) | (
                (f[None, :] == f[:, None]) & (idx[None, :] < idx[:, None])
            )
            rank = jnp.sum(same & beats, axis=1)
            gain = 2.0**y - 1.0
            disc = 1.0 / jnp.log2(2.0 + rank)
            dg = jnp.abs(gain[:, None] - gain[None, :]) * jnp.abs(
                disc[:, None] - disc[None, :]
            )
            w = w * jax.lax.stop_gradient(dg)
        return pref, w

    def init_score(self, y, weight):
        return jnp.asarray(0.0, jnp.float32)

    def grad_hess(self, y, f, qid=None):
        _, w = self._pair_weights(y, f, qid)
        s = jax.nn.sigmoid(-self.sigma * (f[:, None] - f[None, :]))
        g_pair = -self.sigma * w * s  # d(pair)/dF_i
        h_pair = self.sigma**2 * w * s * (1.0 - s)
        grad = jnp.sum(g_pair, axis=1) - jnp.sum(g_pair, axis=0)
        hess = jnp.sum(h_pair, axis=1) + jnp.sum(h_pair, axis=0)
        return grad, hess

    def _pair_losses(self, y, f, qid):
        """(pref, w, per-pair loss) — the O(N^2) matrices, built once."""
        pref, w = self._pair_weights(y, f, qid)
        pair = jnp.logaddexp(0.0, -self.sigma * (f[:, None] - f[None, :]))
        return pref, w, pair

    def loss_sum(self, y, f, qid=None):
        _, w, pair = self._pair_losses(y, f, qid)
        return jnp.sum(w * pair)

    def loss(self, y, f, weight=None, qid=None):
        """Mean pair loss (multiplicity weights do not apply to pairs)."""
        _, w, pair = self._pair_losses(y, f, qid)
        return jnp.sum(w * pair) / jnp.maximum(jnp.sum(w), 1e-12)

    def metrics(self, y, f, weight=None, qid=None):
        pref, w, pair = self._pair_losses(y, f, qid)
        correct = pref & (f[:, None] > f[None, :])
        n_pref = jnp.maximum(jnp.sum(pref), 1)
        return {
            "loss": jnp.sum(w * pair) / jnp.maximum(jnp.sum(w), 1e-12),
            "pairwise_acc": jnp.sum(correct) / n_pref,
        }
