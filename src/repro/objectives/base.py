"""The Objective protocol: K raw scores per sample, end to end.

The paper's analysis (Eq. 1) is stated for a generic functional-space loss
L(F) = sum_i m_i * l(y_i, F_i) with a bounded gradient; nothing in the
algorithm is binary-specific. An ``Objective`` packages everything a
multi-output loss needs to flow through the whole system:

  * ``n_outputs`` — K, the number of raw scores per sample. The forest
    fits one tree per output per boosting round (K pushed as one group),
    and every layer's arrays grow a trailing K axis when K > 1. K = 1
    objectives keep the historical ``(N,)`` shapes bitwise-unchanged.
  * ``init_score`` — the optimal constant model (the paper's init tree),
    ``()`` for K = 1 or ``(K,)``.
  * ``grad_hess`` — per-sample, per-output d/dF and d2/dF2 of the
    *unweighted, unnormalized* loss ``loss_sum``; the engine applies the
    Bernoulli importance weights m' itself. Shapes match ``f``.
  * ``link`` — raw score(s) -> prediction (probability, score, ...);
    applied inside the serving jit so served outputs match training
    semantics.
  * ``loss`` / ``metrics`` — multiplicity-weighted reporting.

Objectives are frozen dataclasses: hashable and comparable by field
values, so they ride inside ``SGBDTConfig`` through ``jax.jit``
static arguments and per-config trainer caches.

The autodiff contract (tested in tests/test_objectives.py): for every
registered objective, ``grad_hess(y, f)[0] == jax.grad(loss_sum)(f)``
exactly, and — when ``exact_hessian`` — ``grad_hess(y, f)[1]`` equals the
diagonal of ``jax.hessian(loss_sum)``. Objectives whose conventional GBM
hessian is a surrogate (e.g. quantile's ones) set ``exact_hessian=False``.
"""
from __future__ import annotations

import jax.numpy as jnp


class Objective:
    """Base class; see the module docstring for the contract."""

    name: str = "abstract"
    # grad_hess[0] is exactly d loss_sum / dF (a.e.).
    exact_gradient: bool = True
    # grad_hess[1] is exactly the diagonal of d2 loss_sum / dF2 (a.e.).
    exact_hessian: bool = True
    # Sample i's (grad, hess) depend ONLY on (y_i, f_i). Rowwise objectives
    # are what make partition-granular leaf-table pulls sound: a worker that
    # zero-fills F rows outside its pulled partitions still computes the
    # exact weighted gradient for every sampled row (unsampled rows carry
    # m' = 0 and are inert in the tree build). Listwise objectives
    # (LambdaRank) mix rows within a query group and must pull full tables.
    rowwise: bool = True

    @property
    def n_outputs(self) -> int:
        return 1

    # ------------------------------------------------------------- core API
    def init_score(self, y, weight):
        """Optimal constant raw score: () for K = 1, (K,) otherwise."""
        raise NotImplementedError

    def grad_hess(self, y, f, qid=None):
        """Per-sample (grad, hess) of ``loss_sum`` w.r.t. ``f``; shapes = f."""
        raise NotImplementedError

    def link(self, f):
        """Raw score(s) -> served prediction. Identity unless overridden."""
        return f

    def per_example(self, y, f):
        """Per-sample unweighted loss (N,) — separable objectives only."""
        raise NotImplementedError

    def loss_sum(self, y, f, qid=None):
        """Unnormalized total loss — the potential ``grad_hess`` derives."""
        return jnp.sum(self.per_example(y, f))

    def loss(self, y, f, weight=None, qid=None):
        """Multiplicity-weighted mean loss (the paper's Eq. 1 normalized)."""
        return weighted_mean(self.per_example(y, f), weight)

    def metrics(self, y, f, weight=None, qid=None):
        """Scalar diagnostics; always includes ``loss``."""
        return {"loss": self.loss(y, f, weight, qid=qid)}


def weighted_mean(x, weight=None):
    if weight is None:
        return jnp.mean(x)
    return jnp.sum(weight * x) / jnp.sum(weight)
