"""Regression objectives: squared error, quantile (pinball), Huber."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.objectives.base import Objective, weighted_mean
from repro.objectives.registry import register
from repro.trees.losses import mse_grad_hess, mse_loss


@register("mse", "squared_error")
@dataclasses.dataclass(frozen=True)
class SquaredError(Objective):
    """l = 0.5 (F - y)^2; init = the multiplicity-weighted label mean."""

    name = "mse"

    def init_score(self, y, weight):
        return jnp.sum(weight * y) / jnp.sum(weight)

    def grad_hess(self, y, f, qid=None):
        return mse_grad_hess(y, f)

    def per_example(self, y, f):
        return 0.5 * (f - y) ** 2

    def loss(self, y, f, weight=None, qid=None):
        return mse_loss(y, f, weight)

    def metrics(self, y, f, weight=None, qid=None):
        rmse = jnp.sqrt(weighted_mean((f - y) ** 2, weight))
        return {"loss": self.loss(y, f, weight), "rmse": rmse}


@register("quantile", "pinball")
@dataclasses.dataclass(frozen=True)
class Quantile(Objective):
    """Pinball loss for the ``alpha`` quantile.

    The conventional GBM surrogate hessian of 1 is returned (the true
    second derivative is 0 a.e., which would degenerate Newton leaves),
    so ``exact_hessian`` is False; the gradient is exact a.e.
    """

    alpha: float = 0.5
    name = "quantile"
    exact_hessian = False

    def init_score(self, y, weight):
        order = jnp.argsort(y)
        ys, ws = y[order], weight[order]
        cum = jnp.cumsum(ws)
        idx = jnp.searchsorted(cum, self.alpha * cum[-1])
        return ys[jnp.clip(idx, 0, y.shape[0] - 1)]

    def grad_hess(self, y, f, qid=None):
        grad = jnp.where(y >= f, -self.alpha, 1.0 - self.alpha)
        return grad, jnp.ones_like(f)

    def per_example(self, y, f):
        return jnp.where(y >= f, self.alpha * (y - f), (1.0 - self.alpha) * (f - y))

    def metrics(self, y, f, weight=None, qid=None):
        cover = weighted_mean(y <= f, weight)  # should approach alpha
        return {"loss": self.loss(y, f, weight), "coverage": cover}


@register("huber")
@dataclasses.dataclass(frozen=True)
class Huber(Objective):
    """Huber loss: quadratic within ``delta`` of the label, linear outside."""

    delta: float = 1.0
    name = "huber"

    def init_score(self, y, weight):
        return jnp.sum(weight * y) / jnp.sum(weight)

    def grad_hess(self, y, f, qid=None):
        r = f - y
        inside = jnp.abs(r) <= self.delta
        grad = jnp.clip(r, -self.delta, self.delta)
        return grad, jnp.where(inside, 1.0, 0.0)

    def per_example(self, y, f):
        r = f - y
        inside = jnp.abs(r) <= self.delta
        return jnp.where(
            inside, 0.5 * r**2, self.delta * (jnp.abs(r) - 0.5 * self.delta)
        )

    def metrics(self, y, f, weight=None, qid=None):
        rmse = jnp.sqrt(weighted_mean((f - y) ** 2, weight))
        return {"loss": self.loss(y, f, weight), "rmse": rmse}
