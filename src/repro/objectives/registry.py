"""Objective registry: name -> factory, with ``name:arg`` parameterization.

``get_objective`` is the single resolution point every layer uses:

    get_objective("logistic")          # the paper's symmetric-logit binary
    get_objective("multiclass:5")      # 5-class softmax, K = 5 trees/round
    get_objective("quantile:0.9")      # 0.9-pinball regression
    get_objective(BinaryLogistic())    # instances pass through

The legacy ``SGBDTConfig.loss`` strings ("logistic", "mse") resolve
through the same table — that is the whole deprecation shim.
"""
from __future__ import annotations

from typing import Callable

from repro.objectives.base import Objective

_REGISTRY: dict[str, Callable[..., Objective]] = {}


def register(name: str, *aliases: str):
    """Class/factory decorator adding an objective under ``name`` (+aliases)."""

    def deco(factory):
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"objective {key!r} registered twice")
            _REGISTRY[key] = factory
        return factory

    return deco


def registered_objectives() -> dict[str, Callable[..., Objective]]:
    """Canonical name -> factory (aliases excluded)."""
    seen, out = set(), {}
    for name, factory in _REGISTRY.items():
        if id(factory) not in seen:
            seen.add(id(factory))
            out[name] = factory
    return out


def _parse_arg(raw: str):
    try:
        return int(raw)
    except ValueError:
        return float(raw)


def get_objective(spec, **kwargs) -> Objective:
    """Resolve an Objective from an instance, a name, or ``name:arg``."""
    if isinstance(spec, Objective):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"objective spec must be Objective or str, got {type(spec)}")
    name, _, arg = spec.partition(":")
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown objective {name!r}; registered: {sorted(_REGISTRY)}"
        )
    factory = _REGISTRY[name]
    if arg:
        return factory(_parse_arg(arg), **kwargs)
    return factory(**kwargs)
