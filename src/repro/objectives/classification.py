"""Classification objectives: the paper's symmetric-logit binary loss and
K-output multiclass softmax.

``BinaryLogistic`` delegates to ``repro.trees.losses`` so the binary path
stays bitwise-identical to the pre-Objective code (the parity tests in
tests/test_sgbdt.py and tests/test_ps_engine.py ride through unchanged).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.objectives.base import Objective, weighted_mean
from repro.objectives.registry import register
from repro.trees.losses import (
    logistic_grad_hess,
    logistic_loss,
    sigmoid2,
)


@register("logistic", "binary_logistic")
@dataclasses.dataclass(frozen=True)
class BinaryLogistic(Objective):
    """Friedman's two-sided logit: p = e^F / (e^F + e^-F) = sigmoid(2F).

    grad = 2(p - y), hess = 4p(1 - p) — both O(1)-bounded, matching the
    paper's bounded-gradient assumption ||l'|| <= phi.
    """

    name = "logistic"

    def init_score(self, y, weight):
        ybar = jnp.sum(weight * y) / jnp.sum(weight)
        ybar = jnp.clip(ybar, 1e-6, 1.0 - 1e-6)
        return 0.5 * jnp.log(ybar / (1.0 - ybar))

    def grad_hess(self, y, f, qid=None):
        return logistic_grad_hess(y, f)

    def link(self, f):
        return sigmoid2(f)

    def per_example(self, y, f):
        margin = (2.0 * y - 1.0) * f
        return jnp.logaddexp(0.0, -2.0 * margin)

    def loss(self, y, f, weight=None, qid=None):
        return logistic_loss(y, f, weight)

    def metrics(self, y, f, weight=None, qid=None):
        acc = weighted_mean((f > 0.0) == (y > 0.5), weight)
        return {"loss": self.loss(y, f, weight), "accuracy": acc}


@register("multiclass", "softmax")
@dataclasses.dataclass(frozen=True)
class MulticlassSoftmax(Objective):
    """K-class cross-entropy over K raw scores per sample.

    One tree per class per boosting round fits the (N, K) gradient field
    g = p - onehot(y); h = p(1 - p) is the exact diagonal of the softmax
    cross-entropy hessian. Labels are class ids stored as floats in
    ``BinnedData.labels``.
    """

    n_classes: int = 3
    name = "multiclass"

    @property
    def n_outputs(self) -> int:
        return self.n_classes

    def _onehot(self, y):
        return jax.nn.one_hot(y.astype(jnp.int32), self.n_classes, dtype=jnp.float32)

    def init_score(self, y, weight):
        prior = jnp.sum(weight[:, None] * self._onehot(y), axis=0) / jnp.sum(weight)
        return jnp.log(jnp.clip(prior, 1e-6, 1.0))

    def grad_hess(self, y, f, qid=None):
        p = jax.nn.softmax(f, axis=-1)
        return p - self._onehot(y), p * (1.0 - p)

    def link(self, f):
        return jax.nn.softmax(f, axis=-1)

    def per_example(self, y, f):
        logp = jax.nn.log_softmax(f, axis=-1)
        return -jnp.sum(self._onehot(y) * logp, axis=-1)

    def metrics(self, y, f, weight=None, qid=None):
        acc = weighted_mean(jnp.argmax(f, axis=-1) == y.astype(jnp.int32), weight)
        return {"loss": self.loss(y, f, weight), "accuracy": acc}
