"""First-class training objectives: K-output losses through every layer.

See ``base.Objective`` for the protocol and ``registry.get_objective``
for name resolution (including the legacy ``loss="logistic"|"mse"``
config shim). Importing this package registers the built-ins.
"""
from repro.objectives.base import Objective
from repro.objectives.classification import BinaryLogistic, MulticlassSoftmax
from repro.objectives.ranking import LambdaRank
from repro.objectives.registry import get_objective, register, registered_objectives
from repro.objectives.regression import Huber, Quantile, SquaredError

__all__ = [
    "Objective",
    "BinaryLogistic",
    "MulticlassSoftmax",
    "SquaredError",
    "Quantile",
    "Huber",
    "LambdaRank",
    "get_objective",
    "register",
    "registered_objectives",
]
