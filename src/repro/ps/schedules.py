"""Delay-schedule providers for the parameter-server engine.

Asynchrony is entirely described by the version map k(j): server update j
folds in a tree that was built from F^{k(j)} (staleness tau_j = j - k(j)).
Prop. 1 is stated in terms of k(j), so the engine executes k(j) exactly.
Schedules come from three kinds of provider, all normalized here:

  * closed forms — ``constant_delay`` / ``worker_round_robin`` (also
    addressable as ``("constant", tau)`` / ``("round_robin", W)`` specs);
  * realized schedules — an explicit int array, e.g. the output of the
    event-driven cluster simulator (``repro.core.simulator``);
  * a ``ClusterSpec`` — resolved by running the simulator on the spot.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def constant_delay(n_trees: int, tau: int) -> np.ndarray:
    """k(j) = max(0, j - tau): every tree is exactly tau versions stale."""
    j = np.arange(n_trees)
    return np.maximum(0, j - tau).astype(np.int32)


def worker_round_robin(n_trees: int, n_workers: int) -> np.ndarray:
    """Steady-state schedule of W homogeneous workers (threads-as-workers).

    A worker whose push became update j immediately pulls F^{j+1}; its next
    push lands W updates later => k(j + W) = j + 1, i.e. k(j) = j - W + 1.
    W = 1 is exactly the serial trainer (k(j) = j, zero staleness). The
    first W trees are all built from F^0 (all workers pulled at launch).
    """
    j = np.arange(n_trees)
    return np.maximum(0, j - n_workers + 1).astype(np.int32)


def max_staleness(schedule: np.ndarray) -> int:
    return int(np.max(np.arange(len(schedule)) - schedule))


def staleness_scales(schedule, rho: float) -> np.ndarray:
    """Per-update adaptive step scales 1 / (1 + 6*rho*tau_j) for a realized
    k(j) — the host twin of ``engine.staleness_scale`` (same rule in f32,
    so trace reporting matches what the jitted fold computed). ``rho = 0``
    is the fixed-step identity (all ones)."""
    schedule = np.asarray(schedule)
    tau = (np.arange(len(schedule)) - schedule).astype(np.float32)
    return (
        np.float32(1.0) / (np.float32(1.0) + np.float32(6.0 * rho) * tau)
    ).astype(np.float32)


def resolve_schedule(spec, n_trees: int) -> np.ndarray:
    """Normalize any schedule provider to a validated (n_trees,) int32 k(j).

    Accepted specs:
      * an int array / sequence — used as-is (realized schedule);
      * ``("constant", tau)`` or ``("round_robin", W)``;
      * a bare int W — shorthand for ``("round_robin", W)``;
      * a ``repro.core.simulator.ClusterSpec`` — runs ``simulate_async``;
      * a callable ``f(n_trees) -> np.ndarray``.
    """
    if isinstance(spec, int):
        spec = ("round_robin", spec)
    if isinstance(spec, tuple) and len(spec) == 2 and isinstance(spec[0], str):
        kind, arg = spec
        if kind == "constant":
            if int(arg) < 0:
                raise ValueError(f"constant delay needs tau >= 0, got {arg}")
            sched = constant_delay(n_trees, int(arg))
        elif kind == "round_robin":
            if int(arg) < 1:
                raise ValueError(f"round_robin needs >= 1 worker, got {arg}")
            sched = worker_round_robin(n_trees, int(arg))
        else:
            raise ValueError(f"unknown schedule kind {kind!r}")
    elif callable(spec):
        sched = np.asarray(spec(n_trees), np.int32)
    elif hasattr(spec, "n_workers") and hasattr(spec, "t_build"):  # ClusterSpec
        from repro.core.simulator import simulate_async

        sched = simulate_async(spec, n_trees).schedule
    elif isinstance(spec, (np.ndarray, Sequence)) or hasattr(spec, "__array__"):
        sched = np.asarray(spec, np.int32)
    else:
        raise TypeError(f"cannot resolve schedule from {type(spec).__name__}")

    sched = np.asarray(sched, np.int32)
    if sched.shape != (n_trees,):
        raise ValueError(f"schedule shape {sched.shape} != ({n_trees},)")
    j = np.arange(n_trees)
    if (sched > j).any():
        raise ValueError("causality violation: k(j) > j in schedule")
    if (sched < 0).any():
        raise ValueError("negative version in schedule")
    return sched
