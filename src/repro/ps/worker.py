"""Worker-parallel tree building: the whole worker pool in one batched call.

On real hardware W asynchronous workers build W trees concurrently. On one
accelerator the same concurrency is a ``vmap`` over the worker axis: gather
the W stale targets F^{k(j)} from the version ring, build all W trees in
one batched ``propose_tree`` call, then let the server fold them in update
order. This makes the Fig. 10 speedup path *executable* — a measured
batched-build-vs-serial ratio — rather than only simulated.

Exactness: a block of W trees can be batched iff no tree in the block
depends on a version created inside the block, i.e. k(j) <= block_start
for every j in the block. The round-robin steady state satisfies this for
blocks of exactly W (k(j) = j - W + 1), so ``train_worker_parallel``
executes the SAME schedule semantics as
``train_async(worker_round_robin(T, W))``: identical targets, identical
fold order. Numerically the two are equivalent up to XLA program
compilation — the batched and per-round programs may round intermediate
values differently by an ulp, which can flip a near-tied split — so
equality of the learned forests is exact when split gains are decisively
separated and loss-level otherwise (see tests/test_ps_engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sgbdt import SGBDTConfig, TrainState, init_state
from repro.ps.engine import propose_tree, server_fold
from repro.ps.schedules import max_staleness, worker_round_robin
from repro.trees.binning import BinnedData
from repro.trees.tree import Tree


def build_trees_batched(
    cfg: SGBDTConfig,
    data: BinnedData,
    f_targets: jax.Array,  # (W, N) — or (W, N, K) — stale targets per worker
    rngs: jax.Array,  # (W, 2) keys — one boosting round each
) -> tuple[Tree, jax.Array]:
    """All W worker builds as ONE vmapped call.

    Returns (trees stacked on a leading W axis, deltas (W, N) — or
    (W, N, K) for K-output objectives). Each lane is numerically identical
    to a standalone ``propose_tree`` with the same (target, key) — vmap
    only batches, it does not reassociate.
    """
    return jax.vmap(lambda ft, r: propose_tree(cfg, data, ft, r))(f_targets, rngs)


@functools.partial(jax.jit, static_argnames=("cfg", "ring_size"))
def _block_step(cfg, data, forest, f, ring, j0, ks, rngs, ring_size):
    """One worker-pool block: batched build, then in-order server folds."""
    f_targets = ring[ks % ring_size]  # (W, N[, K])
    trees, deltas = build_trees_batched(cfg, data, f_targets, rngs)

    def fold(carry, xs):
        forest, f, ring, j = carry
        tree, delta = xs
        forest, f = server_fold(cfg, forest, f, tree, delta)
        ring = jax.lax.dynamic_update_index_in_dim(
            ring, f, (j + 1) % ring_size, 0
        )
        return (forest, f, ring, j + 1), None

    (forest, f, ring, _), _ = jax.lax.scan(
        fold, (forest, f, ring, j0), (trees, deltas)
    )
    return forest, f, ring


def train_worker_parallel(
    cfg: SGBDTConfig,
    data: BinnedData,
    n_workers: int,
    seed: int = 0,
    eval_every: int = 0,
    eval_fn=None,
) -> TrainState:
    """Round-robin W-worker training, the pool batched one block at a time.

    Equals ``ps.engine.train(cfg, data, ("round_robin", W))`` exactly, but
    each W trees cost one vmapped build instead of W sequential ones.
    ``eval_every`` is rounded up to block boundaries.
    """
    sched = worker_round_robin(cfg.n_trees, n_workers)
    ring_size = max_staleness(sched) + 1
    state = init_state(cfg, data)
    ring = jnp.broadcast_to(state.f, (ring_size,) + state.f.shape)
    keys = jax.random.split(jax.random.PRNGKey(seed), cfg.n_trees)
    forest, f = state.forest, state.f
    for b0 in range(0, cfg.n_trees, n_workers):
        b1 = min(b0 + n_workers, cfg.n_trees)
        assert (sched[b0:b1] <= b0).all(), "block depends on in-block version"
        forest, f, ring = _block_step(
            cfg, data, forest, f, ring,
            jnp.asarray(b0, jnp.int32),
            jnp.asarray(sched[b0:b1]),
            keys[b0:b1],
            ring_size,
        )
        if eval_fn is not None and eval_every and (b1 // eval_every) > (b0 // eval_every):
            eval_fn(TrainState(forest, f, jnp.asarray(b1, jnp.int32)), b1)
    return TrainState(forest=forest, f=f, step=jnp.asarray(cfg.n_trees, jnp.int32))
