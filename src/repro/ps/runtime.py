"""Host-asynchronous parameter-server runtime: real threads, recorded k(j).

Everything else in ``repro.ps`` *replays* a delay schedule — the simulator
invents k(j), the engine executes it deterministically. This module is the
other half of the paper's claim: W real worker threads race a server fold
loop, and the version map k(j) is *realized* by the race, not chosen.

Roles (Algorithm 3, but actually concurrent):

  worker thread  — atomically grab a build ticket ``i`` and a snapshot of
                   the freshest ``(version, F)`` pair, build a tree from it
                   with the ticket's PRNG key (the jitted ``propose_tree``,
                   so concurrent builds overlap in XLA's thread pool), and
                   push ``(ticket, pulled_version, tree, delta)`` onto the
                   server queue;
  server loop    — pop pushes in arrival order, fold each via the jitted
                   ``server_fold``, publish the bumped ``(version, F)``,
                   and append one ``RunTrace`` row.

Determinism by record-and-replay: the interleaving is nondeterministic,
but every folded tree is a pure function of ``(F^{k(j)}, keys[i(j)])``.
``RunTrace`` records the realized schedule k(j) and the ticket permutation
i(j); replaying them through ``Trainer.scan_with`` (one fused lax.scan)
reproduces the threaded run's forest bit for bit. The propose/fold seam is
pinned with an ``optimization_barrier`` in ``engine.round_body`` so the
split-program runtime and the fused replay cannot drift by compilation
form. That replay contract is the core correctness test
(tests/test_runtime.py) and the debugging story: any nondeterministic run
can be re-executed deterministically from its trace.

The trace also carries measured per-phase wall times, which parameterize
``core.simulator.ClusterSpec`` — realized staleness vs. the event model's
prediction for the same geometry is the cross-validation
(``RunTrace.crossvalidate`` / ``benchmarks.fig10_speedup`` row
``runtime_measured``).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import queue
import threading
import time
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sgbdt import SGBDTConfig, TrainState, init_state
from repro.ps.engine import Trainer, propose_tree, server_fold
from repro.ps.schedules import max_staleness, resolve_schedule
from repro.trees.binning import BinnedData

_TRACE_VERSION = 1
_TRACE_ARRAYS = {
    "schedule": np.int32,
    "key_index": np.int32,
    "worker": np.int32,
    "t_build": np.float64,
    "t_queue": np.float64,
    "t_fold": np.float64,
}


@dataclasses.dataclass(frozen=True)
class RunTrace:
    """The realized execution of one threaded run — enough to replay it.

    Row j describes server update j (fold order):
      schedule[j]  — k(j): the version the folded tree was built from;
      key_index[j] — i(j): the build ticket, i.e. ``keys[i(j)]`` was the
                     round key (a permutation of ``arange(n_trees)``);
      worker[j]    — which worker thread built it;
      t_build[j]   — wall seconds of the (blocking) jitted build;
      t_queue[j]   — push-to-fold-start wait in the server queue;
      t_fold[j]    — wall seconds of the jitted server fold.
    """

    n_workers: int
    seed: int
    schedule: np.ndarray
    key_index: np.ndarray
    worker: np.ndarray
    t_build: np.ndarray
    t_queue: np.ndarray
    t_fold: np.ndarray
    makespan: float

    @property
    def n_trees(self) -> int:
        return len(self.schedule)

    @property
    def staleness(self) -> np.ndarray:
        return np.arange(self.n_trees) - self.schedule

    @property
    def ring_size(self) -> int:
        return max_staleness(self.schedule) + 1

    def staleness_histogram(self) -> dict[int, int]:
        return self._staleness_stats()["histogram"]

    def _staleness_stats(self) -> dict:
        from repro.core.simulator import staleness_stats

        return staleness_stats(self.schedule)

    def cluster_spec(self, **overrides):
        """A ``ClusterSpec`` parameterized by this run's measured phases.

        ``t_comm`` maps to the in-process queue handoff (there is no wire
        here); jitter/spread coefficients keep their defaults unless
        overridden.
        """
        from repro.core.simulator import ClusterSpec

        args = dict(
            n_workers=self.n_workers,
            t_build=float(self.t_build.mean()),
            t_comm=float(self.t_queue.mean()),
            t_server=float(self.t_fold.mean()),
            seed=self.seed,
        )
        args.update(overrides)
        return ClusterSpec(**args)

    def crossvalidate(self, **spec_overrides) -> dict:
        """Realized staleness vs. the event-driven simulator's prediction
        for the same cluster geometry (``core.simulator.crossvalidate_schedule``)."""
        from repro.core.simulator import crossvalidate_schedule

        return crossvalidate_schedule(
            self.schedule, self.cluster_spec(**spec_overrides), makespan=self.makespan
        )

    def summary(self) -> dict:
        stats = self._staleness_stats()
        return {
            "n_trees": self.n_trees,
            "n_workers": self.n_workers,
            "makespan_s": float(self.makespan),
            "mean_staleness": stats["mean_staleness"],
            "max_staleness": stats["max_staleness"],
            "t_build_mean_s": float(self.t_build.mean()),
            "t_queue_mean_s": float(self.t_queue.mean()),
            "t_fold_mean_s": float(self.t_fold.mean()),
        }

    # ------------------------------------------------------------- trace io
    def to_json(self) -> dict:
        out = {
            "trace_version": _TRACE_VERSION,
            "n_workers": self.n_workers,
            "seed": self.seed,
            "makespan": float(self.makespan),
            "summary": self.summary(),
            "staleness_histogram": {
                str(k): v for k, v in self.staleness_histogram().items()
            },
        }
        for name in _TRACE_ARRAYS:
            out[name] = np.asarray(getattr(self, name)).tolist()
        return out

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "RunTrace":
        d = json.loads(pathlib.Path(path).read_text())
        return cls(
            n_workers=int(d["n_workers"]),
            seed=int(d["seed"]),
            makespan=float(d["makespan"]),
            **{
                name: np.asarray(d[name], dtype)
                for name, dtype in _TRACE_ARRAYS.items()
            },
        )


class AsyncRuntime:
    """W real worker threads against a server fold loop, with tracing.

    ``worker_delay`` injects stragglers: ``{worker_id: seconds}`` slept
    inside that worker's build phase (between pull and push), modeling a
    slow node — its pushes arrive late and stale while the fast workers
    keep folding.
    """

    def __init__(
        self,
        cfg: SGBDTConfig,
        data: BinnedData,
        n_workers: int,
        *,
        worker_delay: Mapping[int, float] | Sequence[float] | None = None,
    ):
        if n_workers < 1:
            raise ValueError(f"need >= 1 worker, got {n_workers}")
        self.cfg = cfg
        self.data = data
        self.n_workers = n_workers
        if worker_delay is None:
            self._delay = {}
        elif isinstance(worker_delay, Mapping):
            self._delay = dict(worker_delay)
        else:
            self._delay = dict(enumerate(worker_delay))
        # Worker and server compile their halves of engine.round_body as
        # separate programs; the seam barrier in round_body keeps them
        # bit-compatible with the fused replay program.
        self._propose = jax.jit(
            lambda data, f_target, rng: propose_tree(cfg, data, f_target, rng)
        )
        self._fold = jax.jit(
            lambda forest, f, tree, delta: server_fold(cfg, forest, f, tree, delta)
        )
        self.trainer = Trainer(cfg)

    # ----------------------------------------------------------------- run
    def run(self, seed: int = 0) -> tuple[TrainState, RunTrace]:
        cfg, data = self.cfg, self.data
        n_trees = cfg.n_trees
        keys = jax.random.split(jax.random.PRNGKey(seed), n_trees)
        state = init_state(cfg, data)

        # Warm the two jit caches outside the timed region so the first
        # worker does not record a compile as a build.
        tree0, delta0 = self._propose(data, state.f, keys[0])
        jax.block_until_ready(
            self._fold(state.forest, state.f, tree0, delta0)
        )

        lock = threading.Lock()  # guards (ticket, version, live f)
        pushes: "queue.Queue[tuple]" = queue.Queue()
        shared = {"ticket": 0, "version": 0, "f": state.f}
        errors: list[BaseException] = []

        def worker(w: int) -> None:
            delay = float(self._delay.get(w, 0.0))
            try:
                while True:
                    with lock:
                        i = shared["ticket"]
                        if i >= n_trees:
                            return
                        shared["ticket"] = i + 1
                        pulled_version = shared["version"]
                        f_snapshot = shared["f"]
                    t0 = time.perf_counter()
                    if delay:
                        time.sleep(delay)
                    tree, delta = self._propose(data, f_snapshot, keys[i])
                    jax.block_until_ready(delta)
                    t_build = time.perf_counter() - t0
                    pushes.put(
                        (i, pulled_version, w, tree, delta, t_build,
                         time.perf_counter())
                    )
            except BaseException as e:  # surface worker crashes to the server
                errors.append(e)
                pushes.put(None)

        threads = [
            threading.Thread(target=worker, args=(w,), daemon=True)
            for w in range(self.n_workers)
        ]
        t_start = time.perf_counter()
        for t in threads:
            t.start()

        rows = {name: np.zeros(n_trees, dtype) for name, dtype in _TRACE_ARRAYS.items()}
        forest, f = state.forest, state.f
        for j in range(n_trees):
            push = pushes.get()
            if push is None:
                raise RuntimeError("async worker failed") from errors[0]
            i, pulled_version, w, tree, delta, t_build, t_pushed = push
            t_fold0 = time.perf_counter()
            forest, f = self._fold(forest, f, tree, delta)
            jax.block_until_ready(f)
            t_fold1 = time.perf_counter()
            with lock:
                shared["version"] = j + 1
                shared["f"] = f
            rows["schedule"][j] = pulled_version
            rows["key_index"][j] = i
            rows["worker"][j] = w
            rows["t_build"][j] = t_build
            rows["t_queue"][j] = t_fold0 - t_pushed
            rows["t_fold"][j] = t_fold1 - t_fold0
        makespan = time.perf_counter() - t_start
        for t in threads:
            t.join()

        trace = RunTrace(
            n_workers=self.n_workers, seed=seed, makespan=makespan, **rows
        )
        # The realized schedule must be a valid causal k(j) and the tickets
        # a permutation — the replay contract's preconditions.
        resolve_schedule(trace.schedule, n_trees)
        assert sorted(trace.key_index) == list(range(n_trees))
        final = TrainState(
            forest=forest, f=f, step=jnp.asarray(n_trees, jnp.int32)
        )
        return final, trace

    # -------------------------------------------------------------- replay
    def replay(self, trace: RunTrace) -> tuple[TrainState, jax.Array]:
        """Re-execute a recorded run deterministically (fused scan form)."""
        return replay_trace(self.cfg, self.data, trace, trainer=self.trainer)


def replay_trace(
    cfg: SGBDTConfig,
    data: BinnedData,
    trace: RunTrace,
    *,
    trainer: Trainer | None = None,
) -> tuple[TrainState, jax.Array]:
    """Replay a ``RunTrace`` through ``Trainer.scan_with``.

    Feeds the realized k(j) and the ticket-permuted per-round keys back
    through the deterministic engine; the returned forest is bit-identical
    to the threaded run that recorded the trace.
    """
    if trace.n_trees != cfg.n_trees:
        raise ValueError(
            f"trace has {trace.n_trees} rounds but cfg.n_trees={cfg.n_trees}"
        )
    if trainer is None:
        trainer = Trainer(cfg)
    keys = jax.random.split(jax.random.PRNGKey(trace.seed), cfg.n_trees)
    rngs = keys[np.asarray(trace.key_index)]
    schedule = resolve_schedule(trace.schedule, cfg.n_trees)
    return trainer.scan_with(
        data, jnp.asarray(schedule), rngs, trace.ring_size
    )
