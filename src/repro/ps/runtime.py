"""Host-asynchronous parameter-server runtime: real threads, recorded k(j),
elastic membership, sharded pulls, crash-resume.

Everything else in ``repro.ps`` *replays* a delay schedule — the simulator
invents k(j), the engine executes it. This module is the other half of the
paper's claim: W real worker threads race a server fold loop, and the
version map k(j) is *realized* by the race, not chosen.

Roles (Algorithm 3, but actually concurrent):

  worker thread  — atomically grab a build ticket ``i`` and a snapshot of
                   the freshest ``(version, F)`` pair, build a tree from it
                   with the ticket's PRNG key (the jitted ``propose_tree``,
                   so concurrent builds overlap in XLA's thread pool), and
                   push ``(ticket, pulled_version, tree, delta)`` onto the
                   server queue;
  server loop    — pop pushes in arrival order, fold each via the jitted
                   ``server_fold``, publish the bumped ``(version, F)``,
                   and append one ``RunTrace`` row.

Determinism by record-and-replay: the interleaving is nondeterministic,
but every folded tree is a pure function of ``(F^{k(j)}, keys[i(j)])``.
``RunTrace`` records the realized schedule k(j) and the ticket permutation
i(j); replaying them through ``Trainer.scan_with`` (one fused lax.scan)
reproduces the threaded run's forest bit for bit. The propose/fold seam is
pinned with an ``optimization_barrier`` in ``engine.round_body`` so the
split-program runtime and the fused replay cannot drift by compilation
form. That replay contract is the core correctness test
(tests/test_runtime.py) and the debugging story: any nondeterministic run
can be re-executed deterministically from its trace.

On top of that contract, this module makes the runtime ELASTIC and
CRASH-SAFE (DESIGN.md §14):

  * ``FaultPlan`` injects deterministic membership faults — crash or
    graceful leave when a chosen ticket is first issued, (re)join when the
    server reaches a chosen fold count. A crashed ticket is re-issued, so
    ``key_index`` stays a permutation and the trace still replays exactly;
    every membership change is recorded as a trace EVENT and bumps the
    membership EPOCH each row is attributed to.
  * ``shard_pulls = P`` shards the server's leaf table (the F vector) into
    P contiguous row partitions: a worker derives its Bernoulli sample
    from the ticket key FIRST and pulls only the partitions its sampled
    rows touch (rowwise objectives only). Unpulled rows are zero-filled —
    bitwise harmless, because unsampled rows carry m' = 0 and are inert in
    the tree build — and the realized ``pull_bytes`` land in the trace.
  * periodic runtime checkpoints save the server state AND every F version
    still referenced by an in-flight build, so any recorded trace suffix
    replays from the checkpoint alone (``replay_from_checkpoint``), and a
    killed run resumes from checkpoint + trace prefix (``resume``) with
    the lost in-flight tickets re-issued to the new worker set.
  * with ``cfg.adaptive_step = rho``, the server deflates each fold by the
    Prop.-1 rule 1/(1 + 6*rho*tau_j) using the staleness OBSERVED at fold
    time (``engine.scale_push``), and the realized per-fold scales are
    recorded for cross-validation against the event simulator.

The trace also carries measured per-phase wall times, which parameterize
``core.simulator.ClusterSpec`` — realized staleness vs. the event model's
prediction for the same geometry is the cross-validation
(``RunTrace.crossvalidate`` / ``benchmarks.fig10_speedup`` row
``runtime_measured``).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import pathlib
import queue
import threading
import time
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store as ckpt_store
from repro.core.sgbdt import SGBDTConfig, TrainState, init_state
from repro.data.sampling import bernoulli_weights
from repro.ps.engine import (
    Trainer,
    propose_tree,
    scale_push,
    server_fold,
    staleness_scale,
)
from repro.ps.schedules import max_staleness, resolve_schedule
from repro.trees.binning import BinnedData

_TRACE_VERSION = 2
# Row arrays by the schema version that introduced them. v1 traces load
# forever (the defaults reconstruct pre-elastic semantics: one epoch, no
# events, unrecorded pull bytes, fixed step).
_ARRAYS_V1 = {
    "schedule": np.int32,
    "key_index": np.int32,
    "worker": np.int32,
    "t_build": np.float64,
    "t_queue": np.float64,
    "t_fold": np.float64,
}
_ARRAYS_V2 = {
    **_ARRAYS_V1,
    "epoch": np.int32,
    "pull_bytes": np.int64,
    "step_scale": np.float32,
}
_SCALARS_V1 = {"trace_version", "n_workers", "seed", "makespan"}
_SCALARS_V2 = _SCALARS_V1 | {"n_parts", "full_pull_bytes", "adaptive_rho"}
# Saved for humans/dashboards; recomputed from the arrays on load.
_DERIVED = {"summary", "staleness_histogram"}
_KNOWN_FIELDS = {
    1: set(_ARRAYS_V1) | _SCALARS_V1 | _DERIVED,
    2: set(_ARRAYS_V2) | _SCALARS_V2 | {"events"} | _DERIVED,
}

_EVENT_KINDS = ("join", "leave", "crash", "resume")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault injection for ``AsyncRuntime``.

    ``crash_tickets``  — crash the worker that FIRST draws each listed
                         ticket: the ticket is returned to the pool
                         (another worker rebuilds it), the thread dies,
                         and a ``crash`` event is recorded. Re-issues of
                         the same ticket do not crash again.
    ``leave_tickets``  — graceful leave: the worker that draws the ticket
                         builds and pushes it, then deregisters (a
                         ``leave`` event; no work is lost).
    ``join_at``        — ``{worker_id: fold_count}``: start a (new or
                         rejoining) worker thread with that id once the
                         server has folded ``fold_count`` trees.

    All three key off deterministic counters (ticket numbers, fold
    counts), not wall time — the same plan on the same geometry produces
    the same membership event set, and the resulting trace replays
    bit-for-bit like any other.
    """

    crash_tickets: frozenset = frozenset()
    leave_tickets: frozenset = frozenset()
    join_at: Mapping[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(
            self, "crash_tickets", frozenset(int(t) for t in self.crash_tickets)
        )
        object.__setattr__(
            self, "leave_tickets", frozenset(int(t) for t in self.leave_tickets)
        )
        object.__setattr__(
            self, "join_at", {int(w): int(j) for w, j in dict(self.join_at).items()}
        )
        if self.crash_tickets & self.leave_tickets:
            raise ValueError("a ticket cannot both crash and leave its worker")
        if any(t < 0 for t in self.crash_tickets | self.leave_tickets):
            raise ValueError("fault tickets must be >= 0")
        if any(j < 0 for j in self.join_at.values()):
            raise ValueError("join_at fold counts must be >= 0")

    @property
    def empty(self) -> bool:
        return not (self.crash_tickets or self.leave_tickets or self.join_at)


@dataclasses.dataclass(frozen=True)
class RunTrace:
    """The realized execution of one threaded run — enough to replay it.

    Row j describes server update j (fold order):
      schedule[j]   — k(j): the version the folded tree was built from;
      key_index[j]  — i(j): the build ticket, i.e. ``keys[i(j)]`` was the
                      round key (a permutation of ``arange(n_trees)``);
      worker[j]     — which worker thread built it;
      epoch[j]      — the membership epoch the build STARTED in (bumped by
                      every join/leave/crash/resume event);
      pull_bytes[j] — bytes the build's leaf-table pull actually moved
                      (full table, or only the touched partitions under
                      ``shard_pulls``);
      step_scale[j] — the staleness-adaptive deflation the server applied
                      at fold time (1.0 when ``adaptive_rho == 0``);
      t_build[j]    — wall seconds of the (blocking) jitted build;
      t_queue[j]    — push-to-fold-start wait in the server queue;
      t_fold[j]     — wall seconds of the jitted server fold.

    ``events`` is the membership log: tuples of dicts with ``kind`` in
    ``join | leave | crash | resume``, the worker, the fold count and
    ticket at which the event fired, and the epoch it opened.
    """

    n_workers: int
    seed: int
    schedule: np.ndarray
    key_index: np.ndarray
    worker: np.ndarray
    t_build: np.ndarray
    t_queue: np.ndarray
    t_fold: np.ndarray
    makespan: float
    epoch: np.ndarray | None = None
    pull_bytes: np.ndarray | None = None
    step_scale: np.ndarray | None = None
    events: tuple = ()
    n_parts: int = 0
    full_pull_bytes: int = 0
    adaptive_rho: float = 0.0

    def __post_init__(self):
        n = len(np.asarray(self.schedule))
        fills = {
            "epoch": np.zeros(n, np.int32),
            "pull_bytes": np.full(n, int(self.full_pull_bytes), np.int64),
            "step_scale": np.ones(n, np.float32),
        }
        for name, dtype in _ARRAYS_V2.items():
            val = getattr(self, name)
            if val is None:
                val = fills[name]
            object.__setattr__(self, name, np.asarray(val, dtype))
            if getattr(self, name).shape != (n,):
                raise ValueError(f"trace array {name!r} is not shaped ({n},)")
        events = tuple(dict(e) for e in self.events)
        for e in events:
            if e.get("kind") not in _EVENT_KINDS:
                raise ValueError(f"unknown membership event kind: {e!r}")
        object.__setattr__(self, "events", events)

    @property
    def n_trees(self) -> int:
        return len(self.schedule)

    @property
    def staleness(self) -> np.ndarray:
        return np.arange(self.n_trees) - self.schedule

    @property
    def ring_size(self) -> int:
        return max_staleness(self.schedule) + 1

    @property
    def n_epochs(self) -> int:
        return int(self.epoch.max()) + 1 if self.n_trees else 1

    def membership_deltas(self) -> list[tuple[int, int]]:
        """``(fold, +-1)`` worker-count changes, the shape
        ``core.simulator.simulate_elastic`` takes as ``membership``."""
        out = []
        for e in self.events:
            if e["kind"] == "join":
                out.append((int(e["fold"]), 1))
            elif e["kind"] in ("leave", "crash"):
                out.append((int(e["fold"]), -1))
        return out

    def staleness_histogram(self) -> dict[int, int]:
        return self._staleness_stats()["histogram"]

    def _staleness_stats(self) -> dict:
        from repro.core.simulator import staleness_stats

        return staleness_stats(self.schedule)

    def cluster_spec(self, **overrides):
        """A ``ClusterSpec`` parameterized by this run's measured phases.

        ``t_comm`` maps to the in-process queue handoff (there is no wire
        here); jitter/spread coefficients keep their defaults unless
        overridden.
        """
        from repro.core.simulator import ClusterSpec

        args = dict(
            n_workers=self.n_workers,
            t_build=float(self.t_build.mean()),
            t_comm=float(self.t_queue.mean()),
            t_server=float(self.t_fold.mean()),
            seed=self.seed,
        )
        args.update(overrides)
        return ClusterSpec(**args)

    def crossvalidate(self, **spec_overrides) -> dict:
        """Realized staleness vs. the event-driven simulator's prediction
        for the same cluster geometry — elastic runs forward their
        membership deltas to ``simulate_elastic``, adaptive runs also get
        realized-vs-predicted effective-step statistics
        (``core.simulator.crossvalidate_schedule``)."""
        from repro.core.simulator import crossvalidate_schedule

        return crossvalidate_schedule(
            self.schedule,
            self.cluster_spec(**spec_overrides),
            makespan=self.makespan,
            membership=self.membership_deltas(),
            adaptive_rho=self.adaptive_rho,
        )

    def summary(self) -> dict:
        stats = self._staleness_stats()
        out = {
            "n_trees": self.n_trees,
            "n_workers": self.n_workers,
            "makespan_s": float(self.makespan),
            "mean_staleness": stats["mean_staleness"],
            "max_staleness": stats["max_staleness"],
            "t_build_mean_s": float(self.t_build.mean()),
            "t_queue_mean_s": float(self.t_queue.mean()),
            "t_fold_mean_s": float(self.t_fold.mean()),
            "n_epochs": self.n_epochs,
            "n_events": len(self.events),
        }
        if self.n_parts and self.full_pull_bytes:
            out["pull_bytes_mean"] = float(self.pull_bytes.mean())
            out["pull_bytes_full"] = int(self.full_pull_bytes)
            out["pull_reduction"] = 1.0 - float(self.pull_bytes.mean()) / float(
                self.full_pull_bytes
            )
        if self.adaptive_rho:
            out["step_scale_mean"] = float(self.step_scale.mean())
        return out

    # ------------------------------------------------------------- trace io
    def to_json(self) -> dict:
        out = {
            "trace_version": _TRACE_VERSION,
            "n_workers": self.n_workers,
            "seed": self.seed,
            "makespan": float(self.makespan),
            "n_parts": int(self.n_parts),
            "full_pull_bytes": int(self.full_pull_bytes),
            "adaptive_rho": float(self.adaptive_rho),
            "events": list(self.events),
            "summary": self.summary(),
            "staleness_histogram": {
                str(k): v for k, v in self.staleness_histogram().items()
            },
        }
        for name in _ARRAYS_V2:
            out[name] = np.asarray(getattr(self, name)).tolist()
        return out

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=1))
        tmp.replace(path)  # atomic: a crash mid-write never truncates
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "RunTrace":
        """Version-tagged loader: v1 and v2 traces load; anything else —
        an unknown version, a missing tag, or fields no schema defines —
        fails LOUDLY instead of being silently dropped (a trace that does
        not fully round-trip is a replay you cannot trust)."""
        d = json.loads(pathlib.Path(path).read_text())
        version = d.get("trace_version")
        if version not in _KNOWN_FIELDS:
            raise ValueError(
                f"{path}: unknown RunTrace schema version {version!r} "
                f"(this build reads {sorted(_KNOWN_FIELDS)}); refusing to "
                "guess at field semantics"
            )
        unknown = set(d) - _KNOWN_FIELDS[version]
        if unknown:
            raise ValueError(
                f"{path}: fields {sorted(unknown)} are not part of trace "
                f"schema v{version}; refusing to silently drop them"
            )
        arrays = _ARRAYS_V1 if version == 1 else _ARRAYS_V2
        kw = {
            name: np.asarray(d[name], dtype) for name, dtype in arrays.items()
        }
        if version >= 2:
            kw.update(
                events=tuple(d.get("events", ())),
                n_parts=int(d.get("n_parts", 0)),
                full_pull_bytes=int(d.get("full_pull_bytes", 0)),
                adaptive_rho=float(d.get("adaptive_rho", 0.0)),
            )
        return cls(
            n_workers=int(d["n_workers"]),
            seed=int(d["seed"]),
            makespan=float(d["makespan"]),
            **kw,
        )


class _LeafTableShards:
    """Contiguous row partitioning of the server's leaf table (the F
    vector) plus the jitted partial-pull: mask F to the partitions the
    ticket's Bernoulli sample touches and account the realized bytes
    (a P-bit request bitmap + 4 bytes per pulled row per output).

    Why masking is exact: the Bernoulli mask depends only on the ticket
    key, never on F, so the worker knows its sampled rows BEFORE pulling;
    every unsampled row carries importance weight m' = +0.0, and for a
    rowwise objective that row's (wrong) gradient enters the build only as
    ``0.0 * g`` — a signed zero — so histogram sums, splits, and leaves
    match the full-pull build. The one residual is IEEE zero SIGN:
    ``0.0 * g`` keeps g's sign, so a leaf summing ONLY unsampled rows can
    flip -0.0/+0.0 if the masked gradient's sign differs from the true
    one. For logloss/softmax the gradient sign is label-determined
    (independent of F), closing even that corner; value-dependent-sign
    objectives (squared error) are bitwise-equal up to zero signs.
    """

    def __init__(self, cfg: SGBDTConfig, data: BinnedData, n_parts: int):
        n = data.n_samples
        if not 1 <= n_parts <= n:
            raise ValueError(
                f"shard_pulls must be in [1, n_samples={n}], got {n_parts}"
            )
        if not cfg.obj.rowwise:
            raise ValueError(
                f"objective {cfg.obj.name!r} is not rowwise (its gradients "
                "mix rows); sharded leaf-table pulls need the full table"
            )
        self.n_parts = n_parts
        sizes = np.full(n_parts, n // n_parts, np.int32)
        sizes[: n % n_parts] += 1
        self.part_sizes = sizes
        self.part_ids = np.repeat(np.arange(n_parts, dtype=np.int32), sizes)
        self.request_bytes = (n_parts + 7) // 8
        k_out = cfg.obj.n_outputs
        part_ids = jnp.asarray(self.part_ids)
        part_sizes = jnp.asarray(sizes, jnp.int32)

        def pull(f, rng):
            # The SAME split propose_tree does: the sample mask is a pure
            # function of the ticket key, so worker and replay agree on Q.
            r_sample, _ = jax.random.split(rng)
            _, q_any = bernoulli_weights(
                r_sample, cfg.sampling_rate, data.multiplicity
            )
            touched = (
                jnp.zeros(n_parts, jnp.int32)
                .at[part_ids]
                .max(q_any.astype(jnp.int32))
            ) > 0
            row_mask = touched[part_ids]
            mask = row_mask if f.ndim == 1 else row_mask[:, None]
            f_masked = jnp.where(mask, f, jnp.float32(0.0))
            pulled_rows = jnp.sum(jnp.where(touched, part_sizes, 0))
            return f_masked, 4 * k_out * pulled_rows + self.request_bytes

        self._pull = jax.jit(pull)

    def pull(self, f, rng) -> tuple[jax.Array, int]:
        f_masked, nbytes = self._pull(f, rng)
        return f_masked, int(nbytes)


class AsyncRuntime:
    """W real worker threads against a server fold loop, with tracing.

    ``worker_delay`` injects stragglers: ``{worker_id: seconds}`` slept
    inside that worker's build phase (between pull and push), modeling a
    slow node — its pushes arrive late and stale while the fast workers
    keep folding. ``faults`` injects deterministic membership churn
    (``FaultPlan``); ``shard_pulls = P`` enables partition-granular leaf
    table pulls. ``cfg.adaptive_step = rho > 0`` turns on the
    staleness-adaptive server fold.
    """

    def __init__(
        self,
        cfg: SGBDTConfig,
        data: BinnedData,
        n_workers: int,
        *,
        worker_delay: Mapping[int, float] | Sequence[float] | None = None,
        faults: FaultPlan | None = None,
        shard_pulls: int = 0,
    ):
        if n_workers < 1:
            raise ValueError(f"need >= 1 worker, got {n_workers}")
        self.cfg = cfg
        self.data = data
        self.n_workers = n_workers
        self.faults = faults if faults is not None else FaultPlan()
        if any(j > cfg.n_trees for j in self.faults.join_at.values()):
            raise ValueError("join_at fold count beyond the end of the run")
        self.shards = (
            _LeafTableShards(cfg, data, shard_pulls) if shard_pulls else None
        )
        self.full_pull_bytes = 4 * cfg.obj.n_outputs * data.n_samples
        if worker_delay is None:
            self._delay = {}
        elif isinstance(worker_delay, Mapping):
            self._delay = dict(worker_delay)
        else:
            self._delay = dict(enumerate(worker_delay))
        # Worker and server compile their halves of engine.round_body as
        # separate programs; the seam barrier in round_body keeps them
        # bit-compatible with the fused replay program. The fold takes the
        # observed staleness so the adaptive deflation (when enabled)
        # happens exactly where the physical program boundary sits.
        self._propose = jax.jit(
            lambda data, f_target, rng: propose_tree(cfg, data, f_target, rng)
        )
        if cfg.adaptive_step:

            def fold(forest, f, tree, delta, stale):
                del delta  # the adaptive server re-derives it (scale_push)
                scale = staleness_scale(cfg.adaptive_step, stale)
                tree, delta = scale_push(cfg, data, tree, scale)
                return server_fold(cfg, forest, f, tree, delta)

        else:

            def fold(forest, f, tree, delta, stale):
                del stale
                return server_fold(cfg, forest, f, tree, delta)

        self._fold = jax.jit(fold)
        self.trainer = Trainer(cfg)

    # ----------------------------------------------------------------- run
    def run(
        self,
        seed: int = 0,
        *,
        checkpoint_dir: str | pathlib.Path | None = None,
        checkpoint_every: int = 0,
        halt_at_fold: int | None = None,
        trace_path: str | pathlib.Path | None = None,
    ) -> tuple[TrainState, RunTrace]:
        """Run the threaded PS loop from scratch.

        ``checkpoint_dir`` + ``checkpoint_every`` write a runtime
        checkpoint every K folds (server state + every F version an
        in-flight build still references — see ``replay_from_checkpoint``).
        ``halt_at_fold = J`` simulates a whole-process crash: the server
        stops after J folds and returns the PREFIX trace (workers are
        abandoned); resume later with ``resume``. ``trace_path`` appends
        the trace to disk after every fold, so a real crash leaves a
        loadable prefix behind.
        """
        state = init_state(self.cfg, self.data)
        return self._execute(
            seed,
            forest=state.forest,
            f=state.f,
            start_fold=0,
            pending=list(range(self.cfg.n_trees)),
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            halt_at_fold=halt_at_fold,
            trace_path=trace_path,
        )

    def resume(
        self,
        prefix: RunTrace,
        checkpoint_dir: str | pathlib.Path,
        *,
        checkpoint_every: int = 0,
        halt_at_fold: int | None = None,
        trace_path: str | pathlib.Path | None = None,
    ) -> tuple[TrainState, RunTrace]:
        """Resume a killed run from its checkpoint + trace prefix.

        Reconstructs the server state at ``prefix.n_trees`` folds by
        loading the newest checkpoint at or before the prefix end and
        deterministically replaying the prefix rows past it, then
        CONTINUES the threaded run: tickets the prefix never folded
        (including any that were in flight at the crash) are re-issued to
        this runtime's worker set. Returns the final state plus the
        COMBINED trace — prefix rows verbatim, continuation rows appended,
        a ``resume`` membership event marking the seam — which replays
        bit-for-bit through ``Trainer.scan_with`` like any other trace.
        """
        j_prefix = prefix.n_trees
        if j_prefix >= self.cfg.n_trees:
            raise ValueError(
                f"prefix already has {j_prefix} folds; nothing to resume "
                f"for cfg.n_trees={self.cfg.n_trees}"
            )
        forest, f, versions = self._restore_to_fold(
            checkpoint_dir, prefix, j_prefix, seed=prefix.seed
        )
        del versions  # continuation workers pull the current version only
        folded = set(int(i) for i in prefix.key_index)
        pending = sorted(set(range(self.cfg.n_trees)) - folded)
        last_epoch = int(prefix.epoch.max()) if j_prefix else 0
        last_epoch = max(
            [last_epoch] + [int(e["epoch"]) for e in prefix.events]
        )
        epoch0 = last_epoch + 1
        resume_event = {
            "kind": "resume",
            "worker": -1,
            "ticket": -1,
            "fold": j_prefix,
            "epoch": epoch0,
        }
        prefix_rows = {
            name: np.asarray(getattr(prefix, name)) for name in _ARRAYS_V2
        }
        return self._execute(
            prefix.seed,
            forest=forest,
            f=f,
            start_fold=j_prefix,
            pending=pending,
            prefix_rows=prefix_rows,
            base_events=prefix.events + (resume_event,),
            base_epoch=epoch0,
            base_makespan=float(prefix.makespan),
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            halt_at_fold=halt_at_fold,
            trace_path=trace_path,
        )

    # ------------------------------------------------------- replay/resume
    def replay_from_checkpoint(
        self,
        checkpoint_dir: str | pathlib.Path,
        trace: RunTrace,
    ) -> TrainState:
        """Deterministically re-execute ``trace``'s suffix from the newest
        checkpoint at or before its end — the crash-resume core, minus the
        threads. Because the checkpoint stashes every F version in-flight
        builds referenced, any suffix row's ``F^{k(j)}`` is available, and
        the same jitted propose/fold programs the threaded run used
        reproduce its forest bit for bit."""
        forest, f, _ = self._restore_to_fold(
            checkpoint_dir, trace, trace.n_trees, seed=trace.seed
        )
        return TrainState(
            forest=forest, f=f, step=jnp.asarray(trace.n_trees, jnp.int32)
        )

    def _restore_to_fold(self, checkpoint_dir, trace, upto: int, seed: int):
        """(forest, f, versions) at fold ``upto``: newest checkpoint <=
        ``upto``, then replay trace rows [ckpt_step, upto)."""
        avail = [s for s in ckpt_store.steps(checkpoint_dir) if s <= upto]
        if not avail:
            raise ValueError(
                f"no checkpoint at or before fold {upto} under "
                f"{checkpoint_dir}"
            )
        step = avail[-1]
        ckpt = self._load_checkpoint(checkpoint_dir, step)
        forest, f = ckpt["forest"], ckpt["f"]
        versions = {
            int(v): ckpt["held_f"][i]
            for i, v in enumerate(np.asarray(ckpt["held_versions"]).tolist())
        }
        versions[step] = f
        schedule = np.asarray(trace.schedule)
        key_index = np.asarray(trace.key_index)
        keys = jax.random.split(jax.random.PRNGKey(seed), self.cfg.n_trees)
        # last fold that still reads each version, for GC as we go
        last_use = {int(k): j for j, k in enumerate(schedule[:upto])}
        for j in range(step, upto):
            k = int(schedule[j])
            if k not in versions:
                raise ValueError(
                    f"checkpoint step {step} cannot serve F^{k} needed by "
                    f"fold {j}: the trace and checkpoint are from different "
                    "runs, or the checkpoint predates this schema"
                )
            tree, delta = self._propose(self.data, versions[k], keys[key_index[j]])
            forest, f = self._fold(forest, f, tree, delta, jnp.int32(j - k))
            versions[j + 1] = f
            for v in [v for v, last in last_use.items() if last == j]:
                if v in versions and v != j + 1:
                    del versions[v]
        return forest, f, versions

    def _load_checkpoint(self, checkpoint_dir, step: int) -> dict:
        manifest = ckpt_store.leaf_manifest(checkpoint_dir, step)
        held_shape = next(
            tuple(e["shape"])
            for p, e in manifest.items()
            if "held_f" in p
        )
        state = init_state(self.cfg, self.data)
        like = {
            "forest": state.forest,
            "f": state.f,
            "step": np.zeros((), np.int32),
            "held_versions": np.zeros(held_shape[0], np.int32),
            "held_f": np.zeros(held_shape, np.float32),
        }
        return ckpt_store.restore_pytree(checkpoint_dir, step, like)

    # ------------------------------------------------------- threaded core
    def _execute(
        self,
        seed: int,
        *,
        forest,
        f,
        start_fold: int,
        pending: list[int],
        prefix_rows: dict | None = None,
        base_events: tuple = (),
        base_epoch: int = 0,
        base_makespan: float = 0.0,
        checkpoint_dir=None,
        checkpoint_every: int = 0,
        halt_at_fold: int | None = None,
        trace_path=None,
    ) -> tuple[TrainState, RunTrace]:
        cfg, data = self.cfg, self.data
        n_trees = cfg.n_trees
        end_fold = n_trees if halt_at_fold is None else int(halt_at_fold)
        if not start_fold < end_fold <= n_trees:
            raise ValueError(
                f"halt_at_fold must be in ({start_fold}, {n_trees}], "
                f"got {halt_at_fold}"
            )
        keys = jax.random.split(jax.random.PRNGKey(seed), n_trees)

        # Warm the jit caches outside the timed region so the first worker
        # does not record a compile as a build.
        tree0, delta0 = self._propose(data, f, keys[0])
        jax.block_until_ready(
            self._fold(forest, f, tree0, delta0, jnp.int32(0))
        )
        if self.shards is not None:
            self.shards.pull(f, keys[0])

        lock = threading.Lock()
        pushes: "queue.Queue[tuple]" = queue.Queue()
        # Everything workers and the fold loop both touch is guarded-by
        # `lock`; repro.analysis.locks enforces the annotations lexically.
        shared = {  # guarded-by: lock
            "version": start_fold,
            "f": f,
            "epoch": base_epoch,
            "fold": start_fold,
            "live": set(),
            # Tickets whose first issue already crashed — seeded from the
            # prefix on resume, so a re-issued ticket never crashes twice.
            "crashed": {
                int(e["ticket"]) for e in base_events if e["kind"] == "crash"
            },
        }
        ticket_heap = list(pending)  # guarded-by: lock
        heapq.heapify(ticket_heap)
        f_by_version: dict[int, jax.Array] = {start_fold: f}  # guarded-by: lock
        refcnt: dict[int, int] = {}  # guarded-by: lock
        events: list[dict] = list(base_events)  # guarded-by: lock
        errors: list[BaseException] = []
        joins = dict(self.faults.join_at)  # guarded-by: lock
        plan = self.faults

        def worker(w: int) -> None:
            delay = float(self._delay.get(w, 0.0))
            try:
                while True:
                    with lock:
                        if not ticket_heap:
                            shared["live"].discard(w)
                            return
                        i = heapq.heappop(ticket_heap)
                        if i in plan.crash_tickets and i not in shared["crashed"]:
                            # Crash: the ticket bounces back for re-issue,
                            # this thread dies. Atomic under the lock, so
                            # no sibling ever observes the ticket missing.
                            shared["crashed"].add(i)
                            heapq.heappush(ticket_heap, i)
                            shared["epoch"] += 1
                            shared["live"].discard(w)
                            events.append({
                                "kind": "crash", "worker": w, "ticket": i,
                                "fold": shared["fold"],
                                "epoch": shared["epoch"],
                            })
                            return
                        pulled_version = shared["version"]
                        f_snapshot = shared["f"]
                        refcnt[pulled_version] = refcnt.get(pulled_version, 0) + 1
                        my_epoch = shared["epoch"]
                    t0 = time.perf_counter()
                    if delay:
                        time.sleep(delay)
                    if self.shards is not None:
                        f_used, nbytes = self.shards.pull(f_snapshot, keys[i])
                    else:
                        f_used, nbytes = f_snapshot, self.full_pull_bytes
                    tree, delta = self._propose(data, f_used, keys[i])
                    jax.block_until_ready(delta)
                    t_build = time.perf_counter() - t0
                    pushes.put(
                        (i, pulled_version, w, my_epoch, nbytes, tree, delta,
                         t_build, time.perf_counter())
                    )
                    if i in plan.leave_tickets:
                        with lock:
                            shared["epoch"] += 1
                            shared["live"].discard(w)
                            events.append({
                                "kind": "leave", "worker": w, "ticket": i,
                                "fold": shared["fold"],
                                "epoch": shared["epoch"],
                            })
                        return
            except BaseException as e:  # surface worker crashes to the server
                errors.append(e)
                pushes.put(None)

        def start_worker(w: int) -> threading.Thread:  # holds-lock: lock
            shared["live"].add(w)
            t = threading.Thread(target=worker, args=(w,), daemon=True)
            t.start()
            return t

        def fire_joins(fold: int) -> None:  # holds-lock: lock
            for w in [w for w, at in joins.items() if at <= fold]:
                del joins[w]
                shared["epoch"] += 1
                events.append({
                    "kind": "join", "worker": w, "ticket": -1,
                    "fold": fold, "epoch": shared["epoch"],
                })
                threads.append(start_worker(w))

        rows = {
            name: np.zeros(n_trees, dtype) for name, dtype in _ARRAYS_V2.items()
        }
        if prefix_rows is not None:
            for name in _ARRAYS_V2:
                rows[name][:start_fold] = prefix_rows[name][:start_fold]

        rho = float(cfg.adaptive_step)
        threads: list[threading.Thread] = []
        t_start = time.perf_counter()
        with lock:
            for w in range(self.n_workers):
                threads.append(start_worker(w))
            fire_joins(start_fold)

        def partial_trace(upto: int, makespan: float) -> RunTrace:  # concurrent
            # Runs on the server thread, but after a simulated halt the
            # abandoned daemon workers may still be appending events —
            # snapshot under the lock instead of iterating a live list.
            with lock:
                events_snapshot = tuple(events)
            return RunTrace(
                n_workers=self.n_workers,
                seed=seed,
                makespan=makespan,
                events=events_snapshot,
                n_parts=self.shards.n_parts if self.shards else 0,
                full_pull_bytes=self.full_pull_bytes,
                adaptive_rho=rho,
                **{name: rows[name][:upto].copy() for name in _ARRAYS_V2},
            )

        j = start_fold
        while j < end_fold:
            try:
                push = pushes.get(timeout=1.0)
            except queue.Empty:
                with lock:
                    stuck = not shared["live"] and not joins
                if stuck:
                    raise RuntimeError(
                        f"no live workers and no pending joins with "
                        f"{end_fold - j} folds outstanding — the fault plan "
                        "killed everyone (rejoins fire on fold counts; a "
                        "rejoin threshold no surviving worker can reach "
                        "deadlocks the run)"
                    )
                continue
            if push is None:
                raise RuntimeError("async worker failed") from errors[0]
            (i, pulled_version, w, my_epoch, nbytes, tree, delta,
             t_build, t_pushed) = push
            t_fold0 = time.perf_counter()
            forest, f = self._fold(
                forest, f, tree, delta, jnp.int32(j - pulled_version)
            )
            jax.block_until_ready(f)
            t_fold1 = time.perf_counter()
            with lock:
                shared["version"] = j + 1
                shared["f"] = f
                shared["fold"] = j + 1
                f_by_version[j + 1] = f
                refcnt[pulled_version] -= 1
                for v in [v for v, c in refcnt.items() if c <= 0]:
                    del refcnt[v]
                # Keep only versions a still-in-flight build references,
                # plus the current one; everything else is garbage.
                for v in [
                    v for v in f_by_version if v != j + 1 and v not in refcnt
                ]:
                    del f_by_version[v]
                fire_joins(j + 1)
                held = sorted(v for v, c in refcnt.items() if c > 0)
                held_f = [f_by_version[v] for v in held]
            rows["schedule"][j] = pulled_version
            rows["key_index"][j] = i
            rows["worker"][j] = w
            rows["epoch"][j] = my_epoch
            rows["pull_bytes"][j] = nbytes
            # Same f32 rounding as engine.staleness_scale: 6*rho rounds
            # once from python f64, then one f32 mul + add + divide.
            rows["step_scale"][j] = (
                np.float32(1.0)
                / (np.float32(1.0) + np.float32(6.0 * rho) * np.float32(j - pulled_version))
                if rho
                else np.float32(1.0)
            )
            rows["t_build"][j] = t_build
            rows["t_queue"][j] = t_fold0 - t_pushed
            rows["t_fold"][j] = t_fold1 - t_fold0
            j += 1
            if checkpoint_dir is not None and checkpoint_every and (
                j % checkpoint_every == 0 or j == end_fold
            ):
                self._save_checkpoint(checkpoint_dir, j, forest, f, held, held_f)
            if trace_path is not None:
                partial_trace(
                    j, base_makespan + time.perf_counter() - t_start
                ).save(trace_path)

        makespan = base_makespan + time.perf_counter() - t_start
        if halt_at_fold is None:
            for t in threads:
                t.join()
        # else: simulated process crash — abandon the daemon workers.

        trace = partial_trace(end_fold, makespan)
        if trace_path is not None:
            trace.save(trace_path)
        if halt_at_fold is None:
            # The realized schedule must be a valid causal k(j) and the
            # tickets a permutation — the replay contract's preconditions.
            resolve_schedule(trace.schedule, n_trees)
            assert sorted(trace.key_index) == list(range(n_trees))
        final = TrainState(
            forest=forest, f=f, step=jnp.asarray(end_fold, jnp.int32)
        )
        return final, trace

    def _save_checkpoint(
        self, checkpoint_dir, fold: int, forest, f, held, held_f
    ) -> None:
        """Server state at ``fold`` plus the stale F versions in-flight
        builds still reference — exactly what a trace-suffix replay needs
        (every suffix row's k(j) is either >= fold or held by a build that
        had pulled it before the checkpoint)."""
        f_np = np.asarray(f)
        stacked = (
            np.stack([np.asarray(x) for x in held_f])
            if held_f
            else np.zeros((0,) + f_np.shape, np.float32)
        )
        ckpt_store.save_pytree(
            checkpoint_dir,
            fold,
            {
                "forest": forest,
                "f": f,
                "step": np.asarray(fold, np.int32),
                "held_versions": np.asarray(held, np.int32),
                "held_f": stacked,
            },
        )

    # -------------------------------------------------------------- replay
    def replay(self, trace: RunTrace) -> tuple[TrainState, jax.Array]:
        """Re-execute a recorded run deterministically (fused scan form)."""
        return replay_trace(self.cfg, self.data, trace, trainer=self.trainer)


def replay_trace(
    cfg: SGBDTConfig,
    data: BinnedData,
    trace: RunTrace,
    *,
    trainer: Trainer | None = None,
) -> tuple[TrainState, jax.Array]:
    """Replay a ``RunTrace`` through ``Trainer.scan_with``.

    Feeds the realized k(j) and the ticket-permuted per-round keys back
    through the deterministic engine; the returned forest is bit-identical
    to the threaded run that recorded the trace. Elastic traces replay the
    same way: membership only decided WHICH worker realized each
    (k(j), i(j)) row, never the row's math.
    """
    if trace.n_trees != cfg.n_trees:
        raise ValueError(
            f"trace has {trace.n_trees} rounds but cfg.n_trees={cfg.n_trees}"
        )
    if float(trace.adaptive_rho) != float(cfg.adaptive_step):
        raise ValueError(
            f"trace was recorded with adaptive_rho={trace.adaptive_rho} but "
            f"cfg.adaptive_step={cfg.adaptive_step}: the replayed folds "
            "would apply different step scales"
        )
    if trainer is None:
        trainer = Trainer(cfg)
    keys = jax.random.split(jax.random.PRNGKey(trace.seed), cfg.n_trees)
    rngs = keys[np.asarray(trace.key_index)]
    schedule = resolve_schedule(trace.schedule, cfg.n_trees)
    return trainer.scan_with(
        data, jnp.asarray(schedule), rngs, trace.ring_size
    )
