"""Parameter-server execution layer (the paper's Algorithm 3, productized).

  * ``engine``    — the single Trainer API + the one shared round body
                    (worker ``propose_tree`` / server ``server_fold``).
  * ``schedules`` — delay-schedule providers k(j): closed forms, realized
                    arrays, or on-the-spot cluster simulation.
  * ``worker``    — the worker pool as one vmapped multi-tree build
                    (the executable Fig. 10 speedup path).
  * ``runtime``   — REAL host asynchrony: W worker threads race a server
                    fold loop, the realized k(j) is recorded into a
                    ``RunTrace``, and replaying the trace through the
                    deterministic engine reproduces the forest exactly.
  * ``sharded``   — shard_map data-parallel builds: per-shard histogram
                    kernels merged with a psum over the 'data' mesh axis.
"""
from repro.ps.engine import (
    Trainer,
    clear_trainers,
    get_trainer,
    propose_tree,
    round_body,
    scale_push,
    server_fold,
    staleness_scale,
    train,
)
from repro.ps.runtime import AsyncRuntime, FaultPlan, RunTrace, replay_trace
from repro.ps.schedules import (
    constant_delay,
    max_staleness,
    resolve_schedule,
    staleness_scales,
    worker_round_robin,
)
from repro.ps.sharded import build_histogram_sharded, make_sharded_builder
from repro.ps.worker import build_trees_batched, train_worker_parallel

__all__ = [
    "AsyncRuntime",
    "FaultPlan",
    "RunTrace",
    "replay_trace",
    "Trainer",
    "clear_trainers",
    "get_trainer",
    "propose_tree",
    "round_body",
    "scale_push",
    "server_fold",
    "staleness_scale",
    "train",
    "constant_delay",
    "max_staleness",
    "resolve_schedule",
    "staleness_scales",
    "worker_round_robin",
    "build_histogram_sharded",
    "make_sharded_builder",
    "build_trees_batched",
    "train_worker_parallel",
]
