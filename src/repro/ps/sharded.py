"""Data-parallel tree building: ``shard_map`` workers + ``psum`` merge.

The block-distributed GBT / DimBoost production shape: every shard of the
``'data'`` mesh axis runs the histogram kernel on its local samples only,
and the level histogram is merged with one ``psum`` across the axis — the
server-side aggregation of the paper's parameter server, executed as an
ICI all-reduce instead of a NIC round-trip. Split search then runs
replicated on the merged histograms, so every shard routes its local
samples through the SAME tree.

The ``psum`` hooks live inside the ordinary build path
(``kernels.ops.build_histogram(axis_name=...)`` and the leaf-stat merge in
``trees.learner.build_tree``); this module only wraps that path in
``shard_map`` with the right specs. Sample counts must divide the shard
count (pad the dataset otherwise).

Histogram-subtraction builds (``LearnerConfig.hist_mode='subtract'``)
compose with the same specs: subtraction is linear, so it COMMUTES with
the psum — the learner psums the per-shard smaller-child histograms (and
the per-node sample counts that pick the child) first, then derives the
sibling as ``merged_parent - merged_child`` AFTER the collective. Every
shard therefore subtracts identical merged values and the replicated tree
stays in lockstep; nothing in this module special-cases the mode.
"""
from __future__ import annotations

import functools

import jax

try:  # moved out of jax.experimental on newer jax releases
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops
from repro.trees.learner import LearnerConfig, build_tree


def make_sharded_builder(cfg: LearnerConfig, mesh: Mesh, axis_name: str = "data"):
    """A TreeBuilder (bins, g, h, rng) -> Tree running data-parallel.

    Inputs are sharded over ``axis_name`` on their sample dim; the rng is
    replicated (every shard draws the same feature mask). The returned Tree
    is replicated — histograms and leaf stats are psum'd, and split search
    is deterministic on the merged values.

    The fused level-build backend is normalized to the STAGED pipeline in
    here: the fused program scans the histograms it holds in VMEM, but
    under shard_map those are shard-LOCAL, and every shard must take the
    split decision on the psum-MERGED level. The collective is the seam
    that pins the staged order (histogram kernel -> psum -> scan kernel);
    ``build_tree`` enforces the fallback whenever ``axis_name`` is set, so
    ``backend='fused'`` is safe to pass here — it just buys nothing.
    Subtraction mode stays in lockstep for the same reason: the sibling is
    derived AFTER the psum (subtraction commutes with it), so every
    shard's derived rows are identical (see trees/learner.py).
    """
    local = functools.partial(build_tree, cfg._replace(axis_name=axis_name))
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P()),
        out_specs=P(),
    )


def build_histogram_sharded(
    mesh: Mesh,
    bins: jax.Array,
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    backend: str = "auto",
    axis_name: str = "data",
) -> jax.Array:
    """Sharded histogram build: per-shard kernel + psum over ``axis_name``.

    Bit-compatible with the single-device path up to float summation order
    (each (node, feature, bin) cell is a sum over disjoint sample subsets).
    """
    local = functools.partial(
        ops.build_histogram,
        n_nodes=n_nodes,
        n_bins=n_bins,
        backend=backend,
        axis_name=axis_name,
    )
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    return fn(bins, node_ids, grad, hess)
