"""Data-parallel tree building: ``shard_map`` workers + ``psum`` merge.

The block-distributed GBT / DimBoost production shape: every shard of the
``'data'`` mesh axis runs the histogram kernel on its local samples only,
and the level histogram is merged with one ``psum`` across the axis — the
server-side aggregation of the paper's parameter server, executed as an
ICI all-reduce instead of a NIC round-trip. Split search then runs
replicated on the merged histograms, so every shard routes its local
samples through the SAME tree.

The ``psum`` hooks live inside the ordinary build path
(``kernels.ops.build_histogram(axis_name=...)`` and the leaf-stat merge in
``trees.learner.build_tree``); this module only wraps that path in
``shard_map`` with the right specs. Sample counts must divide the shard
count (pad the dataset otherwise).

Histogram-subtraction builds (``LearnerConfig.hist_mode='subtract'``)
compose with the same specs: subtraction is linear, so it COMMUTES with
the psum — the learner psums the per-shard smaller-child histograms (and
the per-node sample counts that pick the child) first, then derives the
sibling as ``merged_parent - merged_child`` AFTER the collective. Every
shard therefore subtracts identical merged values and the replicated tree
stays in lockstep; nothing in this module special-cases the mode.
"""
from __future__ import annotations

import functools

import jax

try:  # moved out of jax.experimental on newer jax releases
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import collectives
from repro.kernels import ops
from repro.trees.binning import SparseBins
from repro.trees.learner import LearnerConfig, build_tree


def make_sharded_builder(cfg: LearnerConfig, mesh: Mesh, axis_name: str = "data"):
    """A TreeBuilder (bins, g, h, rng) -> Tree running data-parallel.

    Inputs are sharded over ``axis_name`` on their sample dim; the rng is
    replicated (every shard draws the same feature mask). The returned Tree
    is replicated — histograms and leaf stats are psum'd, and split search
    is deterministic on the merged values.

    The fused level-build backend is normalized to the STAGED pipeline in
    here: the fused program scans the histograms it holds in VMEM, but
    under shard_map those are shard-LOCAL, and every shard must take the
    split decision on the psum-MERGED level. The collective is the seam
    that pins the staged order (histogram kernel -> psum -> scan kernel);
    ``build_tree`` enforces the fallback whenever ``axis_name`` is set, so
    ``backend='fused'`` is safe to pass here — it just buys nothing.
    Subtraction mode stays in lockstep for the same reason: the sibling is
    derived AFTER the psum (subtraction commutes with it), so every
    shard's derived rows are identical (see trees/learner.py).
    """
    local = functools.partial(build_tree, cfg._replace(axis_name=axis_name))
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P()),
        out_specs=P(),
    )

    def builder(bins, g, h, rng):
        if isinstance(bins, SparseBins):
            raise ValueError(
                "SparseBins cannot shard over a 1D data axis (the "
                "feature-major store holds global sample ids); use "
                "make_sharded_builder_2d on a (1, P_f) mesh"
            )
        return fn(bins, g, h, rng)

    return builder


def make_sharded_builder_2d(
    cfg: LearnerConfig,
    mesh: Mesh,
    data_axis: str = "data",
    feature_axis: str = "feature",
):
    """A TreeBuilder running on the block-distributed 2D (data × feature)
    mesh — rows sharded over ``data_axis``, feature columns over
    ``feature_axis`` (DESIGN.md §16).

    Each shard histograms only its own (rows/P_d, F/P_f) block: row psums
    merge histograms over the data axis FIRST (the subtract-after-psum
    invariant now holds per feature shard), then the split decision merges
    over the feature axis with the (L,)-sized argmax collective — never a
    full (2, L, F, B) histogram psum. The dense partition step reconstructs
    the winning bin column with a one-byte-per-sample owner-masked psum.

    Dense bins shard as ``P(data, feature)``. A ``SparseBins`` dataset
    shards its feature-major store over ``feature_axis`` while the
    row-major store and ``zero_bin`` stay replicated (they route samples
    by GLOBAL feature id, which costs no collective at all) — and is
    restricted to ``data_axis`` size 1: the feature-major entries hold
    global sample ids, which row sharding would invalidate.
    """
    d_size = mesh.shape[data_axis]
    f_size = mesh.shape[feature_axis]
    cfg2 = cfg._replace(
        axis_name=data_axis, feature_axis=feature_axis, feature_shards=f_size
    )
    local = functools.partial(build_tree, cfg2)

    def builder(bins, g, h, rng):
        if isinstance(bins, SparseBins):
            if d_size != 1:
                raise ValueError(
                    "sparse 2D builds need a (1, P_f) mesh: the feature-major "
                    f"store holds global sample ids, but {data_axis!r} has "
                    f"size {d_size}"
                )
            bins_spec = SparseBins(
                indices=P(), codes=P(),
                feat_rows=P(feature_axis), feat_codes=P(feature_axis),
                zero_bin=P(),
            )
        else:
            bins_spec = P(data_axis, feature_axis)
        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(bins_spec, P(data_axis), P(data_axis), P()),
            out_specs=P(),
        )
        return fn(bins, g, h, rng)

    return builder


def collective_bytes_per_build(
    cfg: LearnerConfig,
    mesh: Mesh,
    bins,  # (N, F) array / ShapeDtypeStruct, or a SparseBins of either
    data_axis: str = "data",
    feature_axis: str | None = None,
) -> dict:
    """MEASURED per-tree-build collective bytes on the given mesh.

    Traces the sharded builder abstractly (``jax.eval_shape`` — nothing
    executes, so roofline-sized geometries account in milliseconds) with a
    ``collectives.ByteRecorder`` active, and returns its summary:
    ``realized_bytes`` counts only collectives whose mesh axis spans more
    than one shard (a psum over a size-1 axis moves nothing on the wire).
    ``jax.clear_caches()`` first — recording happens at trace time, and a
    cache hit would skip the trace.
    """
    import jax.numpy as jnp

    def _sds(x):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)

    bins_in = jax.tree.map(_sds, bins)
    n = bins.shape[0]
    gh = jax.ShapeDtypeStruct((n,), jnp.float32)
    # Tracer-only key: eval_shape never executes, nothing is ever replayed.
    rng = jax.random.PRNGKey(0)  # analysis: ignore[prngkey-outside-ticket]
    if feature_axis is not None:
        builder = make_sharded_builder_2d(
            cfg, mesh, data_axis=data_axis, feature_axis=feature_axis
        )
    else:
        builder = make_sharded_builder(cfg, mesh, axis_name=data_axis)
    rec = collectives.ByteRecorder(axis_sizes=dict(mesh.shape))
    jax.clear_caches()
    with collectives.recording(rec):
        jax.eval_shape(builder, bins_in, gh, gh, rng)
    return rec.summary()


def build_histogram_sharded(
    mesh: Mesh,
    bins: jax.Array,
    node_ids: jax.Array,
    grad: jax.Array,
    hess: jax.Array,
    n_nodes: int,
    n_bins: int,
    backend: str = "auto",
    axis_name: str = "data",
) -> jax.Array:
    """Sharded histogram build: per-shard kernel + psum over ``axis_name``.

    Bit-compatible with the single-device path up to float summation order
    (each (node, feature, bin) cell is a sum over disjoint sample subsets).
    """
    local = functools.partial(
        ops.build_histogram,
        n_nodes=n_nodes,
        n_bins=n_bins,
        backend=backend,
        axis_name=axis_name,
    )
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(),
    )
    return fn(bins, node_ids, grad, hess)
