"""The parameter-server training engine: ONE round body, every trainer.

Algorithm 3 splits a boosting round across the PS roles:

  worker  — pull a (possibly stale) prediction vector F^{k(j)}, draw the
            Bernoulli subdataset Q, build the gradient target, fit a tree
            (``propose_tree``);
  server  — fold the pushed tree into the live state F <- F + v * Tree
            (``server_fold``).

``round_body`` composes the two; it is the only place that logic exists.
The legacy entry points (``core.sgbdt.train_serial``,
``core.async_sgbdt.train_async`` / ``train_async_scan``) are thin shims
over ``Trainer``, which executes the same step function in two forms:

  * a Python loop with per-round eval hooks (experiments), and
  * a single ``lax.scan`` program (the form the distributed dry-run lowers).

The serial trainer is not a separate code path: it is the round-robin
schedule with W = 1 (k(j) = j, zero staleness).

Sharding: given a mesh whose ``'data'`` axis has more than one shard, the
tree build runs as ``shard_map`` over data shards — each shard feeds its
local samples to the histogram kernel and the level histograms merge with
a ``psum`` (see ``repro.ps.sharded``) — the block-distributed /
DimBoost-style central-aggregation shape, but on ICI collectives instead
of one server NIC.

Determinism is PER HISTOGRAM MODE: ``LearnerConfig.hist_mode`` selects the
worker's level-histogram strategy ('subtract' derives siblings from cached
parents, 'rebuild' re-histograms every node; see ``trees.learner``). The
mode rides inside ``cfg.learner`` through every execution form — threaded
runtime, loop, fused scan replay — so the record-and-replay contract
(DESIGN.md §11) stays bit-for-bit within a mode; the two modes agree with
each other only to f32 subtraction tolerance.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sgbdt import SGBDTConfig, TrainState, init_state
from repro.data.sampling import bernoulli_weights
from repro.ps.schedules import max_staleness, resolve_schedule
from repro.trees.binning import BinnedData
from repro.trees.forest import forest_push
from repro.trees.learner import build_tree, build_tree_multi
from repro.trees.tree import Tree, apply_tree, apply_tree_stack

# (bins, g, h, rng) -> Tree; None means the plain single-device build.
TreeBuilder = Callable[[jax.Array, jax.Array, jax.Array, jax.Array], Tree]


# ------------------------------------------------------------- round body
def propose_tree(
    cfg: SGBDTConfig,
    data: BinnedData,
    f_target: jax.Array,
    rng: jax.Array,
    builder: TreeBuilder | None = None,
) -> tuple[Tree, jax.Array]:
    """Worker side: sample Q -> build target from F^{k(j)} -> fit tree(s).

    Returns the tree and its prediction delta on the training bins (the
    "push" payload: the server folds the delta without re-evaluating).
    K-output objectives fit one tree per output against the (N, K)
    gradient field — a vmapped stacked build, still ONE push: the K trees
    travel as one stacked ``Tree`` group with a (N, K) delta.

    The step length v is applied HERE, to the leaf table, not by the
    server: ``delta`` gathers pre-scaled leaves, so the server fold is a
    pure add. This is what keeps every execution form bit-identical — a
    ``v * delta`` multiply next to the fold's add is FMA-contractable, and
    XLA contracts it in some programs (a small standalone fold) but not
    others (the fused scan body), which would break the threaded runtime's
    record-and-replay contract. ``round(v*leaf)[idx] == round(v*leaf[idx])``
    elementwise, so the trained values are unchanged.
    """
    obj = cfg.obj
    r_sample, r_feat = jax.random.split(rng)
    m_prime, _ = bernoulli_weights(r_sample, cfg.sampling_rate, data.multiplicity)
    g, h = obj.grad_hess(data.labels, f_target, qid=data.qid)
    v = jnp.float32(cfg.step_length)
    if obj.n_outputs == 1:
        hess_w = m_prime * h if cfg.step_kind == "newton" else m_prime
        if builder is None:
            tree = build_tree(cfg.learner, data.bins, m_prime * g, hess_w, r_feat)
        else:
            tree = builder(data.bins, m_prime * g, hess_w, r_feat)
        tree = tree._replace(leaf_value=v * tree.leaf_value)
        return tree, apply_tree(tree, data.bins)
    g_w = m_prime[:, None] * g
    if cfg.step_kind == "newton":
        h_w = m_prime[:, None] * h
    else:
        h_w = jnp.broadcast_to(m_prime[:, None], g.shape)
    if builder is None:
        trees = build_tree_multi(cfg.learner, data.bins, g_w, h_w, r_feat)
    else:
        # Builders (e.g. the shard_map data-parallel build) are defined on
        # single-output signatures; run one per output and stack the group.
        built = [
            builder(data.bins, g_w[:, k], h_w[:, k], r_feat)
            for k in range(obj.n_outputs)
        ]
        trees = jax.tree.map(lambda *xs: jnp.stack(xs), *built)
    trees = trees._replace(leaf_value=v * trees.leaf_value)
    return trees, apply_tree_stack(trees, data.bins)


def server_fold(cfg, forest, f_live, tree, delta):
    """Server side: F <- F + v * Tree (Algorithm 3, server step 2).

    The pushed tree's leaves arrive pre-scaled by v (see ``propose_tree``),
    so the fold is a PURE ADD plus a slot write — deliberately: a lone add
    whose other operand crosses a gather cannot be FMA-contracted, so this
    fold computes the identical f32 value whether it is compiled standalone
    (the threaded runtime's server program), in the per-round loop, in the
    fused lax.scan replay, or inside a vmapped worker block.
    """
    return forest_push(forest, tree, jnp.float32(1.0)), f_live + delta


def staleness_scale(rho: float, staleness) -> jax.Array:
    """Prop.-1 step deflation for a tau-stale push: 1 / (1 + 6*rho*tau).

    The jnp twin of ``optim.staleness_step_scale`` (quadratic term dropped
    — the high-diversity regime), usable on traced staleness values so the
    fused scan replay computes the identical f32 scale the threaded server
    computed from (j, k(j)) at fold time.
    """
    # 6*rho folds in python f64 and rounds ONCE, exactly like the host twin
    # ``schedules.staleness_scales`` — trace-reported scales match bitwise.
    tau = jnp.asarray(staleness, jnp.float32)
    coef = jnp.float32(6.0 * rho)
    return (jnp.float32(1.0) / (jnp.float32(1.0) + coef * tau)).astype(
        jnp.float32
    )


def scale_push(cfg, data, tree, scale):
    """Server-side staleness-adaptive deflation of a pushed tree.

    Scales the LEAF TABLE and re-derives the delta by re-applying the
    scaled tree to the training bins — mul-then-GATHER-then-add, never a
    mul feeding the fold's add, for the same FMA-contraction reason
    ``propose_tree`` pre-scales by v: ``s * delta`` next to ``f + delta``
    contracts in some programs and not others, while a gathered operand
    cannot contract and ``round(s*leaf)[idx] == round(s*leaf[idx])``. The
    pushed delta is discarded (in a real PS the adaptive server would not
    request it: the tree alone determines the update).
    """
    tree = tree._replace(leaf_value=scale * tree.leaf_value)
    if cfg.obj.n_outputs == 1:
        return tree, apply_tree(tree, data.bins)
    return tree, apply_tree_stack(tree, data.bins)


def round_body(cfg, data, forest, f_live, f_target, rng, builder=None,
               staleness=None):
    """One boosting round. Splitting ``f_target`` from ``f_live`` is what
    makes this body shared between every trainer: the tree is built against
    (possibly stale) ``f_target`` but folded into the live server state.

    The barrier pins the worker->server seam: the threaded runtime
    (``ps.runtime``) compiles ``propose_tree`` and ``server_fold`` as two
    separate programs, so the fused forms must not let XLA optimize across
    that boundary or record-and-replay would drift by compilation form.

    ``staleness`` is tau_j = j - k(j), known only at FOLD time (the fold
    order j is decided by the race, not the builder) — so the adaptive
    deflation lives on the server side of the barrier, exactly where the
    threaded runtime's fold program applies it.
    """
    tree, delta = propose_tree(cfg, data, f_target, rng, builder)
    tree, delta = jax.lax.optimization_barrier((tree, delta))
    if cfg.adaptive_step and staleness is not None:
        scale = staleness_scale(cfg.adaptive_step, staleness)
        tree, delta = scale_push(cfg, data, tree, scale)
    return server_fold(cfg, forest, f_live, tree, delta)


# ---------------------------------------------------------------- trainer
class Trainer:
    """Mesh-aware parameter-server GBDT trainer.

    One instance per ``SGBDTConfig`` (jit caches live on the instance).
    The delay schedule is supplied per ``train`` call — anything
    ``ps.schedules.resolve_schedule`` accepts: a closed form spec, a
    realized k(j) array, or a ``ClusterSpec`` to simulate on the spot.

    With ``mesh`` whose ``axis_name`` axis has > 1 shard, tree builds run
    data-parallel via ``shard_map`` + ``psum`` (samples must divide the
    shard count; pad the dataset if needed). A mesh that ALSO carries a
    ``feature_axis`` axis (any size) selects the block-distributed 2D
    build: feature columns shard across it and split decisions merge with
    the (L,)-sized argmax collective instead of full-histogram psums
    (``ps.sharded.make_sharded_builder_2d``, DESIGN.md §16).
    """

    def __init__(
        self,
        cfg: SGBDTConfig,
        *,
        mesh: jax.sharding.Mesh | None = None,
        axis_name: str = "data",
        feature_axis: str = "feature",
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.axis_name = axis_name
        self.feature_axis = feature_axis
        self.builder: TreeBuilder | None = None
        self._is_2d = mesh is not None and feature_axis in mesh.axis_names
        if self._is_2d:
            from repro.ps.sharded import make_sharded_builder_2d

            self.builder = make_sharded_builder_2d(
                cfg.learner, mesh, data_axis=axis_name, feature_axis=feature_axis
            )
        elif mesh is not None and dict(mesh.shape).get(axis_name, 1) > 1:
            from repro.ps.sharded import make_sharded_builder

            self.builder = make_sharded_builder(cfg.learner, mesh, axis_name)
        self._loop_cache: dict[int, Callable] = {}
        self._scan_cache: dict[int, Callable] = {}

    def collective_bytes(self, data: BinnedData) -> dict | None:
        """MEASURED per-tree-build collective bytes on this trainer's mesh
        (trace-time accounting; see ``ps.sharded.collective_bytes_per_build``).
        None when builds are single-device (no collectives at all)."""
        if self.builder is None:
            return None
        from repro.ps.sharded import collective_bytes_per_build

        return collective_bytes_per_build(
            self.cfg.learner, self.mesh, data.bins,
            data_axis=self.axis_name,
            feature_axis=self.feature_axis if self._is_2d else None,
        )

    # The unified step: loop and scan trace exactly this function. The scan
    # form adds a per-round loss as a scan output; the loop form does not
    # pay for it.
    def _step(self, ring_size: int):
        cfg, builder = self.cfg, self.builder

        def step(data, carry, xs):
            forest, f, ring = carry
            j, k_j, rng = xs
            f_target = ring[k_j % ring_size]
            staleness = (j - k_j) if cfg.adaptive_step else None
            forest, f = round_body(
                cfg, data, forest, f, f_target, rng, builder, staleness
            )
            ring = jax.lax.dynamic_update_index_in_dim(
                ring, f, (j + 1) % ring_size, 0
            )
            return (forest, f, ring)

        return step

    def _prep(self, data, schedule, seed):
        sched = resolve_schedule(schedule, self.cfg.n_trees)
        ring_size = max_staleness(sched) + 1
        keys = jax.random.split(jax.random.PRNGKey(seed), self.cfg.n_trees)
        state = init_state(self.cfg, data)
        ring = jnp.broadcast_to(state.f, (ring_size,) + state.f.shape)
        return sched, ring_size, keys, state, ring

    def train(
        self,
        data: BinnedData,
        schedule=("round_robin", 1),
        seed: int = 0,
        eval_every: int = 0,
        eval_fn: Callable[[TrainState, int], None] | None = None,
    ) -> TrainState:
        """Python-loop execution with per-round eval hooks."""
        sched, ring_size, keys, state, ring = self._prep(data, schedule, seed)
        if ring_size not in self._loop_cache:
            self._loop_cache[ring_size] = jax.jit(self._step(ring_size))
        step = self._loop_cache[ring_size]
        forest, f = state.forest, state.f
        carry = (forest, f, ring)
        for j in range(self.cfg.n_trees):
            carry = step(
                data,
                carry,
                (
                    jnp.asarray(j, jnp.int32),
                    jnp.asarray(int(sched[j]), jnp.int32),
                    keys[j],
                ),
            )
            if eval_fn is not None and eval_every and (j + 1) % eval_every == 0:
                eval_fn(
                    TrainState(carry[0], carry[1], jnp.asarray(j + 1, jnp.int32)),
                    j + 1,
                )
        forest, f, _ = carry
        return TrainState(
            forest=forest, f=f, step=jnp.asarray(self.cfg.n_trees, jnp.int32)
        )

    def scan_with(
        self,
        data: BinnedData,
        schedule: jax.Array,
        rngs: jax.Array,
        ring_size: int,
    ) -> tuple[TrainState, jax.Array]:
        """Whole run as one ``lax.scan`` over an explicit (k(j), keys) pair;
        returns per-round train losses too. The program the dry-run lowers."""
        cfg = self.cfg
        if ring_size not in self._scan_cache:
            step = self._step(ring_size)

            @jax.jit
            def run(data, schedule, rngs):
                def body(carry, xs):
                    carry = step(data, carry, xs)
                    loss = cfg.obj.loss(
                        data.labels, carry[1], data.multiplicity, qid=data.qid
                    )
                    return carry, loss

                state = init_state(cfg, data)
                ring = jnp.broadcast_to(state.f, (ring_size,) + state.f.shape)
                (forest, f, _), losses = jax.lax.scan(
                    body,
                    (state.forest, state.f, ring),
                    (
                        jnp.arange(cfg.n_trees, dtype=jnp.int32),
                        schedule,
                        rngs,
                    ),
                )
                return (
                    TrainState(forest, f, jnp.asarray(cfg.n_trees, jnp.int32)),
                    losses,
                )

            self._scan_cache[ring_size] = run
        return self._scan_cache[ring_size](data, jnp.asarray(schedule), rngs)

    def train_scan(
        self, data: BinnedData, schedule=("round_robin", 1), seed: int = 0
    ) -> tuple[TrainState, jax.Array]:
        """scan_with, but resolving the schedule provider and drawing keys."""
        sched, ring_size, keys, _, _ = self._prep(data, schedule, seed)
        return self.scan_with(data, jnp.asarray(sched), keys, ring_size)


# One cached Trainer per config so the legacy shims share jit caches the way
# the old module-level ``@jax.jit(static_argnames=('cfg', ...))`` entry
# points did. The cache is LRU-bounded: each Trainer pins its compiled
# programs, so an unbounded dict leaks executables linearly in any config
# sweep (objective_sweep, fig10 --objective, hyperparameter scans).
_TRAINERS: "OrderedDict[SGBDTConfig, Trainer]" = OrderedDict()
_TRAINERS_MAX = 8


def get_trainer(cfg: SGBDTConfig) -> Trainer:
    trainer = _TRAINERS.get(cfg)
    if trainer is None:
        trainer = Trainer(cfg)
        _TRAINERS[cfg] = trainer
        while len(_TRAINERS) > _TRAINERS_MAX:
            _TRAINERS.popitem(last=False)
    else:
        _TRAINERS.move_to_end(cfg)
    return trainer


def clear_trainers() -> None:
    """Drop every cached Trainer (and the jit executables it pins).

    Config sweeps should call this between unrelated configs; pytest /
    benchmark processes that iterate many ``SGBDTConfig``s otherwise hold
    compiled programs for configs that will never run again.
    """
    _TRAINERS.clear()


def train(
    cfg: SGBDTConfig,
    data: BinnedData,
    schedule=("round_robin", 1),
    seed: int = 0,
    eval_every: int = 0,
    eval_fn=None,
) -> TrainState:
    """Functional convenience over the cached per-config Trainer."""
    return get_trainer(cfg).train(
        data, schedule, seed=seed, eval_every=eval_every, eval_fn=eval_fn
    )
