"""Batched serving: request queue + wave scheduler, for decode and forests."""
from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.forest_server import (
    ForestServer,
    PredictRequest,
    PredictResult,
    load_forest_checkpoint,
)

__all__ = [
    "Completion",
    "Request",
    "ServingEngine",
    "ForestServer",
    "PredictRequest",
    "PredictResult",
    "load_forest_checkpoint",
]
