"""Batched serving: request queue + wave scheduler, for decode and forests."""
from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.forest_server import (
    ForestServer,
    PredictRequest,
    PredictResult,
    load_forest_checkpoint,
)
from repro.serving.continuous import ForestEngine, percentile_latencies, route_hash

__all__ = [
    "Completion",
    "Request",
    "ServingEngine",
    "ForestServer",
    "ForestEngine",
    "PredictRequest",
    "PredictResult",
    "load_forest_checkpoint",
    "percentile_latencies",
    "route_hash",
]
