"""GBDT forest serving: raw-float requests -> binned -> fused traversal.

The inference half of the paper's system: the parameter server trains a
forest (``repro.ps``), checkpoints its ``TrainState``, and this module
serves it. Three contracts (DESIGN.md §6a):

- **Wave batching** — the queue pattern of ``serving.engine``: variable-size
  prediction requests (each a block of rows) are packed row-wise into
  fixed-capacity waves of ``max_rows`` and padded to ONE static shape, so
  every wave hits the same jitted predict and there is exactly one compile.
- **Serve-time binning** — requests carry *raw float* features; the jitted
  predict applies the training-time quantile edges (``BinnedData.bin_edges``
  via ``trees.binning.apply_bins``) before traversal, so serving sees
  exactly the bins training saw.
- **Hot swap** — between waves the server polls the checkpoint directory
  for a newer step and swaps the forest atomically (the forest is a jit
  *argument*, not a captured constant, so a swap is just a new pytree with
  the same shapes: zero retrace, zero downtime).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import pathlib
import threading
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.kernels import ops
from repro.objectives import Objective, get_objective
from repro.trees.binning import apply_bins
from repro.trees.forest import Forest

_FOREST_FIELDS = ("feature", "threshold", "leaf_value", "n_trees", "base_score")


def _nonfinite_rows(x: np.ndarray) -> np.ndarray:
    """Indices of rows containing any NaN/±inf feature."""
    return np.flatnonzero(~np.isfinite(x).all(axis=1))


def load_forest_checkpoint(
    root: str | pathlib.Path, step: int, like: Forest | None = None
) -> Forest:
    """Restore a ``Forest`` from a checkpoint written by the training loop.

    Works on both bare-``Forest`` checkpoints (leaf paths ``.feature`` ...)
    and full ``TrainState`` checkpoints (``.forest/.feature`` ...): leaves
    are matched by their trailing field name, so the server never needs the
    training-set-sized ``f`` vector to rebuild its template. With ``like``,
    shapes are validated against the serving template (capacity and depth
    are static for the jit cache).
    """
    d = checkpoint.step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())
    found: dict[str, np.ndarray] = {}
    for entry in manifest["leaves"]:
        field = entry["path"].split("/")[-1].lstrip(".")
        if field in _FOREST_FIELDS:
            found[field] = np.load(d / entry["file"])
    missing = [f for f in _FOREST_FIELDS if f not in found]
    if missing:
        raise KeyError(f"checkpoint {d} has no forest leaves {missing}")
    forest = Forest(
        feature=jnp.asarray(found["feature"], jnp.int32),
        threshold=jnp.asarray(found["threshold"], jnp.int32),
        leaf_value=jnp.asarray(found["leaf_value"], jnp.float32),
        n_trees=jnp.asarray(found["n_trees"], jnp.int32),
        base_score=jnp.asarray(found["base_score"], jnp.float32),
    )
    if like is not None:
        for name in ("feature", "threshold", "leaf_value", "base_score"):
            got = getattr(forest, name).shape
            want = getattr(like, name).shape
            if got != want:
                raise ValueError(
                    f"{name}: checkpoint shape {got} != serving template {want}"
                )
    return forest


@dataclasses.dataclass
class PredictRequest:
    uid: int
    x: np.ndarray  # (n, F) float32 — raw (unbinned) feature rows


@dataclasses.dataclass
class PredictResult:
    uid: int
    scores: np.ndarray  # (n,) raw margins — or (n, K) linked predictions
    model_step: int  # checkpoint step that served this request
    latency_s: float  # wall time of the wave this request rode
    # Row indices (within the request) that contained NaN/±inf features;
    # empty when the request was clean. Only populated in 'flag' mode —
    # 'reject' mode never admits such a request.
    nonfinite_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64)
    )


class ForestServer:
    """Wave-batched GBDT inference with checkpoint hot-swap.

    ``forest`` is the serving template (its capacity/depth/output count fix
    the jit shapes); ``bin_edges`` are the training-time quantile edges.
    With ``ckpt_root``, ``maybe_reload`` (called between waves and available
    to callers) polls ``checkpoint.latest_step`` and swaps in newer forests.

    With ``objective`` (an ``Objective`` or registry spec string), the
    objective's ``link`` is applied INSIDE the jitted predict — served
    outputs are probabilities/scores with exactly the training-time
    semantics (e.g. (rows, K) softmax rows for ``"multiclass:K"``).
    Without it, raw F(x) margins are served (the historical contract).

    Non-finite requests (``on_nonfinite``): training never sees NaN/±inf,
    so at serve time they are malformed input, not data. ``"reject"``
    (default) refuses the request in ``submit``; ``"flag"`` serves it —
    ``apply_bins`` clamps ±inf and routes NaN to its deterministic NaN bin
    — and reports the offending row indices in
    ``PredictResult.nonfinite_rows`` so the caller can discount them.
    """

    def __init__(
        self,
        forest: Forest,
        bin_edges: jax.Array,
        *,
        ckpt_root: str | pathlib.Path | None = None,
        max_rows: int = 256,
        backend: str = "auto",
        model_step: int = -1,
        objective: Objective | str | None = None,
        on_nonfinite: str = "reject",
    ):
        if on_nonfinite not in ("reject", "flag"):
            raise ValueError(
                f"on_nonfinite must be 'reject' or 'flag', got {on_nonfinite!r}"
            )
        # The hot-swap pair must move together: a wave served with the new
        # forest but the old step (or vice versa) mislabels results. Both
        # live under `_lock`; repro.analysis.locks checks the discipline.
        self._lock = threading.Lock()
        self.forest = forest  # guarded-by: self._lock
        self.bin_edges = jnp.asarray(bin_edges, jnp.float32)
        self.ckpt_root = ckpt_root
        self.max_rows = max_rows
        self.model_step = model_step  # guarded-by: self._lock
        self.on_nonfinite = on_nonfinite
        self.waves_served = 0  # guarded-by: self._lock
        self.objective = get_objective(objective) if objective is not None else None
        depth = forest.depth
        n_outputs = forest.n_outputs
        obj = self.objective
        if obj is not None and obj.n_outputs != n_outputs:
            # A mismatched link would silently normalize across the wave
            # (e.g. softmax over a (rows,) vector) instead of per row.
            raise ValueError(
                f"objective {obj.name!r} has {obj.n_outputs} outputs but the "
                f"forest serves {n_outputs}"
            )

        def predict(forest: Forest, edges: jax.Array, x: jax.Array) -> jax.Array:
            bins = apply_bins(x, edges)
            pred = ops.forest_traverse(
                bins, forest.feature, forest.threshold, forest.leaf_value,
                forest.n_trees, depth, backend=backend, n_outputs=n_outputs,
            )
            raw = forest.base_score + pred
            return raw if obj is None else obj.link(raw)

        self._predict = jax.jit(predict)
        self._queue: collections.deque[PredictRequest] = collections.deque()

    def submit(self, req: PredictRequest) -> None:
        x = np.asarray(req.x, np.float32)
        if x.ndim != 2 or x.shape[1] != self.bin_edges.shape[0]:
            raise ValueError(
                f"request {req.uid}: expected (n, {self.bin_edges.shape[0]}) "
                f"features, got {x.shape}"
            )
        if x.shape[0] > self.max_rows:
            raise ValueError(
                f"request {req.uid}: {x.shape[0]} rows exceeds "
                f"max_rows={self.max_rows}"
            )
        bad = _nonfinite_rows(x)
        if bad.size and self.on_nonfinite == "reject":
            raise ValueError(
                f"request {req.uid}: non-finite features in rows "
                f"{bad.tolist()} (server runs on_nonfinite='reject'; "
                f"use 'flag' to serve them with clamped/NaN-routed bins)"
            )
        self._queue.append(req)

    # ------------------------------------------------------------------ waves
    def _next_wave(self) -> list[PredictRequest]:
        """Pop queued requests while their rows fit in one ``max_rows`` wave."""
        wave, rows = [], 0
        while self._queue and rows + len(self._queue[0].x) <= self.max_rows:
            req = self._queue.popleft()
            wave.append(req)
            rows += len(req.x)
        return wave

    def _run_wave(self, wave: list[PredictRequest]) -> list[PredictResult]:  # concurrent
        sizes = [len(r.x) for r in wave]
        rows = np.zeros((self.max_rows, self.bin_edges.shape[0]), np.float32)
        rows[: sum(sizes)] = np.concatenate([r.x for r in wave], axis=0)
        # One consistent snapshot of the swap pair: every result in this
        # wave is labeled with the step of the forest that computed it,
        # even if a poller thread swaps mid-wave.
        with self._lock:
            forest, model_step = self.forest, self.model_step
        t0 = time.perf_counter()
        scores = self._predict(forest, self.bin_edges, jnp.asarray(rows))
        scores = np.asarray(jax.block_until_ready(scores))
        dt = time.perf_counter() - t0
        with self._lock:
            self.waves_served += 1
        results, off = [], 0
        for req, n in zip(wave, sizes):
            results.append(
                PredictResult(
                    uid=req.uid,
                    scores=scores[off : off + n],
                    model_step=model_step,
                    latency_s=dt,
                    # Recomputed per request at serve time (cheap: <=
                    # max_rows rows) — no uid-keyed bookkeeping to go
                    # stale on duplicate uids or abandoned queue entries.
                    nonfinite_rows=_nonfinite_rows(np.asarray(req.x, np.float32)),
                )
            )
            off += n
        return results

    # --------------------------------------------------------------- hot swap
    def maybe_reload(self) -> bool:  # concurrent
        """Swap in the newest checkpointed forest, if any. Zero-downtime:
        shapes are static, so the next wave just sees the new pytree.
        Safe from a poller thread: the (slow) checkpoint load happens
        outside the lock, then compare-and-swap — a racing reloader that
        already installed this step or newer wins."""
        if self.ckpt_root is None:
            return False
        step = checkpoint.latest_step(self.ckpt_root)
        with self._lock:
            template, current = self.forest, self.model_step
        if step is None or step <= current:
            return False
        forest = load_forest_checkpoint(self.ckpt_root, step, like=template)
        with self._lock:
            if step <= self.model_step:
                return False
            self.forest = forest
            self.model_step = step
        return True

    def run(
        self, requests: Iterable[PredictRequest] | None = None
    ) -> list[PredictResult]:
        for r in requests or ():
            self.submit(r)
        done: list[PredictResult] = []
        while self._queue:
            self.maybe_reload()
            wave = self._next_wave()
            if not wave:
                break
            done.extend(self._run_wave(wave))
        return sorted(done, key=lambda r: r.uid)
