"""GBDT forest serving: raw-float requests -> binned -> fused traversal.

The inference half of the paper's system: the parameter server trains a
forest (``repro.ps``), checkpoints its ``TrainState``, and this module
serves it. Contracts (DESIGN.md §6a, §17):

- **Wave batching** — the queue pattern of ``serving.engine``: variable-size
  prediction requests (each a block of rows) are packed row-wise into
  fixed-capacity waves of ``max_rows`` and padded to ONE static shape, so
  every wave hits the same jitted predict and there is exactly one compile.
  Requests larger than ``max_rows`` are split into sub-waves internally and
  reassembled under the original uid — callers never see the wave geometry.
- **Serve-time binning** — requests carry *raw float* features; the jitted
  predict applies the training-time quantile edges (``BinnedData.bin_edges``
  via ``trees.binning.apply_bins``) before traversal, so serving sees
  exactly the bins training saw.
- **Hot swap** — the server polls the checkpoint directory for a newer step
  and swaps the forest atomically (the forest is a jit *argument*, not a
  captured constant, so a swap is just a new pytree with the same shapes:
  zero retrace, zero downtime). Swap lag is bounded: ``maybe_reload`` runs
  every ``reload_every_waves`` waves from the serving path itself, and
  ``start_reload_poller`` adds a wall-clock-bounded background poller for
  idle servers.
- **Quantized serving** — ``quantize='int8'|'fp16'`` installs
  ``Forest.quantize`` payloads (checkpoint reloads re-quantize on install);
  scores stay within ``trees.quantization_atol`` of the f32 forest's.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import pathlib
import threading
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.kernels import ops
from repro.objectives import Objective, get_objective
from repro.trees.binning import apply_bins
from repro.trees.forest import Forest

_FOREST_FIELDS = ("feature", "threshold", "leaf_value", "n_trees", "base_score")


def _nonfinite_rows(x: np.ndarray) -> np.ndarray:
    """Indices of rows containing any NaN/±inf feature."""
    return np.flatnonzero(~np.isfinite(x).all(axis=1))


def load_forest_checkpoint(
    root: str | pathlib.Path, step: int, like: Forest | None = None
) -> Forest:
    """Restore a ``Forest`` from a checkpoint written by the training loop.

    Works on both bare-``Forest`` checkpoints (leaf paths ``.feature`` ...)
    and full ``TrainState`` checkpoints (``.forest/.feature`` ...), so the
    server never needs the training-set-sized ``f`` vector to rebuild its
    template. Leaves are matched by trailing field name; when several
    leaves end in the same field (a state with both ``forest`` and, say, an
    EMA ``shadow_forest``), the one whose *parent* segment is ``forest`` is
    preferred, and anything still ambiguous raises instead of silently
    picking manifest order. With ``like``, shapes are validated against the
    serving template (capacity and depth are static for the jit cache).
    """
    d = checkpoint.step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())
    candidates: dict[str, list[tuple[list[str], dict]]] = {
        f: [] for f in _FOREST_FIELDS
    }
    for entry in manifest["leaves"]:
        # Path segments come from tree_flatten_with_path: ".forest" for
        # attributes, "['forest']" for dict keys — normalize both.
        segs = [s.strip(".[]'\"") for s in entry["path"].split("/")]
        if segs[-1] in candidates:
            candidates[segs[-1]].append((segs, entry))
    found: dict[str, np.ndarray] = {}
    for field, cands in candidates.items():
        if len(cands) > 1:
            preferred = [c for c in cands if len(c[0]) > 1 and c[0][-2] == "forest"]
            if len(preferred) != 1:
                paths = sorted(e["path"] for _, e in cands)
                raise KeyError(
                    f"checkpoint {d}: forest leaf {field!r} is ambiguous — "
                    f"{len(cands)} leaves end in it ({paths}) and "
                    f"{'none' if not preferred else 'several'} sit under a "
                    "'forest' parent"
                )
            cands = preferred
        if cands:
            found[field] = np.load(d / cands[0][1]["file"])
    missing = [f for f in _FOREST_FIELDS if f not in found]
    if missing:
        raise KeyError(f"checkpoint {d} has no forest leaves {missing}")
    forest = Forest(
        feature=jnp.asarray(found["feature"], jnp.int32),
        threshold=jnp.asarray(found["threshold"], jnp.int32),
        leaf_value=jnp.asarray(found["leaf_value"], jnp.float32),
        n_trees=jnp.asarray(found["n_trees"], jnp.int32),
        base_score=jnp.asarray(found["base_score"], jnp.float32),
    )
    if like is not None:
        for name in ("feature", "threshold", "leaf_value", "base_score"):
            got = getattr(forest, name).shape
            want = getattr(like, name).shape
            if got != want:
                raise ValueError(
                    f"{name}: checkpoint shape {got} != serving template {want}"
                )
    return forest


@dataclasses.dataclass
class PredictRequest:
    uid: int
    x: np.ndarray  # (n, F) float32 — raw (unbinned) feature rows
    # Engine routing (serving.continuous): pin this request to a named
    # forest version; None lets the engine's A/B weights route it.
    version: str | None = None


@dataclasses.dataclass
class PredictResult:
    uid: int
    scores: np.ndarray  # (n,) raw margins — or (n, K) linked predictions
    model_step: int  # checkpoint step that served this request
    latency_s: float  # end-to-end: queue_s + compute_s
    queue_s: float = 0.0  # arrival -> first sub-wave starts computing
    compute_s: float = 0.0  # summed wave compute across this uid's sub-waves
    # Forest version that served this request (set by the continuous
    # engine; a bare ForestServer leaves it None).
    version: str | None = None
    # Row indices (within the request) that contained NaN/±inf features;
    # empty when the request was clean. Only populated in 'flag' mode —
    # 'reject' mode never admits such a request.
    nonfinite_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64)
    )


@dataclasses.dataclass
class _Assembly:
    """Per-request reassembly state for chunked (multi-part) requests.

    All mutable fields are touched only under the server's ``_qlock`` —
    parts of one request can ride waves run by different threads.
    """

    req: PredictRequest
    x: np.ndarray  # validated float32 copy of req.x
    arrival_s: float  # stamped in submit(), before any queueing
    parts_left: int
    scores: np.ndarray | None = None
    model_step: int = -1
    queue_s: float = -1.0  # < 0 until the first part starts computing
    compute_s: float = 0.0


@dataclasses.dataclass
class _Part:
    asm: _Assembly
    lo: int  # row slice [lo, hi) of asm.x this part carries
    hi: int


class ForestServer:
    """Wave-batched GBDT inference with checkpoint hot-swap.

    ``forest`` is the serving template (its capacity/depth/output count fix
    the jit shapes); ``bin_edges`` are the training-time quantile edges.
    With ``ckpt_root``, ``maybe_reload`` polls ``checkpoint.latest_step``
    and swaps in newer forests; the serving path calls it every
    ``reload_every_waves`` waves so swap lag is bounded in waves, and
    ``start_reload_poller`` bounds it in wall-clock for idle servers.

    With ``objective`` (an ``Objective`` or registry spec string), the
    objective's ``link`` is applied INSIDE the jitted predict — served
    outputs are probabilities/scores with exactly the training-time
    semantics (e.g. (rows, K) softmax rows for ``"multiclass:K"``).
    Without it, raw F(x) margins are served (the historical contract).

    With ``quantize`` ('int8' or 'fp16'), the installed forest — initial
    and every hot-swapped reload — is packed via ``Forest.quantize``; the
    f32 template is kept for checkpoint shape validation. Served scores
    stay within ``trees.quantization_atol`` of the f32 scores.

    Non-finite requests (``on_nonfinite``): training never sees NaN/±inf,
    so at serve time they are malformed input, not data. ``"reject"``
    (default) refuses the request in ``submit``; ``"flag"`` serves it —
    ``apply_bins`` clamps ±inf and routes NaN to its deterministic NaN bin
    — and reports the offending row indices in
    ``PredictResult.nonfinite_rows`` so the caller can discount them.

    Thread discipline (repro.analysis.locks): the hot-swap pair
    (``forest``/``model_step``) and the wave counter live under ``_lock``;
    the part queue and reassembly state live under ``_qlock``. The two are
    never held together.
    """

    def __init__(
        self,
        forest: Forest,
        bin_edges: jax.Array,
        *,
        ckpt_root: str | pathlib.Path | None = None,
        max_rows: int = 256,
        backend: str = "auto",
        model_step: int = -1,
        objective: Objective | str | None = None,
        on_nonfinite: str = "reject",
        reload_every_waves: int = 8,
        quantize: str | None = None,
    ):
        if on_nonfinite not in ("reject", "flag"):
            raise ValueError(
                f"on_nonfinite must be 'reject' or 'flag', got {on_nonfinite!r}"
            )
        if reload_every_waves < 1:
            raise ValueError("reload_every_waves must be >= 1")
        # The hot-swap pair must move together: a wave served with the new
        # forest but the old step (or vice versa) mislabels results. Both
        # live under `_lock`; repro.analysis.locks checks the discipline.
        self._lock = threading.Lock()
        # Queue + reassembly state: submit/wave threads race on these.
        self._qlock = threading.Lock()
        self._template = forest  # f32 template for checkpoint validation
        self._quantize = quantize
        packed = forest.quantize(quantize) if quantize else forest
        self.forest = packed  # guarded-by: self._lock
        self.bin_edges = jnp.asarray(bin_edges, jnp.float32)
        self.ckpt_root = ckpt_root
        self.max_rows = max_rows
        self.model_step = model_step  # guarded-by: self._lock
        self.on_nonfinite = on_nonfinite
        self.reload_every_waves = reload_every_waves
        self.waves_served = 0  # guarded-by: self._lock
        self.objective = get_objective(objective) if objective is not None else None
        depth = forest.depth
        n_outputs = forest.n_outputs
        obj = self.objective
        if obj is not None and obj.n_outputs != n_outputs:
            # A mismatched link would silently normalize across the wave
            # (e.g. softmax over a (rows,) vector) instead of per row.
            raise ValueError(
                f"objective {obj.name!r} has {obj.n_outputs} outputs but the "
                f"forest serves {n_outputs}"
            )

        def predict(forest, edges: jax.Array, x: jax.Array) -> jax.Array:
            bins = apply_bins(x, edges)
            pred = ops.forest_traverse(
                bins, forest.feature, forest.threshold, forest.leaf_value,
                forest.n_trees, depth, backend=backend, n_outputs=n_outputs,
                leaf_scale=getattr(forest, "leaf_scale", None),
            )
            raw = forest.base_score + pred
            return raw if obj is None else obj.link(raw)

        self._predict = jax.jit(predict)
        self._queue: collections.deque[_Part] = collections.deque()  # guarded-by: self._qlock
        self._poller: threading.Thread | None = None
        self._poll_stop: threading.Event | None = None

    def submit(self, req: PredictRequest) -> None:  # concurrent
        """Validate and enqueue. Requests wider than ``max_rows`` are split
        into sub-waves here and reassembled under the original uid; arrival
        is stamped NOW, so reported ``queue_s`` includes every second the
        request sits behind earlier traffic."""
        x = np.asarray(req.x, np.float32)
        if x.ndim != 2 or x.shape[1] != self.bin_edges.shape[0]:
            raise ValueError(
                f"request {req.uid}: expected (n, {self.bin_edges.shape[0]}) "
                f"features, got {x.shape}"
            )
        bad = _nonfinite_rows(x)
        if bad.size and self.on_nonfinite == "reject":
            raise ValueError(
                f"request {req.uid}: non-finite features in rows "
                f"{bad.tolist()} (server runs on_nonfinite='reject'; "
                f"use 'flag' to serve them with clamped/NaN-routed bins)"
            )
        n = x.shape[0]
        cuts = list(range(0, n, self.max_rows)) or [0]
        asm = _Assembly(
            req=req, x=x, arrival_s=time.perf_counter(), parts_left=len(cuts)
        )
        # All parts land under ONE lock acquisition: a draining wave thread
        # can never observe a half-enqueued request (drain completeness).
        with self._qlock:
            for lo in cuts:
                self._queue.append(_Part(asm, lo, min(lo + self.max_rows, n)))

    # ------------------------------------------------------------------ waves
    def queued_rows(self) -> int:  # concurrent
        """Rows currently waiting (the engine's fill-cut signal)."""
        with self._qlock:
            return sum(p.hi - p.lo for p in self._queue)

    def oldest_wait(self, now: float | None = None) -> float:  # concurrent
        """Seconds the head-of-line request has waited; 0.0 when idle.
        The engine cuts a wave when this approaches the latency SLO."""
        if now is None:
            now = time.perf_counter()
        with self._qlock:
            if not self._queue:
                return 0.0
            return now - self._queue[0].asm.arrival_s

    def _next_wave(self) -> list[_Part]:  # concurrent
        """Pop queued parts while their rows fit in one ``max_rows`` wave."""
        with self._qlock:
            wave, rows = [], 0
            while self._queue and rows + (
                self._queue[0].hi - self._queue[0].lo
            ) <= self.max_rows:
                part = self._queue.popleft()
                wave.append(part)
                rows += part.hi - part.lo
            return wave

    def serve_next_wave(self) -> list[PredictResult]:  # concurrent
        """Cut and serve one wave; returns results for every request whose
        LAST part rode it (requests still missing parts stay pending)."""
        wave = self._next_wave()
        return self._run_wave(wave) if wave else []

    def _run_wave(self, wave: list[_Part]) -> list[PredictResult]:  # concurrent
        sizes = [p.hi - p.lo for p in wave]
        rows = np.zeros((self.max_rows, self.bin_edges.shape[0]), np.float32)
        if sum(sizes):
            rows[: sum(sizes)] = np.concatenate(
                [p.asm.x[p.lo : p.hi] for p in wave], axis=0
            )
        # One consistent snapshot of the swap pair: every result in this
        # wave is labeled with the step of the forest that computed it,
        # even if a poller thread swaps mid-wave.
        with self._lock:
            forest, model_step = self.forest, self.model_step
        t0 = time.perf_counter()
        scores = self._predict(forest, self.bin_edges, jnp.asarray(rows))
        scores = np.asarray(jax.block_until_ready(scores))
        dt = time.perf_counter() - t0
        with self._lock:
            self.waves_served += 1
            waves = self.waves_served
        results, off = [], 0
        for part, n in zip(wave, sizes):
            asm = part.asm
            with self._qlock:
                if asm.scores is None:
                    asm.scores = np.zeros(
                        (asm.x.shape[0],) + scores.shape[1:], scores.dtype
                    )
                if asm.queue_s < 0:
                    asm.queue_s = t0 - asm.arrival_s
                asm.scores[part.lo : part.hi] = scores[off : off + n]
                asm.compute_s += dt
                # max, not last: with concurrent wave threads, "the step
                # that served this request" is the newest forest any of
                # its parts saw.
                asm.model_step = max(asm.model_step, model_step)
                asm.parts_left -= 1
                if asm.parts_left == 0:
                    results.append(
                        PredictResult(
                            uid=asm.req.uid,
                            scores=asm.scores,
                            model_step=asm.model_step,
                            latency_s=asm.queue_s + asm.compute_s,
                            queue_s=asm.queue_s,
                            compute_s=asm.compute_s,
                            # Recomputed on the FULL request at assembly
                            # time (cheap) — indices are request-relative
                            # regardless of how the rows were chunked.
                            nonfinite_rows=_nonfinite_rows(asm.x),
                        )
                    )
            off += n
        if waves % self.reload_every_waves == 0:
            # Bounded-lag hot swap: the serving path itself polls, so a
            # busy server can never fall more than reload_every_waves
            # waves behind the newest checkpoint.
            self.maybe_reload()
        return results

    # --------------------------------------------------------------- hot swap
    def maybe_reload(self) -> bool:  # concurrent
        """Swap in the newest checkpointed forest, if any. Zero-downtime:
        shapes are static, so the next wave just sees the new pytree.
        Safe from a poller thread: the (slow) checkpoint load happens
        outside the lock, then compare-and-swap — a racing reloader that
        already installed this step or newer wins."""
        if self.ckpt_root is None:
            return False
        step = checkpoint.latest_step(self.ckpt_root)
        with self._lock:
            current = self.model_step
        if step is None or step <= current:
            return False
        forest = load_forest_checkpoint(self.ckpt_root, step, like=self._template)
        if self._quantize:
            forest = forest.quantize(self._quantize)
        with self._lock:
            if step <= self.model_step:
                return False
            self.forest = forest
            self.model_step = step
        return True

    def start_reload_poller(self, interval_s: float = 0.05) -> None:
        """Wall-clock-bounded hot swap: a daemon thread polls the
        checkpoint root every ``interval_s`` even when no waves are being
        served, so swap lag is bounded for idle/bursty servers too."""
        if self._poller is not None:
            return
        stop = threading.Event()

        def _poll():  # concurrent
            while not stop.wait(interval_s):
                self.maybe_reload()

        self._poll_stop = stop
        self._poller = threading.Thread(
            target=_poll, name="forest-reload-poller", daemon=True
        )
        self._poller.start()

    def stop_reload_poller(self) -> None:
        if self._poller is None:
            return
        assert self._poll_stop is not None
        self._poll_stop.set()
        self._poller.join()
        self._poller = None
        self._poll_stop = None

    def run(
        self, requests: Iterable[PredictRequest] | None = None
    ) -> list[PredictResult]:
        for r in requests or ():
            self.submit(r)
        done: list[PredictResult] = []
        while True:
            self.maybe_reload()
            wave = self._next_wave()
            if not wave:
                break  # parts never exceed max_rows: empty wave == drained
            done.extend(self._run_wave(wave))
        return sorted(done, key=lambda r: r.uid)
