"""Continuous-batching GBDT serving engine with multi-version forests.

Replaces the drain-the-queue wave loop with per-arrival admission and
latency-SLO-aware batch cutting (DESIGN.md §17). Three ideas:

- **Continuous batching** — requests are routed to a version's queue the
  moment they arrive; a wave is cut when it FILLS (``max_rows`` queued) or
  when the head-of-line request's deadline budget is spent. The budget is
  ``slo_s`` minus an EWMA estimate of wave compute (floored at a quarter of
  the SLO so a slow wave can't starve cutting entirely): the engine waits
  as long as the SLO allows to pack bigger waves, and no longer.
- **Multi-version forests** — several ``ForestServer`` instances (same bin
  edges and wave geometry, independent forest/checkpoint-root/objective/
  quantization) serve concurrently. Traffic splits by deterministic
  uid-hash over the configured A/B weights; ``PredictRequest.version``
  pins a request to a named version explicitly. **Shadow** versions
  receive a copy of every weighted-routed request but their results are
  diverted to ``shadow_results`` — a candidate forest sees production
  traffic without ever answering it.
- **Per-version everything** — each version carries its own
  ``model_step`` (hot-swap advances them independently), its own
  objective link, and optionally a quantized (int8/fp16) payload; every
  ``PredictResult`` is labeled with the version that computed it.

Thread discipline (repro.analysis.locks): the version table, the EWMA,
and the result buffers live under ``_lock``; the per-version queues are
the servers' own ``_qlock`` business. The engine lock is never held
across a wave compute.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.forest_server import (
    ForestServer,
    PredictRequest,
    PredictResult,
)

# Knuth multiplicative hash: uid -> uniform [0, 1) for weighted routing.
_HASH_MULT = 2654435761
_HASH_MOD = 2**32


def route_hash(uid: int) -> float:
    """Deterministic uniform-ish routing coordinate for a request uid."""
    return ((uid * _HASH_MULT) & (_HASH_MOD - 1)) / _HASH_MOD


@dataclasses.dataclass
class _Version:
    name: str
    server: ForestServer
    weight: float
    shadow: bool


class ForestEngine:
    """Continuous-batching front end over per-version ``ForestServer``s.

    ``submit`` admits (validates, stamps arrival, routes, enqueues) and
    returns immediately with the routed version name; ``step`` cuts and
    serves any wave whose fill or deadline condition fired; ``run`` is the
    synchronous convenience (submit all, drain, sort by uid);
    ``start``/``stop`` run ``step`` from a daemon thread with results
    accumulating for ``poll``.
    """

    def __init__(
        self,
        bin_edges: jax.Array,
        *,
        max_rows: int = 256,
        slo_s: float = 0.05,
        backend: str = "auto",
        on_nonfinite: str = "reject",
        reload_every_waves: int = 8,
    ):
        if slo_s <= 0:
            raise ValueError("slo_s must be > 0")
        self.bin_edges = jnp.asarray(bin_edges, jnp.float32)
        self.max_rows = max_rows
        self.slo_s = slo_s
        self.backend = backend
        self.on_nonfinite = on_nonfinite
        self.reload_every_waves = reload_every_waves
        self._lock = threading.Lock()
        self._versions: dict[str, _Version] = {}  # guarded-by: self._lock
        self._results: list[PredictResult] = []  # guarded-by: self._lock
        self._shadow_results: list[PredictResult] = []  # guarded-by: self._lock
        # EWMA of observed wave compute seconds — the deadline budget's
        # estimate of "how long will the wave I cut now take".
        self._ewma_compute = 0.0  # guarded-by: self._lock
        self._runner: threading.Thread | None = None
        self._runner_stop: threading.Event | None = None

    # ---------------------------------------------------------------- versions
    def add_version(
        self,
        name: str,
        forest,
        *,
        weight: float = 1.0,
        shadow: bool = False,
        ckpt_root=None,
        model_step: int = -1,
        objective=None,
        quantize: str | None = None,
    ) -> None:
        """Register a named forest version. ``weight`` is its share of
        A/B-routed traffic (ignored for ``shadow`` versions, which copy
        routed traffic instead of receiving a share of it)."""
        if weight < 0:
            raise ValueError("weight must be >= 0")
        server = ForestServer(
            forest,
            self.bin_edges,
            ckpt_root=ckpt_root,
            max_rows=self.max_rows,
            backend=self.backend,
            model_step=model_step,
            objective=objective,
            on_nonfinite=self.on_nonfinite,
            reload_every_waves=self.reload_every_waves,
            quantize=quantize,
        )
        with self._lock:
            if name in self._versions:
                raise ValueError(f"version {name!r} already registered")
            self._versions[name] = _Version(name, server, weight, shadow)

    def remove_version(self, name: str) -> None:
        with self._lock:
            self._versions.pop(name)

    def set_weight(self, name: str, weight: float) -> None:
        """Reweight A/B routing live (e.g. ramp a canary 1% -> 50%)."""
        if weight < 0:
            raise ValueError("weight must be >= 0")
        with self._lock:
            self._versions[name].weight = weight

    def version_steps(self) -> dict[str, int]:  # concurrent
        """Current ``model_step`` per version (each under its own lock)."""
        with self._lock:
            versions = list(self._versions.values())
        out = {}
        for v in versions:
            with v.server._lock:
                out[v.name] = v.server.model_step
        return out

    # ---------------------------------------------------------------- admission
    def submit(self, req: PredictRequest) -> str:  # concurrent
        """Admit a request NOW (continuous batching: no wave boundary in
        the way). Routes by ``req.version`` if pinned, else by uid-hash
        over the A/B weights; shadow versions get a copy of every
        weighted-routed request. Returns the serving version's name."""
        with self._lock:
            versions = list(self._versions.values())
        if req.version is not None:
            for v in versions:
                if v.name == req.version:
                    v.server.submit(req)
                    return v.name
            raise KeyError(f"unknown forest version {req.version!r}")
        live = [v for v in versions if not v.shadow and v.weight > 0]
        if not live:
            raise RuntimeError("no routable (non-shadow, weight > 0) versions")
        total = sum(v.weight for v in live)
        h = route_hash(req.uid)
        chosen, acc = live[-1], 0.0
        for v in live:
            acc += v.weight / total
            if h < acc:
                chosen = v
                break
        chosen.server.submit(req)
        for v in versions:
            if v.shadow:
                v.server.submit(
                    PredictRequest(uid=req.uid, x=req.x, version=v.name)
                )
        return chosen.name

    # ------------------------------------------------------------------ serving
    def _cut_budget(self) -> float:
        """Seconds a head-of-line request may still wait before its wave
        must be cut: the SLO minus the expected compute of the wave it
        will ride, floored at slo/4 so one slow wave cannot drive the
        budget to zero and thrash single-request waves forever."""
        with self._lock:
            ewma = self._ewma_compute
        return max(self.slo_s - ewma, 0.25 * self.slo_s)

    def step(self, force: bool = False) -> list[PredictResult]:  # concurrent
        """Cut and serve every wave whose condition fired; returns newly
        completed non-shadow results (shadow completions divert to
        ``shadow_results``). With ``force``, drains all queues."""
        with self._lock:
            versions = list(self._versions.values())
        budget = self._cut_budget()
        out: list[PredictResult] = []
        for v in versions:
            while True:
                queued = v.server.queued_rows()
                if not queued:
                    break
                full = queued >= self.max_rows
                due = v.server.oldest_wait() >= budget
                if not (full or due or force):
                    break
                t0 = time.perf_counter()
                res = v.server.serve_next_wave()
                dt = time.perf_counter() - t0
                with self._lock:
                    self._ewma_compute = (
                        dt
                        if self._ewma_compute == 0.0
                        else 0.8 * self._ewma_compute + 0.2 * dt
                    )
                for r in res:
                    r.version = v.name
                if v.shadow:
                    with self._lock:
                        self._shadow_results.extend(res)
                else:
                    out.extend(res)
        return out

    def flush(self) -> list[PredictResult]:
        """Drain every queue regardless of SLO state."""
        return self.step(force=True)

    def run(
        self, requests: Iterable[PredictRequest] | None = None
    ) -> list[PredictResult]:
        """Synchronous convenience: submit, drain, sort by uid."""
        for r in requests or ():
            self.submit(r)
        return sorted(self.flush(), key=lambda r: r.uid)

    # --------------------------------------------------------------- background
    def start(self, interval_s: float = 0.001) -> None:
        """Serve continuously from a daemon thread: ``step`` runs every
        ``interval_s`` so deadline cuts fire without a caller in the loop.
        Completed results accumulate for ``poll``."""
        if self._runner is not None:
            return
        stop = threading.Event()

        def _engine_loop():  # concurrent
            while not stop.wait(interval_s):
                res = self.step()
                if res:
                    with self._lock:
                        self._results.extend(res)

        self._runner_stop = stop
        self._runner = threading.Thread(
            target=_engine_loop, name="forest-engine", daemon=True
        )
        self._runner.start()

    def stop(self, drain: bool = True) -> None:
        if self._runner is None:
            return
        assert self._runner_stop is not None
        self._runner_stop.set()
        self._runner.join()
        self._runner = None
        self._runner_stop = None
        if drain:
            res = self.flush()
            if res:
                with self._lock:
                    self._results.extend(res)

    def poll(self) -> list[PredictResult]:  # concurrent
        """Pop results completed by the background loop since last poll."""
        with self._lock:
            out = list(self._results)
            self._results.clear()
        return out

    @property
    def shadow_results(self) -> list[PredictResult]:
        with self._lock:
            return list(self._shadow_results)


def percentile_latencies(results: Iterable[PredictResult]) -> dict[str, float]:
    """p50/p99 of queue, compute, and end-to-end latency in milliseconds —
    the reporting contract the serving bench gates on."""
    rs = list(results)
    if not rs:
        return {}
    out = {}
    for field in ("queue_s", "compute_s", "latency_s"):
        vals = np.asarray([getattr(r, field) for r in rs], np.float64) * 1e3
        key = field[:-2]
        out[f"{key}_p50_ms"] = float(np.percentile(vals, 50))
        out[f"{key}_p99_ms"] = float(np.percentile(vals, 99))
    return out
