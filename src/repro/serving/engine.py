"""Batched serving engine: request queue -> same-length waves -> greedy decode.

Requests are bucketed by prompt length (production engines pad within
buckets client-side), packed into fixed-size waves of ``slots`` sequences,
prefilled once, then decoded together against the ring cache until every
sequence hits EOS or its token budget. The decode tick is one jitted
``decode_step`` over the whole wave — the shape the decode_32k dry-run
lowers at (128, 1).

Positions are shared per wave (the cache carries one ``pos`` scalar), which
is exactly the same-length-bucket contract; continuous per-slot batching
would need per-slot position plumbing and is noted in DESIGN.md as future
work.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

import repro.sharding as sharding
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    media: np.ndarray | None = None  # (M, D) frontend embeddings


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray  # generated ids (<= max_new_tokens)
    prefill_s: float
    decode_s: float


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        slots: int = 4,
        max_len: int = 512,
        eos_id: int | None = None,
        mesh=None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        baxes = sharding.batch_axes(mesh) if mesh else ()
        self._prefill = jax.jit(
            make_prefill_step(cfg, mesh, baxes, max_len=max_len)
        )
        self._decode = jax.jit(make_decode_step(cfg, mesh, baxes))
        self._queue: collections.deque[Request] = collections.deque()

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+budget exceeds max_len={self.max_len}"
            )
        self._queue.append(req)

    # ------------------------------------------------------------------ waves
    def _next_wave(self) -> list[Request]:
        """Pop up to ``slots`` queued requests sharing one prompt length."""
        if not self._queue:
            return []
        plen = len(self._queue[0].prompt)
        wave, rest = [], collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if len(r.prompt) == plen and len(wave) < self.slots:
                wave.append(r)
            else:
                rest.append(r)
        self._queue = rest
        return wave

    def _run_wave(self, wave: list[Request]) -> list[Completion]:
        cfg = self.cfg
        n = len(wave)
        pad = self.slots - n
        prompts = np.stack([r.prompt for r in wave] + [wave[-1].prompt] * pad)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.family in ("vlm", "audio"):
            med = [
                r.media
                if r.media is not None
                else np.zeros((cfg.n_media_tokens, cfg.d_model), np.float32)
                for r in wave
            ] + [np.zeros((cfg.n_media_tokens, cfg.d_model), np.float32)] * pad
            batch["media"] = jnp.asarray(
                np.stack(med), jnp.dtype(cfg.dtype)
            )

        t0 = time.time()
        tok, _, cache = self._prefill(self.params, batch)
        tok.block_until_ready()
        t1 = time.time()

        budget = max(r.max_new_tokens for r in wave)
        outs = [tok]
        done = np.zeros(self.slots, bool)
        cur = tok[:, None]
        steps = 1
        while steps < budget and not done[:n].all():
            cur_tok, cache = self._decode(self.params, cur, cache)
            outs.append(cur_tok)
            if self.eos_id is not None:
                done |= np.asarray(cur_tok) == self.eos_id
            cur = cur_tok[:, None]
            steps += 1
        jax.block_until_ready(cur)
        t2 = time.time()

        gen = np.stack([np.asarray(o) for o in outs], axis=1)  # (slots, T)
        results = []
        for i, r in enumerate(wave):
            toks = gen[i, : r.max_new_tokens]
            if self.eos_id is not None:
                hits = np.nonzero(toks == self.eos_id)[0]
                if hits.size:
                    toks = toks[: hits[0] + 1]
            results.append(
                Completion(r.uid, toks, prefill_s=t1 - t0, decode_s=t2 - t1)
            )
        return results

    def run(self, requests: Iterable[Request] | None = None) -> list[Completion]:
        for r in requests or ():
            self.submit(r)
        done: list[Completion] = []
        while self._queue:
            wave = self._next_wave()
            if not wave:
                break
            done.extend(self._run_wave(wave))
        return sorted(done, key=lambda c: c.uid)
