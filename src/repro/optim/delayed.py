"""DelayedGradient — the paper's staleness mechanism as an optimizer wrapper.

Asynch-SGBDT's server applies updates built from stale state F^{k(j)}
(Algorithm 3). For pytree optimizers, the same object is a gradient that was
*computed* tau steps ago and arrives now: the wrapper keeps a ring buffer of
the last ``delay`` gradients and hands the inner optimizer the one pushed
``delay`` steps earlier. With ``delay = 0`` it is the identity wrapper
(tau = 0 is the serial trainer — the same degeneracy the GBDT tests assert).

This is the executable form of delayed SGD on a real pod: pipelined
data-parallel groups push gradients that are one or more server versions
old, and Proposition 1's step-length rule (v ~ 1 / (1 + 6*rho*tau)) applies
verbatim. ``staleness_step_scale`` implements that rule so experiments can
follow the paper's "more workers => smaller step" prescription.

During warm-up (fewer than ``delay`` gradients buffered) the update is zero:
the server has not yet received its first delayed push — matching Algorithm
3, where the first W trees are all built from F^0 and arrive later.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, PyTree


class DelayedState(NamedTuple):
    step: jax.Array  # () int32 — how many grads have been pushed
    ring: PyTree  # each leaf: (delay, *leaf.shape) buffered grads
    inner: PyTree


def delayed_gradient(inner: Optimizer, delay: int) -> Optimizer:
    """Wrap ``inner`` so it consumes gradients ``delay`` steps stale."""
    if delay < 0:
        raise ValueError("delay must be >= 0")
    if delay == 0:
        return inner

    def init(params):
        ring = jax.tree.map(
            lambda p: jnp.zeros((delay,) + p.shape, jnp.float32), params
        )
        return DelayedState(
            step=jnp.zeros((), jnp.int32), ring=ring, inner=inner.init(params)
        )

    def update(grads, state, params):
        slot = state.step % delay
        # Pop the gradient pushed ``delay`` steps ago, push the fresh one.
        stale = jax.tree.map(lambda r: r[slot], state.ring)
        ring = jax.tree.map(
            lambda r, g: r.at[slot].set(g.astype(jnp.float32)), state.ring, grads
        )
        warm = state.step >= delay
        stale = jax.tree.map(
            lambda s, g: jnp.where(warm, s, jnp.zeros_like(s)).astype(g.dtype),
            stale,
            grads,
        )
        updates, inner_state = inner.update(stale, state.inner, params)
        # Freeze the inner state until real (stale) gradients start flowing,
        # so Adam's bias correction does not run on the zero warm-up updates.
        inner_state = jax.tree.map(
            lambda new, old: jnp.where(warm, new, old), inner_state, state.inner
        )
        updates = jax.tree.map(
            lambda u: jnp.where(warm, u, jnp.zeros_like(u)), updates
        )
        return updates, DelayedState(
            step=state.step + 1, ring=ring, inner=inner_state
        )

    return Optimizer(init, update)


def staleness_step_scale(tau: int, rho: float, omega_delta: float = 0.0) -> float:
    """Proposition 1's step-length deflation for ``tau``-stale updates.

    v(tau) / v(0) = 1 / (1 + 6*rho*tau + 4*rho*tau^2 * Omega * Delta^{1/2}).
    ``omega_delta`` carries the Omega * sqrt(Delta) product (0 => drop the
    quadratic term, the high-diversity regime where the paper's requirements
    hold).
    """
    return 1.0 / (1.0 + 6.0 * rho * tau + 4.0 * rho * tau * tau * omega_delta)
