"""Optimizer substrate: pytree gradient transforms + the paper's staleness
mechanism (``delayed_gradient``) and Bernoulli-importance batch weighting.
"""
from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    add_decayed_weights,
    apply_updates,
    chain,
    clip_by_global_norm,
    cosine_schedule,
    scale,
    sgd,
)
from repro.optim.delayed import (
    DelayedState,
    delayed_gradient,
    staleness_step_scale,
)

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "add_decayed_weights",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "cosine_schedule",
    "scale",
    "sgd",
    "DelayedState",
    "delayed_gradient",
    "staleness_step_scale",
]
