"""Gradient-transform optimizers (optax-style, no dependency on optax).

An ``Optimizer`` is an (init, update) pair over parameter pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Everything is a pure pytree function, so optimizers jit, shard (state
inherits the parameter PartitionSpecs; see ``repro.sharding``), scan, and
checkpoint like any other part of the program. ``DelayedGradient`` — the
paper's staleness mechanism lifted to NN training — lives in
``repro.optim.delayed`` and wraps any Optimizer defined here.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


# -------------------------------------------------------------------- chain
def chain(*transforms: Optimizer) -> Optimizer:
    """Compose gradient transforms left-to-right."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)


# ---------------------------------------------------------------- transforms
def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), state

    return Optimizer(init, update)


def scale(factor: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: g * factor, grads), state

    return Optimizer(init, update)


def add_decayed_weights(weight_decay: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return (
            jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            ),
            state,
        )

    return Optimizer(init, update)


# ----------------------------------------------------------------- momentum
class SgdState(NamedTuple):
    momentum: PyTree


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    """SGD with (optional) heavy-ball momentum. The paper's base step is
    plain SGD (momentum = 0): F <- F - v * L'_random."""

    def init(params):
        if momentum == 0.0:
            return SgdState(momentum=())
        return SgdState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        )

    def update(grads, state, params):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads
        )
        return jax.tree.map(lambda m: -lr * m, mom), SgdState(momentum=mom)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
) -> Optimizer:
    """Adam with f32 moments (the production default for the model zoo).

    ``lr`` may be a schedule: a callable step -> learning rate.
    """

    def init(params):
        def zeros():
            return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v: -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(
    lr: float | Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float = 0.0,
) -> Optimizer:
    """The production recipe: clip -> decay -> adam."""
    parts = []
    if max_grad_norm > 0:
        parts.append(clip_by_global_norm(max_grad_norm))
    if weight_decay > 0:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(adam(lr, b1, b2, eps))
    return chain(*parts)


# ----------------------------------------------------------------- schedules
def cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr
