"""Bernoulli importance sampling — the paper's random variable Q.

Each of the m_i copies of distinct sample i carries an independent Bernoulli
Q_ij with P(Q_ij = 1) = R_ij; the sampled objective weights sample i by
m'_i = sum_j Q_ij / R_ij, an unbiased estimator of m_i (E[m'_i] = m_i, the
keystone of Corollary 1). With uniform rates this is Binomial(m_i, R) / R.

Also here: the observable the scalability theory reads — the sparsity of the
Q' vector (Q'_i = any copy drawn), and closed forms for Delta and rho.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bernoulli_weights(
    rng: jax.Array,
    rate: jax.Array | float,
    multiplicity: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Draw one sampling round.

    Returns (m_prime, q_any): the importance weights m'_i (N,) f32 and the
    Q'_i indicator (N,) bool. E[m_prime] = multiplicity.
    """
    rate = jnp.broadcast_to(jnp.asarray(rate, jnp.float32), multiplicity.shape)
    counts = jax.random.binomial(rng, multiplicity, rate)
    m_prime = counts / rate
    return m_prime.astype(jnp.float32), counts > 0


def q_sparsity(q_any: jax.Array) -> jax.Array:
    """Fraction of distinct samples present in the subdataset (density of Q')."""
    return jnp.mean(q_any.astype(jnp.float32))


def delta_max(rate, multiplicity: jax.Array) -> jax.Array:
    """Delta = max_i P(Q'_i = 1) = max_i 1 - (1 - R)^{m_i} (closed form)."""
    rate = jnp.asarray(rate, jnp.float32)
    return jnp.max(1.0 - (1.0 - rate) ** multiplicity)


def overlap_probability(rate, multiplicity: jax.Array) -> jax.Array:
    """rho = P(two independent subdatasets intersect).

    P(i in both) = p_i^2 with p_i = 1 - (1-R)^{m_i};
    rho = 1 - prod_i (1 - p_i^2). High diversity (m_i = 1, small R) => small
    per-sample p_i but the product over many i can still be large — exactly
    the tension the paper's requirements describe.
    """
    rate = jnp.asarray(rate, jnp.float32)
    p = 1.0 - (1.0 - rate) ** multiplicity
    return 1.0 - jnp.exp(jnp.sum(jnp.log1p(-jnp.minimum(p * p, 1.0 - 1e-7))))


def diversity_stats(rate, multiplicity: jax.Array) -> dict[str, jax.Array]:
    """The asynch-SGBDT-requirement observables for a (dataset, rate) pair."""
    return {
        "delta": delta_max(rate, multiplicity),
        "rho": overlap_probability(rate, multiplicity),
        "expected_subdataset_density": jnp.mean(
            1.0 - (1.0 - jnp.asarray(rate, jnp.float32)) ** multiplicity
        ),
    }
