"""Synthetic datasets matched to the *properties* the paper's theory names.

The paper's claims are parameterized by dataset properties, not identities:
dimension, sparsity, and sample diversity (the multiplicity profile m_i that
drives rho and Delta). The generators below control each directly:

- ``make_sparse_classification`` — real-sim-like: high-dimensional, sparse,
  every sample distinct (m_i = 1) => high diversity, small Delta/rho.
- ``make_dense_low_diversity`` — Higgs-like (Fig. 4a): low-dimensional,
  dense, few distinct samples with large multiplicities => low diversity.
- ``make_sparse_regression`` — E2006-log1p-like: sparse high-dim regression.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.trees.binning import BinnedData, bin_dataset


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str  # 'sparse-cls' | 'dense-lowdiv' | 'sparse-reg'
    n: int  # number of distinct samples
    dim: int
    nnz: int  # nonzeros per sample (sparse kinds)
    n_distinct: int = 0  # dense-lowdiv: pool of distinct samples
    loss: str = "logistic"
    seed: int = 0


def make_sparse_classification(
    n: int,
    dim: int,
    nnz: int,
    seed: int = 0,
    label_noise: float = 0.05,
    sparse: bool | str = False,
) -> BinnedData:
    """High-dim sparse binary classification; all samples distinct.

    ``sparse`` passes through to ``bin_dataset`` — ``True``/``'auto'``
    yields the ``SparseBins`` layout for the 2D feature-sharded path.
    """
    rng = np.random.default_rng(seed)
    x = np.zeros((n, dim), np.float32)
    rows = np.repeat(np.arange(n), nnz)
    cols = rng.integers(0, dim, size=n * nnz)
    vals = rng.lognormal(0.0, 1.0, size=n * nnz).astype(np.float32)
    x[rows, cols] = vals
    w = (rng.standard_normal(dim) * (rng.random(dim) < 0.2)).astype(np.float32)
    logits = x @ w + 0.1 * rng.standard_normal(n).astype(np.float32)
    y = (logits > np.median(logits)).astype(np.float32)
    flip = rng.random(n) < label_noise
    y = np.where(flip, 1.0 - y, y)
    return bin_dataset(x, y, n_bins=64, sparse=sparse)


def make_dense_low_diversity(
    n_distinct: int, dim: int, total_mass: int, seed: int = 0
) -> BinnedData:
    """Low-dim dense dataset with heavy sample multiplicity (low diversity).

    Implements the paper's multiset formalism directly: ``n_distinct`` rows,
    with multiplicities m_i summing to ``total_mass`` (Fig. 4a's
    10000*A1 + 20000*A2 + ... pattern).
    """
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_distinct, dim)).astype(np.float32)
    w = rng.standard_normal(dim).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    # Zipf-ish multiplicity profile, normalized to total_mass.
    raw = 1.0 / np.arange(1, n_distinct + 1)
    m = np.maximum(1, np.round(raw / raw.sum() * total_mass)).astype(np.float32)
    return bin_dataset(x, y, n_bins=64, multiplicity=m)


def make_multiclass_classification(
    n: int,
    dim: int,
    n_classes: int,
    seed: int = 0,
    sep: float = 1.5,
    label_noise: float = 0.05,
) -> BinnedData:
    """Gaussian-blob multiclass set; labels are class ids stored as floats.

    Pairs with ``objectives.MulticlassSoftmax(n_classes)``: one tree per
    class per boosting round against the (N, K) softmax gradient field.
    """
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_classes, dim)).astype(np.float32) * sep
    y = rng.integers(0, n_classes, size=n)
    x = centers[y] + rng.standard_normal((n, dim)).astype(np.float32)
    flip = rng.random(n) < label_noise
    y = np.where(flip, rng.integers(0, n_classes, size=n), y)
    return bin_dataset(x, y.astype(np.float32), n_bins=64)


def make_ranking(
    n_queries: int,
    docs_per_query: int,
    dim: int,
    seed: int = 0,
    n_levels: int = 3,
    noise: float = 0.25,
) -> BinnedData:
    """Query-grouped ranking set: labels are relevance grades 0..n_levels-1,
    ``qid`` carries the per-sample query id for pairwise objectives.

    Relevance is the within-query rank of a noisy linear utility, bucketed
    into ``n_levels`` grades — so features are predictive of ordering but
    no grade is globally separable.
    """
    rng = np.random.default_rng(seed)
    n = n_queries * docs_per_query
    x = rng.standard_normal((n, dim)).astype(np.float32)
    w = rng.standard_normal(dim).astype(np.float32)
    util = (x @ w + noise * rng.standard_normal(n)).astype(np.float32)
    qid = np.repeat(np.arange(n_queries, dtype=np.int32), docs_per_query)
    rel = np.empty(n, np.float32)
    for q in range(n_queries):
        sl = slice(q * docs_per_query, (q + 1) * docs_per_query)
        order = np.argsort(np.argsort(util[sl]))  # 0 = worst in query
        rel[sl] = order * n_levels // docs_per_query  # grades 0..n_levels-1
    return bin_dataset(x, rel, n_bins=64, qid=qid)


def make_sparse_regression(n: int, dim: int, nnz: int, seed: int = 0) -> BinnedData:
    """Sparse high-dim regression (E2006-log1p-like); MSE loss."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, dim), np.float32)
    rows = np.repeat(np.arange(n), nnz)
    cols = rng.integers(0, dim, size=n * nnz)
    x[rows, cols] = rng.lognormal(0.0, 1.0, size=n * nnz).astype(np.float32)
    w = (rng.standard_normal(dim) * (rng.random(dim) < 0.1)).astype(np.float32)
    y = (x @ w + 0.05 * rng.standard_normal(n)).astype(np.float32)
    y = (y - y.mean()) / (y.std() + 1e-8)
    return bin_dataset(x, y, n_bins=64)


# Scaled-down stand-ins for the paper's three datasets (same property axes).
PAPER_DATASETS: dict[str, DatasetSpec] = {
    "realsim-like": DatasetSpec(
        name="realsim-like", kind="sparse-cls", n=4000, dim=1500, nnz=25, seed=7
    ),
    "higgs-like": DatasetSpec(
        name="higgs-like", kind="dense-lowdiv", n=60000, dim=28, nnz=28,
        n_distinct=300, seed=11,
    ),
    "e2006-like": DatasetSpec(
        name="e2006-like", kind="sparse-reg", n=3000, dim=2000, nnz=40,
        loss="mse", seed=13,
    ),
}


def load(spec: DatasetSpec) -> BinnedData:
    if spec.kind == "sparse-cls":
        return make_sparse_classification(spec.n, spec.dim, spec.nnz, spec.seed)
    if spec.kind == "dense-lowdiv":
        return make_dense_low_diversity(spec.n_distinct, spec.dim, spec.n, spec.seed)
    if spec.kind == "sparse-reg":
        return make_sparse_regression(spec.n, spec.dim, spec.nnz, spec.seed)
    raise ValueError(spec.kind)
