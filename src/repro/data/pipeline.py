"""Token data pipeline: deterministic, shardable, resumable batching.

Production training needs more than a random-token generator: documents of
uneven length must be PACKED into fixed (B, S) batches without cross-doc
attention leakage, every host must draw disjoint shards, and a restart from
step N must reproduce batch N exactly. This module provides:

  * ``pack_documents`` — greedy sequence packing with segment ids (the
    standard mask-free packing: segment ids feed attention masks).
  * ``TokenPipeline``  — deterministic epoch shuffling (seeded permutation
    per epoch), host sharding (``shard_id``/``num_shards``), and O(1)
    ``resume(step)``.

The paper's Bernoulli sampling composes on top: ``weights`` from
``repro.data.sampling`` attach per-sequence importance weights to each
batch, which ``forward_train`` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def pack_documents(
    docs: list[np.ndarray],
    seq_len: int,
    pad_id: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Greedy-pack variable-length docs into rows of ``seq_len`` tokens.

    Returns (tokens (N, S), segments (N, S)): segment 0 = padding, k >= 1 =
    k-th document in the row. Documents longer than seq_len are split.
    """
    rows: list[np.ndarray] = []
    segs: list[np.ndarray] = []
    cur = np.full(seq_len, pad_id, np.int32)
    cseg = np.zeros(seq_len, np.int32)
    fill = 0
    seg_id = 0

    def flush():
        nonlocal cur, cseg, fill, seg_id
        if fill > 0:
            rows.append(cur)
            segs.append(cseg)
        cur = np.full(seq_len, pad_id, np.int32)
        cseg = np.zeros(seq_len, np.int32)
        fill = 0
        seg_id = 0

    for doc in docs:
        doc = np.asarray(doc, np.int32)
        while doc.size:
            space = seq_len - fill
            if space == 0:
                flush()
                space = seq_len
            take = min(space, doc.size)
            seg_id += 1
            cur[fill : fill + take] = doc[:take]
            cseg[fill : fill + take] = seg_id
            fill += take
            doc = doc[take:]
    flush()
    if not rows:
        return (np.zeros((0, seq_len), np.int32),) * 2
    return np.stack(rows), np.stack(segs)


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic sharded batch stream over a packed token matrix.

    Every (epoch, step) pair maps to a fixed set of rows: epoch order is a
    seeded permutation, hosts take strided slices, and ``resume``/iteration
    from any step reproduces the original stream — the checkpointing
    contract a production loop needs.
    """

    tokens: np.ndarray  # (N, S+1) int32 — +1 for the shifted labels
    batch_size: int  # per-shard batch
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    segments: np.ndarray | None = None

    def __post_init__(self):
        if self.tokens.ndim != 2:
            raise ValueError("tokens must be (N, S+1)")
        n = self.tokens.shape[0]
        self._shard_rows = np.arange(self.shard_id, n, self.num_shards)
        if len(self._shard_rows) < self.batch_size:
            raise ValueError("shard smaller than one batch")

    @property
    def steps_per_epoch(self) -> int:
        return len(self._shard_rows) // self.batch_size

    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self._shard_rows)

    def batch_at(self, step: int) -> dict:
        """The batch for global step ``step`` (deterministic, random access)."""
        spe = self.steps_per_epoch
        epoch, idx = divmod(step, spe)
        order = self._epoch_order(epoch)
        rows = order[idx * self.batch_size : (idx + 1) * self.batch_size]
        chunk = self.tokens[rows]
        out = {
            "tokens": chunk[:, :-1].astype(np.int32),
            "labels": chunk[:, 1:].astype(np.int32),
        }
        if self.segments is not None:
            out["segments"] = self.segments[rows][:, :-1]
        return out

    def __iter__(self) -> Iterator[dict]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
