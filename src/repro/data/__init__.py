"""Data substrate: synthetic dataset generators + Bernoulli importance sampling."""
from repro.data.synthetic import (
    DatasetSpec,
    make_dense_low_diversity,
    make_multiclass_classification,
    make_ranking,
    make_sparse_classification,
    make_sparse_regression,
    PAPER_DATASETS,
)
from repro.data.pipeline import TokenPipeline, pack_documents
from repro.data.sampling import (
    bernoulli_weights,
    diversity_stats,
    overlap_probability,
)

__all__ = [
    "DatasetSpec",
    "make_dense_low_diversity",
    "make_multiclass_classification",
    "make_ranking",
    "make_sparse_classification",
    "make_sparse_regression",
    "PAPER_DATASETS",
    "bernoulli_weights",
    "diversity_stats",
    "overlap_probability",
    "TokenPipeline",
    "pack_documents",
]
