"""Byte-accounted collective wrappers: psum / pmax / pmin with a recorder.

The GBDT build path's collectives (histogram psums over the 'data' axis,
the 2D mesh's argmax-merge pmax/pmin over the 'feature' axis, the
partition-column psum) all route through this module instead of calling
``jax.lax`` directly. Semantically the wrappers ARE ``jax.lax.psum`` /
``pmax`` / ``pmin`` — same primitive in the jaxpr, so the determinism
auditor (``repro.analysis.determinism``) sees the unwrapped program — but
while a ``ByteRecorder`` is active every call also records its payload:
(kind, axis, bytes, shapes). That is what makes the roofline's
"collective bytes per round" row a MEASURED number (counted off the
traced program) rather than a modeled constant.

Recording happens at TRACE time. jit caches skip retracing, so a
measurement pass must trace fresh programs: ``ps.sharded.
collective_bytes_per_build`` calls ``jax.clear_caches()`` and traces the
builder abstractly (``jax.eval_shape`` — nothing executes, so even
roofline-sized geometries account in milliseconds).

Realized vs payload bytes: an all-reduce over a size-1 mesh axis moves
nothing on the wire. The recorder keeps both views — ``payload_bytes``
(every call) and ``realized_bytes`` (calls whose axis spans > 1 shard,
per the ``axis_sizes`` the recorder was built with). Reduction claims
(dense-psum vs argmax-merge) compare realized bytes at equal device
counts.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax


@dataclass
class CollectiveEvent:
    kind: str  # 'psum' | 'pmax' | 'pmin'
    axis: str
    bytes: int
    shapes: tuple
    axis_size: int  # 0 = unknown (recorder built without axis_sizes)


@dataclass
class ByteRecorder:
    """Accumulates one ``CollectiveEvent`` per wrapped collective call.

    ``axis_sizes`` maps mesh axis name -> shard count; without it every
    event counts as realized (conservative: never under-reports).
    """

    axis_sizes: dict = field(default_factory=dict)
    events: list = field(default_factory=list)

    def add(self, kind: str, axis: str, x) -> None:
        leaves = jax.tree.leaves(x)
        nbytes = sum(l.size * l.dtype.itemsize for l in leaves)
        self.events.append(
            CollectiveEvent(
                kind=kind,
                axis=axis,
                bytes=int(nbytes),
                shapes=tuple(tuple(l.shape) for l in leaves),
                axis_size=int(self.axis_sizes.get(axis, 0)),
            )
        )

    # ------------------------------------------------------------- views
    def payload_bytes(self) -> int:
        return sum(e.bytes for e in self.events)

    def realized_bytes(self) -> int:
        """Bytes of collectives whose axis actually spans > 1 shard."""
        return sum(e.bytes for e in self.events if e.axis_size != 1)

    def summary(self) -> dict:
        by_kind: dict[str, int] = {}
        by_axis: dict[str, int] = {}
        for e in self.events:
            if e.axis_size == 1:
                continue
            by_kind[e.kind] = by_kind.get(e.kind, 0) + e.bytes
            by_axis[e.axis] = by_axis.get(e.axis, 0) + e.bytes
        return {
            "n_collectives": len(self.events),
            "payload_bytes": self.payload_bytes(),
            "realized_bytes": self.realized_bytes(),
            "realized_by_kind": by_kind,
            "realized_by_axis": by_axis,
        }


_ACTIVE: list[ByteRecorder] = []


@contextlib.contextmanager
def recording(recorder: ByteRecorder):
    """Route every wrapped collective traced inside the block into
    ``recorder``. Nestable; every active recorder sees every event."""
    _ACTIVE.append(recorder)
    try:
        yield recorder
    finally:
        _ACTIVE.remove(recorder)


def _record(kind: str, axis: str, x) -> None:
    for rec in _ACTIVE:
        rec.add(kind, axis, x)


# -------------------------------------------------------------- wrappers
def psum(x, axis_name: str):
    _record("psum", axis_name, x)
    return jax.lax.psum(x, axis_name)


def pmax(x, axis_name: str):
    _record("pmax", axis_name, x)
    return jax.lax.pmax(x, axis_name)


def pmin(x, axis_name: str):
    _record("pmin", axis_name, x)
    return jax.lax.pmin(x, axis_name)
