"""repro.analysis — machine-checked invariants (DESIGN.md §15).

Four checkers turn the repo's hand-enforced rules into a CI gate:

  determinism — trace the real jaxprs of ``engine.round_body`` /
      ``propose_tree`` / ``server_fold`` / ``staleness_scale`` and the
      sharded builder; flag FMA-contractible seam crossings that bypass
      the ``optimization_barrier``, f64 double-rounding of constants, and
      non-additive combines of local×aggregated values before a ``psum``
      (the subtract-after-psum invariant).
  locks — ``# guarded-by:`` lock-discipline AST pass over the threaded
      runtime and the serving hot-swap pair.
  vmem — BlockSpec scalar/SMEM placement plus tuning-table schema and
      VMEM-budget pricing (absorbs ``benchmarks/check_tuning_table``).
  lints — hardcoded ``interpret=True``, stray ``PRNGKey`` minting outside
      the ticket-key derivation sites, unknown trace-v2 row fields.

Entry point: ``PYTHONPATH=src python -m repro.analysis`` (see
``repro.analysis.cli``). This module — and every checker except
``determinism`` — imports no jax, so the lint tier can run it on a bare
interpreter.
"""
from repro.analysis.findings import Finding  # noqa: F401

__all__ = ["Finding"]
