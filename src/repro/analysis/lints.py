"""Checker 4: repo-invariant lints.

Three rules that exist because each was once a real review comment:

  hardcoded-interpret — ``interpret=True`` literals outside ``tests/``.
      PR 4 made every kernel wrapper take ``interpret=None`` and
      autodetect (interpret on CPU, compiled on TPU); a hardcoded
      ``True`` in a benchmark or example silently benchmarks the Pallas
      interpreter and reports numbers off by orders of magnitude.
  prngkey-outside-ticket — ``jax.random.PRNGKey`` in library code outside
      the ticket-key derivation sites (``ps/worker.py``, ``ps/runtime.py``,
      ``ps/engine.py``). The record→replay contract keys every tree build
      off the ticket's ``key_index``; a fresh PRNGKey minted anywhere
      else produces randomness the trace cannot replay. ``launch/`` is a
      CLI layer (seeds come from argv) and is exempt.
  unknown-trace-field — ``rows["<field>"]`` subscripts in ``ps/runtime.py``
      must name fields in the trace-v2 array schema. The whitelist is
      read out of ``_ARRAYS_V1``/``_ARRAYS_V2`` in the file itself (AST,
      no import), so extending the schema and using the new field is one
      edit, but a typo'd row name — which would silently write to a
      KeyError at runtime, or worse, a fresh dict entry the saver drops —
      is flagged at lint time.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.findings import Finding

CHECKER = "lints"

# Library roots scanned for interpret/PRNGKey; tests/ is exempt by
# construction (corpus snippets and unit tests legitimately hardcode both).
SCAN_ROOTS = ("src", "benchmarks", "examples")

# The only files allowed to mint PRNGKeys: ticket-key derivation and the
# engine's seed plumbing. Everything else must thread keys from tickets.
PRNGKEY_ALLOWLIST = {
    "src/repro/ps/engine.py",
    "src/repro/ps/runtime.py",
    "src/repro/ps/worker.py",
}
# CLI drivers: seeds arrive via argv, not via the replay contract.
PRNGKEY_EXEMPT_DIRS = ("src/repro/launch/",)

RUNTIME_FILE = "src/repro/ps/runtime.py"
TRACE_SCHEMA_NAMES = ("_ARRAYS_V1", "_ARRAYS_V2")


def _iter_py(root: pathlib.Path):
    for scan in SCAN_ROOTS:
        base = root / scan
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(root).as_posix()
            if "/tests/" in f"/{rel}" or rel.startswith("tests/"):
                continue
            yield p, rel


def _enclosing_def(tree: ast.Module, lineno: int) -> str:
    """Name of the innermost def containing ``lineno`` (fingerprint ident)."""
    best = "module"
    best_span = None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            end = getattr(node, "end_lineno", node.lineno)
            if node.lineno <= lineno <= end:
                span = end - node.lineno
                if best_span is None or span < best_span:
                    best, best_span = node.name, span
    return best


def check_interpret(tree: ast.Module, relpath: str) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (
                kw.arg == "interpret"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                findings.append(
                    Finding(
                        CHECKER, "hardcoded-interpret", "error", relpath,
                        kw.value.lineno,
                        "interpret=True hardcoded outside tests/ — pass "
                        "interpret=None and let the PR-4 autodetect pick "
                        "interpreter-on-CPU / compiled-on-TPU; a hardcoded "
                        "True silently times the Pallas interpreter",
                        ident=_enclosing_def(tree, kw.value.lineno),
                    )
                )
    return findings


def check_prngkey(tree: ast.Module, relpath: str) -> list[Finding]:
    if not relpath.startswith("src/repro/"):
        return []
    if relpath in PRNGKEY_ALLOWLIST:
        return []
    if any(relpath.startswith(d) for d in PRNGKEY_EXEMPT_DIRS):
        return []
    findings = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "PRNGKey"
        ):
            findings.append(
                Finding(
                    CHECKER, "prngkey-outside-ticket", "error", relpath,
                    node.lineno,
                    "jax.random.PRNGKey minted outside the ticket-key "
                    "derivation sites — randomness not derived from a "
                    "ticket's key_index cannot be replayed from the trace, "
                    "which breaks the bit-for-bit record→replay contract",
                    ident=_enclosing_def(tree, node.lineno),
                )
            )
    return findings


def _trace_schema_fields(tree: ast.Module) -> set[str]:
    """String keys of ``_ARRAYS_V1``/``_ARRAYS_V2`` dict literals, with
    ``**_ARRAYS_V1``-style spreads resolved by name."""
    by_name: dict[str, set[str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Dict):
            continue
        names = [
            t.id for t in node.targets if isinstance(t, ast.Name)
        ]
        if not any(n in TRACE_SCHEMA_NAMES for n in names):
            continue
        keys: set[str] = set()
        for k in node.value.keys:
            if k is None:
                continue  # ** spread; resolved below via values
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
        for k, v in zip(node.value.keys, node.value.values):
            if k is None and isinstance(v, ast.Name):
                keys |= by_name.get(v.id, set())
        for n in names:
            by_name[n] = keys
    fields: set[str] = set()
    for n in TRACE_SCHEMA_NAMES:
        fields |= by_name.get(n, set())
    return fields


def check_trace_fields(tree: ast.Module, relpath: str) -> list[Finding]:
    fields = _trace_schema_fields(tree)
    findings = []
    if not fields:
        return [
            Finding(
                CHECKER, "trace-schema-missing", "error", relpath, 0,
                "could not find the _ARRAYS_V1/_ARRAYS_V2 dict literals — "
                "the trace schema moved; update repro.analysis.lints",
                ident="schema",
            )
        ]
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and node.value.id == "rows"
            and isinstance(node.slice, ast.Constant)
            and isinstance(node.slice.value, str)
        ):
            continue
        field = node.slice.value
        if field not in fields:
            findings.append(
                Finding(
                    CHECKER, "unknown-trace-field", "error", relpath,
                    node.lineno,
                    f"rows[{field!r}] is not a trace-v2 array field "
                    f"({', '.join(sorted(fields))}) — a typo'd row name "
                    "either KeyErrors mid-run or writes a dict entry the "
                    "trace saver silently drops",
                    ident=field,
                )
            )
    return findings


def check_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for path, rel in _iter_py(root):
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:
            findings.append(
                Finding(
                    CHECKER, "syntax-error", "error", rel,
                    e.lineno or 0, f"cannot parse: {e.msg}", ident="parse",
                )
            )
            continue
        findings.extend(check_interpret(tree, rel))
        findings.extend(check_prngkey(tree, rel))
        if rel == RUNTIME_FILE:
            findings.extend(check_trace_fields(tree, rel))
    return findings
