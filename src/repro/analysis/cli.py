"""CLI driver: ``PYTHONPATH=src python -m repro.analysis``.

Runs the four checkers, subtracts inline suppressions and the committed
baseline (``analysis_baseline.json`` at the repo root), prints the rest,
and exits non-zero when anything NEW is found. Modelled on the repo's
other ratchet gates (coverage floor, ``check_bench`` snapshot): the gate
only ever tightens, and loosening it is a reviewed one-line diff to the
baseline file.

    python -m repro.analysis                    # full run vs baseline
    python -m repro.analysis --only vmem        # one checker (the old
                                                #   check_tuning_table)
    python -m repro.analysis --write-baseline   # accept current findings
    python -m repro.analysis --json out.json    # CI artifact
    python -m repro.analysis --selftest         # inject a violation,
                                                #   assert it is caught

``determinism`` needs the jax stack (it traces real jaxprs); the other
three are stdlib-only AST/JSON passes. When jax is absent — the lint-tier
runner — the determinism checker is skipped with a notice unless it was
requested by name, in which case the missing stack is an error.
"""
from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import sys
import textwrap

from repro.analysis import findings as F

# name -> module (lazy-imported so `--only locks` never touches jax)
CHECKERS = ("determinism", "locks", "vmem", "lints")
NEEDS_JAX = {"determinism"}

BASELINE_NAME = "analysis_baseline.json"


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/cli.py -> repo root
    return pathlib.Path(__file__).resolve().parents[3]


def run_checkers(
    root: pathlib.Path, only: list[str], *, explicit: bool
) -> tuple[list[F.Finding], list[str]]:
    """(findings, notices). Checkers the environment cannot run are
    skipped with a notice, unless the user named them (``explicit``)."""
    out: list[F.Finding] = []
    notices: list[str] = []
    for name in only:
        if name in NEEDS_JAX:
            try:
                importlib.import_module("jax")
            except ImportError:
                if explicit:
                    raise SystemExit(f"checker {name!r} needs jax, which is not installed")
                notices.append(f"skipped {name!r}: jax not installed (lint-tier run)")
                continue
        mod = importlib.import_module(f"repro.analysis.{name}")
        out.extend(mod.check_repo(root))
    return out, notices


def _sources_for(root: pathlib.Path, fs: list[F.Finding]) -> dict[str, list[str]]:
    sources: dict[str, list[str]] = {}
    for f in fs:
        if f.file in sources or not f.line:
            continue
        p = root / f.file
        if p.is_file():
            sources[f.file] = p.read_text().splitlines()
    return sources


def write_report(
    path: pathlib.Path,
    new: list[F.Finding],
    baselined: list[F.Finding],
    stale: list[str],
    notices: list[str],
) -> None:
    payload = {
        "new": [f.to_json() for f in new],
        "baselined": [f.to_json() for f in baselined],
        "stale_baseline_entries": stale,
        "notices": notices,
    }
    path.write_text(json.dumps(payload, indent=1) + "\n")


def selftest() -> int:
    """Inject one synthetic violation per stdlib checker and assert each
    is caught — proof the gate can actually fail (check_bench idiom)."""
    import tempfile

    from repro.analysis import lints, locks, vmem

    failures: list[str] = []

    def expect(name: str, got: list[F.Finding], code: str) -> None:
        if not any(f.code == code for f in got):
            failures.append(f"{name}: injected {code!r} was NOT flagged")

    with tempfile.TemporaryDirectory() as td:
        tdp = pathlib.Path(td)

        bad_lock = tdp / "bad_lock.py"
        bad_lock.write_text(
            textwrap.dedent(
                """\
                import threading
                lock = threading.Lock()
                shared = {}  # guarded-by: lock
                def worker():
                    shared["v"] = 1
                threading.Thread(target=worker).start()
                """
            )
        )
        expect("locks", locks.check_file(bad_lock, "bad_lock.py"), "unguarded-write")

        bad_spec = tdp / "bad_spec.py"
        bad_spec.write_text(
            "import jax.experimental.pallas as pl\n"
            "spec = pl.BlockSpec((1, 1), lambda i: (0, 0))\n"
        )
        expect("vmem", vmem.check_blockspecs(bad_spec, "bad_spec.py"), "blockspec-scalar")

        root = tdp / "repo"
        (root / "src" / "repro" / "core").mkdir(parents=True)
        (root / "benchmarks").mkdir()
        (root / "benchmarks" / "bad_bench.py").write_text(
            "def run(ops):\n    ops.histogram(interpret=True)\n"
        )
        (root / "src" / "repro" / "core" / "bad_rng.py").write_text(
            "import jax\nkey = jax.random.PRNGKey(0)\n"
        )
        got = lints.check_repo(root)
        expect("lints", got, "hardcoded-interpret")
        expect("lints", got, "prngkey-outside-ticket")

        # the baseline machinery itself: a baselined finding must not
        # count as new, an unlisted one must.
        fs = locks.check_file(bad_lock, "bad_lock.py")
        base = {fs[0].fingerprint: "selftest"}
        new, old, _ = F.split_by_baseline(fs, base)
        if new or len(old) != len(fs):
            failures.append("baseline: a baselined finding counted as new")
        new, _, _ = F.split_by_baseline(fs, {})
        if not new:
            failures.append("baseline: an unlisted finding did not count as new")

    if failures:
        for msg in failures:
            print(f"selftest FAILED: {msg}")
        return 1
    print("selftest ok: injected violations trip every stdlib checker "
          "and the baseline gate")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="determinism / race / VMEM static analysis",
    )
    ap.add_argument(
        "--only", action="append", default=None, metavar="CHECKER",
        help=f"run a subset (repeatable; one of {', '.join(CHECKERS)})",
    )
    ap.add_argument(
        "--root", type=pathlib.Path, default=None,
        help="repo root to analyse (default: this checkout)",
    )
    ap.add_argument(
        "--baseline", type=pathlib.Path, default=None,
        help=f"baseline file (default: <root>/{BASELINE_NAME})",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="accept the current findings as the new baseline and exit 0",
    )
    ap.add_argument(
        "--json", type=pathlib.Path, default=None, metavar="PATH",
        help="write the findings report as JSON (CI artifact)",
    )
    ap.add_argument(
        "--fail-on-new", action=argparse.BooleanOptionalAction, default=True,
        help="exit 1 when findings absent from the baseline exist (default)",
    )
    ap.add_argument(
        "--selftest", action="store_true",
        help="inject synthetic violations and assert the checkers fire",
    )
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()

    root = (args.root or _repo_root()).resolve()
    explicit = args.only is not None
    only = args.only or list(CHECKERS)
    for name in only:
        if name not in CHECKERS:
            ap.error(f"unknown checker {name!r} (have {', '.join(CHECKERS)})")

    try:
        raw, notices = run_checkers(root, only, explicit=explicit)
    except SystemExit:
        raise
    except Exception as e:  # a crashed checker must fail the gate loudly
        print(f"error: checker crashed: {type(e).__name__}: {e}")
        return 2

    fs = F.apply_suppressions(raw, _sources_for(root, raw))
    fs.sort(key=lambda f: (f.file, f.line, f.code))

    baseline_path = args.baseline or root / BASELINE_NAME
    if args.write_baseline:
        F.save_baseline(baseline_path, fs, "TODO: justify or fix")
        print(f"wrote {len(fs)} finding(s) to {baseline_path}")
        return 0

    baseline = F.load_baseline(baseline_path)
    new, baselined, stale = F.split_by_baseline(fs, baseline)

    for msg in notices:
        print(f"note: {msg}")
    for f in new:
        print(f.render())
    if baselined:
        print(f"{len(baselined)} baselined finding(s) "
              f"(justified in {baseline_path.name}):")
        for f in baselined:
            print(f"  [baselined] {f.render()}")
    for fp in stale:
        print(f"stale baseline entry (no longer produced — delete it): {fp}")

    if args.json:
        write_report(args.json, new, baselined, stale, notices)

    checked = ", ".join(only)
    if new:
        print(
            f"{len(new)} NEW finding(s) from [{checked}] — fix them, add "
            f"`# analysis: ignore[<code>]` with cause, or re-baseline via "
            f"--write-baseline and justify each entry"
        )
        return 1 if args.fail_on_new else 0
    print(f"analysis clean: [{checked}] — {len(baselined)} baselined, "
          f"{len(stale)} stale")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
