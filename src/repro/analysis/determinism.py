"""Checker 1: the jaxpr determinism auditor.

The repo's bit-for-bit record→replay contract (DESIGN.md §11/§14) rests on
three numeric-core invariants that, until this pass, were enforced by
convention and caught only when a nightly replay flaked:

  seam        — the propose→fold seam in ``engine.round_body`` must be
                pinned by ``optimization_barrier``, and NO value may flow
                from the propose side into the fold side around it. A
                bypassing edge lets XLA optimize (e.g. FMA-contract a
                ``mul`` into the fold's ``add``) across the exact boundary
                where the threaded runtime compiles two separate programs
                — the contraction then happens in some compilation forms
                and not others, and replay drifts by program shape.
                Keuper & Pfreundt (arXiv:1505.04956) locate async-SGD
                convergence exactly in these numeric-core details.
  f64         — no float64 intermediate may appear in the traced round
                path: the PR-7 host-twin rule says every constant rounds
                f64→f32 ONCE, on the host (``6*rho`` folds in python f64,
                then one f32 cast), so the jnp twin and the numpy twin
                report bitwise-equal step scales. An in-trace f64 op means
                a value rounds once in programs that keep it f64 and twice
                in programs that don't. The audit both scans dtypes and
                cross-checks ``engine.staleness_scale`` against its host
                twin ``schedules.staleness_scales`` value-by-value.
  psum-order  — in the sharded build, f32 aggregation order IS the
                determinism: shards must psum their LOCAL partial
                histograms first and derive siblings (parent − child)
                AFTER the collective (``ps/sharded.py``). Reordering is
                algebraically equal but rounds differently per shard and
                breaks lockstep with the single-device goldens. The audit
                taints shard-local aggregates in the shard_map jaxpr and
                flags any non-additive combine (sub/div/max/min) of a
                not-yet-merged aggregate upstream of a ``psum``. The 2D
                block-distributed build adds a second ordering edge: the
                merged-argmax collectives (``pmax``/``pmin``, DESIGN.md
                §16) must consume gains derived from row-psum-MERGED
                histograms — an argmax merge of partial sums is flagged
                the same way.

All three audits run on JAXPRS — traced, never executed — so they check
the program XLA will actually see, not the source text.
"""
from __future__ import annotations

from repro.analysis.findings import Finding

CHECKER = "determinism"

# Primitives that aggregate across the sample axis: a tainted (shard-local)
# input makes the output a LOCAL AGGREGATE that must reach a psum before
# any non-additive combine touches it.
_REDUCTION_PRIMS = {
    "dot_general",
    "reduce_sum",
    "scatter-add",
    "scatter_add",
    "segment_sum",
    "reduce_window_sum",
}
# Non-additive combines: applying one of these to two local aggregates and
# THEN psumming changes the f32 rounding order vs psum-first (sub/div) or
# the value outright (max/min) — either way shards leave lockstep with the
# single-device build.
_NONADDITIVE_PRIMS = {"sub", "div", "max", "min", "pow", "rem"}
_BARRIER_PRIMS = {"optimization_barrier", "opt_barrier"}
_COLLECTIVE_PRIMS = {"psum", "psum2", "all_reduce", "allreduce"}
# Non-additive COLLECTIVES — the 2D merged-argmax split search (pmax of
# per-shard best gains, pmin of global flat indices; DESIGN.md §16). Their
# outputs are merged like psum's, but feeding one a shard-local partial
# aggregate is itself the violation: max/min do not commute with the row
# psum, so an argmax merge that runs BEFORE the data-axis histogram merge
# picks its winner from partial sums and the forest leaves lockstep.
_NONADDITIVE_COLLECTIVES = {"pmax", "pmin"}


# ------------------------------------------------------------ jaxpr walking
def _sub_jaxprs(eqn):
    """Every sub-jaxpr an equation carries (pjit, scan, cond, shard_map...)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            if hasattr(v, "jaxpr"):  # ClosedJaxpr
                yield v.jaxpr
            elif hasattr(v, "eqns"):  # raw Jaxpr
                yield v


def iter_eqns(jaxpr):
    """Depth-first over every equation, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _find_eqns(jaxpr, prim_names: set) -> list:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name in prim_names]


def _ancestors(jaxpr, seed_vars) -> tuple[set, set]:
    """(eqn ids, var ids) of everything ``seed_vars`` depend on, walking
    producers within ONE jaxpr level (sub-jaxprs are opaque nodes)."""
    producer = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            producer[id(v)] = eqn
    eqn_ids: set = set()
    var_ids: set = set()
    stack = [v for v in seed_vars if not _is_literal(v)]
    while stack:
        v = stack.pop()
        if id(v) in var_ids:
            continue
        var_ids.add(id(v))
        eqn = producer.get(id(v))
        if eqn is not None and id(eqn) not in eqn_ids:
            eqn_ids.add(id(eqn))
            stack.extend(u for u in eqn.invars if not _is_literal(u))
    return eqn_ids, var_ids


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"


def _invar_list(eqn):
    return [v for v in eqn.invars if not _is_literal(v)]


# ------------------------------------------------------------- audit: seam
def audit_seam(jaxpr, where: str = "engine.round_body") -> list[Finding]:
    """The propose→fold seam must be barrier-pinned and leak-free.

    Leak = a value produced on the propose side (an ancestor equation of
    the barrier's inputs) consumed by a fold-side equation (downstream of
    the barrier's outputs) without passing through the barrier. The
    mul→add special case is named in the message: that pair is exactly
    what XLA FMA-contracts differently across compilation forms.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    barriers = [e for e in jaxpr.eqns if e.primitive.name in _BARRIER_PRIMS]
    if not barriers:
        return [
            Finding(
                CHECKER, "seam-unpinned", "error", "<traced>", 0,
                f"{where}: no optimization_barrier between the worker's "
                "propose and the server's fold — XLA may contract or CSE "
                "across the seam differently per compilation form, breaking "
                "bitwise record→replay",
                ident=where,
            )
        ]
    findings: list[Finding] = []
    # Propose side: everything the barrier inputs depend on.
    propose_eqns: set = set()
    propose_outvars: set = set()
    for b in barriers:
        eqn_ids, _ = _ancestors(jaxpr, _invar_list(b))
        propose_eqns |= eqn_ids
    for eqn in jaxpr.eqns:
        if id(eqn) in propose_eqns:
            propose_outvars |= {id(v) for v in eqn.outvars}
    # Fold side: everything reachable from the barrier outputs.
    barrier_out = set()
    for b in barriers:
        barrier_out |= {id(v) for v in b.outvars}
    downstream: set = set()
    reach: set = set(barrier_out)
    changed = True
    while changed:
        changed = False
        for eqn in jaxpr.eqns:
            if id(eqn) in downstream or eqn.primitive.name in _BARRIER_PRIMS:
                continue
            if any(id(v) in reach for v in _invar_list(eqn)):
                downstream.add(id(eqn))
                reach |= {id(v) for v in eqn.outvars}
                changed = True
    producer = {id(v): e for e in jaxpr.eqns for v in e.outvars}
    for eqn in jaxpr.eqns:
        if id(eqn) not in downstream:
            continue
        for v in _invar_list(eqn):
            if id(v) in propose_outvars and id(v) not in barrier_out:
                src = producer.get(id(v))
                pair = ""
                if src is not None and src.primitive.name == "mul" and (
                    eqn.primitive.name == "add"
                ):
                    pair = " (mul feeding add: an FMA-contractible pair)"
                findings.append(
                    Finding(
                        CHECKER, "seam-crossing", "error", "<traced>", 0,
                        f"{where}: value {v} flows from the propose side "
                        f"into fold-side `{eqn.primitive.name}` without "
                        f"passing the optimization_barrier{pair} — the "
                        "threaded runtime compiles the two sides as "
                        "separate programs, so cross-seam optimization "
                        "diverges between forms",
                        ident=f"{where}:{src.primitive.name if src else '?'}"
                        f"->{eqn.primitive.name}",
                    )
                )
    return findings


# -------------------------------------------------------------- audit: f64
def audit_f64(jaxpr, where: str) -> list[Finding]:
    """No float64 intermediate in the traced round path (round-once rule)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    findings = []
    seen = set()
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            dtype = getattr(getattr(v, "aval", None), "dtype", None)
            if dtype is not None and str(dtype) == "float64":
                key = f"{where}:{eqn.primitive.name}"
                if key in seen:
                    continue
                seen.add(key)
                findings.append(
                    Finding(
                        CHECKER, "f64-intermediate", "error", "<traced>", 0,
                        f"{where}: `{eqn.primitive.name}` produces float64 "
                        "inside the traced round path — constants must fold "
                        "in host f64 and round to f32 ONCE (the PR-7 "
                        "host-twin rule); an in-trace f64 value double-"
                        "rounds in mixed-precision program forms",
                        ident=key,
                    )
                )
    return findings


def audit_staleness_twin() -> list[Finding]:
    """Bitwise cross-check: ``engine.staleness_scale`` (the jnp form the
    fused replay computes) against ``schedules.staleness_scales`` (the
    host-numpy form the trace records). Any mismatch at any (rho, tau)
    means the recorded ``step_scale`` column would disagree with the
    replayed fold — the exact drift the round-once rule exists to stop."""
    import numpy as np

    from repro.ps import schedules
    from repro.ps.engine import staleness_scale

    findings = []
    taus = np.arange(32, dtype=np.int32)
    schedule = np.arange(32) - taus  # realized k(j) with staleness tau_j = j
    for rho in (0.01, 0.1, 0.3, 0.9, 1.0, 3.0):
        host = schedules.staleness_scales(schedule, rho)
        jnp_scales = np.asarray(
            [np.asarray(staleness_scale(rho, int(t))) for t in taus],
            np.float32,
        )
        if not (host.view(np.uint32) == jnp_scales.view(np.uint32)).all():
            bad = int(np.flatnonzero(host != jnp_scales)[0])
            findings.append(
                Finding(
                    CHECKER, "twin-mismatch", "error", "<traced>", 0,
                    f"staleness_scale(rho={rho}, tau={bad}) = "
                    f"{jnp_scales[bad]!r} but the host twin "
                    f"schedules.staleness_scales reports {host[bad]!r} — "
                    "the trace's step_scale column would not match the "
                    "replayed fold bitwise",
                    ident=f"rho={rho}",
                )
            )
    return findings


# ------------------------------------------------------- audit: psum order
def audit_psum_order(jaxpr, where: str = "ps.sharded") -> list[Finding]:
    """Local aggregates must merge (psum) before any non-additive combine.

    Taint model, per shard_map body:
      local[v] — v depends on shard-local data (a sharded block argument)
                 via a path with no intervening psum;
      agg[v]   — that dependency passes a reduction (dot/segment-sum/...),
                 i.e. v holds a shard-local PARTIAL AGGREGATE.
    psum output clears both. A sub/div/max/min consuming a local aggregate
    is the violation: psum(a) − psum(b) and psum(a − b) agree in algebra
    but not in f32 rounding order (and max/min not even in algebra), so
    the sharded build would leave bitwise lockstep with the single-device
    path — the subtract-AFTER-psum invariant of ps/sharded.py.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    findings: list[Finding] = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        for body in _sub_jaxprs(eqn):
            findings.extend(_audit_shard_body(body, eqn, where))
    return findings


def _audit_shard_body(body, shmap_eqn, where: str) -> list[Finding]:
    # Sharded block args: invars whose in_spec names a mesh axis. Specs can
    # be jax-version-shaped several ways; default to "all sharded" if the
    # param is missing (conservative: more taint, never less).
    specs = shmap_eqn.params.get("in_names") or shmap_eqn.params.get("in_specs")
    invars = list(body.invars)
    local_in = []
    for i in range(len(invars)):
        sharded = True
        if specs is not None and i < len(specs):
            spec = specs[i]
            names = spec if isinstance(spec, (dict, tuple, list)) else [spec]
            flat = []
            for x in (names.values() if isinstance(names, dict) else names):
                flat.extend(x if isinstance(x, (tuple, list)) else [x])
            sharded = any(x is not None for x in flat)
        local_in.append(sharded)
    findings: list[Finding] = []
    _propagate(body, local_in, [False] * len(invars), where, findings)
    return findings


def _propagate(
    body, local_in: list, agg_in: list, where: str, findings: list
) -> tuple[list, list]:
    """Taint-propagate through one jaxpr; recurse into call-like
    sub-jaxprs (pjit/closed_call, whose invars map 1:1 onto the call's)
    so reductions hidden inside jitted helpers still register. Other
    structured eqns (scan/cond/while) are treated opaquely: any tainted
    input taints every output — conservative in `local`, and `agg` only
    combines with `local`, so no false negative hides a real violation
    at the top level where the repo's collectives live. Returns
    (local, agg) flags for ``body.outvars``."""
    body = getattr(body, "jaxpr", body)
    local: set = set()
    agg: set = set()
    for v, loc in zip(body.invars, local_in):
        if loc:
            local.add(id(v))
    for v, ag in zip(body.invars, agg_in):
        if ag:
            agg.add(id(v))
    for eqn in body.eqns:
        name = eqn.primitive.name
        ivs = _invar_list(eqn)
        in_local = [id(v) in local for v in ivs]
        in_agg = [id(v) in agg for v in ivs]
        if name in _COLLECTIVE_PRIMS:
            continue  # outputs merged: neither local nor agg
        if name in _NONADDITIVE_COLLECTIVES:
            if any(loc and ag for loc, ag in zip(in_local, in_agg)):
                findings.append(
                    Finding(
                        CHECKER, "premerge-combine", "error", "<traced>", 0,
                        f"{where}: `{name}` merges a shard-local partial "
                        "aggregate — the argmax-merge collective must run "
                        "on gains derived from row-psum-MERGED histograms "
                        "(max/min do not commute with the data-axis psum; "
                        "DESIGN.md §16): merging partial sums picks a "
                        "different winner per program form and the forest "
                        "leaves bitwise lockstep",
                        ident=f"{where}:{name}",
                    )
                )
            continue  # outputs merged across the axis: clear both taints
        subs = list(_sub_jaxprs(eqn))
        if name in ("pjit", "closed_call", "core_call", "xla_call") and len(subs) == 1:
            sub = subs[0]
            n_sub = len(getattr(sub, "invars", []))
            call_local = [id(v) in local for v in eqn.invars[-n_sub:]] if n_sub else []
            call_agg = [id(v) in agg for v in eqn.invars[-n_sub:]] if n_sub else []
            out_loc, out_ag = _propagate(sub, call_local, call_agg, where, findings)
            for v, loc, ag in zip(eqn.outvars, out_loc, out_ag):
                if loc:
                    local.add(id(v))
                if ag:
                    agg.add(id(v))
            continue
        if name in _NONADDITIVE_PRIMS and any(
            loc and ag for loc, ag in zip(in_local, in_agg)
        ):
            findings.append(
                Finding(
                    CHECKER, "premerge-combine", "error", "<traced>", 0,
                    f"{where}: `{name}` combines a shard-local partial "
                    "aggregate BEFORE its psum — derive siblings / take "
                    "ratios only after the collective (subtract-after-psum "
                    "invariant, ps/sharded.py): pre-merge combines reorder "
                    "the f32 reduction and break cross-shard bitwise "
                    "lockstep",
                    ident=f"{where}:{name}",
                )
            )
        out_local = any(in_local)
        out_agg = any(in_agg) or (name in _REDUCTION_PRIMS and any(in_local))
        for v in eqn.outvars:
            if out_local:
                local.add(id(v))
            if out_agg:
                agg.add(id(v))
    out_loc = [id(v) in local for v in body.outvars]
    out_ag = [id(v) in agg for v in body.outvars]
    return out_loc, out_ag


# ------------------------------------------------------------- repo driver
def _tiny_problem():
    """A minimal (cfg, data) pair for tracing — 64 samples, 8 features."""
    from repro.core.sgbdt import SGBDTConfig, init_state
    from repro.data.synthetic import make_sparse_classification
    from repro.trees.learner import LearnerConfig

    data = make_sparse_classification(64, 8, 3, seed=0)
    cfg = SGBDTConfig(
        n_trees=4,
        learner=LearnerConfig(depth=2, n_bins=64),
        adaptive_step=0.3,  # exercise the scale_push path in the audit
    )
    state = init_state(cfg, data)
    return cfg, data, state


def check_repo(root=None) -> list[Finding]:
    """Trace the engine's round path and the sharded builder; audit all."""
    del root  # jaxpr audits are source-location-free
    import jax
    import jax.numpy as jnp

    from repro.ps import engine

    cfg, data, state = _tiny_problem()
    # Tracer-only key: never folded into a model, so nothing to replay.
    rng = jax.random.PRNGKey(0)  # analysis: ignore[prngkey-outside-ticket]
    findings: list[Finding] = []

    round_jaxpr = jax.make_jaxpr(
        lambda forest, f, f_target, rng: engine.round_body(
            cfg, data, forest, f, f_target, rng, None, jnp.int32(2)
        )
    )(state.forest, state.f, state.f, rng)
    findings += audit_seam(round_jaxpr, "engine.round_body")
    findings += audit_f64(round_jaxpr, "engine.round_body")

    propose_jaxpr = jax.make_jaxpr(
        lambda f_target, rng: engine.propose_tree(cfg, data, f_target, rng)
    )(state.f, rng)
    findings += audit_f64(propose_jaxpr, "engine.propose_tree")

    tree, delta = engine.propose_tree(cfg, data, state.f, rng)
    fold_jaxpr = jax.make_jaxpr(
        lambda forest, f, tree, delta: engine.server_fold(cfg, forest, f, tree, delta)
    )(state.forest, state.f, tree, delta)
    findings += audit_f64(fold_jaxpr, "engine.server_fold")

    scale_jaxpr = jax.make_jaxpr(lambda tau: engine.staleness_scale(0.3, tau))(jnp.int32(3))
    findings += audit_f64(scale_jaxpr, "engine.staleness_scale")
    findings += audit_staleness_twin()

    findings += _check_sharded(cfg, data)
    return findings


def _check_sharded(cfg, data) -> list[Finding]:
    """Trace the shard_map builds on 1-device meshes (the jaxpr is
    identical in structure to the multi-shard program — psum, pmax/pmin
    and all — which is all the ordering audit needs): the 1D data-parallel
    build, and the 2D (data × feature) build with its argmax-merge
    collective, on dense and on SparseBins data."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.ps.sharded import make_sharded_builder, make_sharded_builder_2d
    from repro.trees.binning import to_sparse

    g = jax.numpy.zeros((data.n_samples,), jax.numpy.float32)
    rng = jax.random.PRNGKey(0)  # analysis: ignore[prngkey-outside-ticket]
    findings = []
    mesh_1d = Mesh(np.array(jax.devices()[:1]), ("data",))
    mesh_2d = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "feature"))
    sparse_bins = to_sparse(data.bins)
    for mode in ("subtract", "rebuild"):
        cfg_m = cfg.learner._replace(hist_mode=mode)
        builder_m = make_sharded_builder(cfg_m, mesh_1d, "data")
        jaxpr = jax.make_jaxpr(builder_m)(data.bins, g, g, rng)
        findings += audit_psum_order(jaxpr, f"ps.sharded[{mode}]")
        builder_2d = make_sharded_builder_2d(cfg_m, mesh_2d)
        jaxpr = jax.make_jaxpr(builder_2d)(data.bins, g, g, rng)
        findings += audit_psum_order(jaxpr, f"ps.sharded2d[{mode}]")
        jaxpr = jax.make_jaxpr(builder_2d)(sparse_bins, g, g, rng)
        findings += audit_psum_order(jaxpr, f"ps.sharded2d-sparse[{mode}]")
    return findings
