"""Schema validation for ``src/repro/kernels/tuning_table.json``.

The tuning table is data the kernel dispatcher trusts at import time: a
malformed entry (a typo'd key, a string where a block size should be, a
format bump nobody taught the loader about) turns into a confusing
runtime failure deep inside a Pallas grid computation. This module is
stdlib-only — no jax import — so it runs in the lint tier; the VMEM
checker (``repro.analysis.vmem``) layers the budget cross-check on top.

Moved here from ``benchmarks/check_tuning_table.py`` (now a thin shim) so
the schema and the budget check share one entry point:
``python -m repro.analysis --only vmem``.
"""
from __future__ import annotations

import pathlib
import re

KEY_RE = re.compile(r"^N\d+_F\d+_B\d+_L\d+$")
KNOWN_FORMATS = {1}
# field -> (type, must be > 0)
ENTRY_FIELDS = {
    "sample_block": (int, True),
    "feature_block": (int, True),
    "node_block": (int, True),
    "fused_ms": (float, True),
    "split_ms": (float, True),
    "host": (str, False),
}


def default_table_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1] / "kernels" / "tuning_table.json"


def parse_geometry(key: str) -> tuple[int, int, int, int]:
    """(N, F, B, L) from a ``N<d>_F<d>_B<d>_L<d>`` entry key."""
    parts = dict((seg[0], int(seg[1:])) for seg in key.split("_"))
    return parts["N"], parts["F"], parts["B"], parts["L"]


def validate(table: dict) -> list[str]:
    errors: list[str] = []
    fmt = table.get("format")
    if fmt not in KNOWN_FORMATS:
        errors.append(
            f"format is {fmt!r}; this validator knows {sorted(KNOWN_FORMATS)}"
            " — teach repro.analysis.tuning_schema (and the kernel loader)"
            " the new format before committing it"
        )
        return errors
    unknown_top = set(table) - {"format", "entries", "comment"}
    if unknown_top:
        errors.append(f"unknown top-level fields: {sorted(unknown_top)}")
    entries = table.get("entries")
    if not isinstance(entries, dict):
        errors.append("'entries' must be an object")
        return errors
    for key, entry in entries.items():
        if not KEY_RE.match(key):
            errors.append(f"entry key {key!r} does not match N<d>_F<d>_B<d>_L<d>")
        if not isinstance(entry, dict):
            errors.append(f"{key}: entry must be an object")
            continue
        for field, (typ, positive) in ENTRY_FIELDS.items():
            val = entry.get(field)
            if val is None:
                errors.append(f"{key}: missing field {field!r}")
            elif typ is float:
                if isinstance(val, bool) or not isinstance(val, (int, float)):
                    errors.append(f"{key}.{field}: {val!r} is not a number")
                elif positive and val <= 0:
                    errors.append(f"{key}.{field}: must be > 0, got {val}")
            elif typ is int:
                if isinstance(val, bool) or not isinstance(val, int):
                    errors.append(f"{key}.{field}: {val!r} is not an int")
                elif positive and val <= 0:
                    errors.append(f"{key}.{field}: must be > 0, got {val}")
            elif not isinstance(val, typ):
                errors.append(f"{key}.{field}: {val!r} is not {typ.__name__}")
        unknown = set(entry) - set(ENTRY_FIELDS)
        if unknown:
            errors.append(f"{key}: unknown fields {sorted(unknown)}")
    return errors
