"""Checker 2: lock discipline for the threaded runtime and the server.

The crash-at-grab ticket-loss bug PR 7 fixed is the archetype: a worker
thread touched shared ticket state outside the runtime lock, and the race
only fired when a fault-injection test happened to lose the interleaving
lottery. This checker turns that convention into a machine-checked one,
keyed on annotations IN the code:

  ``# guarded-by: <lock>``   on (or directly above) an assignment marks
                             the assigned name — a local like ``shared``
                             or an attribute like ``self.forest`` — as
                             state that must only be touched while
                             holding ``<lock>``;
  ``# concurrent``           on a ``def`` line opts a function into
                             checking (for code that races without being
                             a literal ``threading.Thread`` target, e.g.
                             the serving hot-swap pair);
  ``# holds-lock: <lock>``   on a ``def`` line asserts the caller already
                             holds the lock (``fire_joins`` in
                             ``ps/runtime.py``) — the body is treated as
                             if wrapped in ``with <lock>:``.

Checked scopes are thread-target functions — any function whose name
appears as ``target=`` in a ``threading.Thread(...)`` call — plus
``# concurrent`` opt-ins, plus functions nested inside either. Within a
checked scope, EVERY read or write of a guarded name must sit lexically
inside ``with <lock>:`` (or in a ``holds-lock`` function). Reads count:
an unlocked read of ``shared["version"]`` races the fold loop's publish
just as surely as a write.

Purely lexical by design: no alias analysis, no interprocedural lock
tracking. The runtime keeps its shared state in a handful of names, and a
lexical rule the checker can actually enforce beats a clever one it
cannot.
"""
from __future__ import annotations

import ast
import pathlib
import re

from repro.analysis.findings import Finding

CHECKER = "locks"

# The files whose lock discipline is machine-checked. Annotation comments
# anywhere else are honored too if the file is passed explicitly.
DEFAULT_FILES = (
    "src/repro/ps/runtime.py",
    "src/repro/serving/forest_server.py",
    "src/repro/serving/continuous.py",
)

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")
_HOLDS_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_][\w.]*)")
_CONCURRENT_RE = re.compile(r"#\s*concurrent\b")


def _kind_of(node: ast.AST) -> str:
    ctx = getattr(node, "ctx", None)
    return "write" if isinstance(ctx, (ast.Store, ast.Del)) else "read"


def _expr_name(node: ast.AST) -> str | None:
    """Dotted name for Name/Attribute expressions (``self._lock``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _expr_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _assign_targets(node: ast.AST) -> list[str]:
    targets: list[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    out = []
    for t in targets:
        name = _expr_name(t)
        if name:
            out.append(name)
    return out


def _collect_annotations(tree: ast.Module, lines: list[str]):
    """guarded: {name: lock}. A ``# guarded-by`` comment binds to the
    assignment on its own line, or — when it stands alone — to the first
    assignment on the next code line."""
    guarded: dict[str, str] = {}
    ann_by_line: dict[int, str] = {}
    for i, line in enumerate(lines, start=1):
        m = _GUARDED_RE.search(line)
        if m:
            ann_by_line[i] = m.group(1)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        lock = ann_by_line.get(node.lineno)
        if lock is None:
            # comment-on-its-own-line directly above
            lock = ann_by_line.get(node.lineno - 1)
            if lock is not None and lines[node.lineno - 2].strip() and not (
                lines[node.lineno - 2].lstrip().startswith("#")
            ):
                lock = None
        if lock is None:
            continue
        for name in _assign_targets(node):
            guarded[name] = lock
    return guarded


def _thread_targets(tree: ast.Module) -> set[str]:
    """Function names passed as ``target=`` to ``threading.Thread``."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        is_thread = (isinstance(fn, ast.Name) and fn.id == "Thread") or (
            isinstance(fn, ast.Attribute) and fn.attr == "Thread"
        )
        if not is_thread:
            continue
        for kw in node.keywords:
            if kw.arg == "target" and isinstance(kw.value, ast.Name):
                out.add(kw.value.id)
    return out


class _ScopeVisitor(ast.NodeVisitor):
    """Walk one checked function body tracking the with-lock stack."""

    def __init__(self, checker: "_FileCheck", fn: ast.FunctionDef, held: set[str]):
        self.c = checker
        self.fn = fn
        self.held = set(held)

    def visit_With(self, node: ast.With) -> None:
        acquired = set()
        for item in node.items:
            name = _expr_name(item.context_expr)
            if name:
                acquired.add(name)
        self.held |= acquired
        for stmt in node.body:
            self.visit(stmt)
        self.held -= acquired
        # with-header expressions evaluate unlocked, but a lock acquiring
        # itself is the one legal unlocked touch; skip re-visiting items.

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested defs inside a checked scope are checked in their own pass
        # (they inherit checked-ness); don't double-visit here.
        if node is not self.fn:
            return
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check(self, node: ast.AST, name: str, kind: str) -> None:
        lock = self.c.guarded.get(name)
        if lock is None:
            return
        if lock in self.held:
            return
        self.c.findings.append(
            Finding(
                CHECKER, f"unguarded-{kind}", "error", self.c.relpath,
                node.lineno,
                f"{kind} of `{name}` (guarded-by: {lock}) outside "
                f"`with {lock}:` in concurrent scope `{self.fn.name}` — "
                "the crash-at-grab ticket-loss class: the interleaving "
                "that breaks this races a fold-loop publish",
                ident=f"{self.fn.name}:{name}",
            )
        )

    def visit_Name(self, node: ast.Name) -> None:
        self._check(node, node.id, _kind_of(node))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        name = _expr_name(node)
        if name:
            self._check(node, name, _kind_of(node))
        else:
            self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        # `shared["v"] = 1` stores through the subscript: the Store ctx
        # sits on the Subscript node while the base Name reads — report
        # the base as the mutated state.
        base = _expr_name(node.value)
        if base is not None:
            self._check(node, base, _kind_of(node))
            self.visit(node.slice)  # guarded names used as the index
        else:
            self.generic_visit(node)


class _FileCheck:
    def __init__(self, path: pathlib.Path, relpath: str):
        self.relpath = relpath
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        self.guarded = _collect_annotations(self.tree, self.lines)
        self.findings: list[Finding] = []

    def run(self) -> list[Finding]:
        if not self.guarded:
            return []
        targets = _thread_targets(self.tree)
        checked: list[tuple[ast.FunctionDef, set[str]]] = []

        def fn_flags(fn: ast.FunctionDef) -> tuple[bool, set[str]]:
            header = self.lines[fn.lineno - 1]
            concurrent = bool(_CONCURRENT_RE.search(header))
            holds = set(_HOLDS_RE.findall(header))
            return concurrent, holds

        def collect(node: ast.AST, inherited: bool) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    concurrent, holds = fn_flags(child)
                    is_checked = inherited or concurrent or child.name in targets
                    if is_checked:
                        checked.append((child, holds))
                    collect(child, is_checked)
                else:
                    collect(child, inherited)

        collect(self.tree, False)
        for fn, holds in checked:
            _ScopeVisitor(self, fn, holds).visit(fn)
        return self.findings


def check_file(path: pathlib.Path, relpath: str | None = None) -> list[Finding]:
    rel = relpath or str(path)
    return _FileCheck(pathlib.Path(path), rel).run()


def check_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for rel in DEFAULT_FILES:
        p = root / rel
        if p.exists():
            findings.extend(check_file(p, rel))
    return findings
