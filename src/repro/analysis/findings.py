"""Findings, suppressions, and the committed baseline.

Every checker in ``repro.analysis`` reports ``Finding`` records. A finding
is identified across runs by its FINGERPRINT — ``checker:code:file:ident``
— deliberately excluding the line number, so an unrelated edit that shifts
a justified finding down a few lines does not break CI. The committed
baseline (``analysis_baseline.json`` at the repo root) lists fingerprints
that are KNOWN and JUSTIFIED; the CLI exits non-zero only on findings
absent from it. The workflow mirrors every ratchet gate in this repo
(coverage floor, bench snapshot): new violations fail, grandfathered ones
are visible, and removing a stale baseline entry is a one-line diff.

Inline suppression: a ``# analysis: ignore[CODE]`` comment on the
offending line silences that code there — for the rare case where the
checker is right about the pattern but wrong about the instance; the
comment itself is the written-down justification.

This module is stdlib-only (no jax) so the lint-tier shim
``benchmarks/check_tuning_table.py`` can import through the package on a
runner that never installed the ML stack.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re

SEVERITIES = ("error", "warning")

_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore\[([A-Za-z0-9_\-, ]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation of a machine-checked invariant.

    ``ident`` is the stable within-file identifier the fingerprint uses
    instead of the line number: a function/variable name, a tuning-table
    geometry key, a primitive name — whatever survives unrelated edits.
    """

    checker: str  # 'determinism' | 'locks' | 'vmem' | 'lints'
    code: str  # short rule id, e.g. 'seam-crossing', 'unguarded-read'
    severity: str  # 'error' | 'warning'
    file: str  # repo-relative path (or '<traced>' for jaxpr audits)
    line: int  # 1-based; 0 when no source location applies
    message: str
    ident: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def fingerprint(self) -> str:
        ident = self.ident or f"L{self.line}"
        return f"{self.checker}:{self.code}:{self.file}:{ident}"

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        return f"{self.severity:7s} {self.checker}:{self.code} {loc}  {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self) | {"fingerprint": self.fingerprint}


def ignored_codes(source_line: str) -> set[str]:
    """Codes suppressed by a ``# analysis: ignore[...]`` comment, if any."""
    m = _IGNORE_RE.search(source_line)
    if not m:
        return set()
    return {c.strip() for c in m.group(1).split(",") if c.strip()}


def apply_suppressions(
    findings: list[Finding], sources: dict[str, list[str]]
) -> list[Finding]:
    """Drop findings whose source line carries an ignore pragma for their
    code. ``sources`` maps repo-relative path -> list of lines."""
    out = []
    for f in findings:
        lines = sources.get(f.file)
        if lines and 0 < f.line <= len(lines):
            codes = ignored_codes(lines[f.line - 1])
            if f.code in codes or "all" in codes:
                continue
        out.append(f)
    return out


# ------------------------------------------------------------------ baseline
def load_baseline(path: pathlib.Path) -> dict[str, str]:
    """{fingerprint: justification} from the committed baseline file.

    A missing file is an empty baseline (the clean-repo default); a
    malformed one raises — a baseline nobody can parse is a gate nobody
    can trust.
    """
    if not path.exists():
        return {}
    raw = json.loads(path.read_text())
    entries = raw.get("findings", [])
    out: dict[str, str] = {}
    for e in entries:
        fp = e.get("fingerprint")
        if not isinstance(fp, str) or not fp:
            raise ValueError(f"{path}: baseline entry without fingerprint: {e!r}")
        if not isinstance(e.get("justification"), str) or not e["justification"]:
            raise ValueError(
                f"{path}: baseline entry {fp!r} has no justification — "
                "every grandfathered finding must say WHY it is acceptable"
            )
        out[fp] = e["justification"]
    return out


def save_baseline(path: pathlib.Path, findings: list[Finding], justification: str) -> None:
    """Write the current findings as the new baseline (one shared
    placeholder justification — edit the file to write real ones)."""
    payload = {
        "comment": "accepted repro.analysis findings; regenerate with "
        "`PYTHONPATH=src python -m repro.analysis --write-baseline`, then "
        "edit each entry's justification",
        "findings": [
            {
                "fingerprint": f.fingerprint,
                "message": f.message,
                "justification": justification,
            }
            for f in sorted(findings, key=lambda f: f.fingerprint)
        ],
    }
    path.write_text(json.dumps(payload, indent=1) + "\n")


def split_by_baseline(
    findings: list[Finding], baseline: dict[str, str]
) -> tuple[list[Finding], list[Finding], list[str]]:
    """(new, baselined, stale_fingerprints).

    ``stale`` lists baseline entries no run produced — fixed violations
    whose baseline line should now be deleted (reported, never fatal).
    """
    seen = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]
    stale = sorted(set(baseline) - seen)
    return new, old, stale
