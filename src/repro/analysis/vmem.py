"""Checker 3: Pallas kernel VMEM budgets and scalar placement.

Anghel et al. (PAPERS.md, arXiv:1809.04559) show GBDT kernels silently
lose correctness-per-watt in resource budgets, and this repo has exactly
one hand-enforced budget: the fused level-build program must fit
``FUSED_VMEM_BUDGET`` (12 MiB of the ~16 MiB/core VMEM; DESIGN.md §13) or
the learner falls back to the staged pipeline. Three machine checks:

  blockspec-scalar — AST scan of the kernel modules' ``pl.pallas_call``
      sites: a ``(1, 1)``-shaped (or all-ones) ``BlockSpec`` without
      ``memory_space=pltpu.SMEM`` parks a scalar in a full vector tile
      (the pre-PR-6 ``split_scan`` bug), and ``pl.ANY`` placement leaves
      the choice to the compiler. Scalars ride in SMEM, full stop.
  tuning-over-budget — every committed ``tuning_table.json`` row is
      re-priced through the real ``fused_level_vmem_bytes`` model at its
      own winning blocks: a row whose blocks exceed the budget describes
      a program the learner will never run (dispatch falls back), so it
      is either dead weight or a model/tuner disagreement.
  model-drift — ``fused_level_fits`` must agree with pricing the looked-up
      blocks directly; disagreement means the fits() fast path and the
      byte model diverged (someone edited one and not the other).

The schema validation from ``benchmarks/check_tuning_table`` (now a shim)
runs first — a malformed table fails here before anything prices it.
"""
from __future__ import annotations

import ast
import json
import pathlib

from repro.analysis import tuning_schema
from repro.analysis.findings import Finding

CHECKER = "vmem"

KERNEL_FILES = (
    "src/repro/kernels/histogram.py",
    "src/repro/kernels/histogram_sparse.py",
    "src/repro/kernels/split_scan.py",
    "src/repro/kernels/forest_traversal.py",
    "src/repro/kernels/level_build.py",
)


# ----------------------------------------------------------- AST: BlockSpec
def _is_all_ones_tuple(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Tuple)
        and len(node.elts) >= 1
        and all(isinstance(e, ast.Constant) and e.value == 1 for e in node.elts)
    )


def _kw(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _spec_calls(tree: ast.Module):
    """Every ``BlockSpec(...)`` call node in the module."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else getattr(fn, "id", None)
            if name == "BlockSpec":
                yield node


def check_blockspecs(path: pathlib.Path, relpath: str) -> list[Finding]:
    tree = ast.parse(path.read_text(), filename=str(path))
    findings = []
    for call in _spec_calls(tree):
        mem = _kw(call, "memory_space")
        mem_name = ast.unparse(mem) if mem is not None else ""
        if "ANY" in mem_name:
            findings.append(
                Finding(
                    CHECKER, "blockspec-any", "error", relpath, call.lineno,
                    "BlockSpec(memory_space=ANY) leaves operand placement "
                    "to the compiler — pin scalars to SMEM and arrays to "
                    "the default VMEM pipeline explicitly",
                    ident=f"L{call.lineno}",
                )
            )
            continue
        shape = call.args[0] if call.args else None
        if shape is not None and _is_all_ones_tuple(shape) and "SMEM" not in mem_name:
            findings.append(
                Finding(
                    CHECKER, "blockspec-scalar", "error", relpath, call.lineno,
                    f"scalar operand BlockSpec({ast.unparse(shape)}) is not "
                    "placed in SMEM — a lone scalar in a vector tile burns "
                    "a VMEM window and serializes against the block DMA "
                    "pipeline (the pre-PR-6 split_scan placement)",
                    ident=f"L{call.lineno}",
                )
            )
    return findings


# ----------------------------------------------------- tuning-table pricing
def check_tuning_table(table_path: pathlib.Path, relpath: str) -> list[Finding]:
    try:
        table = json.loads(table_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [
            Finding(
                CHECKER, "table-unreadable", "error", relpath, 0,
                f"cannot read tuning table: {e}", ident="table",
            )
        ]
    findings = [
        Finding(CHECKER, "table-schema", "error", relpath, 0, err, ident=err[:60])
        for err in tuning_schema.validate(table)
    ]
    if findings:
        return findings  # pricing a malformed table is meaningless
    try:
        from repro.kernels.level_build import (
            FUSED_VMEM_BUDGET,
            fused_level_fits,
            fused_level_vmem_bytes,
        )
    except ImportError:
        # stdlib-only environment (the lint-tier shim): schema checked,
        # budget pricing needs the jax stack — skip, the analysis CI job
        # runs the full check.
        return findings
    for key, entry in table.get("entries", {}).items():
        n, f, b, l = tuning_schema.parse_geometry(key)
        nbytes = fused_level_vmem_bytes(
            l, l, f, b, entry["sample_block"], entry["feature_block"]
        )
        if nbytes > FUSED_VMEM_BUDGET:
            findings.append(
                Finding(
                    CHECKER, "tuning-over-budget", "warning", relpath, 0,
                    f"{key}: tuned blocks (sb={entry['sample_block']}, "
                    f"fb={entry['feature_block']}) price at "
                    f"{nbytes / 2**20:.1f} MiB > the "
                    f"{FUSED_VMEM_BUDGET / 2**20:.0f} MiB fused budget — "
                    "the learner's fused_level_fits() falls back to the "
                    "staged pipeline at this geometry, so this row only "
                    "serves direct ops.level_build callers (kernel_bench)",
                    ident=key,
                )
            )
        # fits() must agree with pricing its own looked-up blocks: the
        # fast path and the byte model drifting apart means dispatch
        # decisions stop matching the documented budget math.
        from repro.kernels import autotune

        blocks = autotune.lookup(n, f, b, l)
        direct = (
            fused_level_vmem_bytes(
                l, l, f, b, blocks["sample_block"], blocks["feature_block"]
            )
            <= FUSED_VMEM_BUDGET
        )
        if fused_level_fits(n, l, l, f, b) != direct:
            findings.append(
                Finding(
                    CHECKER, "model-drift", "error", relpath, 0,
                    f"{key}: fused_level_fits() disagrees with pricing the "
                    "looked-up blocks through fused_level_vmem_bytes() — "
                    "the VMEM model and the dispatch fast path have "
                    "diverged",
                    ident=f"drift:{key}",
                )
            )
    return findings


def check_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for rel in KERNEL_FILES:
        p = root / rel
        if p.exists():
            findings.extend(check_blockspecs(p, rel))
    table_rel = "src/repro/kernels/tuning_table.json"
    table = root / table_rel
    if table.exists():
        findings.extend(check_tuning_table(table, table_rel))
    else:
        findings.append(
            Finding(
                CHECKER, "table-missing", "error", table_rel, 0,
                "tuning_table.json is gone — dispatch silently falls back "
                "to DEFAULT_BLOCKS everywhere",
                ident="table",
            )
        )
    return findings
