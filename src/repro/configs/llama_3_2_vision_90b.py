"""llama-3.2-vision-90b [vlm] — cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
cross-attends to (stubbed) vision-encoder patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_media_tokens=1601,  # 1 tile x (40x40 + 1) patches from the ViT stub
    rope_theta=500_000.0,
    long_context_window=8192,
)
