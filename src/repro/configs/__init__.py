"""Assigned-architecture registry: ``get(arch_id)`` -> ModelConfig.

Each module pins the exact dims from the assignment (source in brackets in
its docstring). GBDT configs for the paper's own experiments live in
``repro.configs.gbdt``.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "h2o_danube_1_8b",
    "dbrx_132b",
    "minitron_4b",
    "llama_3_2_vision_90b",
    "whisper_small",
    "granite_3_2b",
    "codeqwen1_5_7b",
    "zamba2_1_2b",
    "phi3_5_moe_42b",
    "xlstm_1_3b",
]

# CLI ids (assignment spelling) -> module names
ALIASES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "dbrx-132b": "dbrx_132b",
    "minitron-4b": "minitron_4b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-small": "whisper_small",
    "granite-3-2b": "granite_3_2b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def get(arch: str) -> ModelConfig:
    mod = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get(a) for a in ALIASES}
