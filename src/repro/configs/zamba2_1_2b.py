"""zamba2-1.2b [hybrid] — Mamba2 backbone + one shared attention block.

38 Mamba2 layers, d_model=2048, ssm_state=64; a single shared
(attention + MLP) block (32H kv=32, d_ff=8192) is invoked every 6 layers,
re-using the same weights each time. [arXiv:2411.15242]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    rope_theta=10_000.0,
)
