"""codeqwen1.5-7b [dense] — qwen1.5 arch, full MHA (kv=32).

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416. [hf:Qwen/CodeQwen1.5-7B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1_000_000.0,
    long_context_window=8192,
)
