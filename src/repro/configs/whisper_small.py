"""whisper-small [audio] — encoder-decoder backbone; conv frontend stubbed.

12L (enc) + 12L (dec) d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865.
input_specs() provides precomputed mel/conv frame embeddings (B, 1500, 768).
[arXiv:2212.04356]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    n_media_tokens=1500,
    rope_theta=10_000.0,
    # long_500k: SKIPPED (see DESIGN.md — 30 s / 448-token decoding horizon,
    # full-attention enc-dec family has no sub-quadratic variant).
)
