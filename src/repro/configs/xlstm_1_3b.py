"""xlstm-1.3b [ssm] — mLSTM blocks with an sLSTM block every 8th layer.

48L d_model=2048 4H (kv=4, head_dim=512 matrix memories) d_ff=0 (the xLSTM
block carries its own 2x up/down projection). [arXiv:2405.04517]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    ssm_chunk=256,
)
