"""The paper's own experiment configurations, as code.

Validity experiments (§VI.B): real-sim 400 trees / 100 leaves (depth 7),
Higgs 1000 trees / 20 leaves (depth 5), feature_fraction 0.8, v = 0.01.
Efficiency experiments (§VI.C): 400 trees / 400 leaves (depth 9), R = 0.8.

Datasets are the property-matched synthetic stand-ins from
``repro.data.synthetic.PAPER_DATASETS`` (see DESIGN.md §7 for why); the
``quick`` variants keep every ratio but shrink tree counts for CI.
"""
from __future__ import annotations

import dataclasses

from repro.core.sgbdt import SGBDTConfig
from repro.data.synthetic import PAPER_DATASETS, DatasetSpec, load
from repro.trees.learner import LearnerConfig


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    name: str
    dataset: DatasetSpec
    config: SGBDTConfig
    paper_section: str


def _cfg(
    n_trees: int, depth: int, rate: float, v: float, loss: str,
    hist_mode: str = "subtract",
) -> SGBDTConfig:
    # ``hist_mode`` threads the histogram-subtraction builder through the
    # paper experiments; "subtract" is the production default (≈ half the
    # histogram kernel work per tree), "rebuild" reproduces the historical
    # full-level builds bitwise (see trees.learner).
    return SGBDTConfig(
        n_trees=n_trees, step_length=v, sampling_rate=rate, loss=loss,
        learner=LearnerConfig(
            depth=depth, n_bins=64, feature_fraction=0.8, hist_mode=hist_mode
        ),
    )


EXPERIMENTS: dict[str, PaperExperiment] = {
    # validity: real-sim, 400 trees x 100 leaves (depth 7 = 128 leaves)
    "validity-realsim": PaperExperiment(
        name="validity-realsim",
        dataset=PAPER_DATASETS["realsim-like"],
        config=_cfg(400, 7, 0.8, 0.01, "logistic"),
        paper_section="VI.B / Figs. 6, 8",
    ),
    # validity: Higgs, 1000 trees x 20 leaves (depth 5 = 32 leaves)
    "validity-higgs": PaperExperiment(
        name="validity-higgs",
        dataset=PAPER_DATASETS["higgs-like"],
        config=_cfg(1000, 5, 0.8, 0.01, "logistic"),
        paper_section="VI.B / Figs. 5, 7",
    ),
    # efficiency: real-sim, 400 trees x 400 leaves (depth 9 = 512 leaves)
    "efficiency-realsim": PaperExperiment(
        name="efficiency-realsim",
        dataset=PAPER_DATASETS["realsim-like"],
        config=_cfg(400, 9, 0.8, 0.01, "logistic"),
        paper_section="VI.C / Fig. 10",
    ),
    "efficiency-e2006": PaperExperiment(
        name="efficiency-e2006",
        dataset=PAPER_DATASETS["e2006-like"],
        config=_cfg(400, 9, 0.8, 0.01, "mse"),
        paper_section="VI.C / Fig. 10",
    ),
}


def get(name: str, quick: bool = False) -> tuple[SGBDTConfig, object]:
    """-> (config, binned dataset). ``quick`` shrinks the tree budget 5x."""
    exp = EXPERIMENTS[name]
    cfg = exp.config
    if quick:
        cfg = cfg._replace(n_trees=max(cfg.n_trees // 5, 40))
    return cfg, load(exp.dataset)
