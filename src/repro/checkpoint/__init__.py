"""Checkpointing: pytree save/restore with manifest + integrity checks."""
from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
    step_dir,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "restore_pytree",
    "save_pytree",
    "step_dir",
]
