"""Checkpointing: pytree save/restore with manifest + integrity checks."""
from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    leaf_manifest,
    restore_pytree,
    save_pytree,
    step_dir,
    steps,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "leaf_manifest",
    "restore_pytree",
    "save_pytree",
    "step_dir",
    "steps",
]
