"""Filesystem checkpointing for arbitrary pytrees.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json     # treedef, leaf paths, shapes/dtypes, checksums
        leaf_00000.npy    # one .npy per leaf (host numpy, any dtype)
        ...

Writes are atomic (tmp dir + rename), restores validate shapes/dtypes and
(optionally) CRCs, and ``CheckpointManager`` retains the newest K steps —
the minimum a production training service needs. On a real pod each host
writes its local shards; here the host is the only participant.

The paper's server state (GBDT ``TrainState``: forest arrays + F vector +
step) and the NN stack (params + optimizer state) both round-trip through
this module — see tests/test_checkpoint.py.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import zlib

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(root: str | pathlib.Path, step: int, tree, *, crc: bool = True):
    """Atomically save ``tree`` under ``root/step_<step>``."""
    root = pathlib.Path(root)
    final = root / f"step_{step:06d}"
    tmp = root / f".tmp_step_{step:06d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":  # numpy can't round-trip ml_dtypes
            np.save(tmp / fname, arr.view(np.uint16))
        else:
            np.save(tmp / fname, arr)
        entry = {
            "path": path,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical_dtype,
        }
        if crc:
            entry["crc32"] = zlib.crc32(arr.tobytes())
        manifest["leaves"].append(entry)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def restore_pytree(root: str | pathlib.Path, step: int, like, *, check_crc: bool = False):
    """Restore into the structure (and leaf shapes/dtypes) of ``like``."""
    d = step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())
    paths, leaves, treedef = _leaves_with_paths(like)
    entries = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for path, leaf in zip(paths, leaves):
        e = entries.get(path)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = np.load(d / e["file"])
        if e["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != expected {want_shape}"
            )
        if check_crc and "crc32" in e and zlib.crc32(arr.tobytes()) != e["crc32"]:
            raise ValueError(f"{path}: CRC mismatch (corrupt checkpoint)")
        dtype = np.asarray(leaf).dtype
        out.append(jax.numpy.asarray(arr.astype(dtype, copy=False)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _step_entries(root: pathlib.Path) -> list[tuple[int, pathlib.Path]]:
    """``(step, path)`` for every ``step_<digits>`` child, sorted by step.

    Tolerant by design: a checkpoint root is shared real estate — a foreign
    ``step_final/`` symlink, an editor's ``step_backup`` dir, a stray file —
    and both GC and the serving hot-swap poll walk it continuously. Anything
    whose suffix is not purely numeric is somebody else's; skip it rather
    than crash on ``int()``.
    """
    out = []
    for p in root.iterdir():
        suffix = p.name[5:]
        if p.name.startswith("step_") and suffix.isdigit() and p.is_dir():
            out.append((int(suffix), p))
    out.sort()
    return out


def step_dir(root: str | pathlib.Path, step: int) -> pathlib.Path:
    """Resolve the directory holding ``step``: the canonical zero-padded
    name, or any numeric ``step_*`` entry with the same value. Entries
    written by other tools may be unpadded; ``latest_step`` reports them,
    so every loader must be able to open them."""
    root = pathlib.Path(root)
    canonical = root / f"step_{step:06d}"
    if canonical.exists() or not root.exists():
        return canonical
    for s, p in _step_entries(root):
        if s == step:
            return p
    return canonical  # missing either way; let the caller raise naturally


def steps(root: str | pathlib.Path) -> list[int]:
    """All complete checkpoint steps under ``root``, ascending. A crash-
    resume caller picks the newest step <= its trace-prefix length from
    this list; ``latest_step`` is the tail."""
    root = pathlib.Path(root)
    if not root.exists():
        return []
    return [
        s for s, p in _step_entries(root) if (p / "manifest.json").exists()
    ]


def latest_step(root: str | pathlib.Path) -> int | None:
    all_steps = steps(root)
    return all_steps[-1] if all_steps else None


def leaf_manifest(root: str | pathlib.Path, step: int) -> dict[str, dict]:
    """The manifest's leaf entries keyed by tree path — shapes and dtypes
    WITHOUT loading any array data.

    Restoring through ``restore_pytree`` needs a ``like`` tree with exact
    leaf shapes; checkpoints that carry variable-size leaves (the elastic
    runtime's in-flight version stash: (V, N) with V = live stale
    versions) read V from here first and build ``like`` to match.
    """
    d = step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())
    return {e["path"]: e for e in manifest["leaves"]}


@dataclasses.dataclass
class CheckpointManager:
    """save-every-K with retention — the training-loop-facing API."""

    root: str | pathlib.Path
    save_every: int = 100
    keep: int = 3

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.save_every != 0:
            return False
        save_pytree(self.root, step, tree)
        self._gc()
        return True

    def restore_latest(self, like):
        step = latest_step(self.root)
        if step is None:
            return None, None
        return step, restore_pytree(self.root, step, like)

    def _gc(self) -> None:
        # Remove by the entry's OWN path (a dir named step_7 is step 7 even
        # unpadded); foreign step_* entries are skipped by _step_entries.
        for _, p in _step_entries(pathlib.Path(self.root))[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
