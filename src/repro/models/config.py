"""Architecture configuration — one dataclass drives the whole zoo."""
from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # Attention
    sliding_window: int = 0  # 0 = full attention (training/prefill mask)
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024  # q-chunk for memory-bounded attention
    # 'chunked' — lax.map q-chunks (XLA-fused, runs everywhere);
    # 'flash'   — the Pallas online-softmax kernel (TPU target; interpret
    #             mode on CPU). Full-causal training/prefill only; SWA and
    #             decode always use the chunked/ring path.
    attn_impl: str = "chunked"

    # VLM / audio frontends (stubs provide embeddings of this shape)
    cross_attn_every: int = 0  # every k-th layer cross-attends (vlm)
    n_media_tokens: int = 0  # image patch / audio frame count
    encoder_layers: int = 0  # whisper encoder depth

    # SSM / hybrid / xlstm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256  # SSD chunk length
    shared_attn_every: int = 0  # zamba2: shared attention block period
    slstm_every: int = 0  # xlstm: every k-th block is sLSTM

    # Serving
    long_context_window: int = 0  # opt-in SWA for the long_500k shape

    dtype: str = "bfloat16"
    remat: bool = True
    # 'full'  — recompute everything in backward (min memory);
    # 'dots'  — save projection-dot outputs (skips replaying the matmuls
    #           AND their tensor-parallel all-reduces in the backward pass;
    #           costs ~n_layers x d_model activations of extra HBM).
    remat_policy: str = "full"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---------------------------------------------------------- derived dims
    @property
    def padded_vocab(self) -> int:
        """Vocab padded for clean sharding (logits masked back in the loss)."""
        return _round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def window_for(self, seq_len: int) -> int:
        """Effective attention window for a given context length."""
        if self.sliding_window:
            return min(self.sliding_window, seq_len)
        if self.long_context_window and seq_len > 262_144:
            return min(self.long_context_window, seq_len)
        return seq_len

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Analytic parameter count (used to cross-check 6ND in the roofline)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        emb = v * d * 2  # embed + lm head
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm"):
            # vlm: n_layers counts self AND gated-cross layers (the cross
            # layers carry one attention + one MLP, same as a self layer).
            per_layer = attn + mlp
            total = self.n_layers * per_layer
        elif self.family == "moe":
            total = self.n_layers * (attn + self.n_experts * mlp + d * self.n_experts)
        elif self.family == "audio":
            total = (self.encoder_layers + self.n_layers) * (attn + mlp)
            total += self.n_layers * attn  # decoder cross-attention
        elif self.family == "hybrid":
            di, hs, st = self.d_inner, self.ssm_heads, self.ssm_state
            mamba = d * (2 * di + 2 * st + hs) + di * d + 4 * di
            total = self.n_layers * mamba
            if self.shared_attn_every:
                total += attn + mlp  # one shared block
        elif self.family == "ssm":  # xlstm
            # mLSTM: wq wk wv wo_gate wo (5 d^2) + tiny i/f gates;
            # sLSTM: w_gates 4d^2 + wo d^2 + block-diag recurrence 4*d*hd.
            ng = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_mlstm = self.n_layers - ng
            mlstm = 5 * d * d + 2 * self.n_heads * d
            slstm = 5 * d * d + 4 * d * self.head_dim
            total = n_mlstm * mlstm + ng * slstm
        else:
            raise ValueError(self.family)
        return total + emb

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * ff
        per_layer = attn + self.top_k * mlp + d * self.n_experts
        return self.n_layers * per_layer + self.padded_vocab * d * 2

    # --------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers (4 for patterned families),
        d_model <= 512, <= 4 experts — runs a CPU forward/train step."""
        layers = 2
        shared_every = self.shared_attn_every and 2
        slstm_every = self.slstm_every and 2
        cross_every = self.cross_attn_every and 2
        if self.cross_attn_every or self.shared_attn_every or self.slstm_every:
            layers = 4
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=layers,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=64,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            encoder_layers=2 if self.encoder_layers else 0,
            n_media_tokens=16 if self.n_media_tokens else 0,
            cross_attn_every=cross_every,
            shared_attn_every=shared_every,
            slstm_every=slstm_every,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_chunk=64,
            ssm_chunk=16,
            dtype="float32",
        )
