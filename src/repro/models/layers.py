"""Shared transformer building blocks: norms, rope, attention, MLP, MoE.

Attention is *q-chunked* everywhere (lax.map over query chunks): peak score
memory is bounded by (B, H, chunk, S_kv) regardless of sequence length, which
is what lets prefill_32k lower without materializing 32k x 32k score tensors.
The KV cache is a ring buffer over ``capacity`` slots with per-slot absolute
positions, which unifies full attention (capacity = max_len) and sliding
window (capacity = window) under one code path.

MoE uses expert parallelism via shard_map: activations are replicated over
the 'model' axis (megatron convention), so each model shard gathers the
tokens routed to *its* experts locally and one psum combines expert outputs
— the same collective shape as a row-parallel MLP, no all-to-all and no
GShard dispatch-einsum fake FLOPs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

Params = dict[str, Any]


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: ``jax.shard_map``/``check_vma`` on
    newer releases, ``jax.experimental``/``check_rep`` on older ones. Both
    flags disable the replication checker, which rejects the MoE body's
    axis_index-dependent routing."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


# ------------------------------------------------------------------- basics
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # NB: keep the f32 upcast as an explicit astype: the astype boundary is
    # what casts the backward cotangent back to bf16. (An einsum with
    # preferred_element_type=f32 computes the same variance but leaks f32
    # cotangents into every residual all-reduce — observed 2x collective
    # bytes on granite train_4k.)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if ang.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, half)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


# ---------------------------------------------------------------- attention
def _attend(
    q: jax.Array,  # (B, Sq, H, hd) — already rope'd
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    q_pos: jax.Array,  # (B, Sq) absolute positions of queries
    k_pos: jax.Array,  # (Sk,) absolute positions of keys (-1 = empty slot)
    window: int,  # attend iff 0 <= qpos - kpos < window (causal SWA)
    causal: bool,
    q_seg: jax.Array | None = None,  # (B, Sq) packing segment ids (0 = pad)
    k_seg: jax.Array | None = None,  # (B, Sk)
) -> jax.Array:
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    dist = q_pos[:, None, None, :, None] - k_pos[None, None, None, None, :]
    valid = k_pos[None, None, None, None, :] >= 0
    if causal:
        valid &= (dist >= 0) & (dist < window)
    if q_seg is not None and k_seg is not None:
        # packed sequences: attend only within the same document segment
        same = (
            q_seg[:, None, None, :, None] == k_seg[:, None, None, None, :]
        ) & (q_seg[:, None, None, :, None] > 0)
        valid &= same
    scores = jnp.where(valid, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isfinite(scores).any(-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,  # (Sq,) absolute query positions (shared across batch)
    k_pos: jax.Array,  # (Sk,)
    window: int,
    causal: bool,
    chunk: int,
    segments: jax.Array | None = None,  # (B, S) packing segment ids
) -> jax.Array:
    """lax.map over query chunks — bounded score memory for long sequences."""
    b, sq, h, hd = q.shape
    chunk = min(chunk, sq)
    k_seg = segments
    if sq % chunk != 0:  # pad queries; padded rows discarded after
        pad = (-sq) % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-(10**9))
        if segments is not None:
            segments = jnp.pad(segments, ((0, 0), (0, pad)))
    nc = q.shape[1] // chunk
    qc = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(nc, chunk)
    sc = (
        segments.reshape(b, nc, chunk).transpose(1, 0, 2)
        if segments is not None else None
    )

    def one(args):
        if segments is not None:
            qi, pi, si = args
        else:
            qi, pi = args
            si = None
        return _attend(
            qi, k, v, jnp.broadcast_to(pi, (b, chunk)), k_pos, window, causal,
            q_seg=si, k_seg=k_seg,
        )

    xs = (qc, pc, sc) if segments is not None else (qc, pc)
    out = jax.lax.map(one, xs)  # (nc, B, chunk, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, hd)
    return out[:, :sq]


def self_attention_train(
    p: Params, x: jax.Array, cfg: ModelConfig, window: int,
    return_kv: bool = False, segments: jax.Array | None = None,
):
    """Training / scoring path: full sequence, causal (or SWA) mask.
    ``segments`` (B, S) enables packed-sequence isolation (0 = padding)."""
    b, s, d = x.shape
    pos = jnp.arange(s)
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if cfg.attn_impl == "flash" and window >= s and segments is None:
        from repro.kernels import ops as _kops

        out = _kops.flash_attention(q, k, v, causal=True, backend="pallas")
    else:
        out = chunked_attention(
            q, k, v, pos, pos, window, True, cfg.attn_chunk, segments=segments
        )
    out = out.reshape(b, s, cfg.q_dim) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def ring_cache_from_prefill(k: jax.Array, v: jax.Array, cap: int):
    """Fold full-sequence (B, S, KV, hd) K/V into a ring cache of ``cap``
    slots. Requires cap | S so slot s holds absolute position S - cap + s."""
    s = k.shape[1]
    assert s % cap == 0, "ring capacity must divide prefill length"
    slot_pos = jnp.arange(cap, dtype=jnp.int32) + (s - cap)
    return k[:, s - cap :], v[:, s - cap :], slot_pos


def encoder_attention(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional (whisper encoder)."""
    b, s, d = x.shape
    pos = jnp.arange(s)
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    out = chunked_attention(q, k, v, pos, pos, s, False, cfg.attn_chunk)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]


def cross_attention(
    p: Params, x: jax.Array, kv_src: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """x attends to media/encoder states. kv_src: (B, M, D) or precomputed
    (k, v) tuple of (B, M, KV, hd) when serving from cache."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    if isinstance(kv_src, tuple):
        k, v = kv_src
    else:
        m = kv_src.shape[1]
        k = (kv_src @ p["wk"]).reshape(b, m, cfg.n_kv_heads, cfg.head_dim)
        v = (kv_src @ p["wv"]).reshape(b, m, cfg.n_kv_heads, cfg.head_dim)
    m = k.shape[1]
    pos_q = jnp.arange(s)
    pos_k = jnp.arange(m)
    out = chunked_attention(q, k, v, pos_q, pos_k, m + s + 1, False, cfg.attn_chunk)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]


def self_attention_decode(
    p: Params,
    x: jax.Array,  # (B, 1, D) current token
    cache_k: jax.Array,  # (B, C, KV, hd) ring buffer
    cache_v: jax.Array,
    slot_pos: jax.Array,  # (C,) absolute position stored in each slot (-1 empty)
    pos: jax.Array,  # () current absolute position
    cfg: ModelConfig,
    window: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One decode step against the ring cache. Returns (out, k', v', slot')."""
    b = x.shape[0]
    cap = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    posb = jnp.broadcast_to(pos[None], (1,))
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    slot = pos % cap
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, posb.astype(slot_pos.dtype), slot, axis=0
    )
    out = _attend(
        q, cache_k, cache_v,
        jnp.broadcast_to(pos[None, None], (b, 1)), slot_pos, window, True,
    )
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"], cache_k, cache_v, slot_pos


# ---------------------------------------------------------------------- MLP
def mlp(p: Params, x: jax.Array) -> jax.Array:
    return swiglu(x, p["wg"], p["wu"], p["wd"])


# ---------------------------------------------------------------------- MoE
def _router(p: Params, xf: jax.Array, cfg: ModelConfig):
    """Top-k routing + switch-style load-balance aux loss."""
    logits = (xf.astype(jnp.float32)) @ p["wr"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # aux: E * sum_e mean(one_hot tokens_e) * mean(probs_e)
    onehot = jax.nn.one_hot(ids[:, 0], cfg.n_experts)  # top-1 load
    aux = cfg.n_experts * jnp.mean(
        jnp.mean(onehot, axis=0) * jnp.mean(probs, axis=0)
    )
    return weights.astype(xf.dtype), ids, aux


def _expert_block(xf, ids, weights, wg, wu, wd, e_offset, capacity):
    """Compute the experts owned locally (wg/wu/wd: (E_loc, ...)) and return
    the weighted partial output (T, D). Tokens over capacity are dropped."""
    t = xf.shape[0]
    e_loc = wg.shape[0]
    out = jnp.zeros_like(xf)
    for j in range(e_loc):  # E_loc is tiny (1 on the production mesh)
        e = e_offset + j
        m = ids == e  # (T, k)
        tok_w = jnp.sum(jnp.where(m, weights, 0.0), axis=-1)  # (T,)
        routed = jnp.any(m, axis=-1)
        rank = jnp.cumsum(routed.astype(jnp.int32)) - 1
        slot = jnp.where(routed & (rank < capacity), rank, capacity)
        dispatch = jnp.full((capacity + 1,), t, jnp.int32)
        dispatch = dispatch.at[slot].set(jnp.arange(t, dtype=jnp.int32), mode="drop")
        dispatch = dispatch[:capacity]
        xe = jnp.concatenate([xf, jnp.zeros_like(xf[:1])], 0)[dispatch]  # (C, D)
        he = (jax.nn.silu(xe @ wg[j]) * (xe @ wu[j])) @ wd[j]  # (C, D)
        we = jnp.concatenate([tok_w, jnp.zeros_like(tok_w[:1])], 0)[dispatch]
        out = out.at[dispatch].add(he * we[:, None], mode="drop")
    return out


def moe_ffn(
    p: Params,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    mesh: jax.sharding.Mesh | None = None,
    batch_axes: tuple[str, ...] = ("data",),
    model_axis: str = "model",
    capacity: int | None = None,  # None -> capacity_factor rule; -1 -> all
                                   # local tokens (lossless; decode uses this)
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE FFN. Returns (out, aux_loss).

    With a mesh: shard_map over (batch_axes + model_axis); activations are
    replicated over 'model', each model shard computes its E/tp experts on
    locally-gathered tokens, and one psum over 'model' combines outputs —
    identical collective shape to a row-parallel dense MLP.
    """
    b, s, d = x.shape

    # Tiny batches (long-context decode has global_batch = 1) cannot shard
    # over the data axes — fall back to replicated tokens, keeping the
    # expert-parallel split over 'model'.
    if mesh is not None and batch_axes:
        dp_check = 1
        for a in batch_axes:
            dp_check *= dict(mesh.shape).get(a, 1)
        if b % dp_check != 0:
            batch_axes = ()

    if mesh is None or model_axis not in mesh.shape or mesh.shape[model_axis] == 1:
        xf = x.reshape(b * s, d)
        weights, ids, aux = _router(p, xf, cfg)
        if capacity == -1:
            cap = xf.shape[0]
        elif capacity is not None:
            cap = capacity
        else:
            cap = max(
                1, int(cfg.top_k * xf.shape[0] / cfg.n_experts * cfg.capacity_factor)
            )
        out = _expert_block(xf, ids, weights, p["wg"], p["wu"], p["wd"], 0, cap)
        return out.reshape(b, s, d), aux

    tp = mesh.shape[model_axis]
    e_loc = cfg.n_experts // tp
    dp = 1
    for a in batch_axes:
        dp *= dict(mesh.shape).get(a, 1)
    t_loc = (b // dp) * s
    if capacity == -1:
        cap = t_loc
    elif capacity is not None:
        cap = capacity
    else:
        cap = max(1, int(cfg.top_k * t_loc / cfg.n_experts * cfg.capacity_factor))

    # When the batch cannot use the 'data' axis (long-context decode,
    # global_batch = 1), shard each expert's d_ff over 'data' instead: the
    # weights arrive already 2D-sharded (experts x ff), so no expert-weight
    # all-gather is needed — one extra psum over 'data' combines the
    # ff-partial outputs (beyond-paper optimization, §Perf).
    ff_axis = None
    names = dict(mesh.shape)
    if (
        not batch_axes
        and names.get("data", 1) > 1
        and cfg.d_ff % names["data"] == 0
    ):
        ff_axis = "data"

    def body(xb, wr, wg, wu, wd):
        xf = xb.reshape(-1, d)
        weights, ids, aux = _router({"wr": wr}, xf, cfg)
        e_offset = jax.lax.axis_index(model_axis) * e_loc
        out = _expert_block(xf, ids, weights, wg, wu, wd, e_offset, cap)
        axes = (model_axis,) if ff_axis is None else (model_axis, ff_axis)
        out = jax.lax.psum(out, axes)
        aux = jax.lax.pmean(aux, tuple(batch_axes) + (model_axis,))
        return out.reshape(xb.shape), aux

    bspec = P(batch_axes or None, None, None)
    out, aux = _shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(None, None),
                  P(model_axis, None, ff_axis),
                  P(model_axis, None, ff_axis),
                  P(model_axis, ff_axis, None)),
        out_specs=(bspec, P()),
    )(x, p["wr"], p["wg"], p["wu"], p["wd"])
    return out, aux
