"""Model assembly: parameter schema, init, train forward, prefill, decode.

One declarative *parameter schema* per family is the single source of truth:
``param_schema(cfg)`` returns a nested dict of Entry(shape, logical_axes,
init); ``init_params`` / ``abstract_params`` / the sharding policy all map
over it, so parameters, ShapeDtypeStructs and PartitionSpecs can never drift
apart.

Layer stacks are scanned (``lax.scan`` over stacked parameter pytrees) with
optional remat — 100-layer models compile as one loop. Families with
interleaved block types scan over *groups*:

  vlm:    20 groups of [4 self layers + 1 gated cross-attn layer]
  hybrid: 6 groups of [6 mamba2 layers + shared attn block] + 2 tail mamba
  ssm:    6 groups of [7 mLSTM + 1 sLSTM]
  audio:  encoder scan + decoder scan (self + cross + mlp per layer)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.config import ModelConfig

Params = dict[str, Any]


class Entry(NamedTuple):
    shape: tuple
    axes: tuple  # logical axis names, same length as shape
    init: str = "normal"  # normal | zeros | ones | alog | dtbias


# ------------------------------------------------------------------ schemas
def _attn_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "wq": Entry((d, cfg.q_dim), ("embed", "q_flat")),
        "wk": Entry((d, cfg.kv_dim), ("embed", "kv_flat")),
        "wv": Entry((d, cfg.kv_dim), ("embed", "kv_flat")),
        "wo": Entry((cfg.q_dim, d), ("q_flat", "embed")),
    }


def _mlp_schema(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wg": Entry((d, f), ("embed", "ff")),
        "wu": Entry((d, f), ("embed", "ff")),
        "wd": Entry((f, d), ("ff", "embed")),
    }


def _moe_schema(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "wr": Entry((d, e), ("embed", None)),
        "wg": Entry((e, d, f), ("experts", "embed", "ff")),
        "wu": Entry((e, d, f), ("experts", "embed", "ff")),
        "wd": Entry((e, f, d), ("experts", "ff", "embed")),
    }


def _mamba_schema(cfg: ModelConfig) -> dict:
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = 2 * di + 2 * st + nh
    conv_ch = di + 2 * st
    return {
        "in_proj": Entry((d, proj), ("embed", "inner_proj")),
        "conv_w": Entry((4, conv_ch), (None, "conv_ch")),
        "conv_b": Entry((conv_ch,), ("conv_ch",), "zeros"),
        "dt_bias": Entry((nh,), (None,), "dtbias"),
        "a_log": Entry((nh,), (None,), "alog"),
        "d_skip": Entry((nh,), (None,), "ones"),
        "norm": Entry((di,), ("inner",), "ones"),
        "out_proj": Entry((di, d), ("inner", "embed")),
        "ln": Entry((d,), ("embed",), "ones"),
    }


def _mlstm_schema(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq": Entry((d, d), ("embed", "q_flat")),
        "wk": Entry((d, d), ("embed", "q_flat")),
        "wv": Entry((d, d), ("embed", "q_flat")),
        "w_if": Entry((d, 2 * h), ("embed", None)),
        "b_if": Entry((2 * h,), (None,), "zeros"),
        "wo_gate": Entry((d, d), ("embed", "q_flat")),
        "wo": Entry((d, d), ("q_flat", "embed")),
        "ln": Entry((d,), ("embed",), "ones"),
    }


def _slstm_schema(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "w_gates": Entry((d, 4 * d), ("embed", "gates")),
        "b_gates": Entry((4 * d,), ("gates",), "zeros"),
        "r_gates": Entry((h, 4, hd, hd), (None, None, None, "head_dim")),
        "wo": Entry((d, d), ("q_flat", "embed")),
        "ln": Entry((d,), ("embed",), "ones"),
    }


def _dense_layer(cfg) -> dict:
    return {
        "attn": _attn_schema(cfg),
        "mlp": _mlp_schema(cfg),
        "ln1": Entry((cfg.d_model,), ("embed",), "ones"),
        "ln2": Entry((cfg.d_model,), ("embed",), "ones"),
    }


def _moe_layer(cfg) -> dict:
    return {
        "attn": _attn_schema(cfg),
        "moe": _moe_schema(cfg),
        "ln1": Entry((cfg.d_model,), ("embed",), "ones"),
        "ln2": Entry((cfg.d_model,), ("embed",), "ones"),
    }


def _cross_layer(cfg) -> dict:
    return {
        "xattn": _attn_schema(cfg),
        "mlp": _mlp_schema(cfg),
        "ln1": Entry((cfg.d_model,), ("embed",), "ones"),
        "ln2": Entry((cfg.d_model,), ("embed",), "ones"),
        "gate_attn": Entry((), (), "zeros"),
        "gate_mlp": Entry((), (), "zeros"),
    }


def _decoder_layer(cfg) -> dict:  # audio decoder: self + cross + mlp
    return {
        "attn": _attn_schema(cfg),
        "xattn": _attn_schema(cfg),
        "mlp": _mlp_schema(cfg),
        "ln1": Entry((cfg.d_model,), ("embed",), "ones"),
        "lnx": Entry((cfg.d_model,), ("embed",), "ones"),
        "ln2": Entry((cfg.d_model,), ("embed",), "ones"),
    }


def _stack(schema: dict, n: int) -> dict:
    out = {}
    for k, v in schema.items():
        if isinstance(v, dict):
            out[k] = _stack(v, n)
        else:
            out[k] = Entry((n,) + v.shape, ("layers",) + v.axes, v.init)
    return out


def param_schema(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    schema: dict = {
        "embed": Entry((v, d), ("vocab", "embed")),
        "lm_head": Entry((d, v), ("embed", "vocab")),
        "final_norm": Entry((d,), ("embed",), "ones"),
    }
    fam = cfg.family
    if fam == "dense":
        schema["layers"] = _stack(_dense_layer(cfg), cfg.n_layers)
    elif fam == "moe":
        schema["layers"] = _stack(_moe_layer(cfg), cfg.n_layers)
    elif fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        spg = cfg.cross_attn_every - 1
        schema["groups"] = {
            "self": _stack(_stack(_dense_layer(cfg), spg), g),
            "cross": _stack(_cross_layer(cfg), g),
        }
    elif fam == "audio":
        schema["encoder"] = _stack(_dense_layer(cfg), cfg.encoder_layers)
        schema["decoder"] = _stack(_decoder_layer(cfg), cfg.n_layers)
        schema["enc_ln"] = Entry((d,), ("embed",), "ones")
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.shared_attn_every
        tail = cfg.n_layers - g * cfg.shared_attn_every
        schema["groups"] = {
            "mamba": _stack(_stack(_mamba_schema(cfg), cfg.shared_attn_every), g),
        }
        if tail:
            schema["tail"] = _stack(_mamba_schema(cfg), tail)
        schema["shared"] = _dense_layer(cfg)
    elif fam == "ssm":
        g = cfg.n_layers // cfg.slstm_every
        mpg = cfg.slstm_every - 1
        schema["groups"] = {
            "mlstm": _stack(_stack(_mlstm_schema(cfg), mpg), g),
            "slstm": _stack(_slstm_schema(cfg), g),
        }
    else:
        raise ValueError(fam)
    return schema


# --------------------------------------------------------------------- init
def _is_entry(x) -> bool:
    return isinstance(x, Entry)


def _map_schema(fn, schema: dict, path=()):
    out = {}
    for k, v in schema.items():
        if _is_entry(v):
            out[k] = fn(path + (k,), v)
        else:
            out[k] = _map_schema(fn, v, path + (k,))
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.dtype)
    schema = param_schema(cfg)
    flat: list[tuple] = []
    _map_schema(lambda p, e: flat.append((p, e)), schema)
    keys = jax.random.split(key, len(flat))
    kmap = {p: k for (p, _), k in zip(flat, keys)}

    def make(path, e: Entry):
        if e.init == "zeros":
            return jnp.zeros(e.shape, dt)
        if e.init == "ones":
            return jnp.ones(e.shape, dt)
        if e.init == "alog":
            n = e.shape[-1]
            base = jnp.log(1.0 + jnp.arange(n, dtype=jnp.float32) % 15)
            return jnp.broadcast_to(base + 0.5, e.shape).astype(jnp.float32)
        if e.init == "dtbias":
            return jnp.full(e.shape, -4.0, jnp.float32)
        fan_in = e.shape[-2] if len(e.shape) >= 2 else e.shape[-1]
        scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
        return (jax.random.normal(kmap[path], e.shape, jnp.float32) * scale).astype(dt)

    return _map_schema(make, schema)


def abstract_params(cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)

    def make(path, e: Entry):
        if e.init in ("alog", "dtbias"):
            return jax.ShapeDtypeStruct(e.shape, jnp.float32)
        return jax.ShapeDtypeStruct(e.shape, dt)

    return _map_schema(make, param_schema(cfg))


# ----------------------------------------------------------- train forwards
def _dense_block(p, x, cfg, window, segments=None):
    x = x + L.self_attention_train(
        p["attn"], L.rms_norm(x, p["ln1"]), cfg, window, segments=segments
    )
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    return x


def _moe_block(p, x, cfg, window, mesh, batch_axes, segments=None):
    x = x + L.self_attention_train(
        p["attn"], L.rms_norm(x, p["ln1"]), cfg, window, segments=segments
    )
    out, aux = L.moe_ffn(
        p["moe"], L.rms_norm(x, p["ln2"]), cfg, mesh, batch_axes
    )
    return x + out, aux


def _cross_block(p, x, media, cfg):
    g1 = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype)
    g2 = jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
    x = x + g1 * L.cross_attention(p["xattn"], L.rms_norm(x, p["ln1"]), media, cfg)
    x = x + g2 * L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    return x


def _mamba_block(p, x, cfg):
    return x + S.mamba2_train(p, L.rms_norm(x, p["ln"]), cfg)


def _scan(fn, stacked, x, remat=True, aux0=None, policy: str = "full"):
    """Scan ``fn(p_slice, x) -> x'`` or ``-> (x', aux)`` over a stacked tree."""
    if remat:
        kw = {}
        if policy == "dots":
            kw["policy"] = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        f = jax.checkpoint(fn, **kw)
    else:
        f = fn

    def body(carry, p):
        x, aux = carry
        out = f(p, x)
        if isinstance(out, tuple):
            x, a = out
            aux = aux + a
        else:
            x = out
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0) if aux0 is None else aux0), stacked)
    return x, aux


def _constrainer(mesh, batch_axes: tuple):
    """Pin hidden-state sharding at layer boundaries: batch over the data
    axes, model dims replicated (megatron activation convention). Without
    the pin XLA sometimes trades the batch sharding away mid-backbone,
    replicating whole score tensors per device."""
    if mesh is None or not batch_axes:
        return lambda h: h
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = NamedSharding(mesh, P(tuple(batch_axes), None, None))
    return lambda h: jax.lax.with_sharding_constraint(h, sh)


def backbone_train(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, D) embedded tokens
    media: jax.Array | None,
    mesh=None,
    batch_axes: tuple = ("data",),
    segments: jax.Array | None = None,  # (B, S) packing ids (dense/moe)
) -> tuple[jax.Array, jax.Array]:
    """Hidden states + moe aux loss for the full (teacher-forced) sequence."""
    s = x.shape[1]
    window = cfg.window_for(s)
    fam = cfg.family
    cs = _constrainer(mesh, batch_axes)

    if fam == "dense":
        x, aux = _scan(
            lambda p, h: cs(_dense_block(p, h, cfg, window, segments)),
            params["layers"], x, cfg.remat, policy=cfg.remat_policy,
        )
    elif fam == "moe":
        def blk(p, h):
            h, a = _moe_block(p, h, cfg, window, mesh, batch_axes, segments)
            return cs(h), a
        x, aux = _scan(blk, params["layers"], x, cfg.remat,
                       policy=cfg.remat_policy)
    elif fam == "vlm":
        def group(p, h):
            h, _ = _scan(lambda q, u: cs(_dense_block(q, u, cfg, window)),
                         p["self"], h, remat=False)
            return cs(_cross_block(p["cross"], h, media, cfg))
        x, aux = _scan(group, params["groups"], x, remat=cfg.remat)
    elif fam == "audio":
        enc, _ = _scan(
            lambda p, h: _enc_block(p, h, cfg), params["encoder"], media, cfg.remat
        )
        enc = L.rms_norm(enc, params["enc_ln"])
        def dec(p, h):
            h = h + L.self_attention_train(
                p["attn"], L.rms_norm(h, p["ln1"]), cfg, window
            )
            h = h + L.cross_attention(p["xattn"], L.rms_norm(h, p["lnx"]), enc, cfg)
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]))
            return cs(h)
        x, aux = _scan(dec, params["decoder"], x, cfg.remat)
    elif fam == "hybrid":
        def group(p, h):
            h, _ = _scan(lambda q, u: cs(_mamba_block(q, u, cfg)),
                         p["mamba"], h, remat=False)
            return cs(_dense_block(params["shared"], h, cfg, window))
        x, aux = _scan(group, params["groups"], x, remat=cfg.remat)
        if "tail" in params:
            x, _ = _scan(lambda q, u: cs(_mamba_block(q, u, cfg)),
                         params["tail"], x, cfg.remat)
    elif fam == "ssm":
        def group(p, h):
            def mblock(q, u):
                return cs(u + X.mlstm_train(q, L.rms_norm(u, q["ln"]), cfg))
            h, _ = _scan(mblock, p["mlstm"], h, remat=False)
            return cs(
                h + X.slstm_train(p["slstm"], L.rms_norm(h, p["slstm"]["ln"]), cfg)
            )
        x, aux = _scan(group, params["groups"], x, remat=cfg.remat)
    else:
        raise ValueError(fam)
    return x, aux


def _enc_block(p, x, cfg):
    x = x + L.encoder_attention(p["attn"], L.rms_norm(x, p["ln1"]), cfg)
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    return x


def forward_train(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    mesh=None,
    batch_axes: tuple = ("data",),
) -> tuple[jax.Array, dict]:
    """Teacher-forced LM loss. batch: tokens (B,S), labels (B,S),
    [media (B,M,D)], [segments (B,S) — packed-document ids, dense/moe only],
    [weights (B,) — Bernoulli importance weights m'_i / R, the paper's
    sampled objective lifted to sequence level]. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    media = batch.get("media")
    segments = batch.get("segments")
    if segments is not None and cfg.family not in ("dense", "moe"):
        raise ValueError(
            "packed segments need attention masking; recurrent families "
            "would need per-segment state resets (not implemented)"
        )
    x, aux = backbone_train(
        params, cfg, x, media, mesh, batch_axes, segments=segments
    )
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]  # (B, S, Vpad)
    mask_pad = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    logits = jnp.where(mask_pad[None, None, :], logits, -1e9)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    per_seq = jnp.mean(logz - gold, axis=-1)  # (B,)
    w = batch.get("weights")
    if w is None:
        ce = jnp.mean(per_seq)
    else:
        ce = jnp.sum(w * per_seq) / jnp.maximum(jnp.sum(w), 1e-6)
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ------------------------------------------------------------ serving paths
def _logits(params, cfg, x):
    """(B, S, D) hidden -> (B, S, Vpad) masked logits."""
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(mask[None, None, :], logits, jnp.asarray(-1e9, logits.dtype))


def _ring_from_kv(ks: jax.Array, vs: jax.Array, cap: int) -> dict:
    """Stacked full-sequence K/V (L, B, S, KV, hd) -> ring cache of ``cap``
    slots per layer (slot of position p = p % cap).

    cap >= S: positions 0..S-1 land in slots 0..S-1, the rest stay empty —
    the full-attention case with decode headroom. cap < S (sliding window):
    the last ``cap`` positions are kept; requires cap | S so the ring
    alignment (slot = pos % cap) holds.
    """
    s = ks.shape[2]
    nl = ks.shape[0]
    if cap >= s:
        pad = [(0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0)]
        idx = jnp.arange(cap, dtype=jnp.int32)
        slot = jnp.where(idx < s, idx, -1)
        return {
            "k": jnp.pad(ks, pad),
            "v": jnp.pad(vs, pad),
            "slot_pos": jnp.broadcast_to(slot, (nl, cap)),
        }
    assert s % cap == 0, "ring capacity must divide prefill length"
    slot = jnp.arange(cap, dtype=jnp.int32) + (s - cap)
    return {
        "k": ks[:, :, s - cap :],
        "v": vs[:, :, s - cap :],
        "slot_pos": jnp.broadcast_to(slot, (nl, cap)),
    }


def _media_kv(p_attn, media, cfg):
    b, m, _ = media.shape
    k = (media @ p_attn["wk"]).reshape(b, m, cfg.n_kv_heads, cfg.head_dim)
    v = (media @ p_attn["wv"]).reshape(b, m, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    mesh=None,
    batch_axes: tuple = ("data",),
    max_len: int | None = None,
) -> tuple[jax.Array, dict]:
    """Score the prompt and build the decode cache.

    batch: tokens (B, S), [media (B, M, D)]. ``max_len`` is the total
    context budget (prompt + decode headroom); the attention-cache capacity
    is ``cfg.window_for(max_len)``. Returns (last-position logits (B, Vpad),
    cache) — the cache layout matches ``repro.models.cache``.
    """
    tokens = batch["tokens"]
    media = batch.get("media")
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    cap = cfg.window_for(max_len if max_len is not None else s)
    window = cfg.window_for(s)
    fam = cfg.family
    cache: dict = {"pos": jnp.asarray(s, jnp.int32)}

    def maybe_ckpt(f):
        return jax.checkpoint(f) if cfg.remat else f

    if fam in ("dense", "moe"):
        def body(h, p):
            a, (k, v) = L.self_attention_train(
                p["attn"], L.rms_norm(h, p["ln1"]), cfg, window, return_kv=True
            )
            h = h + a
            if fam == "moe":
                out, _ = L.moe_ffn(
                    p["moe"], L.rms_norm(h, p["ln2"]), cfg, mesh, batch_axes
                )
                h = h + out
            else:
                h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]))
            return h, (k, v)

        x, (ks, vs) = jax.lax.scan(maybe_ckpt(body), x, params["layers"])
        cache["self"] = _ring_from_kv(ks, vs, cap)

    elif fam == "vlm":
        def group(h, p):
            def self_body(u, q):
                a, (k, v) = L.self_attention_train(
                    q["attn"], L.rms_norm(u, q["ln1"]), cfg, window, return_kv=True
                )
                u = u + a
                u = u + L.mlp(q["mlp"], L.rms_norm(u, q["ln2"]))
                return u, (k, v)

            h, (ks, vs) = jax.lax.scan(maybe_ckpt(self_body), h, p["self"])
            mk, mv = _media_kv(p["cross"]["xattn"], media, cfg)
            h = _cross_block(p["cross"], h, (mk, mv), cfg)
            return h, (ks, vs, mk, mv)

        x, (ks, vs, mks, mvs) = jax.lax.scan(group, x, params["groups"])
        g, spg = ks.shape[0], ks.shape[1]
        cache["self"] = _ring_from_kv(
            ks.reshape((g * spg,) + ks.shape[2:]),
            vs.reshape((g * spg,) + vs.shape[2:]),
            cap,
        )
        cache["media_k"], cache["media_v"] = mks, mvs

    elif fam == "audio":
        enc, _ = _scan(
            lambda p, h: _enc_block(p, h, cfg), params["encoder"], media, cfg.remat
        )
        enc = L.rms_norm(enc, params["enc_ln"])

        def dec(h, p):
            a, (k, v) = L.self_attention_train(
                p["attn"], L.rms_norm(h, p["ln1"]), cfg, window, return_kv=True
            )
            h = h + a
            mk, mv = _media_kv(p["xattn"], enc, cfg)
            h = h + L.cross_attention(
                p["xattn"], L.rms_norm(h, p["lnx"]), (mk, mv), cfg
            )
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]))
            return h, (k, v, mk, mv)

        x, (ks, vs, mks, mvs) = jax.lax.scan(maybe_ckpt(dec), x, params["decoder"])
        cache["self"] = _ring_from_kv(ks, vs, cap)
        cache["media_k"], cache["media_v"] = mks, mvs

    elif fam == "hybrid":
        def group(h, p_mamba):
            def mb(u, q):
                out, hfin, cst = S.mamba2_train(
                    q, L.rms_norm(u, q["ln"]), cfg, return_state=True
                )
                return u + out, (hfin, cst)

            h, (hs, cs) = jax.lax.scan(maybe_ckpt(mb), h, p_mamba)
            ps = params["shared"]
            a, (k, v) = L.self_attention_train(
                ps["attn"], L.rms_norm(h, ps["ln1"]), cfg, window, return_kv=True
            )
            h = h + a
            h = h + L.mlp(ps["mlp"], L.rms_norm(h, ps["ln2"]))
            return h, (hs, cs, k, v)

        x, (hs, cs, ks, vs) = jax.lax.scan(group, x, params["groups"]["mamba"])
        ssm = hs.reshape((-1,) + hs.shape[2:])  # (g*every, B, nh, hp, st)
        conv = cs.reshape((-1,) + cs.shape[2:])
        if "tail" in params:
            def mb(u, q):
                out, hfin, cst = S.mamba2_train(
                    q, L.rms_norm(u, q["ln"]), cfg, return_state=True
                )
                return u + out, (hfin, cst)
            x, (ht, ct) = jax.lax.scan(maybe_ckpt(mb), x, params["tail"])
            ssm = jnp.concatenate([ssm, ht], axis=0)
            conv = jnp.concatenate([conv, ct], axis=0)
        cache["ssm"], cache["conv"] = ssm, conv
        cache["shared"] = _ring_from_kv(ks, vs, cap)

    elif fam == "ssm":
        def group(h, p):
            def mb(u, q):
                out, (cm, nv, m) = X.mlstm_train(
                    q, L.rms_norm(u, q["ln"]), cfg, return_state=True
                )
                return u + out, (cm, nv, m)

            h, (cms, nvs, ms) = jax.lax.scan(maybe_ckpt(mb), h, p["mlstm"])
            out, (sc, sn, sm, sh) = X.slstm_train(
                p["slstm"], L.rms_norm(h, p["slstm"]["ln"]), cfg, return_state=True
            )
            return h + out, (cms, nvs, ms, sc, sn, sm, sh)

        x, (cms, nvs, ms, sc, sn, sm, sh) = jax.lax.scan(group, x, params["groups"])
        cache["mlstm"] = {"c": cms, "n": nvs, "m": ms}
        cache["slstm"] = {"c": sc, "n": sn, "m": sm, "h": sh}
    else:
        raise ValueError(fam)

    return _logits(params, cfg, x[:, -1:, :])[:, 0], cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, 1) int32 — the newest token
    cache: dict,
    mesh=None,
    batch_axes: tuple = ("data",),
) -> tuple[jax.Array, dict]:
    """One token against the cache. Returns (logits (B, Vpad), cache')."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, D)
    pos = cache["pos"]
    fam = cfg.family
    new = dict(cache)
    new["pos"] = pos + 1

    def attn_decode(p, h, c, window):
        out, k, v, sp = L.self_attention_decode(
            p["attn"], L.rms_norm(h, p["ln1"]),
            c["k"], c["v"], c["slot_pos"], pos, cfg, window,
        )
        return out, {"k": k, "v": v, "slot_pos": sp}

    if fam in ("dense", "moe"):
        cap = cache["self"]["k"].shape[2]

        def body(h, xs):
            p, c = xs
            out, c2 = attn_decode(p, h, c, cap)
            h = h + out
            if fam == "moe":
                o, _ = L.moe_ffn(
                    p["moe"], L.rms_norm(h, p["ln2"]), cfg, mesh, batch_axes,
                    capacity=-1,
                )
                h = h + o
            else:
                h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]))
            return h, c2

        x, new["self"] = jax.lax.scan(body, x, (params["layers"], cache["self"]))

    elif fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        spg = cfg.cross_attn_every - 1
        cap = cache["self"]["k"].shape[2]
        cself = jax.tree.map(
            lambda a: a.reshape((g, spg) + a.shape[1:]), cache["self"]
        )

        def group(h, xs):
            p, c, mk, mv = xs

            def self_body(u, ys):
                q, cc = ys
                out, c2 = attn_decode(q, u, cc, cap)
                u = u + out
                u = u + L.mlp(q["mlp"], L.rms_norm(u, q["ln2"]))
                return u, c2

            h, c2 = jax.lax.scan(self_body, h, (p["self"], c))
            h = _cross_block(p["cross"], h, (mk, mv), cfg)
            return h, c2

        x, c2 = jax.lax.scan(
            group, x,
            (params["groups"], cself, cache["media_k"], cache["media_v"]),
        )
        new["self"] = jax.tree.map(
            lambda a: a.reshape((g * spg,) + a.shape[2:]), c2
        )

    elif fam == "audio":
        cap = cache["self"]["k"].shape[2]

        def dec(h, xs):
            p, c, mk, mv = xs
            out, c2 = attn_decode(p, h, c, cap)
            h = h + out
            h = h + L.cross_attention(
                p["xattn"], L.rms_norm(h, p["lnx"]), (mk, mv), cfg
            )
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]))
            return h, c2

        x, new["self"] = jax.lax.scan(
            dec, x,
            (params["decoder"], cache["self"], cache["media_k"], cache["media_v"]),
        )

    elif fam == "hybrid":
        g = cfg.n_layers // cfg.shared_attn_every
        every = cfg.shared_attn_every
        used = g * every
        cap = cache["shared"]["k"].shape[2]
        ssm_g = cache["ssm"][:used].reshape((g, every) + cache["ssm"].shape[1:])
        conv_g = cache["conv"][:used].reshape((g, every) + cache["conv"].shape[1:])

        def mamba_step(u, ys):
            q, st, cv = ys
            out, st2, cv2 = S.mamba2_decode(q, L.rms_norm(u, q["ln"]), st, cv, cfg)
            return u + out, (st2, cv2)

        def group(h, xs):
            p, st, cv, c = xs
            h, (st2, cv2) = jax.lax.scan(mamba_step, h, (p, st, cv))
            ps = params["shared"]
            out, c2 = attn_decode(ps, h, c, cap)
            h = h + out
            h = h + L.mlp(ps["mlp"], L.rms_norm(h, ps["ln2"]))
            return h, (st2, cv2, c2)

        x, (st2, cv2, c2) = jax.lax.scan(
            group, x, (params["groups"]["mamba"], ssm_g, conv_g, cache["shared"])
        )
        ssm_new = st2.reshape((used,) + st2.shape[2:])
        conv_new = cv2.reshape((used,) + cv2.shape[2:])
        if "tail" in params:
            x, (st3, cv3) = jax.lax.scan(
                mamba_step, x,
                (params["tail"], cache["ssm"][used:], cache["conv"][used:]),
            )
            ssm_new = jnp.concatenate([ssm_new, st3], axis=0)
            conv_new = jnp.concatenate([conv_new, cv3], axis=0)
        new["ssm"], new["conv"], new["shared"] = ssm_new, conv_new, c2

    elif fam == "ssm":
        def group(h, xs):
            p, cm, cs = xs

            def mb(u, ys):
                q, c = ys
                out, c2, n2, m2 = X.mlstm_decode(
                    q, L.rms_norm(u, q["ln"]), c["c"], c["n"], c["m"], cfg
                )
                return u + out, {"c": c2, "n": n2, "m": m2}

            h, cm2 = jax.lax.scan(mb, h, (p["mlstm"], cm))
            out, sc, sn, sm, sh = X.slstm_decode(
                p["slstm"], L.rms_norm(h, p["slstm"]["ln"]),
                cs["c"], cs["n"], cs["m"], cs["h"], cfg,
            )
            return h + out, (cm2, {"c": sc, "n": sn, "m": sm, "h": sh})

        x, (cm2, cs2) = jax.lax.scan(
            group, x, (params["groups"], cache["mlstm"], cache["slstm"])
        )
        new["mlstm"], new["slstm"] = cm2, cs2
    else:
        raise ValueError(fam)

    return _logits(params, cfg, x)[:, 0], new
