"""Decode caches for every family, as plain pytrees of arrays.

Shared conventions:
  * Attention caches are ring buffers of ``capacity`` slots; ``slot_pos``
    stores each slot's absolute position (-1 = empty). capacity = full
    context for full attention, window for SWA — decided by
    ``ModelConfig.window_for(seq_len)``.
  * ``pos`` is the absolute position of the *next* token.
  * Stacked leading axes mirror the layer-scan structure so lax.scan can
    thread cache slices alongside parameter slices.

``cache_structure`` is abstract-first: it returns ShapeDtypeStructs (a
32k-context production cache is hundreds of GB — it must never materialize
on the host; the dry-run only lowers against it). ``init_cache`` maps
``jnp.zeros`` over the structure for real (small) serving runs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Cache = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(d) for d in shape), dtype)


def _attn_cache(cfg, n_stack, batch, cap, dt):
    shape_kv = (n_stack, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": _sds(shape_kv, dt),
        "v": _sds(shape_kv, dt),
        "slot_pos": _sds((n_stack, cap), jnp.int32),
    }


def cache_structure(cfg: ModelConfig, batch: int, seq_len: int) -> Cache:
    """Abstract cache blueprint (ShapeDtypeStruct leaves, no allocation)."""
    dt = _dtype(cfg)
    cap = cfg.window_for(seq_len)
    c: Cache = {"pos": _sds((), jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "moe"):
        c["self"] = _attn_cache(cfg, cfg.n_layers, batch, cap, dt)
    elif fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        spg = cfg.cross_attn_every - 1
        c["self"] = _attn_cache(cfg, g * spg, batch, cap, dt)
        m = cfg.n_media_tokens
        c["media_k"] = _sds((g, batch, m, cfg.n_kv_heads, cfg.head_dim), dt)
        c["media_v"] = _sds((g, batch, m, cfg.n_kv_heads, cfg.head_dim), dt)
    elif fam == "audio":
        c["self"] = _attn_cache(cfg, cfg.n_layers, batch, cap, dt)
        m = cfg.n_media_tokens
        kv = (cfg.n_layers, batch, m, cfg.n_kv_heads, cfg.head_dim)
        c["media_k"] = _sds(kv, dt)
        c["media_v"] = _sds(kv, dt)
    elif fam == "hybrid":
        n_shared = cfg.n_layers // cfg.shared_attn_every
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        c["ssm"] = _sds(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            dt,
        )
        c["conv"] = _sds((cfg.n_layers, batch, 3, conv_ch), dt)
        c["shared"] = _attn_cache(cfg, n_shared, batch, cap, dt)
    elif fam == "ssm":  # xlstm
        ng = cfg.n_layers // cfg.slstm_every
        mpg = cfg.slstm_every - 1
        h, hd = cfg.n_heads, cfg.head_dim
        c["mlstm"] = {
            "c": _sds((ng, mpg, batch, h, hd, hd), dt),
            "n": _sds((ng, mpg, batch, h, hd), dt),
            "m": _sds((ng, mpg, batch, h), jnp.float32),
        }
        c["slstm"] = {
            "c": _sds((ng, batch, h, hd), dt),
            "n": _sds((ng, batch, h, hd), dt),
            "m": _sds((ng, batch, h, hd), jnp.float32),
            "h": _sds((ng, batch, h, hd), dt),
        }
    else:
        raise ValueError(fam)
    return c


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Cache:
    return cache_structure(cfg, batch, seq_len)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Cache:
    """Concrete zero-initialized cache (small/serving use only)."""
    def make(s: jax.ShapeDtypeStruct):
        if s.dtype == jnp.int32 and len(s.shape) <= 2 and s.shape and s.shape[-1] > 0:
            # slot_pos rings start empty (-1); 'pos' starts at 0.
            return jnp.full(s.shape, -1, jnp.int32)
        if s.dtype == jnp.int32:
            return jnp.zeros(s.shape, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)

    out = jax.tree.map(make, cache_structure(cfg, batch, seq_len))
    out["pos"] = jnp.zeros((), jnp.int32)
    return out
