"""Mamba2 (SSD) block — chunked training scan + O(1) decode step.

The selective state space recurrence per head (state n, head dim p):

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t (x)  (outer product p x n)
    y_t = C_t . h_t + D * x_t

Training uses the SSD chunked algorithm: within a chunk the contribution is
an attention-like (c x c) quadratic form with decay mask; across chunks a
short lax.scan carries the (B, H, p, n) state. Memory is bounded by one
chunk's score tensor — the SSM analogue of q-chunked attention.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = dict[str, Any]


def _split_proj(p: Params, x: jax.Array, cfg: ModelConfig):
    """in_proj -> z (gate), xin, B, C, dt."""
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di : 2 * di]
    bmat = zxbcdt[..., 2 * di : 2 * di + st]
    cmat = zxbcdt[..., 2 * di + st : 2 * di + 2 * st]
    dt = zxbcdt[..., 2 * di + 2 * st :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xin, bmat, cmat, dt  # dt: (B, S, nh) f32


def _conv_train(p: Params, u: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel 4, over (B, S, C)."""
    kw = p["conv_w"]  # (4, C)
    pad = jnp.pad(u, ((0, 0), (kw.shape[0] - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + u.shape[1], :] * kw[i][None, None, :]
        for i in range(kw.shape[0])
    )
    return jax.nn.silu(out + p["conv_b"])


def mamba2_train(
    p: Params, x: jax.Array, cfg: ModelConfig, return_state: bool = False
):
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns (ssm_state, conv_state) for decoding.
    """
    b, s, d = x.shape
    nh, hp, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    c = min(cfg.ssm_chunk, s)
    assert s % c == 0, "seq must divide ssm_chunk"
    nc = s // c

    z, xin, bmat, cmat, dt = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_state = conv_in[:, -3:, :]
    conv_out = _conv_train(p, conv_in)
    xin = conv_out[..., : cfg.d_inner]
    bmat = conv_out[..., cfg.d_inner : cfg.d_inner + st]
    cmat = conv_out[..., cfg.d_inner + st :]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (nh,)
    la = dt * a[None, None, :]  # log decay (B, S, nh)
    xh = xin.reshape(b, s, nh, hp)
    xdt = xh * dt[..., None].astype(xh.dtype)  # dt-weighted input

    # chunk views, scanned one chunk at a time so peak memory is one chunk's
    # (B, c, c, nh) decay tensor — never (B, nc, c, c, nh).
    cum = jnp.cumsum(la.reshape(b, nc, c, nh), axis=2)  # (B, nc, c, nh)
    xc = xdt.reshape(b, nc, c, nh, hp).transpose(1, 0, 2, 3, 4)
    bc = bmat.reshape(b, nc, c, st).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, c, st).transpose(1, 0, 2, 3)
    cumt = cum.transpose(1, 0, 2, 3)  # (nc, B, c, nh)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(h, inp):
        cc_, bc_, xc_, cum_ = inp  # per-chunk views
        # Within-chunk: y_intra[i] = sum_{j<=i} (C_i.B_j) e^{cum_i - cum_j} xdt_j
        gmat = jnp.einsum("bis,bjs->bij", cc_, bc_)  # (B, c, c)
        ldiff = cum_[:, :, None, :] - cum_[:, None, :, :]  # (B, c, c, nh)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
        m = gmat[..., None] * decay.astype(gmat.dtype)  # (B, c, c, nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m.astype(xc_.dtype), xc_)
        # Inter-chunk: y_inter[i] = e^{cum_i} * C_i . h_prev
        y_inter = jnp.einsum(
            "bis,bhps,bih->bihp", cc_, h, jnp.exp(cum_).astype(cc_.dtype)
        )
        # State update: h' = e^{cum_last} h + sum_j e^{cum_last - cum_j} B_j (x) xdt_j
        w = jnp.exp(cum_[:, -1:, :] - cum_)  # (B, c, nh)
        s_chunk = jnp.einsum("bcs,bch,bchp->bhps", bc_, w.astype(bc_.dtype), xc_)
        a_tot = jnp.exp(cum_[:, -1, :]).astype(h.dtype)  # (B, nh)
        h = h * a_tot[..., None, None] + s_chunk
        return h, y_intra + y_inter  # (B, c, nh, hp)

    h0 = jnp.zeros((b, nh, hp, st), xh.dtype)
    h_final, ys = jax.lax.scan(chunk_step, h0, (cc, bc, xc, cumt))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hp)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = _gated_norm(y, z, p["norm"])
    out = y @ p["out_proj"]
    if return_state:
        return out, h_final, conv_state
    return out


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * scale


def mamba2_decode(
    p: Params,
    x: jax.Array,  # (B, 1, D)
    ssm_state: jax.Array,  # (B, nh, p, st)
    conv_state: jax.Array,  # (B, K-1, conv_channels)
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One token; returns (y, ssm_state', conv_state')."""
    b = x.shape[0]
    nh, hp, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xin, bmat, cmat, dt = _split_proj(p, x, cfg)
    u = jnp.concatenate([xin, bmat, cmat], axis=-1)[:, 0]  # (B, C)
    kw, kb = p["conv_w"], p["conv_b"]
    full = jnp.concatenate([conv_state, u[:, None, :]], axis=1)  # (B, K, C)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, kw) + kb)
    conv_state = full[:, 1:]
    xin = conv[:, : cfg.d_inner]
    bmat = conv[:, cfg.d_inner : cfg.d_inner + st]
    cmat = conv[:, cfg.d_inner + st :]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt0 = dt[:, 0]  # (B, nh)
    decay = jnp.exp(dt0 * a[None, :]).astype(x.dtype)  # (B, nh)
    xh = xin.reshape(b, nh, hp) * dt0[..., None].astype(x.dtype)
    upd = jnp.einsum("bhp,bs->bhps", xh, bmat)
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhps,bs->bhp", ssm_state, cmat)
    y = y + xin.reshape(b, nh, hp) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, cfg.d_inner)
    y = _gated_norm(y, z, p["norm"])
    return y @ p["out_proj"], ssm_state, conv_state
