"""Model zoo: the 10 assigned architectures as one composable decoder stack.

Families: dense GQA (optionally sliding-window), MoE (expert-parallel
shard_map dispatch), VLM (interleaved cross-attention), audio enc-dec
(whisper), hybrid SSM (zamba2: Mamba2 + shared attention block), and
xLSTM (mLSTM + sLSTM). All families share the same parameter-schema,
layer-group-scan, KV-cache, and sharding machinery.
"""
from repro.models.config import ModelConfig
from repro.models.transformer import (
    init_params,
    param_schema,
    abstract_params,
    forward_train,
    prefill,
    decode_step,
)
from repro.models.cache import init_cache, abstract_cache

__all__ = [
    "ModelConfig",
    "init_params",
    "param_schema",
    "abstract_params",
    "forward_train",
    "prefill",
    "decode_step",
    "init_cache",
    "abstract_cache",
]
