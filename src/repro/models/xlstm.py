"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM: per-head matrix memory C (hd x hd) with exponential input gate and
sigmoid forget gate, trained with the chunkwise-parallel form (log-space
gate algebra, running-max stabilizer m) so the backward pass stores one
chunk's quadratic form instead of S matrix states.

sLSTM: scalar memory with a block-diagonal recurrent matrix (per-head),
inherently sequential — lax.scan over time, carrying (c, n, m, h).

Both cells run at model width d (head_dim * n_heads = d), matching the
assigned xlstm-1.3b dims (4 heads x 512). Stabilizer follows the xLSTM
paper: m_t = max(logf + m_{t-1}, logi).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = dict[str, Any]


# ------------------------------------------------------------------- mLSTM
def _mlstm_chunk_scan(q, k, v, li, lf, chunk, return_state: bool = False):
    """Chunkwise mLSTM. q/k/v: (B, S, H, p); li/lf: (B, S, H) log gates.

    Carry per head: C (p, p) and n (p,) stored *pre-scaled* by exp(-m), plus
    the running max m. Within a chunk, intra weights are
    W[i, j] = exp(F_i - F_j + li_j - m_i) for j <= i, with
    m_i = max(max_j(...), F_i + m_prev) so every exponent is <= 0.
    """
    b, s, h, p = q.shape
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    tri = jnp.tril(jnp.ones((c, c), bool))

    def reshape(x):
        return x.reshape(b, nc, c, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1)
        )

    qc, kc, vc = reshape(q), reshape(k), reshape(v)  # (nc, B, c, H, p)
    lic = li.reshape(b, nc, c, h).transpose(1, 0, 2, 3)  # (nc, B, c, H)
    lfc = lf.reshape(b, nc, c, h).transpose(1, 0, 2, 3)

    def step(carry, inp):
        cmat, nvec, m_prev = carry  # (B,H,p,p), (B,H,p), (B,H)
        qi, ki, vi, lii, lfi = inp
        fcum = jnp.cumsum(lfi, axis=1)  # (B, c, H)
        # intra log weights (B, c_i, c_j, H)
        logw = fcum[:, :, None, :] - fcum[:, None, :, :] + lii[:, None, :, :]
        logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)
        m_intra = jnp.max(logw, axis=2)  # (B, c, H)
        m_inter = fcum + m_prev[:, None, :]
        m_i = jnp.maximum(m_intra, m_inter)  # (B, c, H)
        m_i = jnp.maximum(m_i, -80.0)  # keep exp() sane when all gates tiny
        w = jnp.exp(logw - m_i[:, :, None, :])  # (B, c, c, H)
        binter = jnp.exp(m_inter - m_i)  # (B, c, H)

        scale = 1.0 / jnp.sqrt(p)
        scores = jnp.einsum("bihp,bjhp->bijh", qi, ki) * scale  # (B, c, c, H)
        aw = (scores * w.astype(scores.dtype))
        y_num = jnp.einsum("bijh,bjhp->bihp", aw, vi)
        y_num += jnp.einsum(
            "bihp,bhpq,bih->bihq", qi * scale, cmat, binter.astype(qi.dtype)
        )
        denom = jnp.einsum("bijh->bih", aw) + jnp.einsum(
            "bihp,bhp,bih->bih", qi * scale, nvec, binter.astype(qi.dtype)
        )
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_i).astype(denom.dtype))
        y = y_num / denom[..., None]

        # carry update (scaled by exp(-m_next))
        ftot = fcum[:, -1, :]  # (B, H)
        m_next = jnp.maximum(
            ftot + m_prev, jnp.max(ftot[:, None, :] - fcum + lii, axis=1)
        )
        m_next = jnp.maximum(m_next, -80.0)
        kw = jnp.exp(ftot[:, None, :] - fcum + lii - m_next[:, None, :])
        cmat = cmat * jnp.exp(ftot + m_prev - m_next)[..., None, None].astype(
            cmat.dtype
        ) + jnp.einsum("bihp,bihq,bih->bhpq", ki, vi, kw.astype(ki.dtype))
        nvec = nvec * jnp.exp(ftot + m_prev - m_next)[..., None].astype(
            nvec.dtype
        ) + jnp.einsum("bihp,bih->bhp", ki, kw.astype(ki.dtype))
        return (cmat, nvec, m_next), y

    carry0 = (
        jnp.zeros((b, h, p, p), q.dtype),
        jnp.zeros((b, h, p), q.dtype),
        jnp.full((b, h), 0.0, jnp.float32),
    )
    carry, ys = jax.lax.scan(step, carry0, (qc, kc, vc, lic, lfc))
    out = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    if return_state:
        return out, carry
    return out


def mlstm_train(
    p: Params, x: jax.Array, cfg: ModelConfig, return_state: bool = False
):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, h, hd)
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    gates = x @ p["w_if"] + p["b_if"]  # (B, S, 2H)
    li = gates[..., :h].astype(jnp.float32)  # log input gate
    lf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    if return_state:
        y, (cmat, nvec, m) = _mlstm_chunk_scan(
            q, k, v, li, lf, cfg.ssm_chunk, return_state=True
        )
    else:
        y = _mlstm_chunk_scan(q, k, v, li, lf, cfg.ssm_chunk)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    y = y.reshape(b, s, d) * o
    out = y @ p["wo"]
    if return_state:
        return out, (cmat, nvec, m)
    return out


def mlstm_decode(
    p: Params, x: jax.Array, cmat: jax.Array, nvec: jax.Array, m: jax.Array,
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One step. cmat: (B, H, p, p) (pre-scaled), nvec: (B, H, p), m: (B, H)."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, h, hd)
    k = (x @ p["wk"]).reshape(b, h, hd)
    v = (x @ p["wv"]).reshape(b, h, hd)
    gates = (x @ p["w_if"] + p["b_if"])[:, 0]
    li = gates[..., :h].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    m_next = jnp.maximum(lf + m, li)
    m_next = jnp.maximum(m_next, -80.0)
    fw = jnp.exp(lf + m - m_next)[..., None]
    iw = jnp.exp(li - m_next)[..., None]
    cmat = cmat * fw[..., None].astype(cmat.dtype) + jnp.einsum(
        "bhp,bhq,bh1->bhpq", k, v, iw.astype(k.dtype)
    )
    nvec = nvec * fw.astype(nvec.dtype) + k * iw.astype(k.dtype)
    scale = 1.0 / jnp.sqrt(hd)
    num = jnp.einsum("bhp,bhpq->bhq", q * scale, cmat)
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", q * scale, nvec))
    den = jnp.maximum(den, jnp.exp(-m_next).astype(den.dtype))
    y = (num / den[..., None]).reshape(b, 1, cfg.d_model)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return (y * o) @ p["wo"], cmat, nvec, m_next


# ------------------------------------------------------------------- sLSTM
def slstm_train(
    p: Params, x: jax.Array, cfg: ModelConfig, return_state: bool = False
):
    """Sequential scalar-memory LSTM with block-diagonal recurrence."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    pre = x @ p["w_gates"] + p["b_gates"]  # (B, S, 4d)
    pre = pre.reshape(b, s, 4, h, hd)

    def step(carry, inp):
        cst, nst, mst, hst = carry  # (B, h, hd) x3 + h
        pre_t = inp  # (B, 4, h, hd)
        rec = jnp.einsum("bhp,hgpq->bghq", hst, p["r_gates"])  # (B, 4, h, hd)
        zi, zf, zz, zo = [pre_t[:, g] + rec[:, g] for g in range(4)]
        zif = zi.astype(jnp.float32)
        zff = jax.nn.log_sigmoid(zf.astype(jnp.float32))
        m_new = jnp.maximum(zff + mst, zif)
        m_new = jnp.maximum(m_new, -80.0)
        iw = jnp.exp(zif - m_new).astype(x.dtype)
        fw = jnp.exp(zff + mst - m_new).astype(x.dtype)
        cst = fw * cst + iw * jnp.tanh(zz)
        nst = fw * nst + iw
        hst = jax.nn.sigmoid(zo) * cst / jnp.maximum(nst, 1e-6)
        return (cst, nst, m_new, hst), hst

    zeros = jnp.zeros((b, h, hd), x.dtype)
    carry0 = (zeros, zeros, jnp.zeros((b, h, hd), jnp.float32), zeros)
    carry, ys = jax.lax.scan(step, carry0, pre.transpose(1, 0, 2, 3, 4))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    out = y @ p["wo"]
    if return_state:
        return out, carry
    return out


def slstm_decode(
    p: Params, x: jax.Array, cst, nst, mst, hst, cfg: ModelConfig
):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    pre = (x @ p["w_gates"] + p["b_gates"]).reshape(b, 4, h, hd)
    rec = jnp.einsum("bhp,hgpq->bghq", hst, p["r_gates"])
    zi, zf, zz, zo = [pre[:, g] + rec[:, g] for g in range(4)]
    zif = zi.astype(jnp.float32)
    zff = jax.nn.log_sigmoid(zf.astype(jnp.float32))
    m_new = jnp.maximum(jnp.maximum(zff + mst, zif), -80.0)
    iw = jnp.exp(zif - m_new).astype(x.dtype)
    fw = jnp.exp(zff + mst - m_new).astype(x.dtype)
    cst = fw * cst + iw * jnp.tanh(zz)
    nst = fw * nst + iw
    hst = jax.nn.sigmoid(zo) * cst / jnp.maximum(nst, 1e-6)
    y = hst.reshape(b, 1, cfg.d_model) @ p["wo"]
    return y, cst, nst, m_new, hst
