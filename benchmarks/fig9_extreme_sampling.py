"""Paper Fig. 9: an extremely small sampling rate kills sensitivity to
asynchrony (conclusion 1+3) but slows convergence — the trees are built
from too few samples and get 'distorted'."""
from __future__ import annotations


from benchmarks.common import paper_cfg, realsim_like, save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import train_loss


def run(quick: bool = True) -> dict:
    n_trees = 120 if quick else 400
    data = realsim_like(quick)
    # paper: 0.000005 on 72k samples ~ 500 rows; scale to our N
    tiny = max(200.0 / data.n_samples, 1e-4)
    out: dict = {"rates": [tiny, 0.6], "curves": {}}
    for rate in (tiny, 0.6):
        cfg = paper_cfg(n_trees, 6, sampling_rate=rate)
        for w in (1, 16):
            losses: list[float] = []
            train_async(
                cfg, data, worker_round_robin(n_trees, w), seed=0,
                eval_every=max(n_trees // 10, 1),
                eval_fn=lambda st, j: losses.append(
                    float(train_loss(cfg, data, st))
                ),
            )
            out["curves"][f"rate{rate:.6f}_W{w}"] = losses
            print(f"  rate={rate:.6f} W={w}: final {losses[-1]:.4f}", flush=True)
    save("fig9_extreme_sampling", out)
    return out


def main(quick: bool = True):
    res = run(quick)
    c = res["curves"]
    keys = sorted(c)
    tiny_keys = [k for k in keys if not k.startswith("rate0.6")]
    big_keys = [k for k in keys if k.startswith("rate0.6")]
    gap_tiny = abs(c[tiny_keys[1]][-1] - c[tiny_keys[0]][-1])
    gap_big = abs(c[big_keys[1]][-1] - c[big_keys[0]][-1])
    slower = c[tiny_keys[0]][-1] > c[big_keys[0]][-1]
    print(f"\nasync gap tiny-rate={gap_tiny:.4f} vs normal-rate={gap_big:.4f} "
          f"(paper: tiny < normal); tiny-rate converges slower: {slower}")
    return res


if __name__ == "__main__":
    main()
