"""Paper Figs. 7 & 8: sensitivity to the sampling rate at a fixed worker
count. Higher sampling rates make the algorithm MORE sensitive to
asynchrony (conclusion 3); the effect is strong on low-diversity data
(Higgs, Fig. 7) and mild on high-diversity data (real-sim, Fig. 8)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import higgs_like, paper_cfg, realsim_like, save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import train_loss
from repro.data.sampling import diversity_stats

RATES = [0.2, 0.4, 0.6, 0.8]
W = 16


def run(quick: bool = True) -> dict:
    n_trees = 120 if quick else 400
    out: dict = {"rates": RATES, "workers": W, "curves": {}, "diversity": {}}
    for tag, data, depth in [
        ("fig8_realsim", realsim_like(quick), 6),
        ("fig7_higgs", higgs_like(quick), 4),
    ]:
        curves = {}
        for rate in RATES:
            cfg = paper_cfg(n_trees, depth, sampling_rate=rate)
            for w in (1, W):
                losses: list[float] = []
                train_async(
                    cfg, data, worker_round_robin(n_trees, w), seed=0,
                    eval_every=max(n_trees // 10, 1),
                    eval_fn=lambda st, j: losses.append(
                        float(train_loss(cfg, data, st))
                    ),
                )
                curves[f"rate{rate}_W{w}"] = losses
            stats = diversity_stats(rate, data.multiplicity)
            out["diversity"].setdefault(tag, {})[str(rate)] = {
                k: float(v) for k, v in stats.items()
            }
            gap = np.mean(
                np.asarray(curves[f"rate{rate}_W{W}"])
                - np.asarray(curves[f"rate{rate}_W1"])
            )
            print(f"  {tag} rate={rate}: async gap {gap:+.4f} "
                  f"delta={out['diversity'][tag][str(rate)]['delta']:.3f}",
                  flush=True)
        out["curves"][tag] = curves
    save("fig7_fig8_sampling_sensitivity", out)
    return out


def main(quick: bool = True):
    res = run(quick)
    print("\nasync gap should grow with sampling rate (conclusion 3),")
    print("and be larger on the low-diversity (higgs) dataset (conclusion 5).")
    return res


if __name__ == "__main__":
    main()
