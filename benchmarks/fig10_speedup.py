"""Paper Fig. 10 + Eq. 13: speedup of asynch-SGBDT vs fork-join baselines.

Wall-clock asynchrony cannot run on one CPU, so the timing geometry is
reproduced by the event-driven cluster simulator, parameterized by
COMPONENT TIMES MEASURED from the actual jitted implementation:
  t_build  — one build_tree call on a sampled subdataset,
  t_server — target rebuild (grad + sample + fold),
  t_comm   — tree pull+push bytes over the paper's 1 GbE TCP/IP network.
The paper's numbers to match: asynch-SGBDT 14x (real-sim) / 20x
(E2006-log1p) at 32 workers; LightGBM 5-7x; DimBoost 4-6x.

Beyond the simulation, ``async_measured`` is an EXECUTED speedup: the PS
engine's worker pool builds W trees in one vmapped call
(``repro.ps.worker``), and we time that block against W sequential
builds — the Fig. 10 claim running for real on this machine's vector
units rather than through the event model.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import e2006_like, paper_cfg, realsim_like, save, time_call
from repro.core.baselines import (
    max_workers_bound,
    speedup_model_async,
    speedup_model_dimboost,
    speedup_model_sync,
)
from repro.core.simulator import ClusterSpec, simulate_async, simulate_sync
from repro.core.sgbdt import init_state
from repro.data.sampling import bernoulli_weights
from repro.ps import clear_trainers
from repro.ps.worker import build_trees_batched
from repro.trees.learner import build_tree, build_tree_multi
from repro.trees.tree import apply_tree, apply_tree_stack

WORKERS = [1, 2, 4, 8, 16, 32]
GBE_BYTES_PER_S = 110e6  # ~1 GbE effective

# Accounting subprocess for the block-distributed 2D mesh: trace the REAL
# feature-sharded builder (argmax-merge split search, DESIGN.md §16) with
# a ByteRecorder on forced host devices and report what one tree build
# actually puts on the wire — fig10's 2D rows derive their communication
# bytes from this, never from shape arithmetic.
_MESH2D_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={shards}"
import json

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_gbdt_mesh
from repro.ps.sharded import collective_bytes_per_build
from repro.trees.binning import SparseBins
from repro.trees.learner import LearnerConfig

N, F, E, depth, shards = {N}, {F}, {E}, {depth}, {shards}
cfg = LearnerConfig(depth=depth, n_bins=64, backend="ref", hist_mode="subtract")
mesh = make_gbdt_mesh(1, shards)
dense = jax.ShapeDtypeStruct((N, F), jnp.int32)
C = max(N * E // F, 1)
sp = SparseBins(
    indices=jax.ShapeDtypeStruct((N, E), jnp.int32),
    codes=jax.ShapeDtypeStruct((N, E), jnp.int32),
    feat_rows=jax.ShapeDtypeStruct((F, C), jnp.int32),
    feat_codes=jax.ShapeDtypeStruct((F, C), jnp.int32),
    zero_bin=jax.ShapeDtypeStruct((F,), jnp.int32),
)
out = {{
    "bytes_2d_dense": collective_bytes_per_build(
        cfg, mesh, dense, feature_axis="feature")["realized_bytes"],
    "bytes_2d_sparse": collective_bytes_per_build(
        cfg, mesh, sp, feature_axis="feature")["realized_bytes"],
}}
print("MESH2D_JSON=" + json.dumps(out))
"""


def measure_mesh2d_comm(cfg, data, shards: int = 8) -> dict | None:
    """ACCOUNTING-derived per-round wire bytes on the (1, ``shards``) 2D
    mesh — the 2D analogue of ``measure_components``'s pull/tree payload,
    with the bytes MEASURED from the builder's own collectives
    (``ps.sharded.collective_bytes_per_build``) instead of hand-derived
    constants. Returns None when the feature count does not tile the mesh.
    """
    import json as _json
    import os
    import subprocess
    import sys

    from repro.trees.binning import SparseBins, to_sparse

    n, f = data.bins.shape
    if f % shards:
        return None
    sp = data.bins if isinstance(data.bins, SparseBins) \
        else to_sparse(data.bins)
    code = _MESH2D_CODE.format(
        N=n, F=f, E=sp.max_nnz_row, depth=cfg.learner.depth, shards=shards
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1400, env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("MESH2D_JSON="):
            out = _json.loads(line.split("=", 1)[1])
            out["shards"] = shards
            return out
    return None


def measure_components(cfg, data) -> dict:
    key = jax.random.PRNGKey(0)
    obj = cfg.obj
    k_out = obj.n_outputs
    state = init_state(cfg, data)
    g, h = obj.grad_hess(data.labels, state.f, qid=data.qid)
    m_prime, _ = bernoulli_weights(key, cfg.sampling_rate, data.multiplicity)

    if k_out == 1:
        t_build, tree = time_call(
            lambda: build_tree(cfg.learner, data.bins, m_prime * g, m_prime, key)
        )
        apply_fn = apply_tree
    else:
        t_build, tree = time_call(
            lambda: build_tree_multi(
                cfg.learner, data.bins, m_prime[:, None] * g,
                jnp.broadcast_to(m_prime[:, None], g.shape), key,
            )
        )
        apply_fn = apply_tree_stack

    def server_side():
        mp, _ = bernoulli_weights(key, cfg.sampling_rate, data.multiplicity)
        gg, _ = obj.grad_hess(data.labels, state.f, qid=data.qid)
        return state.f + cfg.step_length * apply_fn(tree, data.bins), mp, gg

    t_server, _ = time_call(jax.jit(server_side))

    # tree payload: feature/threshold int32 + leaf f32, x K trees per round
    n_int = tree.feature.shape[-1]
    n_leaf = tree.leaf_value.shape[-1]
    tree_bytes = 4 * (2 * n_int + n_leaf) * k_out
    # pull payload: the target field L'_random (N x K floats)
    pull_bytes = 4 * data.n_samples * k_out
    t_comm = (tree_bytes + pull_bytes) / GBE_BYTES_PER_S
    return {
        "t_build": t_build,
        "t_server": t_server,
        "t_comm": t_comm,
        "tree_bytes": tree_bytes,
        "pull_bytes": pull_bytes,
    }


def measure_worker_parallel(cfg, data, workers: list[int]) -> list[float]:
    """Executed speedup of the vmapped worker pool: (W x one-build time) /
    (one batched W-build time), per worker count."""
    key = jax.random.PRNGKey(0)
    state = init_state(cfg, data)

    t_one, _ = time_call(
        jax.jit(lambda k: build_trees_batched(
            cfg, data, state.f[None, ...], k)),
        jax.random.split(key, 1),
    )
    out = []
    for w in workers:
        targets = jnp.broadcast_to(state.f, (w,) + state.f.shape)
        t_blk, _ = time_call(
            jax.jit(lambda k, t: build_trees_batched(cfg, data, t, k)),
            jax.random.split(key, w), targets,
        )
        out.append(w * t_one / t_blk)
    return out


def measure_runtime_threads(
    cfg, data, workers: list[int], n_trees: int, tag: str
) -> dict:
    """EXECUTED wall-clock speedup of the real threaded runtime, plus the
    realized staleness cross-validated against the simulator's prediction
    for the measured cluster geometry (``RunTrace.crossvalidate``).

    One CPU serves every thread, so this measures the host-async overlap
    the runtime actually achieves here (XLA's intra-op pool), not an
    idealized cluster — the point is that it is *measured*, with the trace
    exported for the simulator to be validated against.
    """
    from repro.ps import AsyncRuntime

    rt_cfg = cfg._replace(n_trees=n_trees)
    rows = {
        "speedup": [], "makespan_s": [],
        "mean_staleness": [], "max_staleness": [],
        "sim_mean_staleness": [], "sim_max_staleness": [],
    }
    base = None
    last_trace = None
    for w in workers:
        state, trace = AsyncRuntime(rt_cfg, data, n_workers=w).run(seed=0)
        del state
        if base is None:
            base = trace.makespan
        xval = trace.crossvalidate()
        rows["speedup"].append(base / trace.makespan)
        rows["makespan_s"].append(float(trace.makespan))
        rows["mean_staleness"].append(xval["realized"]["mean_staleness"])
        rows["max_staleness"].append(xval["realized"]["max_staleness"])
        rows["sim_mean_staleness"].append(xval["simulated"]["mean_staleness"])
        rows["sim_max_staleness"].append(xval["simulated"]["max_staleness"])
        last_trace = trace
    trace_path = last_trace.save(
        pathlib.Path("experiments") / f"runtime_trace_{tag}.json"
    )
    rows["trace_json"] = str(trace_path)
    return rows


def measure_sharded_pulls(cfg, data, n_trees: int) -> dict:
    """EXECUTED pull-byte reduction from sharding the server leaf table.

    Runs the threaded runtime at W=4 with the leaf table split into P
    partitions for a sweep of P; each worker derives its Bernoulli sample
    from the ticket key and pulls only the partitions its sampled rows
    touch, and the trace records the bytes each pull actually moved
    (request bitmap + touched-partition payload). Reported per P: the mean
    realized pull bytes, the reduction vs. the full 4*N*K pull, and the
    Eq.-13-style simulated speedup with t_comm rescaled to the reduced
    payload — what the saved bytes are worth on the paper's 1 GbE wire.
    """
    from repro.ps import AsyncRuntime

    rt_cfg = cfg._replace(n_trees=n_trees)
    n = data.n_samples
    full = 4 * cfg.obj.n_outputs * n
    sweep = sorted({min(16, n), min(256, n), n})
    out = {"n_parts": [], "pull_bytes_mean": [], "reduction": [],
           "sim_speedup_32w": [], "full_pull_bytes": full}
    comp = measure_components(cfg, data)
    base = simulate_async(
        ClusterSpec(n_workers=1, t_build=comp["t_build"],
                    t_comm=comp["t_comm"], t_server=comp["t_server"]),
        n_trees,
    ).makespan
    for p in sweep:
        _, trace = AsyncRuntime(
            rt_cfg, data, n_workers=4, shard_pulls=p
        ).run(seed=0)
        mean_bytes = float(trace.pull_bytes.mean())
        reduction = 1.0 - mean_bytes / full
        t_comm = (comp["tree_bytes"] + mean_bytes) / GBE_BYTES_PER_S
        sharded = simulate_async(
            ClusterSpec(n_workers=32, t_build=comp["t_build"],
                        t_comm=t_comm, t_server=comp["t_server"]),
            n_trees,
        ).makespan
        out["n_parts"].append(p)
        out["pull_bytes_mean"].append(mean_bytes)
        out["reduction"].append(reduction)
        out["sim_speedup_32w"].append(base / sharded)
    return out


def _objective_dataset(objective: str, quick: bool):
    """(tag, data) for a requested --objective override — the launch
    driver's shared objective -> workload dispatch, benchmark-sized."""
    from repro.launch.train import gbdt_dataset_for

    obj, data = gbdt_dataset_for(objective, seed=7, n=1_600 if quick else 6_400)
    tag = obj.name if obj.n_outputs == 1 else f"{obj.name}{obj.n_outputs}"
    return tag, data


def run(quick: bool = True, objective: str | None = None) -> dict:
    """Default: the paper's two workloads. With ``objective``, the same
    speedup measurement on that objective's matched workload — the paper's
    scalability claim checked beyond binary classification (multiclass
    rounds build K trees per push; the measured vmapped-pool ratio and the
    Eq. 13 model both see the bigger build/comm payloads)."""
    n_trees = 150 if quick else 400
    if objective is None:
        cases = [
            ("realsim", realsim_like(quick), 6, "logistic"),
            ("e2006", e2006_like(quick), 6, "mse"),
        ]
    else:
        tag, data = _objective_dataset(objective, quick)
        cases = [(tag, data, 6, objective)]
    out: dict = {"workers": WORKERS, "objective": objective, "datasets": {}}
    for tag, data, depth, loss in cases:
        cfg = paper_cfg(n_trees, depth, objective=loss)
        comp = measure_components(cfg, data)
        print(f"  {tag}: t_build={comp['t_build']*1e3:.1f}ms "
              f"t_server={comp['t_server']*1e3:.1f}ms "
              f"t_comm={comp['t_comm']*1e3:.1f}ms "
              f"(Eq.13 max workers ~ {max_workers_bound(**{k: comp[k] for k in ('t_build','t_comm','t_server')}):.0f})",
              flush=True)
        rows = {"async_sim": [], "sync_sim": [], "dimboost_sim": []}
        base = None
        for w in WORKERS:
            spec = ClusterSpec(
                n_workers=w, t_build=comp["t_build"],
                t_comm=comp["t_comm"], t_server=comp["t_server"],
            )
            a = simulate_async(spec, n_trees).makespan
            s = simulate_sync(spec, n_trees)
            d = simulate_sync(spec, n_trees, comm_model="central")
            if w == 1:
                base = max(a, s, d)
            rows["async_sim"].append(base / a)
            rows["sync_sim"].append(base / s)
            rows["dimboost_sim"].append(base / d)
        warr = np.asarray(WORKERS, float)
        rows["async_eq13"] = speedup_model_async(
            warr, comp["t_build"], comp["t_comm"], comp["t_server"]
        ).tolist()
        # The paper's environment: ps-lite over 1 GbE TCP/IP put
        # T(comm)+T(server) at ~T(build)/25 (their Eq. 13 discussion says
        # 16-32 workers is close to the max for real-sim), which is what
        # caps their async speedup at 14-22x. Same algorithm, their wire.
        t_over = comp["t_build"] / 25.0

        def _paper_env_makespan(w: int) -> float:
            # ps-lite's server owns the NIC: comm serializes *on the server*
            # (that is exactly Eq. 13's T(Communicate + BuildTarget) term).
            spec = ClusterSpec(
                n_workers=w, t_build=comp["t_build"],
                t_comm=0.0, t_server=t_over,
            )
            return simulate_async(spec, n_trees).makespan

        base_pe = _paper_env_makespan(1)
        rows["async_paper_env"] = [base_pe / _paper_env_makespan(w) for w in WORKERS]
        rows["async_measured"] = measure_worker_parallel(cfg, data, WORKERS)
        print(f"  {tag} measured vmapped-pool speedup @"
              f"{WORKERS[-1]}w: {rows['async_measured'][-1]:.1f}x", flush=True)
        rows["runtime_measured"] = measure_runtime_threads(
            cfg, data, WORKERS, n_trees=32 if quick else 96, tag=tag
        )
        rt = rows["runtime_measured"]
        print(f"  {tag} threaded-runtime speedup @{WORKERS[-1]}w: "
              f"{rt['speedup'][-1]:.2f}x, staleness "
              f"{rt['mean_staleness'][-1]:.1f} realized vs "
              f"{rt['sim_mean_staleness'][-1]:.1f} simulated "
              f"(trace -> {rt['trace_json']})", flush=True)
        if cfg.obj.rowwise:
            rows["sharded_pulls"] = measure_sharded_pulls(
                cfg, data, n_trees=24 if quick else 64
            )
            sp = rows["sharded_pulls"]
            print(f"  {tag} sharded pulls: " + "  ".join(
                f"P={p}: -{100 * r:.0f}% bytes"
                for p, r in zip(sp["n_parts"], sp["reduction"])
            ), flush=True)
        mesh2d = measure_mesh2d_comm(cfg, data)
        if mesh2d is not None:
            # The 2D-mesh speedup rows: same Eq.-13 event model, but the
            # per-round communication payload is the build's OWN measured
            # collective bytes (argmax merge + partition column) + the
            # tree push — pull_bytes is replaced by accounting, because on
            # the block-distributed mesh the target never crosses the
            # wire; only the build collectives do.
            for kind in ("dense", "sparse"):
                wire = mesh2d[f"bytes_2d_{kind}"] + comp["tree_bytes"]
                t_comm = wire / GBE_BYTES_PER_S
                sims = []
                base2d = None
                for w in WORKERS:
                    spec = ClusterSpec(
                        n_workers=w, t_build=comp["t_build"],
                        t_comm=t_comm, t_server=comp["t_server"],
                    )
                    m = simulate_async(spec, n_trees).makespan
                    base2d = base2d or m
                    sims.append(base2d / m)
                mesh2d[f"round_wire_bytes_{kind}"] = wire
                mesh2d[f"async_sim_2d_{kind}"] = sims
            rows["mesh2d"] = mesh2d
            print(f"  {tag} 2D mesh (1x{mesh2d['shards']}) accounting: "
                  f"{mesh2d['bytes_2d_dense']:,}B/round dense, "
                  f"{mesh2d['bytes_2d_sparse']:,}B/round sparse "
                  f"(vs {comp['pull_bytes']:,}B pull constant); "
                  f"@32w sim {mesh2d['async_sim_2d_dense'][-1]:.1f}x / "
                  f"{mesh2d['async_sim_2d_sparse'][-1]:.1f}x", flush=True)
        rows["sync_model"] = speedup_model_sync(
            warr, comp["t_build"], comp["t_comm"], comp["t_server"]
        ).tolist()
        rows["dimboost_model"] = speedup_model_dimboost(
            warr, comp["t_build"], comp["t_comm"], comp["t_server"]
        ).tolist()
        out["datasets"][tag] = {"components": comp, "speedup": rows}
        print(f"  {tag} @32w: async {rows['async_sim'][-1]:.1f}x "
              f"sync {rows['sync_sim'][-1]:.1f}x dimboost {rows['dimboost_sim'][-1]:.1f}x",
              flush=True)
        # each case is a distinct SGBDTConfig; drop its cached Trainer (and
        # the compiled programs it pins) before the next one.
        clear_trainers()
    name = "fig10_speedup" if objective is None else f"fig10_speedup_{objective.replace(':', '')}"
    save(name, out)
    return out


def main(quick: bool = True, objective: str | None = None):
    res = run(quick, objective=objective)
    print("\npaper targets @32: async 14-20x, LightGBM 5-7x, DimBoost 4-6x")
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--objective", default=None,
                    help="objective registry spec (e.g. multiclass:3, "
                         "lambdarank); default = the paper's two workloads")
    a = ap.parse_args()
    main(quick=not a.full, objective=a.objective)
