"""Thin shim: tuning-table schema validation moved to ``repro.analysis``.

The validator now lives in ``repro.analysis.tuning_schema`` (stdlib-only,
so the lint tier can still run it without jax), where the VMEM checker
layers budget pricing on top. This wrapper keeps the historical entry
point and exit-code contract:

    python -m benchmarks.check_tuning_table [path]

For the full check (schema + VMEM budgets + BlockSpec placement), run
``PYTHONPATH=src python -m repro.analysis --only vmem``.
"""
from __future__ import annotations

import json
import pathlib
import sys

_SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.analysis.tuning_schema import (  # noqa: E402,F401 (re-exports)
    ENTRY_FIELDS,
    KEY_RE,
    KNOWN_FORMATS,
    default_table_path,
    validate,
)


def main() -> int:
    path = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else default_table_path()
    table = json.loads(path.read_text())
    errors = validate(table)
    if errors:
        print(f"{path}: tuning table schema validation FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"{path}: ok ({len(table['entries'])} entries, format "
          f"{table['format']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
