"""Schema validation for ``src/repro/kernels/tuning_table.json``.

The tuning table is data the kernel dispatcher trusts at import time: a
malformed entry (a typo'd key, a string where a block size should be, a
format bump nobody taught the loader about) turns into a confusing
runtime failure deep inside a Pallas grid computation. This check runs in
the lint job — stdlib only, no jax import — and fails fast with a
field-level message instead.

Usage:
    python -m benchmarks.check_tuning_table [path]
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

KEY_RE = re.compile(r"^N\d+_F\d+_B\d+_L\d+$")
KNOWN_FORMATS = {1}
# field -> (type, must be > 0)
ENTRY_FIELDS = {
    "sample_block": (int, True),
    "feature_block": (int, True),
    "node_block": (int, True),
    "fused_ms": (float, True),
    "split_ms": (float, True),
    "host": (str, False),
}


def validate(table: dict) -> list[str]:
    errors: list[str] = []
    fmt = table.get("format")
    if fmt not in KNOWN_FORMATS:
        errors.append(
            f"format is {fmt!r}; this validator knows {sorted(KNOWN_FORMATS)}"
            " — teach benchmarks.check_tuning_table (and the kernel loader)"
            " the new format before committing it"
        )
        return errors
    unknown_top = set(table) - {"format", "entries", "comment"}
    if unknown_top:
        errors.append(f"unknown top-level fields: {sorted(unknown_top)}")
    entries = table.get("entries")
    if not isinstance(entries, dict):
        errors.append("'entries' must be an object")
        return errors
    for key, entry in entries.items():
        if not KEY_RE.match(key):
            errors.append(
                f"entry key {key!r} does not match N<d>_F<d>_B<d>_L<d>"
            )
        if not isinstance(entry, dict):
            errors.append(f"{key}: entry must be an object")
            continue
        for field, (typ, positive) in ENTRY_FIELDS.items():
            val = entry.get(field)
            if val is None:
                errors.append(f"{key}: missing field {field!r}")
            elif typ is float:
                if isinstance(val, bool) or not isinstance(val, (int, float)):
                    errors.append(f"{key}.{field}: {val!r} is not a number")
                elif positive and val <= 0:
                    errors.append(f"{key}.{field}: must be > 0, got {val}")
            elif typ is int:
                if isinstance(val, bool) or not isinstance(val, int):
                    errors.append(f"{key}.{field}: {val!r} is not an int")
                elif positive and val <= 0:
                    errors.append(f"{key}.{field}: must be > 0, got {val}")
            elif not isinstance(val, typ):
                errors.append(f"{key}.{field}: {val!r} is not {typ.__name__}")
        unknown = set(entry) - set(ENTRY_FIELDS)
        if unknown:
            errors.append(f"{key}: unknown fields {sorted(unknown)}")
    return errors


def main() -> int:
    default = (
        pathlib.Path(__file__).resolve().parents[1]
        / "src" / "repro" / "kernels" / "tuning_table.json"
    )
    path = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else default
    table = json.loads(path.read_text())
    errors = validate(table)
    if errors:
        print(f"{path}: tuning table schema validation FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"{path}: ok ({len(table['entries'])} entries, format "
          f"{table['format']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
