"""Objective sweep: every registered objective family, serial vs async.

The ROADMAP's "open a new workload" check, runnable in CI: for each
objective (binary logistic, squared error, quantile, multiclass softmax,
pairwise LambdaRank) train a quick forest serially (W = 1) and under an
8-worker round-robin delay schedule, and record init/final loss plus the
objective's own metrics -> ``experiments/objective_sweep.json``.

The async column is the paper's validity claim generalized: bounded
staleness should not wreck per-round convergence on high-diversity data,
whatever the loss — multiclass rounds push K trees per update, ranking
targets are pairwise fields, and both ride the same PS engine.

    PYTHONPATH=src python -m benchmarks.objective_sweep [--full]
"""
from __future__ import annotations

import repro.data as D
from benchmarks.common import save
from repro.core.sgbdt import SGBDTConfig, init_state, train_metrics
from repro.ps import clear_trainers, get_trainer
from repro.trees.learner import LearnerConfig

WORKERS = 8


def sweep_cases(quick: bool):
    """(tag, objective spec, dataset, step length). The pinball step is
    smaller: its gradients have constant magnitude, so W stale pushes
    overshoot at steps the curvature-damped losses tolerate."""
    n = 800 if quick else 4_000
    return [
        ("binary", "logistic", D.make_sparse_classification(n, 200, 10, seed=7), 0.2),
        ("mse", "mse", D.make_sparse_regression(n, 300, 12, seed=9), 0.2),
        (
            "quantile",
            "quantile:0.5",
            D.make_sparse_regression(n, 300, 12, seed=9),
            0.05,
        ),
        (
            "multiclass3",
            "multiclass:3",
            D.make_multiclass_classification(n, 30, 3, seed=11),
            0.2,
        ),
        ("ranking", "lambdarank", D.make_ranking(n // 16, 16, 24, seed=13), 0.2),
    ]


def run(quick: bool = True) -> dict:
    n_trees = 60 if quick else 300
    out: dict = {"n_trees": n_trees, "workers": WORKERS, "objectives": {}}
    for tag, spec, data, step in sweep_cases(quick):
        cfg = SGBDTConfig(
            n_trees=n_trees,
            step_length=step,
            sampling_rate=0.8,
            objective=spec,
            learner=LearnerConfig(depth=4, n_bins=64, feature_fraction=0.9),
        )
        trainer = get_trainer(cfg)
        init_m = train_metrics(cfg, data, init_state(cfg, data))
        serial = train_metrics(cfg, data, trainer.train(data, ("round_robin", 1)))
        asynch = train_metrics(
            cfg, data, trainer.train(data, ("round_robin", WORKERS))
        )
        row = {
            "spec": spec,
            "n_outputs": cfg.obj.n_outputs,
            "init": {k: float(v) for k, v in init_m.items()},
            "serial": {k: float(v) for k, v in serial.items()},
            f"async_w{WORKERS}": {k: float(v) for k, v in asynch.items()},
        }
        out["objectives"][tag] = row
        print(
            f"  {tag:12s} loss {row['init']['loss']:.4f} -> "
            f"serial {row['serial']['loss']:.4f} / "
            f"async{WORKERS} {row[f'async_w{WORKERS}']['loss']:.4f}",
            flush=True,
        )
        assert row["serial"]["loss"] < row["init"]["loss"], tag
        assert row[f"async_w{WORKERS}"]["loss"] < row["init"]["loss"], tag
        # one config per objective — release its Trainer's compiled programs
        # instead of letting the sweep accumulate them.
        clear_trainers()
    save("objective_sweep", out)
    return out


def main(quick: bool = True):
    return run(quick)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
