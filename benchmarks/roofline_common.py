"""Shared roofline harness (EXPERIMENTS.md §Roofline).

One place for the three-term model both roofline benchmarks use:
    compute term    = per-device loop-aware dot FLOPs / 197 TF/s (bf16)
    memory term     = per-device HBM-traffic proxy    / 819 GB/s
    collective term = per-device collective bytes     / 50 GB/s per link
plus the dominant-term bottleneck note that the perf loop iterates on.
``roofline.py`` applies it to the dry-run artifacts of the model zoo;
``gbdt_roofline.py`` applies it to the PS engine's sharded GBDT step.
"""
from __future__ import annotations

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

NOTES = {
    "compute": "compute-bound: raise MXU utilization (tile sizes, fewer "
               "remat recomputes, fuse small dots)",
    "memory": "HBM-bound: fuse elementwise chains, widen blocks, cut "
              "activation dtype to bf16 end-to-end",
    "collective": "collective-bound: hoist FSDP all-gathers out of the "
                  "microbatch loop / cache gathered params, or trade FSDP "
                  "for pure TP on the small-param tensors",
}


def roofline_terms(
    dot_flops: float, hbm_bytes: float, collective_bytes: float
) -> dict:
    """The three per-device time terms + which one dominates."""
    t_compute = dot_flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_coll = collective_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "note": NOTES[dominant],
    }
