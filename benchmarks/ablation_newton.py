"""Ablation: gradient vs Newton (xgboost-style) steps under staleness.

The paper's counter-intuitive conclusion 2: "Only gradient step can use
asynchronous parallel manner. Thus, xgboost cannot be modified into
asynch-parallel manner." Mechanism: the Newton leaf -G/(H+lam) divides by a
curvature estimated at the STALE F^{k(j)}; near the optimum the stale
hessian underestimates p(1-p) drift and the effective step inflates, so
staleness hurts Newton steps disproportionately. The gradient leaf only
rescales by sample counts, which are staleness-independent.

We train both step kinds at matched effective speed (Newton needs no
step-length tuning; gradient uses the same v) and compare the relative
degradation from W=1 to W=16/32.
"""
from __future__ import annotations


from benchmarks.common import paper_cfg, realsim_like, save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import train_loss

WORKERS = [1, 16, 32]


def run(quick: bool = True) -> dict:
    n_trees = 120 if quick else 400
    data = realsim_like(quick)
    out: dict = {"workers": WORKERS, "final_loss": {}}
    for kind in ("gradient", "newton"):
        cfg = paper_cfg(n_trees, 6, sampling_rate=0.8, step=0.3)._replace(
            step_kind=kind
        )
        losses = {}
        for w in WORKERS:
            st = train_async(
                cfg, data, worker_round_robin(n_trees, w), seed=0
            )
            losses[str(w)] = float(train_loss(cfg, data, st))
        out["final_loss"][kind] = losses
        base = losses["1"]
        degr = {w: losses[w] - base for w in losses}
        print(f"  {kind:9s}: " + "  ".join(
            f"W{w}={losses[w]:.4f} (Δ{degr[w]:+.4f})" for w in losses
        ), flush=True)
    g = out["final_loss"]["gradient"]
    n = out["final_loss"]["newton"]
    out["degradation_ratio_w32"] = float(
        (n["32"] - n["1"]) / max(g["32"] - g["1"], 1e-9)
        if (g["32"] - g["1"]) > 0 else (n["32"] - n["1"])
    )
    save("ablation_newton", out)
    return out


def main(quick: bool = True):
    res = run(quick)
    print("\npaper conclusion 2: Newton (xgboost-style) steps should degrade "
          "more under staleness than gradient steps.")
    return res


if __name__ == "__main__":
    main()
