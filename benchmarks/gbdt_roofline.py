"""Beyond the zoo: the paper's own GBDT training step on the production
mesh — lower + compile ``train_async_scan`` with the dataset sharded over
'data' (samples) x 'model' (features), and report its roofline terms.

This is the distributed form of the DimBoost comparison: histogram psum
over data shards replaces the centralized parameter-server aggregation
(the all-reduce happens on ICI instead of through one server NIC).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import save

_CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.async_sgbdt import train_async_scan, worker_round_robin
    from repro.core.sgbdt import SGBDTConfig
    from repro.trees.binning import BinnedData
    from repro.trees.learner import LearnerConfig
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import (
        make_production_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW,
    )

    mesh = make_production_mesh()
    NS = lambda *spec: NamedSharding(mesh, P(*spec))
    N, F, T = 262_144, 2_048, 64
    cfg = SGBDTConfig(
        n_trees=T, step_length=0.1, sampling_rate=0.8,
        learner=LearnerConfig(depth=7, n_bins=64, backend="ref"),
    )
    data_abs = BinnedData(
        bins=jax.ShapeDtypeStruct((N, F), jnp.int32),
        bin_edges=jax.ShapeDtypeStruct((F, 63), jnp.float32),
        labels=jax.ShapeDtypeStruct((N,), jnp.float32),
        multiplicity=jax.ShapeDtypeStruct((N,), jnp.float32),
        n_bins=64,
    )
    data_sh = BinnedData(
        bins=NS("data", "model"),
        bin_edges=NS("model"),
        labels=NS("data"),
        multiplicity=NS("data"),
        n_bins=NS(),
    )
    fn = jax.jit(
        lambda d, s, r: train_async_scan(cfg, d, s, r, ring_size=32),
        in_shardings=(data_sh, NS(), NS()),
    )
    lowered = fn.lower(
        data_abs,
        jax.ShapeDtypeStruct((T,), jnp.int32),
        jax.ShapeDtypeStruct((T, 2), jnp.uint32),
    )
    compiled = lowered.compile()
    st = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {
        "n_samples": N, "n_features": F, "n_trees": T,
        "dot_flops": st.dot_flops,
        "hbm_bytes": st.hbm_bytes,
        "collective_bytes": st.total_collective_bytes,
        "collective_by_kind": {k: v for k, v in st.collective_bytes.items()},
        "compute_s": st.dot_flops / PEAK_FLOPS_BF16,
        "memory_s": st.hbm_bytes / HBM_BW,
        "collective_s": st.total_collective_bytes / ICI_BW,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
    }
    print("GBDT_ROOFLINE_JSON=" + json.dumps(out))
    """
)


def run(quick: bool = True) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1400,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("GBDT_ROOFLINE_JSON="):
            payload = json.loads(line.split("=", 1)[1])
            save("gbdt_roofline", payload)
            dom = max(
                ("compute", payload["compute_s"]),
                ("memory", payload["memory_s"]),
                ("collective", payload["collective_s"]),
                key=lambda kv: kv[1],
            )[0]
            print(f"  GBDT step on 16x16: compute {payload['compute_s']:.3e}s "
                  f"memory {payload['memory_s']:.3e}s "
                  f"collective {payload['collective_s']:.3e}s -> {dom}-bound")
            return payload
    print("  gbdt roofline failed:", proc.stderr[-800:])
    return {"error": proc.stderr[-800:]}


def main(quick: bool = True):
    return run(quick)


if __name__ == "__main__":
    main()
