"""Beyond the zoo: the paper's own GBDT training step on the production
mesh — lower + compile the PS engine's scan form with the dataset sharded
over 'data' (samples) x 'model' (features), and report its roofline terms
through the shared harness (``benchmarks.roofline_common``).

The tree build inside the step is the sharded-histogram path
(``repro.ps.sharded``): every 'data' shard runs the histogram kernel on
its local samples and the level histograms merge with a psum across the
axis — the distributed form of the DimBoost comparison, with the
parameter-server aggregation on ICI instead of one server NIC.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import save
from benchmarks.roofline_common import roofline_terms

_CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core.sgbdt import SGBDTConfig
    from repro.ps import Trainer
    from repro.ps.schedules import max_staleness, worker_round_robin
    from repro.sharding import gbdt_data_specs
    from repro.trees.binning import BinnedData
    from repro.trees.learner import LearnerConfig
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh

    mesh = jax.make_mesh(({mesh_shape}), ("data", "model"))
    N, F, T = {N}, {F}, {T}
    cfg = SGBDTConfig(
        n_trees=T, step_length=0.1, sampling_rate=0.8,
        learner=LearnerConfig(
            depth={depth}, n_bins=64, backend="ref", hist_mode="{hist_mode}"
        ),
    )
    data_abs = BinnedData(
        bins=jax.ShapeDtypeStruct((N, F), jnp.int32),
        bin_edges=jax.ShapeDtypeStruct((F, 63), jnp.float32),
        labels=jax.ShapeDtypeStruct((N,), jnp.float32),
        multiplicity=jax.ShapeDtypeStruct((N,), jnp.float32),
        n_bins=64,
    )
    specs = gbdt_data_specs(mesh, shard_features=True)
    data_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: not isinstance(x, BinnedData),
    )

    trainer = Trainer(cfg, mesh=mesh)       # sharded shard_map+psum builds
    # Lower the W-worker round-robin steady state: ring carries W versions.
    W = {W}
    ring_size = max_staleness(worker_round_robin(T, W)) + 1
    fn = jax.jit(
        lambda d, s, r: trainer.scan_with(d, s, r, ring_size),
        in_shardings=(data_sh, None, None),
    )
    lowered = fn.lower(
        data_abs,
        jax.ShapeDtypeStruct((T,), jnp.int32),
        jax.ShapeDtypeStruct((T, 2), jnp.uint32),
    )
    compiled = lowered.compile()
    st = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {{
        "n_samples": N, "n_features": F, "n_trees": T,
        "dot_flops": st.dot_flops,
        "hbm_bytes": st.hbm_bytes,
        "collective_bytes": st.total_collective_bytes,
        "collective_by_kind": {{k: v for k, v in st.collective_bytes.items()}},
        "temp_gib": mem.temp_size_in_bytes / 2**30,
    }}
    print("GBDT_ROOFLINE_JSON=" + json.dumps(out))
    """
)


def _run_mode(shape: dict, hist_mode: str) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CODE.format(hist_mode=hist_mode, **shape)],
        capture_output=True, text=True, timeout=1400,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("GBDT_ROOFLINE_JSON="):
            payload = json.loads(line.split("=", 1)[1])
            payload.update(roofline_terms(
                payload["dot_flops"], payload["hbm_bytes"],
                payload["collective_bytes"],
            ))
            return payload
    return {"error": proc.stderr[-800:]}


def run(quick: bool = True) -> dict:
    shape = dict(
        n_dev=16, mesh_shape="8, 2", N=32_768, F=256, T=8, depth=5, W=4,
    ) if quick else dict(
        n_dev=256, mesh_shape="16, 16", N=262_144, F=2_048, T=64, depth=7, W=32,
    )
    # One compile per histogram mode: 'subtract' is the production default,
    # the 'rebuild' row quantifies what the subtraction builder saves in
    # the lowered program (hbm/collective bytes; the ref-backend build has
    # no dots, so flop deltas live in kernel_bench's hist_subtract rows).
    modes = {m: _run_mode(shape, m) for m in ("subtract", "rebuild")}
    payload = dict(modes["subtract"])
    payload["hist_modes"] = modes
    sub, reb = modes["subtract"], modes["rebuild"]
    if "error" not in sub and "error" not in reb:
        payload["hist_subtract_hbm_ratio"] = (
            sub["hbm_bytes"] / max(reb["hbm_bytes"], 1)
        )
        payload["hist_subtract_collective_ratio"] = (
            sub["collective_bytes"] / max(reb["collective_bytes"], 1)
        )
        save("gbdt_roofline", payload)
        print(f"  GBDT sharded-histogram step on {shape['mesh_shape']} "
              f"(hist_mode=subtract): "
              f"compute {sub['compute_s']:.3e}s "
              f"memory {sub['memory_s']:.3e}s "
              f"collective {sub['collective_s']:.3e}s "
              f"-> {sub['dominant']}-bound")
        print(f"  vs rebuild: hbm x{payload['hist_subtract_hbm_ratio']:.3f} "
              f"collective x{payload['hist_subtract_collective_ratio']:.3f}")
        return payload
    err = sub.get("error") or reb.get("error")
    print("  gbdt roofline failed:", err)
    save("gbdt_roofline", payload)
    return payload


# ------------------------------------------------- collective-bytes rows
# Trace-time accounting (jax.eval_shape + collectives.ByteRecorder —
# nothing executes, so paper-scale geometries account in seconds): the
# per-tree-build bytes on the wire for the three build shapes of
# DESIGN.md §16. The committed snapshot is BENCH_collectives.json at the
# repo root; check_bench.py --collectives gates it (the numbers are
# DETERMINISTIC, so the gate is exact equality, not a tolerance).
_COLLECTIVES_CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json

    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_gbdt_mesh
    from repro.ps.sharded import collective_bytes_per_build
    from repro.trees.binning import SparseBins
    from repro.trees.learner import LearnerConfig

    N, F, B, E, depth = {N}, {F}, {B}, {E}, {depth}
    cfg = LearnerConfig(
        depth=depth, n_bins=B, backend="ref", hist_mode="subtract"
    )
    dense = jax.ShapeDtypeStruct((N, F), jnp.int32)
    C = max(N * E // F, 1)  # feature-major ELL capacity at this density
    sp = SparseBins(
        indices=jax.ShapeDtypeStruct((N, E), jnp.int32),
        codes=jax.ShapeDtypeStruct((N, E), jnp.int32),
        feat_rows=jax.ShapeDtypeStruct((F, C), jnp.int32),
        feat_codes=jax.ShapeDtypeStruct((F, C), jnp.int32),
        zero_bin=jax.ShapeDtypeStruct((F,), jnp.int32),
    )
    mesh_1d = jax.make_mesh((16,), ("data",))
    mesh_2d = make_gbdt_mesh(1, 16)
    row = {{"geometry": {{
        "N": N, "F": F, "B": B, "depth": depth, "nnz_row": E,
        "hist_mode": "subtract", "shards": 16,
    }}}}
    row["bytes_1d_dense_psum"] = collective_bytes_per_build(
        cfg, mesh_1d, dense
    )["realized_bytes"]
    s2 = collective_bytes_per_build(
        cfg, mesh_2d, dense, feature_axis="feature"
    )
    row["bytes_2d_argmax_merge"] = s2["realized_bytes"]
    row["by_kind_2d"] = s2["realized_by_kind"]
    ss = collective_bytes_per_build(cfg, mesh_2d, sp, feature_axis="feature")
    row["bytes_2d_sparse"] = ss["realized_bytes"]
    row["by_kind_2d_sparse"] = ss["realized_by_kind"]
    row["reduction_dense"] = (
        row["bytes_1d_dense_psum"] / max(row["bytes_2d_argmax_merge"], 1)
    )
    row["reduction_sparse"] = (
        row["bytes_1d_dense_psum"] / max(row["bytes_2d_sparse"], 1)
    )
    print("GBDT_COLLECTIVES_JSON=" + json.dumps(row))
    """
)

# (name, N, F, B, nnz/row, depth) — the acceptance row first, then the
# paper-dataset lookalikes (real-sim ~72K x 21K, E2006 ~16K x 150K).
COLLECTIVE_GEOMETRIES = [
    ("smoke_16k_x_256", 16_384, 256, 64, 64, 7),
    ("realsim_like", 65_536, 20_992, 64, 52, 7),
    ("e2006_like", 16_384, 150_528, 64, 96, 7),
]


def _run_collectives_row(N, F, B, E, depth) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c",
         _COLLECTIVES_CODE.format(N=N, F=F, B=B, E=E, depth=depth)],
        capture_output=True, text=True, timeout=1400,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("GBDT_COLLECTIVES_JSON="):
            return json.loads(line.split("=", 1)[1])
    return {"error": proc.stderr[-800:]}


def collectives(quick: bool = True) -> dict:
    """Measure per-tree-build collective bytes for every geometry row."""
    geoms = COLLECTIVE_GEOMETRIES[:1] if quick else COLLECTIVE_GEOMETRIES
    rows = {}
    for name, N, F, B, E, depth in geoms:
        row = _run_collectives_row(N, F, B, E, depth)
        rows[name] = row
        if "error" in row:
            print(f"  {name}: FAILED {row['error'][:200]}")
            continue
        print(f"  {name} (N={N} F={F} B={B} depth={depth}): "
              f"dense-psum {row['bytes_1d_dense_psum']:,}B "
              f"argmax-merge {row['bytes_2d_argmax_merge']:,}B "
              f"(x{row['reduction_dense']:.0f}) "
              f"sparse {row['bytes_2d_sparse']:,}B "
              f"(x{row['reduction_sparse']:.0f})")
    payload = {"rows": rows}
    save("gbdt_collectives", payload)
    return payload


def main(quick: bool = True):
    out = run(quick)
    out["collectives"] = collectives(quick)["rows"]
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--collectives", action="store_true",
                    help="only the collective-bytes accounting rows")
    args = ap.parse_args()
    if args.collectives:
        collectives(quick=not args.full)
    else:
        main(quick=not args.full)
