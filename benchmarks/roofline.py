"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh): the three-term model from
``benchmarks.roofline_common`` plus MODEL_FLOPS = 6*N_active*D (train) or
2*N_active*D (fwd), and the utilization ratio
MODEL_FLOPS / (dot_flops * n_devices) that exposes remat and
redundant-compute waste. The dominant term is the bottleneck the perf
loop iterates on.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.roofline_common import roofline_terms

DRYRUN_DIR = pathlib.Path("experiments/dryrun")


def terms(rec: dict) -> dict:
    hlo = rec["hlo"]
    out = roofline_terms(
        hlo["dot_flops"], hlo["hbm_bytes"], hlo["total_collective_bytes"]
    )
    n_dev = rec["n_devices"]
    out.update(
        model_flops=rec["model_flops"],
        useful_flops_ratio=rec["model_flops"] / max(hlo["dot_flops"] * n_dev, 1.0),
        hbm_gib_per_dev=(
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
        ) / 2**30,
    )
    return out


def load(mesh: str = "single") -> list[dict]:
    rows = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec["status"] != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec["status"],
                         "reason": rec.get("reason", rec.get("error", ""))})
            continue
        row = {"arch": rec["arch"], "shape": rec["shape"], "status": "ok"}
        row.update(terms(rec))  # includes the bottleneck 'note'
        rows.append(row)
    return rows


def table(mesh: str = "single") -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful-FLOP ratio | GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r['status']}: {r['reason'][:60]} | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['hbm_gib_per_dev']:.2f} |"
        )
    return "\n".join(lines)


def main(quick: bool = True):
    for mesh in ("single", "multi"):
        rows = [r for r in load(mesh) if r["status"] == "ok"]
        if not rows:
            print(f"  ({mesh}: no dry-run artifacts — run repro.launch.dryrun)")
            continue
        dom = {}
        for r in rows:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        print(f"  {mesh}: {len(rows)} pairs; dominant terms: {dom}")
        worst = min(rows, key=lambda r: r["useful_flops_ratio"])
        print(f"  worst useful-FLOP ratio: {worst['arch']}/{worst['shape']} "
              f"= {worst['useful_flops_ratio']:.3f}")
        out = pathlib.Path(f"experiments/roofline_{mesh}.md")
        out.write_text(table(mesh) + "\n")
        print(f"  wrote {out}")
    return load("single")


if __name__ == "__main__":
    main()
