"""Bench-regression gate: diff a fresh BENCH_gbdt.json against the
committed snapshot.

``kernel_bench --check`` already gates fused-vs-staged *within* one run;
this script gates the run against HISTORY — a fresh measurement whose
wall times regressed more than the tolerance vs. the committed snapshot
fails CI, so a kernel change that quietly doubles the fused level-build
cannot land just because it is still faster than the staged pipeline.

Rules per field:
  * ``*_ms`` rows  — fail if fresh > (1 + tolerance) * baseline. Faster is
    always fine (the snapshot is refreshed by the same CI run that
    measures it, so improvements ratchet in).
  * ``smoke_geometry`` — must match exactly: times from a different
    geometry are not comparable, and a silent geometry drift is exactly
    the kind of apples-to-oranges diff this gate exists to catch.
  * ``parity_ok`` — must be true in the fresh run.
  * other numeric fields (speedup, flop ratios) — informational only.

Usage:
    python -m benchmarks.check_bench --baseline BENCH_gbdt.json \
        --fresh experiments/BENCH_gbdt_fresh.json [--max-regression 0.25]
    python -m benchmarks.check_bench --selftest

The default tolerance is deliberately loose (25%): shared CI runners
jitter by tens of percent, and a gate that cries wolf gets deleted. A
real kernel regression (a lost fusion, an accidental O(N^2) path) shows
up as 2-10x, far outside any runner noise.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def compare(baseline: dict, fresh: dict, max_regression: float) -> list[str]:
    """Return a list of human-readable failures (empty = gate passes)."""
    failures: list[str] = []
    base_geo = baseline.get("smoke_geometry")
    fresh_geo = fresh.get("smoke_geometry")
    if base_geo != fresh_geo:
        failures.append(
            f"smoke_geometry changed: baseline {base_geo} vs fresh "
            f"{fresh_geo} — times are not comparable; if the geometry "
            "change is intentional, commit the fresh snapshot"
        )
        return failures  # comparing times across geometries is meaningless
    if not fresh.get("parity_ok", False):
        failures.append("fresh run has parity_ok != true (kernel mismatch)")
    for key, base_val in baseline.items():
        if not key.endswith("_ms"):
            continue
        fresh_val = fresh.get(key)
        if not isinstance(fresh_val, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            continue
        limit = (1.0 + max_regression) * float(base_val)
        if float(fresh_val) > limit:
            failures.append(
                f"{key}: {fresh_val:.2f}ms vs baseline {base_val:.2f}ms "
                f"(+{100 * (fresh_val / base_val - 1):.0f}%, limit "
                f"+{100 * max_regression:.0f}%)"
            )
    return failures


def compare_collectives(baseline: dict, fresh: dict) -> list[str]:
    """Gate the collective-bytes accounting rows (BENCH_collectives.json
    vs a fresh ``benchmarks.gbdt_roofline --collectives`` run).

    Unlike wall times, these numbers are TRACE-TIME accounting
    (``jax.eval_shape`` + a byte recorder) — fully deterministic on any
    host — so the gate is exact equality, not a tolerance band:
      * ``geometry`` rows must match exactly (same reason as
        ``smoke_geometry`` above);
      * every ``bytes_*`` row must match the baseline bit-for-bit — a
        drift means the builder's collective structure changed, and the
        fresh snapshot must be recommitted deliberately;
      * ``reduction_dense`` must stay >= 10 on every row: the 2D
        argmax-merge exists to beat the dense histogram psum by at least
        an order of magnitude (DESIGN.md §16), and ``reduction_sparse``
        must not fall below ``reduction_dense``.
    """
    failures: list[str] = []
    if "smoke_16k_x_256" not in fresh.get("rows", {}):
        failures.append(
            "smoke_16k_x_256: the acceptance row is missing from the fresh "
            "run (even the quick config measures it)"
        )
    for name, base_row in baseline.get("rows", {}).items():
        row = fresh.get("rows", {}).get(name)
        if row is None:
            # quick runs measure only the acceptance row; the full-geometry
            # rows are gated whenever a --full run provides them
            continue
        if "error" in row:
            failures.append(f"{name}: fresh run errored: {row['error'][:200]}")
            continue
        if row.get("geometry") != base_row.get("geometry"):
            failures.append(
                f"{name}: geometry changed: baseline {base_row.get('geometry')} "
                f"vs fresh {row.get('geometry')} — if intentional, commit the "
                "fresh snapshot"
            )
            continue
        for key, base_val in base_row.items():
            if not key.startswith("bytes_"):
                continue
            if row.get(key) != base_val:
                failures.append(
                    f"{name}.{key}: {row.get(key)} vs baseline {base_val} "
                    "(trace-time accounting is deterministic — the collective "
                    "structure of the build changed)"
                )
        red = row.get("reduction_dense", 0.0)
        if red < 10.0:
            failures.append(
                f"{name}: reduction_dense {red:.1f}x < 10x — the argmax "
                "merge no longer beats the dense histogram psum"
            )
        if row.get("reduction_sparse", 0.0) < red:
            failures.append(
                f"{name}: reduction_sparse {row.get('reduction_sparse'):.1f}x "
                f"fell below reduction_dense {red:.1f}x"
            )
    return failures


def compare_serve(baseline: dict, fresh: dict, max_regression: float) -> list[str]:
    """Gate the serving-latency record (``BENCH_serve.json`` vs a fresh
    ``benchmarks.gbdt_serve`` run's ``gate`` object).

    Geometry (batch/trees/depth/dim/bins/SLO) must match exactly; every
    ``*_p99_ms`` row fails if it grew past the tolerance (p50 rows are
    informational — tail latency is the serving contract); and the
    continuous engine must have met its SLO on at least half the
    requests (a broken cut policy serves everything late, which runner
    jitter cannot explain away)."""
    gate = fresh.get("gate", fresh)
    failures: list[str] = []
    if baseline.get("geometry") != gate.get("geometry"):
        failures.append(
            f"geometry changed: baseline {baseline.get('geometry')} vs "
            f"fresh {gate.get('geometry')} — latencies are not comparable; "
            "if intentional, commit the fresh snapshot"
        )
        return failures
    for key, base_val in baseline.items():
        if not key.endswith("_p99_ms"):
            continue
        fresh_val = gate.get(key)
        if not isinstance(fresh_val, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            continue
        limit = (1.0 + max_regression) * float(base_val)
        if float(fresh_val) > limit:
            failures.append(
                f"{key}: {fresh_val:.2f}ms vs baseline {base_val:.2f}ms "
                f"(+{100 * (fresh_val / base_val - 1):.0f}%, limit "
                f"+{100 * max_regression:.0f}%)"
            )
    met = gate.get("engine_slo_met")
    if not isinstance(met, (int, float)) or met < 0.5:
        failures.append(
            f"engine_slo_met {met} < 0.5 — the continuous engine is not "
            "cutting waves inside its latency budget"
        )
    return failures


def selftest(max_regression: float) -> int:
    """Prove the gate trips: inject a synthetic 1.5x regression into a
    copy of the committed snapshot and assert compare() rejects it, and
    that the unmodified snapshot passes against itself."""
    baseline = json.loads(
        (pathlib.Path(__file__).resolve().parents[1] / "BENCH_gbdt.json")
        .read_text()
    )
    clean = compare(baseline, baseline, max_regression)
    if clean:
        print(f"selftest FAILED: snapshot does not pass vs itself: {clean}")
        return 1
    slow = dict(baseline)
    for key, val in baseline.items():
        if key.endswith("_ms"):
            slow[key] = 1.5 * float(val)
    tripped = compare(baseline, slow, max_regression)
    if not tripped:
        print("selftest FAILED: a 1.5x wall-time regression passed the gate")
        return 1
    geo = dict(baseline)
    geo["smoke_geometry"] = dict(baseline["smoke_geometry"], n=1)
    if not compare(baseline, geo, max_regression):
        print("selftest FAILED: a geometry mismatch passed the gate")
        return 1

    coll = json.loads(
        (pathlib.Path(__file__).resolve().parents[1]
         / "BENCH_collectives.json").read_text()
    )
    if compare_collectives(coll, coll):
        print("selftest FAILED: collectives snapshot does not pass vs itself")
        return 1
    drift = json.loads(json.dumps(coll))
    first = next(iter(drift["rows"]))
    drift["rows"][first]["bytes_2d_argmax_merge"] += 4
    if not compare_collectives(coll, drift):
        print("selftest FAILED: a collective-bytes drift passed the gate")
        return 1
    weak = json.loads(json.dumps(coll))
    row = weak["rows"][first]
    row["bytes_2d_argmax_merge"] = row["bytes_1d_dense_psum"] // 2
    row["reduction_dense"] = 2.0
    if not any("reduction_dense" in f
               for f in compare_collectives(coll, weak)):
        print("selftest FAILED: a sub-10x argmax merge passed the gate")
        return 1
    serve = json.loads(
        (pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json")
        .read_text()
    )
    if compare_serve(serve, serve, max_regression):
        print("selftest FAILED: serve snapshot does not pass vs itself")
        return 1
    slow_serve = dict(serve)
    for key, val in serve.items():
        if key.endswith("_p99_ms"):
            slow_serve[key] = 1.5 * float(val)
    if not compare_serve(serve, slow_serve, max_regression):
        print("selftest FAILED: a 1.5x serving-p99 regression passed the gate")
        return 1
    late = dict(serve)
    late["engine_slo_met"] = 0.1
    if not any("slo_met" in f for f in compare_serve(serve, late, max_regression)):
        print("selftest FAILED: a 10%-SLO-met engine passed the gate")
        return 1
    serve_geo = dict(serve)
    serve_geo["geometry"] = dict(serve["geometry"], batch=1)
    if not compare_serve(serve, serve_geo, max_regression):
        print("selftest FAILED: a serving geometry mismatch passed the gate")
        return 1

    print(f"selftest ok: injected +50% regression trips "
          f"({len(tripped)} rows), geometry drift trips, collective-bytes "
          f"drift trips, sub-10x reduction trips, serving p99/SLO/geometry "
          f"injections trip, clean diffs pass")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_gbdt.json",
                    help="committed snapshot to gate against")
    ap.add_argument("--fresh", default="experiments/BENCH_gbdt_fresh.json",
                    help="freshly measured snapshot (same schema)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional wall-time growth per _ms row")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate trips on an injected regression")
    ap.add_argument("--collectives", action="store_true",
                    help="gate collective-bytes rows (exact match + >=10x "
                         "reduction) instead of wall-time rows")
    ap.add_argument("--serve", action="store_true",
                    help="gate serving p99 latency + SLO attainment "
                         "(BENCH_serve.json vs a fresh gbdt_serve run)")
    args = ap.parse_args()
    if args.selftest:
        return selftest(args.max_regression)
    baseline = json.loads(pathlib.Path(args.baseline).read_text())
    fresh = json.loads(pathlib.Path(args.fresh).read_text())
    if args.serve:
        failures = compare_serve(baseline, fresh, args.max_regression)
        if failures:
            print("serving latency gate FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        gate = fresh.get("gate", fresh)
        p99s = {k: f"{gate[k]:.1f}ms" for k in gate if k.endswith("_p99_ms")}
        print(f"serving latency gate ok (<= +{100 * args.max_regression:.0f}% "
              f"vs baseline, SLO met on {100 * gate['engine_slo_met']:.0f}%): "
              f"{p99s}")
        return 0
    if args.collectives:
        failures = compare_collectives(baseline, fresh)
        if failures:
            print("collective-bytes gate FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        reds = {name: f"x{row['reduction_dense']:.0f}"
                for name, row in fresh.get("rows", {}).items()}
        print(f"collective-bytes gate ok (exact match, reductions {reds})")
        return 0
    failures = compare(baseline, fresh, args.max_regression)
    if failures:
        print("bench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    ms = {k: f"{fresh[k]:.1f}ms" for k in fresh if k.endswith("_ms")}
    print(f"bench regression gate ok (<= +{100 * args.max_regression:.0f}% "
          f"vs baseline): {ms}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
