"""Bench-regression gate: diff a fresh BENCH_gbdt.json against the
committed snapshot.

``kernel_bench --check`` already gates fused-vs-staged *within* one run;
this script gates the run against HISTORY — a fresh measurement whose
wall times regressed more than the tolerance vs. the committed snapshot
fails CI, so a kernel change that quietly doubles the fused level-build
cannot land just because it is still faster than the staged pipeline.

Rules per field:
  * ``*_ms`` rows  — fail if fresh > (1 + tolerance) * baseline. Faster is
    always fine (the snapshot is refreshed by the same CI run that
    measures it, so improvements ratchet in).
  * ``smoke_geometry`` — must match exactly: times from a different
    geometry are not comparable, and a silent geometry drift is exactly
    the kind of apples-to-oranges diff this gate exists to catch.
  * ``parity_ok`` — must be true in the fresh run.
  * other numeric fields (speedup, flop ratios) — informational only.

Usage:
    python -m benchmarks.check_bench --baseline BENCH_gbdt.json \
        --fresh experiments/BENCH_gbdt_fresh.json [--max-regression 0.25]
    python -m benchmarks.check_bench --selftest

The default tolerance is deliberately loose (25%): shared CI runners
jitter by tens of percent, and a gate that cries wolf gets deleted. A
real kernel regression (a lost fusion, an accidental O(N^2) path) shows
up as 2-10x, far outside any runner noise.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def compare(baseline: dict, fresh: dict, max_regression: float) -> list[str]:
    """Return a list of human-readable failures (empty = gate passes)."""
    failures: list[str] = []
    base_geo = baseline.get("smoke_geometry")
    fresh_geo = fresh.get("smoke_geometry")
    if base_geo != fresh_geo:
        failures.append(
            f"smoke_geometry changed: baseline {base_geo} vs fresh "
            f"{fresh_geo} — times are not comparable; if the geometry "
            "change is intentional, commit the fresh snapshot"
        )
        return failures  # comparing times across geometries is meaningless
    if not fresh.get("parity_ok", False):
        failures.append("fresh run has parity_ok != true (kernel mismatch)")
    for key, base_val in baseline.items():
        if not key.endswith("_ms"):
            continue
        fresh_val = fresh.get(key)
        if not isinstance(fresh_val, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            continue
        limit = (1.0 + max_regression) * float(base_val)
        if float(fresh_val) > limit:
            failures.append(
                f"{key}: {fresh_val:.2f}ms vs baseline {base_val:.2f}ms "
                f"(+{100 * (fresh_val / base_val - 1):.0f}%, limit "
                f"+{100 * max_regression:.0f}%)"
            )
    return failures


def selftest(max_regression: float) -> int:
    """Prove the gate trips: inject a synthetic 1.5x regression into a
    copy of the committed snapshot and assert compare() rejects it, and
    that the unmodified snapshot passes against itself."""
    baseline = json.loads(
        (pathlib.Path(__file__).resolve().parents[1] / "BENCH_gbdt.json")
        .read_text()
    )
    clean = compare(baseline, baseline, max_regression)
    if clean:
        print(f"selftest FAILED: snapshot does not pass vs itself: {clean}")
        return 1
    slow = dict(baseline)
    for key, val in baseline.items():
        if key.endswith("_ms"):
            slow[key] = 1.5 * float(val)
    tripped = compare(baseline, slow, max_regression)
    if not tripped:
        print("selftest FAILED: a 1.5x wall-time regression passed the gate")
        return 1
    geo = dict(baseline)
    geo["smoke_geometry"] = dict(baseline["smoke_geometry"], n=1)
    if not compare(baseline, geo, max_regression):
        print("selftest FAILED: a geometry mismatch passed the gate")
        return 1
    print(f"selftest ok: injected +50% regression trips "
          f"({len(tripped)} rows), geometry drift trips, clean diff passes")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_gbdt.json",
                    help="committed snapshot to gate against")
    ap.add_argument("--fresh", default="experiments/BENCH_gbdt_fresh.json",
                    help="freshly measured snapshot (same schema)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional wall-time growth per _ms row")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate trips on an injected regression")
    args = ap.parse_args()
    if args.selftest:
        return selftest(args.max_regression)
    baseline = json.loads(pathlib.Path(args.baseline).read_text())
    fresh = json.loads(pathlib.Path(args.fresh).read_text())
    failures = compare(baseline, fresh, args.max_regression)
    if failures:
        print("bench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    ms = {k: f"{fresh[k]:.1f}ms" for k in fresh if k.endswith("_ms")}
    print(f"bench regression gate ok (<= +{100 * args.max_regression:.0f}% "
          f"vs baseline): {ms}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
