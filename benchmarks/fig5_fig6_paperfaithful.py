"""Paper-faithful Figs. 5/6: v = 0.01, 400 trees, held-out evaluation.

This is the configuration under which the paper's C1 claim reproduces
INCLUDING direction (see EXPERIMENTS.md §Validity): on held-out loss,
asynchrony is free on the high-diversity sparse dataset and degrades
monotonically with worker count on the low-diversity dense dataset.

Slow (~6 full 400-tree runs); not part of the default benchmark suite —
run explicitly:  PYTHONPATH=src python -m benchmarks.fig5_fig6_paperfaithful
"""
from __future__ import annotations


import repro.data as D
from benchmarks.common import save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import SGBDTConfig, train_loss
from repro.trees import forest_predict
from repro.trees.learner import LearnerConfig
from repro.trees.losses import logistic_loss

WORKERS = [1, 16, 32]


def run(quick: bool = False) -> dict:
    n_trees = 100 if quick else 400
    out: dict = {}
    for tag, data_all, depth in [
        ("realsim", D.make_sparse_classification(4000, 1500, 25, seed=7), 7),
        ("higgs", D.make_dense_low_diversity(300, 28, 60000, seed=11), 5),
    ]:
        n = data_all.n_samples
        ntr = int(n * 0.8)
        tr = data_all._replace(
            bins=data_all.bins[:ntr], labels=data_all.labels[:ntr],
            multiplicity=data_all.multiplicity[:ntr],
        )
        te_b, te_y = data_all.bins[ntr:], data_all.labels[ntr:]
        cfg = SGBDTConfig(
            n_trees=n_trees, step_length=0.01, sampling_rate=0.8,
            learner=LearnerConfig(depth=depth, n_bins=64, feature_fraction=0.8),
        )
        for w in WORKERS:
            st = train_async(cfg, tr, worker_round_robin(n_trees, w), seed=0)
            trl = float(train_loss(cfg, tr, st))
            tel = float(logistic_loss(te_y, forest_predict(st.forest, te_b)))
            out[f"{tag}_W{w}"] = {"train": trl, "test": tel}
            print(f"  {tag} W={w:3d}: train {trl:.4f} test {tel:.4f}", flush=True)
    save("fig56_paperfaithful", out)
    return out


def main(quick: bool = False):
    res = run(quick)
    print("\npaper C1: realsim test loss flat in W; higgs test loss rises "
          "monotonically with W.")
    return res


if __name__ == "__main__":
    main()
