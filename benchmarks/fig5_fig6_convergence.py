"""Paper Figs. 5 & 6: convergence vs number of workers, fixed sampling rate.

Fig. 5 (Higgs, low diversity): more workers => visibly slower per-epoch
convergence. Fig. 6 (real-sim, high diversity): worker count barely moves
the curve. Workers are executed exactly as delay schedules k(j) = j - W + 1
(threads-as-workers steady state, the paper's validity-experiment setup).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import higgs_like, paper_cfg, realsim_like, save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import train_loss

WORKERS = [1, 4, 8, 16, 32]


def run(quick: bool = True) -> dict:
    n_trees = 120 if quick else 400
    out: dict = {"workers": WORKERS, "n_trees": n_trees, "curves": {}}
    for tag, data, depth, rate in [
        ("fig6_realsim", realsim_like(quick), 6, 0.5),
        ("fig5_higgs", higgs_like(quick), 4, 0.5),
    ]:
        cfg = paper_cfg(n_trees, depth, sampling_rate=rate)
        curves = {}
        for w in WORKERS:
            losses: list[float] = []
            train_async(
                cfg, data, worker_round_robin(n_trees, w), seed=0,
                eval_every=max(n_trees // 20, 1),
                eval_fn=lambda st, j: losses.append(
                    float(train_loss(cfg, data, st))
                ),
            )
            curves[str(w)] = losses
            print(f"  {tag} W={w:3d}: final loss {losses[-1]:.4f}", flush=True)
        out["curves"][tag] = curves
        # sensitivity index: area between the W curve and the W=1 curve
        base = np.asarray(curves["1"])
        out.setdefault("sensitivity", {})[tag] = {
            str(w): float(np.mean(np.asarray(curves[str(w)]) - base))
            for w in WORKERS
        }
    save("fig5_fig6_convergence", out)
    return out


def main(quick: bool = True):
    res = run(quick)
    s = res["sensitivity"]
    print("\nsensitivity to workers (mean loss gap vs W=1; paper: higgs >> realsim)")
    for tag in s:
        print(f"  {tag}: " + " ".join(f"W{w}={v:+.4f}" for w, v in s[tag].items()))
    return res


if __name__ == "__main__":
    main()
