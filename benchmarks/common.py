"""Shared benchmark plumbing: dataset registry, timing, result sink."""
from __future__ import annotations

import json
import pathlib
import time

import jax

import repro.data as D
from repro.core.sgbdt import SGBDTConfig
from repro.trees.learner import LearnerConfig

OUT_DIR = pathlib.Path("experiments")


def save(name: str, payload: dict) -> pathlib.Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    p = OUT_DIR / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def realsim_like(quick: bool = True):
    """High-dimensional sparse classification (the paper's real-sim role)."""
    if quick:
        return D.make_sparse_classification(2_000, 800, 20, seed=7)
    return D.make_sparse_classification(8_000, 3_000, 40, seed=7)


def higgs_like(quick: bool = True):
    """Dense low-diversity classification (the paper's Higgs role)."""
    if quick:
        return D.make_dense_low_diversity(120, 28, 20_000, seed=11)
    return D.make_dense_low_diversity(400, 28, 120_000, seed=11)


def e2006_like(quick: bool = True):
    """Sparse high-dim regression (the paper's E2006-log1p role)."""
    if quick:
        return D.make_sparse_regression(1_500, 1_000, 25, seed=13)
    return D.make_sparse_regression(6_000, 4_000, 40, seed=13)


def paper_cfg(n_trees: int, depth: int, loss: str = "logistic",
              sampling_rate: float = 0.8, step: float = 0.1,
              objective: str | None = None) -> SGBDTConfig:
    """The paper's validity-experiment settings, scaled: 400 trees / 100
    leaves -> quick variants keep the same ratios. ``objective`` takes any
    registry spec and supersedes the legacy ``loss`` string."""
    return SGBDTConfig(
        n_trees=n_trees,
        step_length=step,
        sampling_rate=sampling_rate,
        loss=loss,
        objective=objective,
        learner=LearnerConfig(depth=depth, n_bins=64, feature_fraction=0.8),
    )


def time_call(fn, *args, reps: int = 3, **kw) -> tuple[float, object]:
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out
