"""Ablation: Proposition 1's step-length law, measured.

Prop. 1 prescribes v ∝ 1 / (1 + 6ρτ + O(τ²)): the maximum step length that
keeps asynchrony harmless shrinks roughly hyperbolically with staleness τ.
Measurement: over a grid of step lengths v, call (v, W) *stable* when the
W-worker run's final loss is within 10% (of the achievable improvement) of
the SAME-v serial run — i.e. staleness cost ≈ 0 at that step size. For each
W, report the largest stable v; fit ρ to the decay and report the residual.
"""
from __future__ import annotations

import numpy as np

import repro.data as D
from benchmarks.common import paper_cfg, save
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import init_state, train_loss

WORKERS = [1, 2, 4, 8, 16, 32]
STEPS = [0.05, 0.1, 0.2, 0.4, 0.8, 1.2, 1.8, 2.5]


def run(quick: bool = True) -> dict:
    n_trees = 80 if quick else 200
    data = D.make_sparse_classification(1_200, 400, 12, seed=5)
    base = paper_cfg(n_trees, 5, sampling_rate=0.8)
    l0 = float(train_loss(base, data, init_state(base, data)))

    # serial reference per step length
    serial = {}
    for v in STEPS:
        cfg = base._replace(step_length=v)
        st = train_async(cfg, data, worker_round_robin(n_trees, 1), seed=0)
        serial[v] = float(train_loss(cfg, data, st))

    vmax: dict[int, float] = {}
    grid: dict[str, dict] = {}
    for w in WORKERS:
        best = 0.0
        grid[str(w)] = {}
        for v in STEPS:
            cfg = base._replace(step_length=v)
            st = train_async(cfg, data, worker_round_robin(n_trees, w), seed=0)
            lw = float(train_loss(cfg, data, st))
            slack = 0.10 * max(l0 - serial[v], 1e-9)
            stable = np.isfinite(lw) and lw <= serial[v] + slack
            grid[str(w)][str(v)] = {"loss": lw, "stable": bool(stable)}
            if stable:
                best = max(best, v)
        vmax[w] = best
        print(f"  W={w:3d}: max stable step = {best:.2f}", flush=True)

    v0 = max(vmax[1], 1e-9)
    taus = np.array([w - 1 for w in WORKERS if w > 1], float)
    ratios = np.array([vmax[w] / v0 for w in WORKERS if w > 1])
    ok = ratios > 0
    rho = (
        float(np.mean(((1.0 / ratios[ok]) - 1.0) / (6.0 * taus[ok])))
        if ok.any() else 0.0
    )
    pred = 1.0 / (1.0 + 6.0 * rho * taus)
    resid = float(np.max(np.abs(pred[ok] - ratios[ok]))) if ok.any() else 1.0
    monotone = all(
        vmax[a] >= vmax[b] - 1e-9 for a, b in zip(WORKERS, WORKERS[1:])
    )

    out = {
        "workers": WORKERS,
        "steps_grid": STEPS,
        "max_stable_step": {str(w): vmax[w] for w in WORKERS},
        "serial_loss_by_step": {str(v): serial[v] for v in STEPS},
        "grid": grid,
        "fitted_rho": rho,
        "max_abs_residual": resid,
        "monotone_decreasing": monotone,
    }
    save("ablation_prop1", out)
    return out


def main(quick: bool = True):
    res = run(quick)
    print(f"\nmax stable step: " + "  ".join(
        f"W{w}={res['max_stable_step'][str(w)]:.2f}" for w in res["workers"]
    ))
    print(f"monotone decreasing: {res['monotone_decreasing']}; "
          f"fitted rho = {res['fitted_rho']:.3f} "
          f"(residual {res['max_abs_residual']:.3f})")
    print("expected (Prop. 1): the stable-step ceiling falls with worker "
          "count, ~1/(1+6*rho*tau).")
    return res


if __name__ == "__main__":
    main()
