"""Benchmark entrypoint — one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig10,...]

Artifacts land in experiments/*.json; summaries print as they finish.
"""
from __future__ import annotations

import argparse
import pathlib
import time


BENCHES = [
    ("fig5_fig6", "benchmarks.fig5_fig6_convergence",
     "convergence vs workers (Figs. 5-6)"),
    ("fig7_fig8", "benchmarks.fig7_fig8_sampling_sensitivity",
     "sampling-rate sensitivity (Figs. 7-8)"),
    ("fig9", "benchmarks.fig9_extreme_sampling",
     "extreme small sampling rate (Fig. 9)"),
    ("fig10", "benchmarks.fig10_speedup",
     "speedup vs fork-join baselines (Fig. 10 / Eq. 13)"),
    ("ablation_newton", "benchmarks.ablation_newton",
     "gradient vs Newton steps under staleness (paper conclusion 2)"),
    ("ablation_prop1", "benchmarks.ablation_prop1",
     "max stable step length vs staleness (Prop. 1 law)"),
    ("kernels", "benchmarks.kernel_bench", "kernel micro-bench"),
    ("gbdt_roofline", "benchmarks.gbdt_roofline",
     "distributed GBDT step roofline (16x16 mesh)"),
    ("roofline", "benchmarks.roofline",
     "arch-zoo roofline from dry-run artifacts"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (quick mode is the default)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib

    # Harnesses assume the artifact sink exists even before the first
    # save(); cheap to guarantee here (e.g. a fresh clone, a CI runner).
    pathlib.Path("experiments").mkdir(parents=True, exist_ok=True)

    t00 = time.time()
    failures = []
    skipped = []
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(module).main(quick=not args.full)
        except ImportError as e:
            # Optional deps (plotting, profiling) missing from the host is
            # not a benchmark failure — record the skip and keep going.
            skipped.append(name)
            print(f"  SKIPPED: missing dependency "
                  f"({getattr(e, 'name', None) or e})")
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append(name)
            print(f"  FAILED: {type(e).__name__}: {e}")
        print(f"  ({time.time() - t0:.1f}s)", flush=True)
    print(f"\nall benchmarks done in {time.time() - t00:.1f}s; "
          f"skipped: {skipped or 'none'}; failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
