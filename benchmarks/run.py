"""Benchmark entrypoint — one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig10,...]

Artifacts land in experiments/*.json; summaries print as they finish.
"""
from __future__ import annotations

import argparse
import time


BENCHES = [
    ("fig5_fig6", "benchmarks.fig5_fig6_convergence",
     "convergence vs workers (Figs. 5-6)"),
    ("fig7_fig8", "benchmarks.fig7_fig8_sampling_sensitivity",
     "sampling-rate sensitivity (Figs. 7-8)"),
    ("fig9", "benchmarks.fig9_extreme_sampling",
     "extreme small sampling rate (Fig. 9)"),
    ("fig10", "benchmarks.fig10_speedup",
     "speedup vs fork-join baselines (Fig. 10 / Eq. 13)"),
    ("ablation_newton", "benchmarks.ablation_newton",
     "gradient vs Newton steps under staleness (paper conclusion 2)"),
    ("ablation_prop1", "benchmarks.ablation_prop1",
     "max stable step length vs staleness (Prop. 1 law)"),
    ("kernels", "benchmarks.kernel_bench", "kernel micro-bench"),
    ("gbdt_roofline", "benchmarks.gbdt_roofline",
     "distributed GBDT step roofline (16x16 mesh)"),
    ("roofline", "benchmarks.roofline",
     "arch-zoo roofline from dry-run artifacts"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (quick mode is the default)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib

    t00 = time.time()
    failures = []
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(module).main(quick=not args.full)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append(name)
            print(f"  FAILED: {type(e).__name__}: {e}")
        print(f"  ({time.time() - t0:.1f}s)", flush=True)
    print(f"\nall benchmarks done in {time.time() - t00:.1f}s; "
          f"failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
