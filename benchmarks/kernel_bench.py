"""Kernel micro-benchmarks: jnp oracle vs Pallas (interpret mode on CPU).

Interpret-mode wall times do NOT reflect TPU performance — the meaningful
artifacts are (a) correctness at benchmark scale, (b) the ref-backend CPU
time that parameterizes the Fig. 10 component model, and (c) the kernels'
arithmetic-intensity table (bytes/flops per tile) used by the roofline.

The `fused_level` section is the exception: fused-vs-staged compares two
Pallas programs under the SAME interpreter, so the ratio measures what the
fusion actually removes (per-stage dispatch + the staged intermediates),
and it is the ratio CI gates on. This run also REGENERATES the committed
autotuner table (src/repro/kernels/tuning_table.json) and the top-level
BENCH_gbdt.json snapshot:

    PYTHONPATH=src python -m benchmarks.kernel_bench [--full] [--check]
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, time_call
from repro.kernels import autotune, ops
from repro.kernels.ref import level_build_ref

CASES = [
    # (n, f, n_bins, n_nodes)
    (4_096, 128, 64, 8),
    (16_384, 256, 64, 32),
    (65_536, 64, 64, 64),
]

# The fused-vs-staged comparison geometries. The first row is the CI smoke
# geometry (small enough for a PR gate); the second is the contractual
# 16K x 256 win the tuning table must witness.
FUSED_CASES = [
    (4_096, 128, 64, 8),
    (16_384, 256, 64, 32),
]

BENCH_SNAPSHOT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_gbdt.json"

# CI gate: fused must not be slower than (1 + slack) x staged at the smoke
# geometry. Fused runs ~5x FASTER under the interpreter, so tripping this
# means the fusion itself broke, not timing noise.
REGRESSION_SLACK = 0.10


def hist_intensity(n, f, n_bins, n_nodes, sample_block=512, feature_block=8):
    """Analytic FLOPs/bytes per histogram kernel invocation (MXU path)."""
    rows = 2 * n_nodes
    flops = 2.0 * rows * n * f * n_bins  # dense one-hot contraction
    bytes_in = n * f * 4 + 3 * n * 4  # bins + node/grad/hess
    bytes_out = rows * f * n_bins * 4
    return flops, bytes_in + bytes_out


def tree_hist_rows(depth: int, mode: str) -> int:
    """Node-histograms built per tree: rebuild histograms every node of
    every level (2^d - 1); subtract builds the root plus one child per
    parent below it (2^(d-1))."""
    if mode == "rebuild":
        return (1 << depth) - 1
    return 1 + sum(1 << (level - 1) for level in range(1, depth))


def _per_tree_hist_fn(mode: str, backend: str, depth: int, n_bins: int):
    """All of one tree's level-histogram kernel calls as a single jitted
    program (random fixed node ids per level stand in for the routing;
    the kernel cost depends only on the row count, not which nodes)."""

    @jax.jit
    def run_levels(bins, g, h, level_nodes):
        total = 0.0
        for level in range(depth):
            n_nodes = 1 << level
            node = level_nodes[level]
            if mode == "rebuild" or level == 0:
                hist = ops.build_histogram(
                    bins, node, g, h, n_nodes, n_bins, backend=backend
                )
            else:
                active = 2 * jnp.arange(n_nodes // 2, dtype=jnp.int32)
                hist = ops.build_histogram_subset(
                    bins, node, g, h, active, n_nodes, n_bins, backend=backend
                )
            total = total + jnp.sum(hist)  # keep every level live
        return total

    return run_levels


def run_hist_subtract(quick: bool = True) -> dict:
    """The `hist_subtract` rows: per-tree histogram kernel work at depth 7,
    subtraction builder vs full rebuild.

    The contractual number is the MXU work model: kernel cost is linear
    in GH rows, so subtract/rebuild = 64/127 node-histograms = 0.504
    (exact, `hist_flops_*`). CPU wall times bracket it from above:

      * `pallas` — the real kernel program; on CPU the row-independent
        one-hot factor construction (VPU work the MXU overlaps on real
        hardware) dilutes the dot saving, so the measured ratio lands
        between the flop ratio and 1 and shrinks with scale;
      * `ref` — segment_sum scatters all N*F entries regardless of the
        node subset: ~1.0 by construction. Listed so nobody mistakes the
        oracle backend for the optimized path.
    """
    depth, n_bins = 7, 64

    def measure(backend: str, n: int, f: int) -> dict:
        key = jax.random.PRNGKey(7)
        k1, k2, k3 = jax.random.split(key, 3)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        g = jax.random.normal(k2, (n,))
        h = jax.random.uniform(k3, (n,))
        level_nodes = [
            jax.random.randint(jax.random.PRNGKey(100 + level), (n,), 0,
                               1 << level, dtype=jnp.int32)
            for level in range(depth)
        ]
        times = {}
        for mode in ("rebuild", "subtract"):
            fn = _per_tree_hist_fn(mode, backend, depth, n_bins)
            t, _ = time_call(lambda: fn(bins, g, h, level_nodes))
            times[mode] = t
        print(f"  hist_subtract[{backend}] depth={depth} N={n} F={f}: "
              f"rebuild {times['rebuild']*1e3:.1f}ms "
              f"subtract {times['subtract']*1e3:.1f}ms "
              f"(time x{times['subtract']/times['rebuild']:.2f})", flush=True)
        return {
            "n": n, "f": f,
            "rebuild_ms": times["rebuild"] * 1e3,
            "subtract_ms": times["subtract"] * 1e3,
            "time_ratio": times["subtract"] / times["rebuild"],
        }

    rows = {m: tree_hist_rows(depth, m) for m in ("rebuild", "subtract")}
    n_model, f_model = (16_384, 64)
    flops = {m: 2.0 * (2 * r) * n_model * f_model * n_bins
             for m, r in rows.items()}
    out = {
        "depth": depth, "n_bins": n_bins, "n": n_model, "f": f_model,
        "node_hists_rebuild": rows["rebuild"],
        "node_hists_subtract": rows["subtract"],
        "hist_flops_rebuild": flops["rebuild"],
        "hist_flops_subtract": flops["subtract"],
        "flop_ratio": flops["subtract"] / flops["rebuild"],
        "measured": {
            "pallas": measure("pallas", *((2_048, 8) if quick else (16_384, 64))),
            "ref": measure("ref", *((4_096, 16) if quick else (16_384, 64))),
        },
    }
    print(f"  hist_subtract kernel-work model: {rows['subtract']}/"
          f"{rows['rebuild']} node-histograms = x{out['flop_ratio']:.3f} "
          f"MXU flops per tree", flush=True)
    return out


def staged_level_hbm_bytes(n: int, f: int, b: int, l: int) -> int:
    """Modeled HBM traffic of ONE staged level: input stream, the histogram
    round-trip into the split kernel, the gain round-trip into the argmax,
    and the partition's gathers. The 4*L*F*B floats of intermediates are
    exactly what the fused program keeps in VMEM."""
    fp32 = 4
    stream = (n * f + 3 * n) * fp32  # bins + node/grad/hess, read once
    hist = 2 * l * f * b * fp32  # histogram: kernel out + scan in
    gain = l * f * b * fp32  # gain surface: kernel out + argmax in
    partition = 3 * n * fp32  # bins-column gather + node read/write
    return stream + 2 * hist + 2 * gain + partition


def fused_level_hbm_bytes(n: int, f: int, b: int, l: int) -> int:
    """Modeled HBM traffic of ONE fused level. The histogram/gain staging
    is gone; the price is that the partition phase re-streams the row
    blocks (the split feature is dynamic, so whole blocks flow again).
    Net savings therefore need 4*L*F*B > N*F + 3*N - 2*N — deep levels
    win on bytes, every level wins on dispatches (1 program vs 2 kernels
    + 2 jnp stages). Both columns are reported so the crossover is
    visible rather than implied."""
    fp32 = 4
    stream = 2 * (n * f + 3 * n) * fp32  # phases A and C both stream rows
    hist_out = 2 * l * f * b * fp32  # the next level's subtraction cache
    return stream + hist_out + n * fp32  # + the re-routed node map


def _staged_level_fn(n_nodes: int, n_bins: int):
    """The staged pipeline as one jitted program — the fair baseline: the
    same work the fused kernel absorbs, with its HBM round-trips intact."""

    @jax.jit
    def staged(bins, node, g, h):
        hist = ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                   backend="pallas")
        gain = ops.split_gain(hist, 1.0, 1e-3, backend="pallas")
        flat = gain.reshape(n_nodes, -1)
        idx = jnp.argmax(flat, axis=-1)
        best = jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]
        feat = (idx // n_bins).astype(jnp.int32)
        thr = (idx % n_bins).astype(jnp.int32)
        ok = jnp.isfinite(best) & (best > 0.0)
        feat = jnp.where(ok, feat, 0)
        thr = jnp.where(ok, thr, n_bins - 1)
        val = jnp.take_along_axis(
            bins, jnp.take(feat, node)[:, None], axis=1)[:, 0]
        return hist, feat, thr, 2 * node + (val > jnp.take(thr, node)).astype(
            jnp.int32)

    return staged


def run_fused_level(quick: bool = True, retune: bool = True) -> dict:
    """Fused-vs-staged per-level rows + the tuning-table regeneration.

    Per geometry: sweep the autotuner grid (winners merged into the
    committed ``tuning_table.json`` when ``retune``), then time the staged
    pipeline against the fused program at its autotuned blocks, checking
    the fused outputs against the jnp oracle."""
    rows = []
    entries: dict[str, dict] = {}
    for n, f, n_bins, n_nodes in FUSED_CASES[: 1 if quick else len(FUSED_CASES)]:
        key = jax.random.PRNGKey(42)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        node = jax.random.randint(k2, (n,), 0, n_nodes, dtype=jnp.int32)
        g = jax.random.normal(k3, (n,))
        h = jax.random.uniform(k4, (n,))

        entry, _ = autotune.sweep_level_build(
            bins, node, g, h, n_nodes, n_bins, reps=2 if quick else 3)
        gkey = autotune.geometry_key(n, f, n_bins, n_nodes)
        entries[gkey] = entry

        staged = _staged_level_fn(n_nodes, n_bins)
        t_staged, (h_st, f_st, t_st, nn_st) = time_call(
            lambda: staged(bins, node, g, h))

        active = jnp.arange(n_nodes, dtype=jnp.int32)
        mask = jnp.ones((f,), jnp.float32)
        sb, fb = entry["sample_block"], entry["feature_block"]
        t_fused, (h_fu, f_fu, t_fu, _, nn_fu) = time_call(
            lambda: ops.level_build(
                bins, node, g, h, active, None, mask, 1.0, 1e-3,
                n_nodes, n_bins, backend="fused",
                sample_block=sb, feature_block=fb))

        _, f_rf, t_rf, _, nn_rf = level_build_ref(
            bins, node, g, h, active, None, mask, 1.0, 1e-3,
            n_nodes, n_bins)
        parity = bool(
            np.array_equal(np.asarray(f_fu), np.asarray(f_rf))
            and np.array_equal(np.asarray(t_fu), np.asarray(t_rf))
            and np.array_equal(np.asarray(nn_fu), np.asarray(nn_rf))
            and np.array_equal(np.asarray(f_fu), np.asarray(f_st))
            and np.array_equal(np.asarray(nn_fu), np.asarray(nn_st))
        )

        row = {
            "n": n, "f": f, "n_bins": n_bins, "n_nodes": n_nodes,
            "staged_ms": t_staged * 1e3,
            "fused_ms": t_fused * 1e3,
            "speedup": t_staged / t_fused,
            "staged_hbm_bytes": staged_level_hbm_bytes(n, f, n_bins, n_nodes),
            "fused_hbm_bytes": fused_level_hbm_bytes(n, f, n_bins, n_nodes),
            "sample_block": sb, "feature_block": fb,
            "node_block": entry["node_block"],
            "parity_ok": parity,
        }
        rows.append(row)
        print(f"  fused_level N={n} F={f} L={n_nodes}: staged "
              f"{row['staged_ms']:.0f}ms fused {row['fused_ms']:.0f}ms "
              f"(x{row['speedup']:.2f}, blocks sb={sb} fb={fb}) "
              f"HBM {row['staged_hbm_bytes']/2**20:.1f}->"
              f"{row['fused_hbm_bytes']/2**20:.1f}MiB parity={parity}",
              flush=True)

    if retune and entries:
        path = autotune.save_table(entries)
        print(f"  tuning table -> {path}", flush=True)
    return {"cases": rows, "tuned": entries}


def write_snapshot(out: dict) -> pathlib.Path:
    """The committed top-level BENCH_gbdt.json: the smoke-geometry
    fused-vs-staged numbers CI regenerates, uploads, and gates on."""
    smoke = out["fused_level"]["cases"][0]
    snapshot = {
        "comment": "regenerate with `PYTHONPATH=src python -m "
                   "benchmarks.kernel_bench`; CI fails if fused_ms > "
                   f"(1 + {REGRESSION_SLACK}) * staged_ms at the smoke "
                   "geometry",
        "host": jax.default_backend(),
        "smoke_geometry": {k: smoke[k] for k in
                           ("n", "f", "n_bins", "n_nodes")},
        "staged_ms": smoke["staged_ms"],
        "fused_ms": smoke["fused_ms"],
        "speedup": smoke["speedup"],
        "parity_ok": smoke["parity_ok"],
        "hist_subtract_flop_ratio": out["hist_subtract"]["flop_ratio"],
    }
    BENCH_SNAPSHOT.write_text(json.dumps(snapshot, indent=1) + "\n")
    return BENCH_SNAPSHOT


def check_snapshot(out: dict) -> None:
    """The CI gate: fused must beat (1 + slack) x staged and match the
    oracle at the smoke geometry."""
    smoke = out["fused_level"]["cases"][0]
    assert smoke["parity_ok"], "fused kernel diverged from the oracle"
    limit = (1.0 + REGRESSION_SLACK) * smoke["staged_ms"]
    assert smoke["fused_ms"] <= limit, (
        f"fused level-build regressed: {smoke['fused_ms']:.0f}ms > "
        f"{limit:.0f}ms (staged {smoke['staged_ms']:.0f}ms + "
        f"{REGRESSION_SLACK:.0%} slack)")
    print(f"  bench gate OK: fused {smoke['fused_ms']:.0f}ms vs staged "
          f"{smoke['staged_ms']:.0f}ms (limit {limit:.0f}ms)", flush=True)


def run(quick: bool = True) -> dict:
    out: dict = {"cases": []}
    key = jax.random.PRNGKey(0)
    for n, f, n_bins, n_nodes in CASES[: 2 if quick else 3]:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        node = jax.random.randint(k2, (n,), 0, n_nodes, dtype=jnp.int32)
        g = jax.random.normal(k3, (n,))
        h = jax.random.uniform(k4, (n,))

        t_ref, h_ref = time_call(
            lambda: ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                        backend="ref")
        )
        h_pal = ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                    backend="pallas")
        ok = bool(np.allclose(h_ref, h_pal, atol=1e-3))

        t_gain, _ = time_call(
            lambda: ops.split_gain(h_ref, 1.0, 1e-3, backend="ref")
        )
        flops, bts = hist_intensity(n, f, n_bins, n_nodes)
        case = {
            "n": n, "f": f, "n_bins": n_bins, "n_nodes": n_nodes,
            "hist_ref_ms": t_ref * 1e3,
            "gain_ref_ms": t_gain * 1e3,
            "pallas_matches_ref": ok,
            "hist_flops": flops,
            "hist_bytes": bts,
            "arithmetic_intensity": flops / bts,
        }
        out["cases"].append(case)
        print(f"  N={n} F={f}: hist {t_ref*1e3:.1f}ms gain {t_gain*1e3:.2f}ms "
              f"pallas_ok={ok} AI={flops/bts:.1f} flop/byte", flush=True)
    out["hist_subtract"] = run_hist_subtract(quick)
    out["fused_level"] = run_fused_level(quick)
    print(f"  snapshot -> {write_snapshot(out)}", flush=True)
    save("kernel_bench", out)
    return out


def main(quick: bool = True, check: bool = False):
    out = run(quick)
    if check:
        check_snapshot(out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all geometries incl. the 16K x 256 contract row")
    ap.add_argument("--check", action="store_true",
                    help="fail if fused regresses >10%% vs staged (CI gate)")
    args = ap.parse_args()
    main(quick=not args.full, check=args.check)
