"""Kernel micro-benchmarks: jnp oracle vs Pallas (interpret mode on CPU).

Interpret-mode wall times do NOT reflect TPU performance — the meaningful
artifacts are (a) correctness at benchmark scale, (b) the ref-backend CPU
time that parameterizes the Fig. 10 component model, and (c) the kernels'
arithmetic-intensity table (bytes/flops per tile) used by the roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, time_call
from repro.kernels import ops

CASES = [
    # (n, f, n_bins, n_nodes)
    (4_096, 128, 64, 8),
    (16_384, 256, 64, 32),
    (65_536, 64, 64, 64),
]


def hist_intensity(n, f, n_bins, n_nodes, sample_block=512, feature_block=8):
    """Analytic FLOPs/bytes per histogram kernel invocation (MXU path)."""
    rows = 2 * n_nodes
    flops = 2.0 * rows * n * f * n_bins  # dense one-hot contraction
    bytes_in = n * f * 4 + 3 * n * 4  # bins + node/grad/hess
    bytes_out = rows * f * n_bins * 4
    return flops, bytes_in + bytes_out


def tree_hist_rows(depth: int, mode: str) -> int:
    """Node-histograms built per tree: rebuild histograms every node of
    every level (2^d - 1); subtract builds the root plus one child per
    parent below it (2^(d-1))."""
    if mode == "rebuild":
        return (1 << depth) - 1
    return 1 + sum(1 << (level - 1) for level in range(1, depth))


def _per_tree_hist_fn(mode: str, backend: str, depth: int, n_bins: int):
    """All of one tree's level-histogram kernel calls as a single jitted
    program (random fixed node ids per level stand in for the routing;
    the kernel cost depends only on the row count, not which nodes)."""

    @jax.jit
    def run_levels(bins, g, h, level_nodes):
        total = 0.0
        for level in range(depth):
            n_nodes = 1 << level
            node = level_nodes[level]
            if mode == "rebuild" or level == 0:
                hist = ops.build_histogram(
                    bins, node, g, h, n_nodes, n_bins, backend=backend
                )
            else:
                active = 2 * jnp.arange(n_nodes // 2, dtype=jnp.int32)
                hist = ops.build_histogram_subset(
                    bins, node, g, h, active, n_nodes, n_bins, backend=backend
                )
            total = total + jnp.sum(hist)  # keep every level live
        return total

    return run_levels


def run_hist_subtract(quick: bool = True) -> dict:
    """The `hist_subtract` rows: per-tree histogram kernel work at depth 7,
    subtraction builder vs full rebuild.

    The contractual number is the MXU work model: kernel cost is linear
    in GH rows, so subtract/rebuild = 64/127 node-histograms = 0.504
    (exact, `hist_flops_*`). CPU wall times bracket it from above:

      * `pallas` — the real kernel program; on CPU the row-independent
        one-hot factor construction (VPU work the MXU overlaps on real
        hardware) dilutes the dot saving, so the measured ratio lands
        between the flop ratio and 1 and shrinks with scale;
      * `ref` — segment_sum scatters all N*F entries regardless of the
        node subset: ~1.0 by construction. Listed so nobody mistakes the
        oracle backend for the optimized path.
    """
    depth, n_bins = 7, 64

    def measure(backend: str, n: int, f: int) -> dict:
        key = jax.random.PRNGKey(7)
        k1, k2, k3 = jax.random.split(key, 3)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        g = jax.random.normal(k2, (n,))
        h = jax.random.uniform(k3, (n,))
        level_nodes = [
            jax.random.randint(jax.random.PRNGKey(100 + level), (n,), 0,
                               1 << level, dtype=jnp.int32)
            for level in range(depth)
        ]
        times = {}
        for mode in ("rebuild", "subtract"):
            fn = _per_tree_hist_fn(mode, backend, depth, n_bins)
            t, _ = time_call(lambda: fn(bins, g, h, level_nodes))
            times[mode] = t
        print(f"  hist_subtract[{backend}] depth={depth} N={n} F={f}: "
              f"rebuild {times['rebuild']*1e3:.1f}ms "
              f"subtract {times['subtract']*1e3:.1f}ms "
              f"(time x{times['subtract']/times['rebuild']:.2f})", flush=True)
        return {
            "n": n, "f": f,
            "rebuild_ms": times["rebuild"] * 1e3,
            "subtract_ms": times["subtract"] * 1e3,
            "time_ratio": times["subtract"] / times["rebuild"],
        }

    rows = {m: tree_hist_rows(depth, m) for m in ("rebuild", "subtract")}
    n_model, f_model = (16_384, 64)
    flops = {m: 2.0 * (2 * r) * n_model * f_model * n_bins
             for m, r in rows.items()}
    out = {
        "depth": depth, "n_bins": n_bins, "n": n_model, "f": f_model,
        "node_hists_rebuild": rows["rebuild"],
        "node_hists_subtract": rows["subtract"],
        "hist_flops_rebuild": flops["rebuild"],
        "hist_flops_subtract": flops["subtract"],
        "flop_ratio": flops["subtract"] / flops["rebuild"],
        "measured": {
            "pallas": measure("pallas", *((2_048, 8) if quick else (16_384, 64))),
            "ref": measure("ref", *((4_096, 16) if quick else (16_384, 64))),
        },
    }
    print(f"  hist_subtract kernel-work model: {rows['subtract']}/"
          f"{rows['rebuild']} node-histograms = x{out['flop_ratio']:.3f} "
          f"MXU flops per tree", flush=True)
    return out


def run(quick: bool = True) -> dict:
    out: dict = {"cases": []}
    key = jax.random.PRNGKey(0)
    for n, f, n_bins, n_nodes in CASES[: 2 if quick else 3]:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        node = jax.random.randint(k2, (n,), 0, n_nodes, dtype=jnp.int32)
        g = jax.random.normal(k3, (n,))
        h = jax.random.uniform(k4, (n,))

        t_ref, h_ref = time_call(
            lambda: ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                        backend="ref")
        )
        h_pal = ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                    backend="pallas")
        ok = bool(np.allclose(h_ref, h_pal, atol=1e-3))

        t_gain, _ = time_call(
            lambda: ops.split_gain(h_ref, 1.0, 1e-3, backend="ref")
        )
        flops, bts = hist_intensity(n, f, n_bins, n_nodes)
        case = {
            "n": n, "f": f, "n_bins": n_bins, "n_nodes": n_nodes,
            "hist_ref_ms": t_ref * 1e3,
            "gain_ref_ms": t_gain * 1e3,
            "pallas_matches_ref": ok,
            "hist_flops": flops,
            "hist_bytes": bts,
            "arithmetic_intensity": flops / bts,
        }
        out["cases"].append(case)
        print(f"  N={n} F={f}: hist {t_ref*1e3:.1f}ms gain {t_gain*1e3:.2f}ms "
              f"pallas_ok={ok} AI={flops/bts:.1f} flop/byte", flush=True)
    out["hist_subtract"] = run_hist_subtract(quick)
    save("kernel_bench", out)
    return out


def main(quick: bool = True):
    return run(quick)


if __name__ == "__main__":
    main()
