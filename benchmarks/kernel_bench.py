"""Kernel micro-benchmarks: jnp oracle vs Pallas (interpret mode on CPU).

Interpret-mode wall times do NOT reflect TPU performance — the meaningful
artifacts are (a) correctness at benchmark scale, (b) the ref-backend CPU
time that parameterizes the Fig. 10 component model, and (c) the kernels'
arithmetic-intensity table (bytes/flops per tile) used by the roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, time_call
from repro.kernels import ops

CASES = [
    # (n, f, n_bins, n_nodes)
    (4_096, 128, 64, 8),
    (16_384, 256, 64, 32),
    (65_536, 64, 64, 64),
]


def hist_intensity(n, f, n_bins, n_nodes, sample_block=512, feature_block=8):
    """Analytic FLOPs/bytes per histogram kernel invocation (MXU path)."""
    rows = 2 * n_nodes
    flops = 2.0 * rows * n * f * n_bins  # dense one-hot contraction
    bytes_in = n * f * 4 + 3 * n * 4  # bins + node/grad/hess
    bytes_out = rows * f * n_bins * 4
    return flops, bytes_in + bytes_out


def run(quick: bool = True) -> dict:
    out: dict = {"cases": []}
    key = jax.random.PRNGKey(0)
    for n, f, n_bins, n_nodes in CASES[: 2 if quick else 3]:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
        node = jax.random.randint(k2, (n,), 0, n_nodes, dtype=jnp.int32)
        g = jax.random.normal(k3, (n,))
        h = jax.random.uniform(k4, (n,))

        t_ref, h_ref = time_call(
            lambda: ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                        backend="ref")
        )
        h_pal = ops.build_histogram(bins, node, g, h, n_nodes, n_bins,
                                    backend="pallas")
        ok = bool(np.allclose(h_ref, h_pal, atol=1e-3))

        t_gain, _ = time_call(
            lambda: ops.split_gain(h_ref, 1.0, 1e-3, backend="ref")
        )
        flops, bts = hist_intensity(n, f, n_bins, n_nodes)
        case = {
            "n": n, "f": f, "n_bins": n_bins, "n_nodes": n_nodes,
            "hist_ref_ms": t_ref * 1e3,
            "gain_ref_ms": t_gain * 1e3,
            "pallas_matches_ref": ok,
            "hist_flops": flops,
            "hist_bytes": bts,
            "arithmetic_intensity": flops / bts,
        }
        out["cases"].append(case)
        print(f"  N={n} F={f}: hist {t_ref*1e3:.1f}ms gain {t_gain*1e3:.2f}ms "
              f"pallas_ok={ok} AI={flops/bts:.1f} flop/byte", flush=True)
    save("kernel_bench", out)
    return out


def main(quick: bool = True):
    return run(quick)


if __name__ == "__main__":
    main()
