"""Forest-serving throughput: batch size x forest size sweep -> JSON record.

Measures the jitted serve-time predict (raw floats -> training-bin lookup ->
fused forest traversal) the way Anghel et al. (2018) benchmark GBT
inference: steady-state latency and rows/s per (batch, trees) cell, plus an
end-to-end continuous-engine measurement (``serving.ForestEngine``: per-
arrival admission, SLO-aware wave cuts) whose reported p99 includes queue
wait, and a quantized-traversal (int8/fp16) comparison. Forest contents are
random — traversal cost is data-independent — so the sweep needs no
training run.

    PYTHONPATH=src python -m benchmarks.gbdt_serve [--full] [--backend ref]

Writes ``experiments/gbdt_serve.json`` (the CI benchmark-smoke artifact).
The ``gate`` record (p50/p99 predict latency at the 256-row x 32-tree cell
plus engine p99 end-to-end latency) is what ``check_bench --serve`` diffs
against the committed ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, time_call
from repro.serving import ForestEngine, ForestServer, PredictRequest, percentile_latencies
from repro.trees.binning import make_bins
from repro.trees.forest import Forest, quantization_atol
from repro.trees.tree import tree_num_nodes

GATE_BATCH, GATE_TREES = 256, 32  # the geometry check_bench --serve pins

QUICK = {"batches": [16, 64, 256], "trees": [8, 32, 128], "depth": 5, "dim": 32}
FULL = {"batches": [64, 256, 1024, 4096], "trees": [32, 128, 400], "depth": 7,
        "dim": 128}


def random_forest(capacity: int, depth: int, dim: int, n_bins: int,
                  seed: int = 0) -> Forest:
    """A fully-live forest with random splits/leaves (cost-equivalent to a
    trained one: traversal work does not depend on the values)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    n_int, n_leaf = tree_num_nodes(depth)
    return Forest(
        feature=jax.random.randint(k1, (capacity, n_int), 0, dim, dtype=jnp.int32),
        threshold=jax.random.randint(k2, (capacity, n_int), 0, n_bins,
                                     dtype=jnp.int32),
        leaf_value=0.1 * jax.random.normal(k3, (capacity, n_leaf), jnp.float32),
        n_trees=jnp.asarray(capacity, jnp.int32),
        base_score=jnp.asarray(0.0, jnp.float32),
    )


def run(quick: bool = True, backend: str = "auto", seed: int = 0) -> dict:
    p = QUICK if quick else FULL
    n_bins = 64
    rng = np.random.default_rng(seed)
    edges = jnp.asarray(
        make_bins(rng.standard_normal((4096, p["dim"])).astype(np.float32), n_bins)
    )
    out: dict = {
        "backend": backend, "depth": p["depth"], "dim": p["dim"],
        "n_bins": n_bins, "sweep": [],
    }
    for n_trees in p["trees"]:
        forest = random_forest(n_trees, p["depth"], p["dim"], n_bins, seed)
        server = ForestServer(forest, edges, max_rows=max(p["batches"]),
                              backend=backend)
        for batch in p["batches"]:
            x = jnp.asarray(
                rng.standard_normal((batch, p["dim"])).astype(np.float32)
            )
            t_s, _ = time_call(server._predict, forest, edges, x)
            rec = {
                "batch": batch, "trees": n_trees,
                "latency_ms": 1e3 * t_s,
                "rows_per_s": batch / t_s,
                "tree_rows_per_s": batch * n_trees / t_s,
            }
            out["sweep"].append(rec)
            print(f"  trees={n_trees:4d} batch={batch:5d}: "
                  f"{rec['latency_ms']:8.3f} ms  {rec['rows_per_s']:12,.0f} rows/s",
                  flush=True)

    # End-to-end wave path: queueing + packing + padding included.
    n_trees = p["trees"][-1]
    forest = random_forest(n_trees, p["depth"], p["dim"], n_bins, seed)
    max_rows = p["batches"][-1]
    server = ForestServer(forest, edges, max_rows=max_rows, backend=backend)
    reqs = [
        PredictRequest(
            uid=i,
            x=rng.standard_normal(
                (int(rng.integers(1, max_rows // 2 + 1)), p["dim"])
            ).astype(np.float32),
        )
        for i in range(24)
    ]
    def serve_all():
        """One full pass; wave count deltas so warmup runs don't pollute it.
        (time_call's untimed warmup invocation also compiles the predict.)"""
        n0 = server.waves_served
        outs = server.run(reqs)
        return outs, server.waves_served - n0

    t_s, (outs, waves) = time_call(serve_all, reps=1)
    rows = sum(len(r.scores) for r in outs)
    out["engine"] = {
        "trees": n_trees, "max_rows": max_rows, "requests": len(reqs),
        "rows": rows, "wall_s": t_s, "rows_per_s": rows / t_s,
        "waves": waves,
    }
    print(f"  engine: {rows} rows over {len(reqs)} requests in {t_s:.3f}s "
          f"({rows / t_s:,.0f} rows/s)", flush=True)

    out["gate"] = gate_record(edges, p, n_bins, backend, rng, seed)
    out["quantized"] = quantized_record(edges, p, n_bins, backend, rng, seed)
    save("gbdt_serve", out)
    return out


def gate_record(edges, p, n_bins, backend, rng, seed) -> dict:
    """The check_bench --serve payload: p50/p99 steady-state predict
    latency at the pinned 256-row x 32-tree cell, and p50/p99 END-TO-END
    (queue + compute) latency through the continuous engine serving a
    mixed-size trickle under a 50ms SLO."""
    slo_ms = 50.0
    forest = random_forest(GATE_TREES, p["depth"], p["dim"], n_bins, seed)
    server = ForestServer(forest, edges, max_rows=GATE_BATCH, backend=backend)
    x = jnp.asarray(
        rng.standard_normal((GATE_BATCH, p["dim"])).astype(np.float32)
    )
    jax.block_until_ready(server._predict(forest, edges, x))  # compile
    times = []
    for _ in range(30):
        t0 = time.perf_counter()
        jax.block_until_ready(server._predict(forest, edges, x))
        times.append(1e3 * (time.perf_counter() - t0))
    rec = {
        "geometry": {
            "batch": GATE_BATCH, "trees": GATE_TREES, "depth": p["depth"],
            "dim": p["dim"], "n_bins": n_bins, "slo_ms": slo_ms,
        },
        "predict_p50_ms": float(np.percentile(times, 50)),
        "predict_p99_ms": float(np.percentile(times, 99)),
    }

    eng = ForestEngine(edges, max_rows=GATE_BATCH, slo_s=slo_ms / 1e3,
                       backend=backend)
    eng.add_version("live", forest)
    eng.run([PredictRequest(uid=0, x=np.asarray(x))])  # warm the jit cache
    eng.start(interval_s=0.002)
    try:
        for uid in range(1, 41):
            n = int(rng.integers(1, GATE_BATCH // 2))
            eng.submit(PredictRequest(
                uid=uid,
                x=rng.standard_normal((n, p["dim"])).astype(np.float32),
            ))
            time.sleep(0.002)
        got = []
        deadline = time.perf_counter() + 30.0
        while len(got) < 40 and time.perf_counter() < deadline:
            got.extend(eng.poll())
            time.sleep(0.005)
    finally:
        eng.stop()
    got.extend(eng.poll())
    stats = percentile_latencies(got)
    rec.update({f"engine_{k}": v for k, v in stats.items()})
    rec["engine_requests"] = len(got)
    rec["engine_slo_met"] = float(
        np.mean([r.latency_s * 1e3 <= slo_ms for r in got])
    )
    print(f"  gate ({GATE_BATCH}x{GATE_TREES}): predict p99 "
          f"{rec['predict_p99_ms']:.2f} ms; engine p99 "
          f"{rec.get('engine_latency_p99_ms', float('nan')):.2f} ms "
          f"(SLO {slo_ms:.0f} ms met on {100 * rec['engine_slo_met']:.0f}% "
          f"of requests)", flush=True)
    return rec


def quantized_record(edges, p, n_bins, backend, rng, seed) -> dict:
    """int8/fp16 traversal at the gate cell: latency vs f32 plus the
    observed-vs-documented score error (informational, not gated)."""
    forest = random_forest(GATE_TREES, p["depth"], p["dim"], n_bins, seed)
    server = ForestServer(forest, edges, max_rows=GATE_BATCH, backend=backend)
    x = jnp.asarray(
        rng.standard_normal((GATE_BATCH, p["dim"])).astype(np.float32)
    )
    t_f32, base = time_call(server._predict, forest, edges, x)
    rec: dict = {"f32_latency_ms": 1e3 * t_f32}
    for mode in ("int8", "fp16"):
        qf = forest.quantize(mode)
        qsrv = ForestServer(forest, edges, max_rows=GATE_BATCH,
                            backend=backend, quantize=mode)
        t_q, scores = time_call(qsrv._predict, qf, edges, x)
        err = float(jnp.max(jnp.abs(scores - base)))
        atol = quantization_atol(forest, qf)
        rec[mode] = {
            "latency_ms": 1e3 * t_q,
            "speedup_vs_f32": t_f32 / t_q,
            "max_abs_err": err,
            "documented_atol": atol,
            "parity_ok": bool(err <= atol + 1e-6),
        }
        print(f"  quantized {mode}: {1e3 * t_q:8.3f} ms "
              f"(f32 {1e3 * t_f32:.3f} ms), max|err| {err:.2e} "
              f"<= atol {atol:.2e}: {rec[mode]['parity_ok']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="quick", action="store_false", default=True)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    return run(quick=args.quick, backend=args.backend, seed=args.seed)


if __name__ == "__main__":
    main()
