"""Forest-serving throughput: batch size x forest size sweep -> JSON record.

Measures the jitted serve-time predict (raw floats -> training-bin lookup ->
fused forest traversal) the way Anghel et al. (2018) benchmark GBT
inference: steady-state latency and rows/s per (batch, trees) cell, plus an
end-to-end ``ForestServer`` wave measurement that includes queueing and
padding. Forest contents are random — traversal cost is data-independent —
so the sweep needs no training run.

    PYTHONPATH=src python -m benchmarks.gbdt_serve [--full] [--backend ref]

Writes ``experiments/gbdt_serve.json`` (the CI benchmark-smoke artifact).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, time_call
from repro.serving import ForestServer, PredictRequest
from repro.trees.binning import make_bins
from repro.trees.forest import Forest
from repro.trees.tree import tree_num_nodes

QUICK = {"batches": [16, 64, 256], "trees": [8, 32, 128], "depth": 5, "dim": 32}
FULL = {"batches": [64, 256, 1024, 4096], "trees": [32, 128, 400], "depth": 7,
        "dim": 128}


def random_forest(capacity: int, depth: int, dim: int, n_bins: int,
                  seed: int = 0) -> Forest:
    """A fully-live forest with random splits/leaves (cost-equivalent to a
    trained one: traversal work does not depend on the values)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    n_int, n_leaf = tree_num_nodes(depth)
    return Forest(
        feature=jax.random.randint(k1, (capacity, n_int), 0, dim, dtype=jnp.int32),
        threshold=jax.random.randint(k2, (capacity, n_int), 0, n_bins,
                                     dtype=jnp.int32),
        leaf_value=0.1 * jax.random.normal(k3, (capacity, n_leaf), jnp.float32),
        n_trees=jnp.asarray(capacity, jnp.int32),
        base_score=jnp.asarray(0.0, jnp.float32),
    )


def run(quick: bool = True, backend: str = "auto", seed: int = 0) -> dict:
    p = QUICK if quick else FULL
    n_bins = 64
    rng = np.random.default_rng(seed)
    edges = jnp.asarray(
        make_bins(rng.standard_normal((4096, p["dim"])).astype(np.float32), n_bins)
    )
    out: dict = {
        "backend": backend, "depth": p["depth"], "dim": p["dim"],
        "n_bins": n_bins, "sweep": [],
    }
    for n_trees in p["trees"]:
        forest = random_forest(n_trees, p["depth"], p["dim"], n_bins, seed)
        server = ForestServer(forest, edges, max_rows=max(p["batches"]),
                              backend=backend)
        for batch in p["batches"]:
            x = jnp.asarray(
                rng.standard_normal((batch, p["dim"])).astype(np.float32)
            )
            t_s, _ = time_call(server._predict, forest, edges, x)
            rec = {
                "batch": batch, "trees": n_trees,
                "latency_ms": 1e3 * t_s,
                "rows_per_s": batch / t_s,
                "tree_rows_per_s": batch * n_trees / t_s,
            }
            out["sweep"].append(rec)
            print(f"  trees={n_trees:4d} batch={batch:5d}: "
                  f"{rec['latency_ms']:8.3f} ms  {rec['rows_per_s']:12,.0f} rows/s",
                  flush=True)

    # End-to-end wave path: queueing + packing + padding included.
    n_trees = p["trees"][-1]
    forest = random_forest(n_trees, p["depth"], p["dim"], n_bins, seed)
    max_rows = p["batches"][-1]
    server = ForestServer(forest, edges, max_rows=max_rows, backend=backend)
    reqs = [
        PredictRequest(
            uid=i,
            x=rng.standard_normal(
                (int(rng.integers(1, max_rows // 2 + 1)), p["dim"])
            ).astype(np.float32),
        )
        for i in range(24)
    ]
    def serve_all():
        """One full pass; wave count deltas so warmup runs don't pollute it.
        (time_call's untimed warmup invocation also compiles the predict.)"""
        n0 = server.waves_served
        outs = server.run(reqs)
        return outs, server.waves_served - n0

    t_s, (outs, waves) = time_call(serve_all, reps=1)
    rows = sum(len(r.scores) for r in outs)
    out["engine"] = {
        "trees": n_trees, "max_rows": max_rows, "requests": len(reqs),
        "rows": rows, "wall_s": t_s, "rows_per_s": rows / t_s,
        "waves": waves,
    }
    print(f"  engine: {rows} rows over {len(reqs)} requests in {t_s:.3f}s "
          f"({rows / t_s:,.0f} rows/s)", flush=True)
    save("gbdt_serve", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", dest="quick", action="store_false", default=True)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    return run(quick=args.quick, backend=args.backend, seed=args.seed)


if __name__ == "__main__":
    main()
