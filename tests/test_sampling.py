"""The paper's random variable Q: unbiasedness, diversity observables."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

from repro.data.sampling import (
    bernoulli_weights,
    delta_max,
    diversity_stats,
    overlap_probability,
    q_sparsity,
)


def test_importance_weights_unbiased(key):
    """E[m'_i] = m_i (the keystone of Corollary 1)."""
    m = jnp.asarray([1.0, 2.0, 5.0, 10.0, 50.0])
    total = jnp.zeros_like(m)
    n = 3000
    for i in range(n):
        w, _ = bernoulli_weights(jax.random.fold_in(key, i), 0.3, m)
        total = total + w
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(m), rtol=0.1)


@settings(max_examples=20, deadline=None)
@given(
    rate=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_weights_zero_iff_not_drawn(rate, seed):
    key = jax.random.PRNGKey(seed)
    m = jnp.ones(200)
    w, q = bernoulli_weights(key, rate, m)
    w = np.asarray(w)
    q = np.asarray(q)
    assert ((w > 0) == q).all()
    # with m_i = 1, weights are either 0 or 1/rate
    nz = w[w > 0]
    np.testing.assert_allclose(nz, 1.0 / rate, rtol=1e-5)


def test_delta_closed_form_matches_mc(key):
    m = jnp.asarray([1.0, 3.0, 7.0])
    rate = 0.25
    hits = np.zeros(3)
    n = 4000
    for i in range(n):
        _, q = bernoulli_weights(jax.random.fold_in(key, i), rate, m)
        hits += np.asarray(q, float)
    p_emp = hits / n
    p_closed = 1.0 - (1.0 - rate) ** np.asarray(m)
    np.testing.assert_allclose(p_emp, p_closed, atol=0.03)
    assert float(delta_max(rate, m)) == np.testing.assert_allclose(
        float(delta_max(rate, m)), p_closed.max(), rtol=1e-5
    ) or True


def test_diversity_ordering():
    """The paper's Fig. 4: low-diversity (heavy multiplicity) datasets have
    larger Delta and rho than high-diversity (m_i = 1) datasets at the same
    sampling rate."""
    rate = 0.1
    high_div = jnp.ones(10_000)  # 10k distinct samples
    low_div = jnp.full(10, 1_000.0)  # 10 distinct, m_i = 1000
    s_high = diversity_stats(rate, high_div)
    s_low = diversity_stats(rate, low_div)
    assert float(s_low["delta"]) > float(s_high["delta"])
    assert float(s_low["expected_subdataset_density"]) > float(
        s_high["expected_subdataset_density"]
    )


def test_small_rate_reduces_density():
    m = jnp.ones(5000)
    d_small = diversity_stats(0.01, m)["expected_subdataset_density"]
    d_big = diversity_stats(0.9, m)["expected_subdataset_density"]
    assert float(d_small) < 0.05 < float(d_big)


def test_q_sparsity(key):
    m = jnp.ones(1000)
    _, q = bernoulli_weights(key, 0.2, m)
    s = float(q_sparsity(q))
    assert 0.1 < s < 0.3


def test_overlap_probability_bounds():
    m = jnp.ones(100)
    rho_small = float(overlap_probability(0.01, m))
    rho_big = float(overlap_probability(0.9, m))
    assert 0.0 <= rho_small < rho_big <= 1.0
