"""Tree substrate: binning, learner, routing."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

from repro.trees import (
    LearnerConfig,
    apply_bins,
    bin_dataset,
    build_tree,
    make_bins,
)
from repro.trees.tree import apply_tree, leaf_indices


def test_binning_nonfinite_policy(rng):
    """Serve-time regression: NaN must NOT silently land in the top bin
    (searchsorted's comparison-order artifact); ±inf clamp to the ends."""
    x = rng.standard_normal((100, 3)).astype(np.float32)
    edges = make_bins(x, n_bins=16)
    bad = x.copy()
    bad[0, 0] = np.nan
    bad[1, 1] = np.inf
    bad[2, 2] = -np.inf
    bins = np.asarray(apply_bins(jnp.asarray(bad), jnp.asarray(edges)))
    assert bins[0, 0] == 0  # NaN routes to the designated bin, not bin 15
    assert bins[1, 1] == 15  # +inf really is above every edge
    assert bins[2, 2] == 0  # -inf really is below every edge
    # a non-default NaN bin routes there instead
    bins7 = np.asarray(
        apply_bins(jnp.asarray(bad), jnp.asarray(edges), nan_bin=7)
    )
    assert bins7[0, 0] == 7
    # finite entries are untouched by the policy
    clean = np.asarray(apply_bins(jnp.asarray(x), jnp.asarray(edges)))
    mask = np.isfinite(bad)
    np.testing.assert_array_equal(bins[mask], clean[mask])


def test_binning_monotone_and_bounded(rng):
    x = rng.standard_normal((500, 7)).astype(np.float32)
    edges = make_bins(x, n_bins=16)
    bins = np.asarray(apply_bins(jnp.asarray(x), jnp.asarray(edges)))
    assert bins.min() >= 0 and bins.max() <= 15
    # monotone: larger value -> bin id never decreases (per feature)
    f = 3
    order = np.argsort(x[:, f])
    assert (np.diff(bins[order, f]) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_bins=st.sampled_from([4, 16, 64]))
def test_binning_quantile_balance(seed, n_bins):
    """Property: quantile bins get roughly equal mass on continuous data."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2000, 1)).astype(np.float32)
    data = bin_dataset(x, np.zeros(2000, np.float32), n_bins=n_bins)
    counts = np.bincount(np.asarray(data.bins[:, 0]), minlength=n_bins)
    assert counts.max() <= 3 * 2000 / n_bins  # no bin grossly overloaded


def test_tree_fits_axis_aligned_step(key):
    """A depth-1-expressible target must be fit exactly."""
    bins = jax.random.randint(key, (400, 5), 0, 32, dtype=jnp.int32)
    target = jnp.where(bins[:, 2] > 13, 2.0, -1.0)
    tree = build_tree(
        LearnerConfig(depth=3, n_bins=32, lam=0.0, feature_fraction=1.0),
        bins, -target, jnp.ones(400), key,  # g = -target => leaf = mean target
    )
    pred = apply_tree(tree, bins)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(target), atol=1e-5)


def test_tree_reduces_residual(key):
    bins = jax.random.randint(key, (500, 10), 0, 64, dtype=jnp.int32)
    g = jax.random.normal(key, (500,))
    tree = build_tree(
        LearnerConfig(depth=5, n_bins=64, feature_fraction=1.0),
        bins, g, jnp.ones(500), key,
    )
    pred = apply_tree(tree, bins)
    before = float(jnp.sum(g**2))
    after = float(jnp.sum((g + pred) ** 2))  # tree predicts -g direction
    assert after < before


def test_leaf_routing_partition(key):
    """Every sample lands in exactly one leaf; siblings partition parents."""
    bins = jax.random.randint(key, (300, 4), 0, 16, dtype=jnp.int32)
    g = jax.random.normal(key, (300,))
    tree = build_tree(
        LearnerConfig(depth=4, n_bins=16, feature_fraction=1.0),
        bins, g, jnp.ones(300), key,
    )
    leaf = np.asarray(leaf_indices(tree, bins))
    assert leaf.min() >= 0 and leaf.max() < 16
    # deterministic: same input -> same leaf
    leaf2 = np.asarray(leaf_indices(tree, bins))
    assert (leaf == leaf2).all()


def test_unsplittable_node_passthrough(key):
    """Constant gradients -> no split gain -> all samples route left and the
    single active leaf predicts the regularized mean."""
    bins = jnp.zeros((100, 3), jnp.int32)  # all samples identical
    g = jnp.ones(100)
    h = jnp.ones(100)
    tree = build_tree(
        LearnerConfig(depth=3, n_bins=8, lam=1.0, feature_fraction=1.0),
        bins, g, h, key,
    )
    pred = np.asarray(apply_tree(tree, bins))
    expected = -100.0 / (100.0 + 1.0)
    np.testing.assert_allclose(pred, expected, rtol=1e-5)
