"""Assigned-architecture smoke + consistency tests (reduced configs).

Per the harness contract: every architecture instantiates a REDUCED variant
(<= 4 layers, d_model <= 512, <= 4 experts) and runs one forward/train step
on CPU asserting output shapes and no NaNs. On top of that, the serving
path (prefill + decode) is cross-validated against the teacher-forced
forward with chunk size 1 — which simultaneously validates the chunked SSD
/ mLSTM scans against their pure recurrences.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as M
from repro.models import transformer as T

ARCHS = list(configs.ALIASES)


def _batch(cfg, key, b=2, s=32, with_labels=True):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}
    if with_labels:
        batch["labels"] = toks[:, 1:]
    if cfg.family in ("vlm", "audio"):
        batch["media"] = (
            jax.random.normal(key, (b, cfg.n_media_tokens, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    return batch, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch, key):
    """One optimizer step on the reduced config: finite loss, shapes, grads."""
    from repro.launch.steps import make_train_step
    from repro.optim import adamw

    cfg = configs.get(arch).reduced()
    params = M.init_params(cfg, key)
    opt = adamw(1e-3, weight_decay=0.01, max_grad_norm=1.0)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch, _ = _batch(cfg, key)
    params2, opt_state2, metrics = step(params, opt_state, batch, key)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), params, params2
    )
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = configs.get(arch).reduced()
    params = M.init_params(cfg, key)
    batch, _ = _batch(cfg, key)
    loss, metrics = M.forward_train(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """prefill(S) logits == forward(S) logits; decode(S+1) == forward(S+1).
    The oracle uses chunk=1 (pure recurrence) and lossless MoE capacity, so
    this also cross-checks the chunked scan algebra."""
    cfg = configs.get(arch).reduced()
    params = M.init_params(cfg, key)
    b, s = 2, 32
    batch, toks = _batch(cfg, key, b=b, s=s, with_labels=False)

    ocfg = dataclasses.replace(cfg, ssm_chunk=1, capacity_factor=100.0)
    lg_pre, cache = M.prefill(params, ocfg, batch, max_len=s + 8)

    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    h, _ = T.backbone_train(params, ocfg, x, batch.get("media"))
    full = T._logits(params, ocfg, h)[:, -1]
    np.testing.assert_allclose(
        np.asarray(lg_pre), np.asarray(full), rtol=2e-2, atol=2e-3
    )

    lg_dec, _ = M.decode_step(params, cfg, toks[:, s : s + 1], cache)
    x2 = jnp.take(params["embed"], toks, axis=0)
    h2, _ = T.backbone_train(params, ocfg, x2, batch.get("media"))
    full2 = T._logits(params, ocfg, h2)[:, -1]
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(full2), rtol=2e-2, atol=2e-3
    )


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "zamba2-1.2b", "xlstm-1.3b"])
def test_multi_token_decode(arch, key):
    """Decode 8 tokens sequentially; each must match the teacher-forced
    oracle at that position (catches cache-update drift)."""
    cfg = configs.get(arch).reduced()
    ocfg = dataclasses.replace(cfg, ssm_chunk=1)
    params = M.init_params(cfg, key)
    b, s, extra = 1, 16, 8
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=s + extra)
    for i in range(extra):
        lg, cache = M.decode_step(params, cfg, toks[:, s + i : s + i + 1], cache)
        x = jnp.take(params["embed"], toks[:, : s + i + 1], axis=0)
        h, _ = T.backbone_train(params, ocfg, x, None)
        full = T._logits(params, ocfg, h)[:, -1]
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full), rtol=2e-2, atol=2e-3,
            err_msg=f"divergence at decode step {i}",
        )


def test_sliding_window_decode_evicts(key):
    """SWA ring cache: tokens older than the window must not influence the
    decode logits. One layer only — with stacked layers the receptive field
    grows by `window` per layer, so eviction is only exact at depth 1."""
    cfg = dataclasses.replace(
        configs.get("h2o-danube-1.8b").reduced(), sliding_window=8, n_layers=1
    )
    params = M.init_params(cfg, key)
    s = 16
    toks = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, {"tokens": toks}, max_len=s + 4)
    lg, _ = M.decode_step(params, cfg, toks[:, :1], cache)
    # Same suffix, different early prefix -> identical logits under SWA
    toks2 = toks.at[:, : s - 8].set((toks[:, : s - 8] + 1) % cfg.vocab_size)
    _, cache2 = M.prefill(params, cfg, {"tokens": toks2}, max_len=s + 4)
    lg2, _ = M.decode_step(params, cfg, toks[:, :1], cache2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2), atol=1e-4)


def test_param_count_matches_schema(key):
    for arch in ARCHS:
        cfg = configs.get(arch)
        schema_n = 0
        import repro.models.transformer as TT

        def count(path, e):
            nonlocal schema_n
            n = 1
            for d in e.shape:
                n *= d
            schema_n += n

        TT._map_schema(count, TT.param_schema(cfg))
        analytic = cfg.param_count()
        # analytic count ignores norms/gates -> within 2%
        assert abs(schema_n - analytic) / analytic < 0.05, (
            f"{arch}: schema {schema_n:,} vs analytic {analytic:,}"
        )


def test_packed_segments_isolate_documents(key):
    """Two documents packed in one row must produce the same logits as the
    same documents in separate rows (no cross-document attention leak)."""
    from repro.models import transformer as TT

    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    d1 = jax.random.randint(jax.random.fold_in(key, 1), (16,), 0, cfg.vocab_size)
    d2 = jax.random.randint(jax.random.fold_in(key, 2), (16,), 0, cfg.vocab_size)
    packed = jnp.concatenate([d1, d2])[None, :]  # (1, 32)
    segs = jnp.concatenate([jnp.ones(16), jnp.full(16, 2)])[None, :].astype(
        jnp.int32
    )
    x = jnp.take(params["embed"], packed, axis=0)
    h_packed, _ = TT.backbone_train(params, cfg, x, None, segments=segs)
    lg_packed = TT._logits(params, cfg, h_packed)

    separate = jnp.stack([d1, d2])  # (2, 16)
    xs = jnp.take(params["embed"], separate, axis=0)
    h_sep, _ = TT.backbone_train(params, cfg, xs, None)
    lg_sep = TT._logits(params, cfg, h_sep)

    np.testing.assert_allclose(
        np.asarray(lg_packed[0, :16]), np.asarray(lg_sep[0]),
        rtol=2e-2, atol=2e-3,
    )
    np.testing.assert_allclose(
        np.asarray(lg_packed[0, 16:]), np.asarray(lg_sep[1]),
        rtol=2e-2, atol=2e-3,
    )


def test_flash_attention_backend_equivalence(key):
    """attn_impl='flash' must match the chunked path in fwd AND grad."""
    cfg = configs.get("granite-3-2b").reduced()
    fcfg = dataclasses.replace(cfg, attn_impl="flash")
    params = M.init_params(cfg, key)
    batch, _ = _batch(cfg, key, b=2, s=64)
    l1, _ = M.forward_train(params, cfg, batch)
    l2, _ = M.forward_train(params, fcfg, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: M.forward_train(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: M.forward_train(p, fcfg, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_moe_router_load_balance_aux(key):
    """Aux loss is ~1 for uniform routing and larger for collapsed routing."""
    from repro.models.layers import _router

    cfg = configs.get("dbrx-132b").reduced()
    xf = jax.random.normal(key, (256, cfg.d_model))
    wr_uniform = jnp.zeros((cfg.d_model, cfg.n_experts))
    _, _, aux_u = _router({"wr": wr_uniform}, xf, cfg)
    wr_collapsed = jnp.zeros((cfg.d_model, cfg.n_experts)).at[:, 0].set(5.0)
    _, _, aux_c = _router({"wr": wr_collapsed}, xf, cfg)
    assert float(aux_c) > float(aux_u)
