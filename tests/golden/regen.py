"""Regenerate the golden regression corpus under ``tests/golden/``.

    PYTHONPATH=src python tests/golden/regen.py

The corpus locks three contracts across future PRs (tests/test_golden.py):

  * **checkpoint format** — ``ckpt/step_000008/`` is a ``TrainState``
    checkpoint written by ``checkpoint.save_pytree``; it must stay
    readable by both ``restore_pytree`` (CRC-checked) and the serving
    loader ``load_forest_checkpoint``;
  * **trace replay** — ``run_trace.json`` is a realized ``RunTrace`` from
    a threaded ``AsyncRuntime`` run (W=3, ``hist_mode='subtract'`` — the
    production default); replaying it through ``Trainer.scan_with`` must
    keep reproducing the checkpointed forest;
  * **serving outputs** — ``expected_scores.npy`` are the ``ForestServer``
    predictions for ``eval_rows.npy`` (raw floats, served through
    serve-time binning) under that forest.

This module doubles as the single source of the golden configuration:
``golden_config()`` / ``golden_data()`` / ``golden_eval_rows()`` are
imported by the test so the fixture and its reader can never drift apart.
The threaded RECORDING is nondeterministic (that is the point of the
trace); everything derived from a committed trace is deterministic, which
is why regeneration rewrites the whole corpus together.
"""
from __future__ import annotations

import pathlib

import numpy as np

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent
GOLDEN_STEP = 8
_N, _F = 320, 48


def golden_config():
    from repro.core.sgbdt import SGBDTConfig
    from repro.trees.learner import LearnerConfig

    return SGBDTConfig(
        n_trees=GOLDEN_STEP, step_length=0.3, sampling_rate=0.8,
        loss="logistic",
        learner=LearnerConfig(depth=3, n_bins=64, hist_mode="subtract"),
    )


def golden_data():
    import repro.data as D

    return D.make_sparse_classification(_N, _F, 6, seed=17)


def golden_eval_rows() -> np.ndarray:
    """Raw (unbinned) float rows the serving contract is locked on."""
    rng = np.random.default_rng(71)
    rows = rng.lognormal(0.0, 1.0, size=(16, _F)).astype(np.float32)
    rows[rng.random((16, _F)) < 0.8] = 0.0  # sparse, like the train set
    return rows


def main() -> None:
    from repro import checkpoint
    from repro.ps.runtime import AsyncRuntime
    from repro.serving.forest_server import ForestServer, PredictRequest

    cfg, data = golden_config(), golden_data()
    rt = AsyncRuntime(cfg, data, n_workers=3)
    state, trace = rt.run(seed=5)

    replayed, _ = rt.replay(trace)
    for name in ("feature", "threshold", "leaf_value"):
        assert np.array_equal(
            np.asarray(getattr(state.forest, name)),
            np.asarray(getattr(replayed.forest, name)),
        ), f"recorded run does not replay bitwise ({name}) — refusing to commit"

    trace.save(GOLDEN_DIR / "run_trace.json")
    checkpoint.save_pytree(GOLDEN_DIR / "ckpt", GOLDEN_STEP, state)

    rows = golden_eval_rows()
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    (result,) = server.run([PredictRequest(uid=0, x=rows)])
    np.save(GOLDEN_DIR / "eval_rows.npy", rows)
    np.save(GOLDEN_DIR / "expected_scores.npy", np.asarray(result.scores))

    print(f"golden corpus regenerated under {GOLDEN_DIR}")
    print(f"  staleness histogram {trace.staleness_histogram()}")
    print(f"  expected_scores[:4] = {np.asarray(result.scores)[:4]}")


if __name__ == "__main__":
    main()
