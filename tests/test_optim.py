"""Optimizers + the paper's DelayedGradient staleness mechanism."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

import repro.optim as O


TARGET = jnp.asarray([3.0, -1.0, 0.5])


def _grad(w):
    return w - TARGET


def _run(opt, steps=300, w0=None):
    w = jnp.zeros_like(TARGET) if w0 is None else w0
    st_ = opt.init(w)
    for _ in range(steps):
        u, st_ = opt.update(_grad(w), st_, w)
        w = O.apply_updates(w, u)
    return w


def test_sgd_converges():
    assert np.allclose(_run(O.sgd(0.3)), TARGET, atol=1e-3)


def test_sgd_momentum_converges():
    assert np.allclose(_run(O.sgd(0.05, momentum=0.9)), TARGET, atol=1e-2)


def test_adam_converges():
    assert np.allclose(_run(O.adam(0.1), 400), TARGET, atol=1e-2)


def test_adamw_full_recipe():
    opt = O.adamw(0.1, weight_decay=1e-4, max_grad_norm=1.0)
    assert np.allclose(_run(opt, 500), TARGET, atol=5e-2)


def test_clip_by_global_norm():
    opt = O.clip_by_global_norm(1.0)
    st_ = opt.init(TARGET)
    g = jnp.asarray([30.0, 40.0, 0.0])  # norm 50
    u, _ = opt.update(g, st_, TARGET)
    np.testing.assert_allclose(float(jnp.linalg.norm(u)), 1.0, rtol=1e-5)
    u2, _ = opt.update(g / 100, st_, TARGET)  # below max: untouched
    np.testing.assert_allclose(np.asarray(u2), np.asarray(g / 100), rtol=1e-5)


def test_cosine_schedule_shape():
    lr = O.cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.asarray(1))) < 0.2
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.asarray(100))) < 0.2


# --------------------------------------------------------------- delayed SGD
def test_delay_zero_is_identity():
    inner = O.sgd(0.3)
    assert O.delayed_gradient(inner, 0) is inner


def test_delayed_warmup_applies_nothing():
    opt = O.delayed_gradient(O.sgd(0.5), delay=3)
    w = jnp.zeros_like(TARGET)
    st_ = opt.init(w)
    for _ in range(3):
        u, st_ = opt.update(_grad(w), st_, w)
        assert np.allclose(np.asarray(u), 0.0)


def test_delayed_applies_stale_gradient_exactly():
    """After warm-up, step t must apply the gradient pushed at t - delay."""
    delay = 2
    opt = O.delayed_gradient(O.sgd(1.0), delay=delay)
    w = jnp.zeros(1)
    st_ = opt.init(w)
    grads = [jnp.asarray([float(i + 1)]) for i in range(5)]
    applied = []
    for g in grads:
        u, st_ = opt.update(g, st_, w)
        applied.append(float(-u[0]))  # sgd(1.0): update = -grad
    assert applied == [0.0, 0.0, 1.0, 2.0, 3.0]


@settings(max_examples=10, deadline=None)
@given(delay=st.integers(1, 6), seed=st.integers(0, 1000))
def test_delayed_converges_with_prop1_scaling(delay, seed):
    """Paper conclusion 2: with the step length deflated per Prop. 1,
    delayed SGD converges for any bounded staleness."""
    lr = 0.4 * O.staleness_step_scale(delay, rho=0.5)
    opt = O.delayed_gradient(O.sgd(lr), delay=delay)
    w = _run(opt, steps=800)
    assert np.allclose(w, TARGET, atol=0.1), f"delay={delay}: {w}"


def test_staleness_scale_monotone():
    scales = [O.staleness_step_scale(t, 0.3) for t in range(6)]
    assert all(a > b for a, b in zip(scales, scales[1:]))
    assert scales[0] == 1.0


def test_delayed_adam_pytree():
    """Delayed wrapper must handle arbitrary pytrees (dict of arrays)."""
    params = {"a": jnp.zeros(3), "b": {"c": jnp.ones(2)}}
    tgt = {"a": TARGET, "b": {"c": jnp.asarray([2.0, -2.0])}}
    # paper conclusion 2: stale gradients need a smaller step (adam with
    # lr 0.05 limit-cycles at ~0.14 error under delay=2; 0.01 converges)
    opt = O.delayed_gradient(O.adam(0.01), delay=2)
    st_ = opt.init(params)
    w = params
    for _ in range(1500):
        g = jax.tree.map(lambda x, t: x - t, w, tgt)
        u, st_ = opt.update(g, st_, w)
        w = O.apply_updates(w, u)
    flat_err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(w), jax.tree.leaves(tgt))
    )
    assert flat_err < 0.1
