"""Sharding rules + per-arch policies, validated against the production mesh
geometry. ``spec_for``/``param_specs``/``cache_specs`` only read
``mesh.shape``, so a lightweight stand-in mesh lets these run on 1 device
(real lower+compile coverage lives in the dry-run)."""
import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
import repro.sharding as SH
from repro.launch.shapes import SHAPES, shape_skip_reason
from repro.models.transformer import _map_schema, param_schema


@dataclasses.dataclass
class FakeMesh:
    shape: dict


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})
ARCHS = list(configs.ALIASES)


def _iter_specs(specs):
    out = []

    def walk(node):
        if isinstance(node, P):
            out.append(node)
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(specs)
    return out


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must be divisible by the product of its mesh axes,
    and no mesh axis may appear twice in one spec."""
    cfg = configs.get(arch)
    schema = param_schema(cfg)
    flat: list = []
    _map_schema(lambda path, e: flat.append((path, e)), schema)
    for path, e in flat:
        spec = SH.spec_for(e.shape, e.axes, mesh)
        used = []
        for dim, part in zip(e.shape, tuple(spec)):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            prod = 1
            for a in axes:
                assert a not in used, f"{arch} {path}: axis {a} reused"
                used.append(a)
                prod *= mesh.shape[a]
            assert dim % prod == 0, f"{arch} {path}: {dim} % {prod}"


@pytest.mark.parametrize("arch", ARCHS)
def test_big_params_are_sharded(arch):
    """No tensor above 64 MB may stay fully replicated on the single-pod
    mesh — the ZeRO/megatron invariant that makes 90B params fit."""
    cfg = configs.get(arch)
    schema = param_schema(cfg)
    flat: list = []
    _map_schema(lambda path, e: flat.append((path, e)), schema)
    for path, e in flat:
        n = 1
        for d in e.shape:
            n *= d
        if n * 2 < 64 * 2**20:
            continue
        spec = SH.spec_for(e.shape, e.axes, SINGLE)
        assert any(part is not None for part in tuple(spec)), (
            f"{arch} {'/'.join(path)}: {e.shape} replicated"
        )


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_cache_specs_divisible(arch, shape_name, mesh):
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape_skip_reason(cfg, shape):
        pytest.skip("documented skip")
    from repro.models.cache import cache_structure

    struct = cache_structure(cfg, shape.global_batch, shape.seq_len)
    specs = SH.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)

    def check(s, spec):
        for dim, part in zip(s.shape, tuple(spec)):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            assert dim % prod == 0, f"{arch} {shape_name}: {s.shape} vs {spec}"

    import jax

    jax.tree.map(
        check, struct, specs, is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape")
    )


def test_big_cache_is_distributed():
    """decode_32k KV caches above 1 GiB must shard somewhere."""
    for arch in ARCHS:
        cfg = configs.get(arch)
        if shape_skip_reason(cfg, SHAPES["decode_32k"]):
            continue
        from repro.models.cache import cache_structure

        struct = cache_structure(cfg, 128, 32_768)
        specs = SH.cache_specs(cfg, SINGLE, 128, 32_768)
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            k = struct["self"]["k"]
            n_bytes = 2
            n = n_bytes
            for d in k.shape:
                n *= d
            if n > 2**30:
                spec = specs["self"]["k"]
                assert any(p is not None for p in tuple(spec)), arch


def test_divisible_batch_axes():
    assert SH.divisible_batch_axes(SINGLE, 256) == ("data",)
    assert SH.divisible_batch_axes(SINGLE, 1) == ()
    assert SH.divisible_batch_axes(MULTI, 256) == ("pod", "data")
    assert SH.divisible_batch_axes(MULTI, 2) == ("pod",)


def test_optimizer_state_specs_structure():
    import jax
    import jax.numpy as jnp

    import repro.optim as O

    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    pspecs = {"w": P("data", "model"), "b": P()}
    opt = O.adamw(1e-3, weight_decay=0.1, max_grad_norm=1.0)
    state = jax.eval_shape(opt.init, params)
    specs = SH.optimizer_state_specs(state, pspecs)
    # adam moments inherit param specs
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert P("data", "model") in leaves

    dopt = O.delayed_gradient(opt, 3)
    dstate = jax.eval_shape(dopt.init, params)
    dspecs = SH.optimizer_state_specs(dstate, pspecs)
    ring_spec = dspecs.ring["w"]
    assert tuple(ring_spec) == (None, "data", "model")
