"""Property-based invariants of the histogram/tree substrate.

Randomized draws (hypothesis when installed, the deterministic fallback of
``tests/_hypothesis_compat.py`` otherwise) over the algebraic contracts the
subtraction builder leans on:

  * parent histogram == left child + right child (the subtraction identity);
  * histogram totals == masked ``segment_sum`` (no mass invented or lost);
  * inert samples (h == 0, g == 0 — the Bernoulli-sampled-out invariant)
    contribute to no bucket and no leaf;
  * unsplittable nodes pass every sample left;
  * ``build_tree_multi`` lane k == a standalone ``build_tree`` on column k.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.trees.learner import LearnerConfig, build_tree, build_tree_multi
from repro.trees.tree import leaf_indices


def _draw_case(seed: int, n: int, f: int, n_bins: int, n_nodes: int):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    node = jax.random.randint(k2, (n,), 0, n_nodes, dtype=jnp.int32)
    g = jax.random.normal(k3, (n,))
    h = jax.random.uniform(k4, (n,))
    return bins, node, g, h


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(16, 300),
    f=st.integers(1, 10),
    n_bins=st.sampled_from([4, 8, 16]),
    level=st.integers(1, 4),
)
def test_parent_histogram_equals_child_sum(seed, n, f, n_bins, level):
    """The subtraction identity: children partition their parent's samples,
    so hist(parent p) == hist(child 2p) + hist(child 2p+1)."""
    n_children = 1 << level
    bins, child, g, h = _draw_case(seed, n, f, n_bins, n_children)
    child_hist = ref.histogram_ref(bins, child, g, h, n_children, n_bins)
    parent_hist = ref.histogram_ref(bins, child >> 1, g, h, n_children // 2, n_bins)
    recomposed = child_hist[:, 0::2] + child_hist[:, 1::2]
    np.testing.assert_allclose(
        np.asarray(parent_hist), np.asarray(recomposed), rtol=1e-5, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(16, 300),
    f=st.integers(1, 8),
    n_bins=st.sampled_from([4, 8, 16]),
    n_nodes=st.sampled_from([1, 2, 4, 8]),
)
def test_histogram_totals_match_segment_sum(seed, n, f, n_bins, n_nodes):
    """Summing a histogram over bins recovers the per-node masked
    segment_sum of g and h, for every feature column."""
    bins, node, g, h = _draw_case(seed, n, f, n_bins, n_nodes)
    hist = ref.histogram_ref(bins, node, g, h, n_nodes, n_bins)
    per_node_g = jax.ops.segment_sum(g, node, num_segments=n_nodes)
    per_node_h = jax.ops.segment_sum(h, node, num_segments=n_nodes)
    for feat in range(f):
        np.testing.assert_allclose(
            np.asarray(hist[0, :, feat].sum(-1)), np.asarray(per_node_g),
            rtol=1e-4, atol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(hist[1, :, feat].sum(-1)), np.asarray(per_node_h),
            rtol=1e-4, atol=1e-4,
        )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    depth=st.sampled_from([2, 3, 4]),
    hist_mode=st.sampled_from(["subtract", "rebuild"]),
)
def test_inert_samples_touch_no_bucket_or_leaf(seed, depth, hist_mode):
    """Samples the Bernoulli sampler zeroed out (h == 0 implies g == 0 in
    the trainer) are inert: perturbing their FEATURE ROWS changes neither
    any histogram nor the built tree — structure and leaves are bitwise
    unchanged, because the inert rows of the GH factor are exactly zero."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, f, n_bins = 200, 6, 16
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    m = (jax.random.uniform(k2, (n,)) < 0.7).astype(jnp.float32)
    g = m * jax.random.normal(k3, (n,))
    h = m  # the paper's gradient step: hessian weight = sample weight
    cfg = LearnerConfig(
        depth=depth, n_bins=n_bins, feature_fraction=1.0, hist_mode=hist_mode
    )
    tree = build_tree(cfg, bins, g, h, key)
    # rebin every inert sample to garbage
    scrambled = jnp.where(
        (m == 0.0)[:, None],
        jax.random.randint(k4, (n, f), 0, n_bins, dtype=jnp.int32),
        bins,
    )
    tree2 = build_tree(cfg, scrambled, g, h, key)
    np.testing.assert_array_equal(np.asarray(tree.feature), np.asarray(tree2.feature))
    np.testing.assert_array_equal(
        np.asarray(tree.threshold), np.asarray(tree2.threshold)
    )
    np.testing.assert_array_equal(
        np.asarray(tree.leaf_value), np.asarray(tree2.leaf_value)
    )
    # and at the histogram layer: node 0, both moved and unmoved bins agree
    hist = ref.histogram_ref(bins, jnp.zeros((n,), jnp.int32), g, h, 1, n_bins)
    hist2 = ref.histogram_ref(
        scrambled, jnp.zeros((n,), jnp.int32), g, h, 1, n_bins
    )
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(hist2))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    depth=st.sampled_from([2, 3]),
    hist_mode=st.sampled_from(["subtract", "rebuild"]),
)
def test_unsplittable_nodes_pass_all_samples_left(seed, depth, hist_mode):
    """With min_child_hess above the total hessian mass no split is valid:
    every node degrades to the pass-through split (feature 0, threshold
    n_bins - 1) and every sample routes to leaf 0."""
    key = jax.random.PRNGKey(seed)
    n, f, n_bins = 120, 5, 8
    bins = jax.random.randint(key, (n, f), 0, n_bins, dtype=jnp.int32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    h = jnp.ones((n,))
    cfg = LearnerConfig(
        depth=depth, n_bins=n_bins, feature_fraction=1.0,
        min_child_hess=float(n + 1), hist_mode=hist_mode,
    )
    tree = build_tree(cfg, bins, g, h, key)
    np.testing.assert_array_equal(np.asarray(tree.feature), 0)
    np.testing.assert_array_equal(np.asarray(tree.threshold), n_bins - 1)
    assert (np.asarray(leaf_indices(tree, bins)) == 0).all()


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([2, 3]),
    hist_mode=st.sampled_from(["subtract", "rebuild"]),
)
def test_build_tree_multi_lane_equals_standalone(seed, k, hist_mode):
    """Lane k of the vmapped K-output build is identical to a standalone
    build on column k (vmap batches, it does not reassociate)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    n, f, n_bins = 150, 6, 16
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    g = jax.random.normal(k2, (n, k))
    h = jnp.broadcast_to(
        (jax.random.uniform(k3, (n,)) < 0.8).astype(jnp.float32)[:, None], (n, k)
    )
    g = jnp.where(h > 0, g, 0.0)
    cfg = LearnerConfig(
        depth=3, n_bins=n_bins, feature_fraction=0.8, hist_mode=hist_mode
    )
    stacked = build_tree_multi(cfg, bins, g, h, key)
    for lane in range(k):
        single = build_tree(cfg, bins, g[:, lane], h[:, lane], key)
        np.testing.assert_array_equal(
            np.asarray(stacked.feature[lane]), np.asarray(single.feature)
        )
        np.testing.assert_array_equal(
            np.asarray(stacked.threshold[lane]), np.asarray(single.threshold)
        )
        np.testing.assert_allclose(
            np.asarray(stacked.leaf_value[lane]), np.asarray(single.leaf_value),
            rtol=1e-6, atol=1e-7,
        )
