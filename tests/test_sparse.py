"""The sparse binned-data layer (DESIGN.md §16).

Contracts under test:
  * dense -> SparseBins -> dense round-trips EXACTLY (integer bin codes,
    explicit zero-bin — no tolerance anywhere);
  * histogram builds dispatch on the representation and the ref paths are
    BITWISE identical dense-vs-sparse (the sparse oracle densifies);
  * the Pallas sparse kernel (interpret mode on CPU) matches the oracle to
    f32 tolerance on full and subset (subtraction-mode) builds;
  * build_tree grows the IDENTICAL forest from either representation;
  * serving-side routing (apply_tree) reads the same values through
    ``gather_feature_bins`` on either layout;
  * ``bin_dataset(sparse='auto')`` picks the layout by measured density;
  * the 1D data-parallel builder REJECTS SparseBins (global sample ids
    cannot shard over rows).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.data as D
from repro.kernels import ops, ref
from repro.kernels.histogram_sparse import histogram_sparse_pallas
from repro.trees import binning
from repro.trees.learner import LearnerConfig, build_tree
from repro.trees.tree import apply_tree, leaf_indices


@pytest.fixture(scope="module")
def sparse_pair():
    """(dense bins, SparseBins) views of one high-dim sparse dataset."""
    data = D.make_sparse_classification(256, 24, 4, seed=11, sparse=True)
    sp = data.bins
    assert isinstance(sp, binning.SparseBins)
    return binning.to_dense(sp), sp, data


def _rand_gh(n, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (n,)), jax.random.uniform(k2, (n,)) + 0.1


# ------------------------------------------------------------- round trip
def test_sparse_roundtrip_exact(sparse_pair):
    dense, sp, _ = sparse_pair
    assert np.array_equal(np.asarray(binning.to_dense(sp)), np.asarray(dense))
    sp2 = binning.to_sparse(dense)
    assert np.array_equal(np.asarray(binning.to_dense(sp2)), np.asarray(dense))


def test_sparse_shape_properties(sparse_pair):
    dense, sp, _ = sparse_pair
    assert sp.shape == dense.shape
    assert sp.n_samples == dense.shape[0]
    assert sp.n_features == dense.shape[1]
    # stored entries never collide with the zero bin (exactness invariant)
    codes = np.asarray(sp.codes)
    idx = np.asarray(sp.indices)
    zb = np.asarray(sp.zero_bin)
    valid = idx >= 0
    assert (codes[valid] != zb[idx[valid]]).all()


def test_gather_feature_bins_matches_dense(sparse_pair):
    dense, sp, _ = sparse_pair
    feat = jax.random.randint(
        jax.random.PRNGKey(4), (sp.n_samples,), 0, sp.n_features
    )
    got = binning.gather_feature_bins(sp, feat)
    want = binning.gather_feature_bins(dense, feat)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------------------------- histograms
def test_histogram_ref_bitwise_dense_vs_sparse(sparse_pair):
    dense, sp, _ = sparse_pair
    n = sp.n_samples
    g, h = _rand_gh(n)
    node = jax.random.randint(jax.random.PRNGKey(7), (n,), -1, 4)
    want = ops.build_histogram(dense, node, g, h, 4, n_bins=64, backend="ref")
    got = ops.build_histogram(sp, node, g, h, 4, n_bins=64, backend="ref")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_histogram_sparse_pallas_matches_oracle(sparse_pair):
    dense, sp, _ = sparse_pair
    n = sp.n_samples
    g, h = _rand_gh(n, seed=1)
    node = jax.random.randint(jax.random.PRNGKey(8), (n,), -1, 4)
    want = ref.histogram_ref(dense, node, g, h, 4, 64)
    got = ops.build_histogram_sparse(
        sp.feat_rows, sp.feat_codes, sp.zero_bin, node, g, h,
        4, 64, backend="pallas",
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=0, atol=1e-5
    )


def test_histogram_sparse_subset_matches_oracle(sparse_pair):
    dense, sp, _ = sparse_pair
    n = sp.n_samples
    g, h = _rand_gh(n, seed=2)
    node = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, 4)
    active = jnp.asarray([1, 2], jnp.int32)
    want = ref.histogram_subset_ref(dense, node, g, h, active, 4, 64)
    got = ops.build_histogram_sparse(
        sp.feat_rows, sp.feat_codes, sp.zero_bin, node, g, h,
        4, 64, backend="pallas", active_nodes=active,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=0, atol=1e-5
    )


# ------------------------------------------------------------------ forest
@pytest.mark.parametrize("mode", ["rebuild", "subtract"])
def test_build_tree_identical_forest(sparse_pair, mode):
    dense, sp, _ = sparse_pair
    g, h = _rand_gh(sp.n_samples, seed=3)
    cfg = LearnerConfig(depth=4, n_bins=64, hist_mode=mode)
    key = jax.random.PRNGKey(5)
    td = build_tree(cfg, dense, g, h, key)
    ts = build_tree(cfg, sp, g, h, key)
    for a, b in zip(td, ts):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_apply_tree_routes_identically(sparse_pair):
    dense, sp, _ = sparse_pair
    g, h = _rand_gh(sp.n_samples, seed=4)
    cfg = LearnerConfig(depth=3, n_bins=64)
    tree = build_tree(cfg, dense, g, h, jax.random.PRNGKey(6))
    assert np.array_equal(
        np.asarray(leaf_indices(tree, sp)), np.asarray(leaf_indices(tree, dense))
    )
    assert np.array_equal(
        np.asarray(apply_tree(tree, sp)), np.asarray(apply_tree(tree, dense))
    )


# ---------------------------------------------------------------- dispatch
def test_bin_dataset_auto_picks_by_density():
    rng = np.random.default_rng(0)
    x_sparse = np.zeros((128, 32), np.float32)
    x_sparse[rng.random((128, 32)) < 0.05] = 1.0
    got = binning.bin_dataset(x_sparse, np.zeros(128, np.float32), sparse="auto")
    assert isinstance(got.bins, binning.SparseBins)
    x_dense = rng.standard_normal((128, 8)).astype(np.float32)
    got = binning.bin_dataset(x_dense, np.zeros(128, np.float32), sparse="auto")
    assert not isinstance(got.bins, binning.SparseBins)
    # default stays dense regardless of density
    got = binning.bin_dataset(x_sparse, np.zeros(128, np.float32))
    assert not isinstance(got.bins, binning.SparseBins)


def test_1d_builder_rejects_sparse(sparse_pair):
    _, sp, _ = sparse_pair
    from repro.ps.sharded import make_sharded_builder

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    builder = make_sharded_builder(LearnerConfig(depth=2, n_bins=64), mesh)
    g = jnp.zeros((sp.n_samples,), jnp.float32)
    with pytest.raises(ValueError, match="1, P_f"):
        builder(sp, g, g, jax.random.PRNGKey(0))
