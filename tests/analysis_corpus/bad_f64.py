"""Corpus: an in-trace float64 intermediate (the double-rounding shape).

Traced under ``jax_enable_x64`` this promotes to f64 mid-program and
rounds back down — the value rounds TWICE, violating the round-once
host-twin rule ``repro.analysis.determinism.audit_f64`` enforces.
"""
import jax.numpy as jnp


def double_round(x):
    wide = x.astype(jnp.float64) * 3.141592653589793
    return wide.astype(jnp.float32)
