"""Corpus (fake repo): a trace row write outside the v2 schema."""
import numpy as np

_ARRAYS_V1 = {"schedule": np.int32}
_ARRAYS_V2 = {**_ARRAYS_V1, "epoch": np.int32}


def fill(rows):
    rows["schedule"][0] = 1
    rows["staleness"][0] = 2
