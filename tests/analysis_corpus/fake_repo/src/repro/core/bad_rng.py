"""Corpus (fake repo): a PRNGKey minted outside ticket-key derivation."""
import jax


def fresh_key():
    return jax.random.PRNGKey(1234)
