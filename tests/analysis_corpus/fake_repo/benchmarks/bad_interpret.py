"""Corpus (fake repo): hardcoded interpret=True outside tests/."""


def run(ops, bins, g):
    return ops.histogram(bins, g, interpret=True)
