"""Corpus: the crash-at-grab bug class — guarded state touched lock-free.

``repro.analysis.locks`` must flag the write in ``worker`` (a thread
target) and the read in ``reporter`` (a ``# concurrent`` opt-in).
"""
import threading

lock = threading.Lock()
shared = {"version": 0}  # guarded-by: lock


def worker() -> None:
    shared["version"] += 1  # racy: no lock held


def reporter() -> int:  # concurrent
    return shared["version"]


def fine() -> None:
    with lock:
        shared["version"] += 1


def main() -> None:
    t = threading.Thread(target=worker)
    t.start()
    t.join()
