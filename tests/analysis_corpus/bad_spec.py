"""Corpus: BlockSpec placements ``repro.analysis.vmem`` must flag.

A ``(1, 1)`` scalar spec without ``memory_space=pltpu.SMEM`` parks a
scalar in a full VMEM vector tile (the pre-PR-6 split_scan placement);
``ANY`` leaves placement to the compiler. ``good_scalar_spec`` is the
correct SMEM form and must be clean.
"""
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def bad_scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def bad_any_spec():
    return pl.BlockSpec((8, 128), lambda i: (0, 0), memory_space=pltpu.ANY)


def good_scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)
