"""Corpus: sibling derived BEFORE the psum — the exact inversion of the
subtract-after-psum invariant in ``ps/sharded.py``.

``parent`` and ``left`` are shard-local partial aggregates; subtracting
them pre-merge reorders the f32 reduction per shard, so the merged result
leaves bitwise lockstep with the single-device build.
``make_good_builder`` subtracts after the collective and must be clean.
"""
import jax
import jax.numpy as jnp

try:
    from jax import shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_bad_builder(mesh: Mesh):
    def body(bins, g):
        parent = jnp.sum(g)
        left = jnp.sum(jnp.where(bins > 0, g, jnp.float32(0.0)))
        sibling = parent - left  # pre-merge subtract: the violation
        return jax.lax.psum(sibling, "data")

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())


def make_good_builder(mesh: Mesh):
    def body(bins, g):
        parent = jax.lax.psum(jnp.sum(g), "data")
        left = jax.lax.psum(jnp.sum(jnp.where(bins > 0, g, jnp.float32(0.0))), "data")
        return parent - left  # post-merge: commutes with the collective

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())


def make_bad_argmax_builder(mesh: Mesh):
    """Argmax merge BEFORE the row psum — the 2D mesh inversion.

    ``best`` is a max over shard-local PARTIAL histogram sums: pmax-merging
    it picks the winner from per-shard partials (max does not commute with
    the data-axis psum), so different shard counts elect different splits.
    """

    def body(bins, g):
        hist = jax.ops.segment_sum(g, bins, num_segments=8)  # local partial
        best = jnp.max(hist)  # gain over UNMERGED sums
        return jax.lax.pmax(best, "data")  # premerge argmax: the violation

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())


def make_good_argmax_builder(mesh: Mesh):
    """Row psum first, argmax merge after — DESIGN.md §16 ordering."""

    def body(bins, g):
        hist = jax.ops.segment_sum(g, bins, num_segments=8)
        hist = jax.lax.psum(hist, "data")  # merge rows FIRST
        best = jnp.max(hist)  # gain over merged sums
        return jax.lax.pmax(best, "data")  # merged-argmax collective: clean

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
