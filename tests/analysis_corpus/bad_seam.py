"""Corpus: propose→fold seams ``repro.analysis.determinism`` must flag.

``unpinned_round`` has no barrier at all; ``leaky_round`` pins one value
but lets ``delta`` flow around the barrier straight into the fold-side
add — the FMA-contractible mul→add pair the seam audit exists to catch.
``pinned_round`` is the clean shape (everything crossing the seam passes
the barrier) and must produce no findings.
"""
import jax
import jax.numpy as jnp


def unpinned_round(f, g):
    delta = g * jnp.float32(0.5)
    return f + delta


def leaky_round(f, g):
    delta = g * jnp.float32(0.5)
    tree = delta + jnp.float32(1.0)
    tree = jax.lax.optimization_barrier(tree)
    return (f + tree) + delta


def pinned_round(f, g):
    delta = g * jnp.float32(0.5)
    tree = delta + jnp.float32(1.0)
    tree, delta = jax.lax.optimization_barrier((tree, delta))
    return (f + tree) + delta
