"""Event-driven cluster simulator + closed-form speedup models (Eq. 13)."""
import numpy as np
import pytest

from repro.core.baselines import (
    max_workers_bound,
    speedup_model_async,
    speedup_model_dimboost,
    speedup_model_sync,
)
from repro.core.simulator import ClusterSpec, simulate_async, simulate_sync


def _spec(workers, **kw):
    base = dict(t_build=1.0, t_comm=0.02, t_server=0.01, seed=3)
    base.update(kw)
    return ClusterSpec(n_workers=workers, **base)


def test_async_makespan_scales_with_workers():
    m1 = simulate_async(_spec(1), 200).makespan
    m8 = simulate_async(_spec(8), 200).makespan
    m32 = simulate_async(_spec(32), 200).makespan
    assert m8 < m1 / 4  # near-linear early
    assert m32 < m8  # still improving


def test_async_staleness_tracks_worker_count():
    for w in (2, 8, 24):
        res = simulate_async(_spec(w), 400)
        assert w * 0.3 < res.mean_staleness < w * 2.5, (w, res.mean_staleness)
        assert res.max_staleness >= res.mean_staleness


def test_async_schedule_is_valid():
    res = simulate_async(_spec(8), 300)
    j = np.arange(300)
    assert (res.schedule <= j).all()  # k(j) <= j
    # locally jittered (network noise) but globally advancing
    assert res.schedule[-50:].mean() > res.schedule[:50].mean() + 100
    assert res.schedule[-1] >= 300 - 8 * 3  # tail staleness bounded ~W


def test_server_saturation_limits_speedup():
    """Eq. 13: beyond T(build)/T(comm+server) extra workers stop helping.
    In the simulator the serialized resource is the server (worker-side
    comm overlaps), so the bound uses t_comm=0 + the server time."""
    bound = max_workers_bound(t_build=1.0, t_comm=0.0, t_server=0.1)
    m_at = simulate_async(_spec(int(bound), t_comm=0.0, t_server=0.1), 300).makespan
    m_over = simulate_async(
        _spec(int(bound * 4), t_comm=0.0, t_server=0.1), 300
    ).makespan
    assert m_over > m_at * 0.5  # no 4x gain from 4x workers past the bound


def test_sync_slower_than_async_at_scale():
    for w in (8, 32):
        sync = simulate_sync(_spec(w), 100)
        async_ = simulate_async(_spec(w), 100).makespan
        assert async_ < sync, f"W={w}"


def test_sync_straggler_penalty_grows():
    """More heterogeneity => worse fork-join makespan (the paper's core
    argument for asynchrony)."""
    calm = simulate_sync(_spec(16, speed_spread=0.05), 100)
    rough = simulate_sync(_spec(16, speed_spread=0.6), 100)
    assert rough > calm


def test_speedup_models_shapes():
    w = np.array([1, 2, 4, 8, 16, 32])
    a = speedup_model_async(w, 1.0, 0.02, 0.01)
    s = speedup_model_sync(w, 1.0, 0.02, 0.01)
    d = speedup_model_dimboost(w, 1.0, 0.02, 0.01)
    assert a[0] == pytest.approx(1.0, rel=0.1)
    assert (np.diff(a) >= -1e-9).all()  # monotone
    assert a[-1] > s[-1] and a[-1] > d[-1]  # async wins at 32 (paper Fig. 10)
    # DimBoost's centralized comm makes it degrade hardest at scale
    assert d[-1] < s[-1] * 1.5


def test_dimboost_linear_comm_penalty():
    w = np.array([32])
    d_fast_net = speedup_model_dimboost(w, 1.0, 0.001, 0.01)
    d_slow_net = speedup_model_dimboost(w, 1.0, 0.05, 0.01)
    assert d_fast_net > d_slow_net * 2


# ----------------------------------------------------------- elastic churn
def test_simulate_elastic_no_churn_matches_async():
    """No membership events: simulate_elastic degenerates to the same
    process simulate_async models (same distributional knobs; identical
    staleness scale)."""
    from repro.core.simulator import simulate_elastic

    spec = _spec(4)
    plain = simulate_async(spec, 200)
    elastic = simulate_elastic(spec, 200)
    assert abs(elastic.mean_staleness - plain.mean_staleness) < 1.5
    assert elastic.max_staleness <= 4 * plain.max_staleness + 2


def test_simulate_elastic_leave_reduces_staleness():
    """Workers leaving mid-run: fewer pullers racing the server, so the
    post-event staleness drops — and a join brings it back up."""
    from repro.core.simulator import simulate_elastic

    spec = _spec(8)
    shrink = simulate_elastic(spec, 400, membership=[(100, -6)])
    tail = np.arange(400)[200:] - shrink.schedule[200:]
    head = np.arange(400)[:100] - shrink.schedule[:100]
    assert tail.mean() < head.mean()
    grow = simulate_elastic(spec, 400, membership=[(100, -6), (200, 6)])
    regrown = np.arange(400)[300:] - grow.schedule[300:]
    assert regrown.mean() > tail.mean()


def test_simulate_elastic_everyone_leaves_raises():
    from repro.core.simulator import simulate_elastic

    with pytest.raises(RuntimeError, match="no live workers"):
        simulate_elastic(_spec(2), 400, membership=[(10, -2)])
    with pytest.raises(ValueError):
        simulate_elastic(_spec(2), 10, membership=[(-1, 1)])


def test_step_scale_stats_and_elastic_crossvalidation():
    """The elastic + adaptive arms of crossvalidate_schedule: membership
    deltas route to simulate_elastic, adaptive_rho adds realized and
    simulated effective-step summaries."""
    from repro.core.simulator import crossvalidate_schedule, step_scale_stats

    spec = _spec(4)
    sim = simulate_async(spec, 120)
    stats = step_scale_stats(sim.schedule, rho=0.1)
    assert 0 < stats["min_scale"] <= stats["mean_scale"] <= 1.0
    serial = step_scale_stats(np.arange(50), rho=0.1)
    assert serial["mean_scale"] == 1.0  # tau = 0 everywhere
    xval = crossvalidate_schedule(
        sim.schedule, spec, makespan=sim.makespan,
        membership=[(30, -1), (60, 1)], adaptive_rho=0.1,
    )
    assert "realized_step_scale" in xval and "simulated_step_scale" in xval
    assert xval["realized_step_scale"]["mean_scale"] == stats["mean_scale"]
    assert xval["simulated"]["max_staleness"] >= 0
