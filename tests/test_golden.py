"""Golden regression corpus: checkpoint format, trace replay, serving.

The fixtures under ``tests/golden/`` were written by ``regen.py`` (which
is also imported here as the single source of the golden config/data, so
the fixture and this reader cannot drift). They pin three cross-PR
contracts:

  * the on-disk checkpoint format stays readable — by the CRC-checked
    pytree restore AND by the serving loader;
  * a committed ``RunTrace`` keeps replaying to the committed forest
    (ints exact; float leaves to 1e-6 — bitwise on the recording
    container, tolerance covers jax-version drift in CI's `latest` lane);
  * serving outputs for committed raw rows stay put.

If a PR intentionally changes any of these contracts, rerun
``PYTHONPATH=src python tests/golden/regen.py`` and commit the diff —
the regeneration self-checks its own record/replay bitwise first.
"""
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro import checkpoint
from repro.core.sgbdt import init_state
from repro.ps.runtime import RunTrace, replay_trace
from repro.serving.forest_server import (
    ForestServer,
    PredictRequest,
    load_forest_checkpoint,
)

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"

_spec = importlib.util.spec_from_file_location("golden_regen", GOLDEN / "regen.py")
regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regen)


@pytest.fixture(scope="module")
def golden_cfg():
    return regen.golden_config()


@pytest.fixture(scope="module")
def golden_data():
    return regen.golden_data()


@pytest.fixture(scope="module")
def golden_forest(golden_cfg, golden_data):
    """The committed forest, via the CRC-checked TrainState restore."""
    like = init_state(golden_cfg, golden_data)
    return checkpoint.restore_pytree(
        GOLDEN / "ckpt", regen.GOLDEN_STEP, like, check_crc=True
    ).forest


def test_checkpoint_latest_step_and_manifest():
    assert checkpoint.latest_step(GOLDEN / "ckpt") == regen.GOLDEN_STEP
    manifest = json.loads(
        (checkpoint.step_dir(GOLDEN / "ckpt", regen.GOLDEN_STEP)
         / "manifest.json").read_text()
    )
    assert manifest["step"] == regen.GOLDEN_STEP
    assert all("crc32" in leaf for leaf in manifest["leaves"])


def test_checkpoint_readable_by_trainstate_restore(golden_forest):
    assert int(golden_forest.n_trees) == regen.GOLDEN_STEP
    assert golden_forest.depth == regen.golden_config().learner.depth
    assert np.isfinite(np.asarray(golden_forest.leaf_value)).all()


def test_checkpoint_readable_by_serving_loader(golden_forest):
    """The serving loader must keep opening training checkpoints without a
    training-set-sized template."""
    served = load_forest_checkpoint(GOLDEN / "ckpt", regen.GOLDEN_STEP)
    for name in ("feature", "threshold", "leaf_value", "n_trees", "base_score"):
        np.testing.assert_array_equal(
            np.asarray(getattr(served, name)),
            np.asarray(getattr(golden_forest, name)),
        )


def test_trace_replays_to_committed_forest(golden_cfg, golden_data, golden_forest):
    trace = RunTrace.load(GOLDEN / "run_trace.json")
    assert trace.n_trees == golden_cfg.n_trees
    state, losses = replay_trace(golden_cfg, golden_data, trace)
    np.testing.assert_array_equal(
        np.asarray(state.forest.feature), np.asarray(golden_forest.feature)
    )
    np.testing.assert_array_equal(
        np.asarray(state.forest.threshold), np.asarray(golden_forest.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(state.forest.leaf_value),
        np.asarray(golden_forest.leaf_value),
        rtol=0, atol=1e-6,
    )
    assert np.isfinite(np.asarray(losses)).all()


def test_serving_outputs_locked(golden_data, golden_forest):
    rows = np.load(GOLDEN / "eval_rows.npy")
    expected = np.load(GOLDEN / "expected_scores.npy")
    np.testing.assert_array_equal(rows, regen.golden_eval_rows())
    server = ForestServer(golden_forest, golden_data.bin_edges, max_rows=32)
    (result,) = server.run([PredictRequest(uid=0, x=rows)])
    np.testing.assert_allclose(result.scores, expected, rtol=1e-5, atol=1e-5)
