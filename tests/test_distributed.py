"""Distributed-equivalence tests: the sharded program must compute the SAME
numbers as the single-device program. Runs in a subprocess so the forced
8-device CPU platform never leaks into the rest of the suite."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.configs as configs
    import repro.models as M
    import repro.optim as O
    import repro.sharding as SH
    from repro.launch.steps import make_decode_step, make_train_step

    assert jax.device_count() == 8
    from repro.launch.mesh import _mesh
    mesh = _mesh((4, 2), ("data", "model"))

    results = {}
    key = jax.random.PRNGKey(0)

    for arch in ["granite-3-2b", "phi3.5-moe-42b-a6.6b", "xlstm-1.3b"]:
        cfg = configs.get(arch).reduced()
        params = M.init_params(cfg, key)
        opt = O.adamw(1e-3, max_grad_norm=1.0)
        ostate = opt.init(params)
        B, S = 8, 32
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}

        # single device
        step0 = jax.jit(make_train_step(cfg, opt))
        p0, o0, m0 = step0(params, ostate, batch, key)

        # sharded: params over rules, batch over data
        pspecs = SH.param_specs(cfg, mesh)
        pshard = SH.tree_shardings(mesh, pspecs)
        oshard = SH.tree_shardings(
            mesh, SH.optimizer_state_specs(jax.eval_shape(opt.init, params), pspecs)
        )
        bshard = SH.tree_shardings(mesh, SH.data_specs(cfg, mesh, B))
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        step1 = jax.jit(
            make_train_step(cfg, opt, mesh, ("data",), grad_specs=pspecs),
            in_shardings=(pshard, oshard, bshard, rep),
            out_shardings=(pshard, oshard, None),
        )
        p1, o1, m1 = step1(params, ostate, batch, key)

        err = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1))
        )
        results[arch] = {
            "loss_single": float(m0["loss"]),
            "loss_sharded": float(m1["loss"]),
            "max_param_diff": err,
        }

    # decode equivalence on one arch (serving placement)
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    B, S = 8, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, {"tokens": toks}, max_len=S + 4)
    d0 = jax.jit(make_decode_step(cfg))
    t0, _ = d0(params, toks[:, :1], cache)
    pshard = SH.tree_shardings(
        mesh, SH.param_specs(cfg, mesh, rules=SH.serving_rules())
    )
    cshard = SH.tree_shardings(mesh, SH.cache_specs(cfg, mesh, B, S + 4))
    d1 = jax.jit(
        make_decode_step(cfg, mesh, ("data",)),
        in_shardings=(pshard, None, cshard),
        out_shardings=(None, cshard),
    )
    t1, _ = d1(params, toks[:, :1], cache)
    results["decode_tokens_equal"] = bool((np.asarray(t0) == np.asarray(t1)).all())

    print("RESULTS_JSON=" + json.dumps(results))
    """
)


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON="):
            return json.loads(line.split("=", 1)[1])
    raise RuntimeError(f"subprocess failed:\n{proc.stderr[-3000:]}")


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch", ["granite-3-2b", "phi3.5-moe-42b-a6.6b", "xlstm-1.3b"]
)
def test_sharded_train_step_matches_single_device(dist_results, arch):
    r = dist_results[arch]
    # MoE tolerates more: expert capacity is enforced per data shard in the
    # expert-parallel path, so a few tokens drop differently than under the
    # single-device global-capacity rule (locality-aware dropping is the
    # standard semantics — GShard does the same).
    tol = 5e-2 if "moe" in arch else 2e-2
    assert abs(r["loss_single"] - r["loss_sharded"]) < tol, r
    assert r["max_param_diff"] < 5e-2, r


@pytest.mark.slow
def test_sharded_decode_matches_single_device(dist_results):
    assert dist_results["decode_tokens_equal"]
