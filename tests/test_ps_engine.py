"""The PS execution layer: trainer equivalences, schedules, sharded builds.

The contracts under test:
  * serial training IS the W=1 round-robin schedule — bitwise;
  * the engine's loop and scan forms produce identical forests;
  * the vmapped worker pool executes the same schedule semantics as the
    per-round loop (exact when split gains are decisive);
  * the shard_map+psum histogram path matches the single-device kernel
    (subprocess with a forced multi-device CPU platform).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sgbdt import SGBDTConfig, train_loss, train_serial
from repro.core.simulator import ClusterSpec
from repro.ps import (
    Trainer,
    resolve_schedule,
    train_worker_parallel,
    worker_round_robin,
)
from repro.ps.schedules import constant_delay, max_staleness
from repro.trees.binning import BinnedData
from repro.trees.learner import LearnerConfig


def _forests_identical(a, b) -> bool:
    return (
        np.array_equal(np.asarray(a.feature), np.asarray(b.feature))
        and np.array_equal(np.asarray(a.threshold), np.asarray(b.threshold))
        and np.allclose(
            np.asarray(a.leaf_value), np.asarray(b.leaf_value), atol=1e-6
        )
    )


# ------------------------------------------------------------ equivalences
def test_round_robin_w1_bitmatches_serial(fast_cfg, sparse_data):
    """The serial trainer is the zero-staleness schedule, same program."""
    st_serial = train_serial(fast_cfg, sparse_data, seed=0)
    st_w1 = Trainer(fast_cfg).train(sparse_data, ("round_robin", 1), seed=0)
    assert np.array_equal(np.asarray(st_serial.f), np.asarray(st_w1.f))
    assert _forests_identical(st_serial.forest, st_w1.forest)


def test_loop_and_scan_identical_forests(fast_cfg, sparse_data):
    """Same schedule + seeds -> the two execution forms agree exactly."""
    tr = Trainer(fast_cfg)
    sched = worker_round_robin(fast_cfg.n_trees, 8)
    st_loop = tr.train(sparse_data, sched, seed=0)
    st_scan, losses = tr.train_scan(sparse_data, sched, seed=0)
    assert np.array_equal(np.asarray(st_loop.f), np.asarray(st_scan.f))
    assert _forests_identical(st_loop.forest, st_scan.forest)
    assert losses.shape == (fast_cfg.n_trees,)
    assert float(losses[-1]) < float(losses[0])


def _decisive_data(n=256):
    """A dataset whose split gains are decisively separated, so tree choice
    cannot flip on ulp-level differences between compiled programs."""
    rng = np.random.default_rng(0)
    bins = rng.integers(0, 16, size=(n, 4)).astype(np.int32)
    y = 10.0 * (bins[:, 0] > 8) + 3.0 * (bins[:, 1] > 4)
    return BinnedData(
        bins=jnp.asarray(bins),
        bin_edges=jnp.zeros((4, 15), jnp.float32),
        labels=jnp.asarray(y, jnp.float32),
        multiplicity=jnp.ones((n,), jnp.float32),
        n_bins=16,
    )


def test_worker_parallel_exact_on_decisive_splits():
    """Batched worker-pool == per-round loop, tree for tree, when gains are
    decisive (deterministic sampling, full features)."""
    data = _decisive_data()
    cfg = SGBDTConfig(
        n_trees=12, step_length=0.5, sampling_rate=1.0, loss="mse",
        learner=LearnerConfig(depth=2, n_bins=16, feature_fraction=1.0),
    )
    st_loop = Trainer(cfg).train(data, ("round_robin", 4), seed=0)
    st_pool = train_worker_parallel(cfg, data, 4, seed=0)
    assert _forests_identical(st_loop.forest, st_pool.forest)
    np.testing.assert_allclose(
        np.asarray(st_loop.f), np.asarray(st_pool.f), atol=1e-5
    )


def test_worker_parallel_loss_equivalence(fast_cfg, sparse_data):
    """On realistic data, near-tied splits may resolve differently between
    the batched and per-round programs; the trained models must agree at
    the loss level."""
    st_loop = Trainer(fast_cfg).train(sparse_data, ("round_robin", 8), seed=0)
    st_pool = train_worker_parallel(fast_cfg, sparse_data, 8, seed=0)
    l_loop = float(train_loss(fast_cfg, sparse_data, st_loop))
    l_pool = float(train_loss(fast_cfg, sparse_data, st_pool))
    assert abs(l_loop - l_pool) < 0.02, (l_loop, l_pool)


def test_simulator_schedule_provider(fast_cfg, sparse_data):
    """A ClusterSpec is a schedule provider: the engine simulates it and
    trains on the realized k(j)."""
    spec = ClusterSpec(n_workers=8, t_build=0.1, t_comm=0.01, t_server=0.01)
    st = Trainer(fast_cfg).train(sparse_data, spec, seed=0)
    from repro.core.sgbdt import init_state

    l0 = float(train_loss(fast_cfg, sparse_data, init_state(fast_cfg, sparse_data)))
    l1 = float(train_loss(fast_cfg, sparse_data, st))
    assert l1 < 0.85 * l0


# --------------------------------------------------------------- schedules
def test_resolve_schedule_specs():
    np.testing.assert_array_equal(
        resolve_schedule(("constant", 3), 10), constant_delay(10, 3)
    )
    np.testing.assert_array_equal(
        resolve_schedule(("round_robin", 4), 10), worker_round_robin(10, 4)
    )
    np.testing.assert_array_equal(
        resolve_schedule(4, 10), worker_round_robin(10, 4)
    )
    np.testing.assert_array_equal(
        resolve_schedule(lambda n: constant_delay(n, 2), 10),
        constant_delay(10, 2),
    )
    explicit = worker_round_robin(10, 2)
    np.testing.assert_array_equal(resolve_schedule(explicit, 10), explicit)


def test_resolve_schedule_rejects_bad():
    with pytest.raises(ValueError):
        resolve_schedule(np.arange(5), 10)  # wrong length
    with pytest.raises(ValueError):
        resolve_schedule(np.arange(10) + 1, 10)  # k(j) > j
    with pytest.raises(ValueError):
        resolve_schedule(np.full(10, -1), 10)  # negative version
    with pytest.raises(ValueError):
        resolve_schedule(("warp", 3), 10)  # unknown closed form
    assert max_staleness(worker_round_robin(16, 4)) == 3


# ------------------------------------------------------- sharded histograms
_SHARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.data as D
    from repro.kernels import ref
    from repro.ps.sharded import build_histogram_sharded, make_sharded_builder
    from repro.trees.learner import LearnerConfig, build_tree

    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, f, n_bins, n_nodes = 512, 16, 16, 4
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    node = jax.random.randint(k2, (n,), -1, n_nodes, dtype=jnp.int32)
    grad = jax.random.normal(k3, (n,))
    hess = jax.random.uniform(k4, (n,))
    h_ref = ref.histogram_ref(bins, node, grad, hess, n_nodes, n_bins)
    h_sh = build_histogram_sharded(
        mesh, bins, node, grad, hess, n_nodes, n_bins, backend="ref"
    )
    hist_max_diff = float(jnp.max(jnp.abs(h_ref - h_sh)))

    cfg = LearnerConfig(depth=3, n_bins=64, feature_fraction=1.0)
    data = D.make_sparse_classification(512, 64, 8, seed=3)
    g = jax.random.normal(key, (512,))
    h = jnp.abs(jax.random.normal(k2, (512,))) + 0.1
    t0 = build_tree(cfg, data.bins, g, h, key)
    t1 = make_sharded_builder(cfg, mesh)(data.bins, g, h, key)
    results = {
        "hist_max_diff": hist_max_diff,
        "tree_feature_equal": bool(
            np.array_equal(np.asarray(t0.feature), np.asarray(t1.feature))
        ),
        "tree_threshold_equal": bool(
            np.array_equal(np.asarray(t0.threshold), np.asarray(t1.threshold))
        ),
        "leaf_max_diff": float(
            jnp.max(jnp.abs(t0.leaf_value - t1.leaf_value))
        ),
    }
    print("RESULTS_JSON=" + json.dumps(results))
    """
)


@pytest.fixture(scope="module")
def shard_results():
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON="):
            return json.loads(line.split("=", 1)[1])
    raise RuntimeError(f"subprocess failed:\n{proc.stderr[-3000:]}")


def test_sharded_histogram_matches_single_device(shard_results):
    """shard_map over a 4-shard 'data' axis + psum == the one-device kernel
    (disjoint sample subsets per cell, so partial sums compose exactly)."""
    assert shard_results["hist_max_diff"] < 1e-4, shard_results


def test_sharded_tree_build_matches_single_device(shard_results):
    assert shard_results["tree_feature_equal"], shard_results
    assert shard_results["tree_threshold_equal"], shard_results
    assert shard_results["leaf_max_diff"] < 1e-5, shard_results


# ------------------------------------------------------------ trainer cache
def test_trainer_cache_lru_bounded():
    """get_trainer must not leak one Trainer (plus its jit caches) per
    config forever across sweeps; the cache is LRU-bounded and clearable."""
    from repro.ps import clear_trainers, get_trainer
    from repro.ps.engine import _TRAINERS, _TRAINERS_MAX

    clear_trainers()
    cfgs = [
        SGBDTConfig(
            n_trees=5 + i, step_length=0.1, sampling_rate=0.8,
            learner=LearnerConfig(depth=2, n_bins=16),
        )
        for i in range(_TRAINERS_MAX + 4)
    ]
    trainers = [get_trainer(c) for c in cfgs]
    assert len(_TRAINERS) == _TRAINERS_MAX
    # most-recent configs hit the same instance; the oldest were evicted
    assert get_trainer(cfgs[-1]) is trainers[-1]
    assert get_trainer(cfgs[0]) is not trainers[0]
    # LRU recency: re-touching an entry protects it from the next eviction
    get_trainer(cfgs[-2])
    extra = SGBDTConfig(
        n_trees=99, step_length=0.1, sampling_rate=0.8,
        learner=LearnerConfig(depth=2, n_bins=16),
    )
    get_trainer(extra)
    assert cfgs[-2] in _TRAINERS
    clear_trainers()
    assert len(_TRAINERS) == 0


# ------------------------------------------------------- staleness-adaptive
def test_adaptive_step_serial_is_bitwise_fixed(fast_cfg, sparse_data):
    """tau = 0 everywhere => scale = 1/(1+6*rho*0) = exactly 1.0f, so the
    adaptive trainer on a serial schedule must reproduce the fixed-step
    forest bit for bit (the flag is free when there is no asynchrony)."""
    fixed = Trainer(fast_cfg).train_scan(sparse_data, ("round_robin", 1), seed=0)[0]
    adaptive = Trainer(fast_cfg._replace(adaptive_step=0.25)).train_scan(
        sparse_data, ("round_robin", 1), seed=0
    )[0]
    assert _forests_identical(fixed.forest, adaptive.forest)
    np.testing.assert_array_equal(np.asarray(fixed.f), np.asarray(adaptive.f))


def test_adaptive_step_rescues_aggressive_step_under_staleness(sparse_data):
    """The point of the 1/(1+6*rho*tau) rule: with an aggressive step and
    deep staleness, fixed-step async diverges toward garbage while the
    deflated step still converges. (At mild step lengths fixed wins — the
    rule is a safety valve, not a free lunch — so the test pins the regime
    the paper's Prop. 1 actually covers: step ~1, tau >> 1.)"""
    cfg = SGBDTConfig(
        n_trees=40, step_length=0.9, sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )
    schedule = ("constant", 12)
    fixed_state = Trainer(cfg).train_scan(sparse_data, schedule, seed=0)[0]
    adaptive_state = Trainer(cfg._replace(adaptive_step=0.1)).train_scan(
        sparse_data, schedule, seed=0
    )[0]
    fixed_loss = float(train_loss(cfg, sparse_data, fixed_state))
    adaptive_loss = float(train_loss(cfg, sparse_data, adaptive_state))
    assert adaptive_loss < fixed_loss * 0.75, (fixed_loss, adaptive_loss)
    # and the deflated run is actually good, not just "less bad"
    assert adaptive_loss < 0.45, adaptive_loss


# --------------------------------------------- 2D (data x feature) sharding
_SHARD2D_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import importlib.util
    import json
    import pathlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.data as D
    from repro.core.sgbdt import init_state
    from repro import checkpoint
    from repro.launch.mesh import make_gbdt_mesh
    from repro.ps.engine import Trainer
    from repro.ps.runtime import RunTrace, replay_trace
    from repro.ps.sharded import (
        collective_bytes_per_build,
        make_sharded_builder,
        make_sharded_builder_2d,
    )
    from repro.trees import binning
    from repro.trees.learner import LearnerConfig, build_tree

    assert jax.device_count() == 8
    results = {}

    def same(a, b):
        return all(
            bool(np.array_equal(np.asarray(x), np.asarray(y)))
            for x, y in zip(a, b)
        )

    cfg = LearnerConfig(depth=3, n_bins=64)
    data = D.make_sparse_classification(512, 64, 8, seed=3)
    sp = binning.to_sparse(data.bins)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    g = jax.random.normal(k1, (512,))
    h = jnp.abs(jax.random.normal(k2, (512,))) + 0.1

    # (1, 4): feature-only sharding is BITWISE vs single-device (the data
    # psum is a size-1 identity; the argmax merge preserves first-max).
    t0 = build_tree(cfg, data.bins, g, h, key)
    mesh_14 = make_gbdt_mesh(1, 4)
    b14 = make_sharded_builder_2d(cfg, mesh_14)
    results["dense_2d_bitwise"] = same(t0, b14(data.bins, g, h, key))
    results["sparse_2d_bitwise"] = same(t0, b14(sp, g, h, key))

    # (2, 4) vs a plain 2-shard 1D mesh: identical data-psum structure,
    # so adding the feature axis changes NOTHING — bitwise incl. leaves.
    mesh_1d = jax.make_mesh((2,), ("data",))
    t_1d = make_sharded_builder(cfg, mesh_1d)(data.bins, g, h, key)
    mesh_24 = make_gbdt_mesh(2, 4)
    t_24 = make_sharded_builder_2d(cfg, mesh_24)(data.bins, g, h, key)
    results["mesh_2x4_matches_1d_x2"] = same(t_1d, t_24)

    # 2x2 (data, feature) smoke through the Trainer
    cfg_t = __import__("repro.core.sgbdt", fromlist=["SGBDTConfig"]).SGBDTConfig(
        n_trees=4, loss="logistic",
        learner=LearnerConfig(depth=3, n_bins=64),
    )
    mesh_22 = make_gbdt_mesh(2, 2)
    st_22 = Trainer(cfg_t, mesh=mesh_22).train(data, ("round_robin", 1), seed=3)
    st_1d = Trainer(cfg_t, mesh=mesh_1d).train(data, ("round_robin", 1), seed=3)
    results["trainer_2x2_matches_1d_x2"] = same(
        jax.tree.leaves(st_22.forest), jax.tree.leaves(st_1d.forest)
    )
    results["trainer_2x2_finite"] = bool(np.isfinite(np.asarray(st_22.f)).all())

    # Realized collective bytes: argmax merge beats the dense-histogram
    # psum, sparse beats dense (trace-time accounting, nothing executes).
    results["bytes_1d"] = collective_bytes_per_build(
        cfg, mesh_1d, data.bins
    )["realized_bytes"]
    results["bytes_2d_dense"] = collective_bytes_per_build(
        cfg, mesh_14, data.bins, feature_axis="feature"
    )["realized_bytes"]
    results["bytes_2d_sparse"] = collective_bytes_per_build(
        cfg, mesh_14, sp, feature_axis="feature"
    )["realized_bytes"]

    # Golden-trace replay under the 2D mesh: the committed forest must
    # reproduce bit-for-bit on dense AND sparse representations.
    golden = pathlib.Path("tests/golden")
    spec = importlib.util.spec_from_file_location("golden_regen", golden / "regen.py")
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)
    gcfg, gdata = regen.golden_config(), regen.golden_data()
    gforest = checkpoint.restore_pytree(
        golden / "ckpt", regen.GOLDEN_STEP, init_state(gcfg, gdata), check_crc=True
    ).forest
    trace = RunTrace.load(golden / "run_trace.json")
    st_g, _ = replay_trace(
        gcfg, gdata, trace, trainer=Trainer(gcfg, mesh=make_gbdt_mesh(1, 4))
    )
    results["golden_replay_2d_bitwise"] = same(
        jax.tree.leaves(st_g.forest), jax.tree.leaves(gforest)
    )
    gdata_sp = gdata._replace(bins=binning.to_sparse(gdata.bins))
    st_gs, _ = replay_trace(
        gcfg, gdata_sp, trace, trainer=Trainer(gcfg, mesh=make_gbdt_mesh(1, 4))
    )
    results["golden_replay_2d_sparse_bitwise"] = same(
        jax.tree.leaves(st_gs.forest), jax.tree.leaves(gforest)
    )

    print("RESULTS_JSON=" + json.dumps(results))
    """
)


@pytest.fixture(scope="module")
def shard2d_results():
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD2D_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON="):
            return json.loads(line.split("=", 1)[1])
    raise RuntimeError(f"subprocess failed:\n{proc.stderr[-3000:]}")


def test_2d_feature_shard_bitwise_vs_single_device(shard2d_results):
    """(1, P_f): the merged-argmax split search preserves the first-max
    tie-break bitwise on dense and sparse representations."""
    assert shard2d_results["dense_2d_bitwise"], shard2d_results
    assert shard2d_results["sparse_2d_bitwise"], shard2d_results


def test_2d_mesh_matches_1d_at_same_data_shards(shard2d_results):
    """(P_d, P_f) == P_d-shard 1D bitwise incl. leaves: the feature axis
    adds only the argmax merge, which picks the identical split."""
    assert shard2d_results["mesh_2x4_matches_1d_x2"], shard2d_results
    assert shard2d_results["trainer_2x2_matches_1d_x2"], shard2d_results
    assert shard2d_results["trainer_2x2_finite"], shard2d_results


def test_2d_collective_bytes_reduced(shard2d_results):
    """The (L,)-sized argmax merge replaces the full (2, L, F, B) histogram
    psum; sparse drops the owner-masked partition psum too."""
    b1 = shard2d_results["bytes_1d"]
    b2 = shard2d_results["bytes_2d_dense"]
    bs = shard2d_results["bytes_2d_sparse"]
    assert b2 < b1 / 10, shard2d_results
    assert bs < b2, shard2d_results


def test_golden_trace_replays_under_2d_mesh(shard2d_results):
    """Record once, replay anywhere: the committed golden forest
    reproduces bit-for-bit under the block-distributed 2D mesh."""
    assert shard2d_results["golden_replay_2d_bitwise"], shard2d_results
    assert shard2d_results["golden_replay_2d_sparse_bitwise"], shard2d_results
