"""Deterministic stand-ins for the small hypothesis API this suite uses.

CI installs hypothesis (requirements.txt) and gets real property-based
testing. On containers without it, test modules fall back to these shims:
``@given`` becomes a pytest parametrization over a fixed number of
deterministic draws from the same strategies, so the property checks still
run (with less adversarial coverage) instead of dying at collection.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import numpy as np
import pytest

_FALLBACK_EXAMPLES = 8


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    @staticmethod
    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def sampled_from(items):
        items = list(items)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def floats(lo, hi, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


st = _Strategies()


def settings(**_kw):
    """All hypothesis settings are irrelevant to the fixed-draw fallback."""

    def deco(fn):
        return fn

    return deco


def given(**strategies):
    """Parametrize over deterministic draws from the given strategies."""

    def deco(fn):
        def wrapper(_example):
            rng = np.random.default_rng(0xC0FFEE + _example)
            fn(**{name: s.draw(rng) for name, s in strategies.items()})

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return pytest.mark.parametrize("_example", range(_FALLBACK_EXAMPLES))(
            wrapper
        )

    return deco
