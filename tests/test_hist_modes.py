"""Differential parity: ``hist_mode='subtract'`` vs ``'rebuild'``.

The subtraction builder (trees/learner.py) is exact in exact arithmetic —
children partition their parent's samples — so the two modes must agree:

  * bitwise on tree STRUCTURE whenever split gains are decisively
    separated (continuous random data; a derived sibling differs from a
    rebuilt one only by f32 subtraction rounding, which can flip argmax
    only on near-ties);
  * to f32 tolerance on histograms, leaves, and losses. Documented
    tolerances: one level of subtraction costs ~1 ulp per cell
    (atol 1e-4 on O(1..100) sums); across a depth-7 build and a
    multi-round training run the drift stays within rtol ~1e-3 on losses.

WITHIN a mode, determinism is bitwise: the threaded runtime's
record-and-replay contract (DESIGN.md §11) must keep holding under the
new 'subtract' default, which this file pins for both modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sgbdt import SGBDTConfig, init_state
from repro.kernels import ops
from repro.ps.engine import get_trainer, propose_tree
from repro.ps.runtime import AsyncRuntime
from repro.trees.learner import LearnerConfig, build_tree
from repro.trees.tree import apply_tree

DEPTHS = (1, 3, 7)
# 'fused' runs the whole-level Pallas program through the same parity
# sweeps; in the histogram-only tests ops.resolve_backend folds it onto
# the staged pallas kernel (level_build is the only fused-aware op).
BACKENDS = ("ref", "pallas", "fused")


def _case(seed, n=700, f=9, n_bins=32):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    g = jax.random.normal(k2, (n,))
    h = (jax.random.uniform(k3, (n,)) < 0.8).astype(jnp.float32)
    return bins, jnp.where(h > 0, g, 0.0), h


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("level", [1, 2, 4])
def test_level_reconstruction_parity(key, backend, level):
    """One level in isolation: build the even children + derive the odd
    ones from the parent, compare against the full rebuild. Tolerance-only
    (no argmax involved): this is the core f32 subtraction error bound."""
    n, f, n_bins = 640, 8, 16
    n_nodes = 1 << level
    bins, g, h = _case(11, n=n, f=f, n_bins=n_bins)
    child = jax.random.randint(jax.random.fold_in(key, 9), (n,), 0, n_nodes,
                               dtype=jnp.int32)
    full = ops.build_histogram(bins, child, g, h, n_nodes, n_bins, backend=backend)
    parent = ops.build_histogram(
        bins, child >> 1, g, h, n_nodes // 2, n_bins, backend=backend
    )
    active = 2 * jnp.arange(n_nodes // 2, dtype=jnp.int32)  # even children
    built = ops.build_histogram_subset(
        bins, child, g, h, active, n_nodes, n_bins, backend=backend
    )
    derived = parent - built  # the odd siblings
    np.testing.assert_allclose(
        np.asarray(built), np.asarray(full[:, 0::2]), rtol=1e-5, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(derived), np.asarray(full[:, 1::2]), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("depth", DEPTHS)
def test_build_tree_mode_parity(key, backend, depth):
    """Whole-tree parity across depths and backends.

    Top levels (well-populated nodes, decisively separated gains) must
    match BITWISE in structure. Deep levels of a depth-7 tree hold a
    handful of samples each; their gains are tiny and near-tied, so one
    ulp of subtraction rounding may flip an argmax — the documented f32
    contract is therefore quantitative below level 4: >= 97% of nodes
    identical and RMS prediction drift <= 1% of the prediction scale.
    """
    bins, g, h = _case(23)
    sub = LearnerConfig(
        depth=depth, n_bins=32, feature_fraction=1.0, backend=backend,
        hist_mode="subtract",
    )
    t_sub = build_tree(sub, bins, g, h, key)
    t_reb = build_tree(sub._replace(hist_mode="rebuild"), bins, g, h, key)
    exact_nodes = (1 << min(depth, 4)) - 1  # heap prefix: levels 0..3
    for name in ("feature", "threshold"):
        a = np.asarray(getattr(t_sub, name))
        b = np.asarray(getattr(t_reb, name))
        np.testing.assert_array_equal(a[:exact_nodes], b[:exact_nodes])
        assert np.mean(a == b) >= 0.97, f"{name}: too many deep-node flips"
    if depth <= 4:
        np.testing.assert_allclose(
            np.asarray(t_sub.leaf_value), np.asarray(t_reb.leaf_value),
            rtol=1e-4, atol=1e-5,
        )
    pred_sub = np.asarray(apply_tree(t_sub, bins))
    pred_reb = np.asarray(apply_tree(t_reb, bins))
    scale = np.sqrt(np.mean(pred_reb**2)) + 1e-12
    drift = np.sqrt(np.mean((pred_sub - pred_reb) ** 2))
    assert drift <= 0.01 * scale, f"prediction drift {drift:.3e} vs scale {scale:.3e}"


def _train_cfg(objective, hist_mode, depth=3, n_trees=15):
    return SGBDTConfig(
        n_trees=n_trees, step_length=0.3, sampling_rate=0.8,
        objective=objective,
        learner=LearnerConfig(depth=depth, n_bins=64, hist_mode=hist_mode),
    )


@pytest.mark.parametrize("objective", ["logistic", "multiclass:3", "quantile:0.5"])
def test_training_mode_parity(objective, sparse_data):
    """End-to-end scan training per objective: the two modes' loss curves
    stay within f32 drift of each other and both converge."""
    data = sparse_data
    if objective == "multiclass:3":
        data = data._replace(
            labels=jnp.asarray(np.asarray(data.labels) % 3, jnp.float32)
        )
    losses = {}
    for mode in ("subtract", "rebuild"):
        _, losses[mode] = get_trainer(_train_cfg(objective, mode)).train_scan(
            data, ("round_robin", 2), seed=0
        )
    sub, reb = (np.asarray(losses[m]) for m in ("subtract", "rebuild"))
    assert np.isfinite(sub).all() and np.isfinite(reb).all()
    np.testing.assert_allclose(sub, reb, rtol=5e-3, atol=5e-4)
    assert sub[-1] < sub[0] and reb[-1] < reb[0]


@pytest.mark.parametrize("objective", ["logistic", "multiclass:3", "quantile:0.5"])
def test_propose_round_mode_parity(objective, sparse_data, key):
    """One worker round per objective: the pushed (tree, delta) payloads of
    the two modes agree to f32 tolerance (K-output shapes included).

    The bitwise structure assertions need a draw whose deep-node gains are
    decisively separated (the file-docstring contract: subtraction rounding
    may flip near-tied argmaxes). The shard-invariant PRNG flag (PR 9,
    ``jax_threefry_partitionable``) re-rolled the stream and PRNGKey(0) now
    lands two level-2 near-ties under multiclass:3 — fold to a decisive
    draw instead of weakening the assertions.
    """
    key = jax.random.fold_in(key, 1)
    data = sparse_data
    if objective == "multiclass:3":
        data = data._replace(
            labels=jnp.asarray(np.asarray(data.labels) % 3, jnp.float32)
        )
    out = {}
    for mode in ("subtract", "rebuild"):
        cfg = _train_cfg(objective, mode)
        state = init_state(cfg, data)
        out[mode] = propose_tree(cfg, data, state.f, key)
    (tree_s, delta_s), (tree_r, delta_r) = out["subtract"], out["rebuild"]
    np.testing.assert_array_equal(
        np.asarray(tree_s.feature), np.asarray(tree_r.feature)
    )
    np.testing.assert_array_equal(
        np.asarray(tree_s.threshold), np.asarray(tree_r.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(tree_s.leaf_value), np.asarray(tree_r.leaf_value),
        rtol=1e-4, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(delta_s), np.asarray(delta_r), rtol=1e-4, atol=1e-6
    )


@pytest.mark.parametrize("hist_mode", ["subtract", "rebuild"])
def test_threaded_replay_bitwise_per_mode(hist_mode, sparse_data):
    """The PR-4 replay contract under the new default: threaded record ->
    ``Trainer.scan_with`` replay reproduces the forest BIT FOR BIT in
    either histogram mode (modes only differ from each other, never from
    themselves)."""
    cfg = SGBDTConfig(
        n_trees=10, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=3, n_bins=64, hist_mode=hist_mode),
    )
    rt = AsyncRuntime(cfg, sparse_data, n_workers=3)
    state, trace = rt.run(seed=1)
    replayed, _ = rt.replay(trace)
    np.testing.assert_array_equal(np.asarray(state.f), np.asarray(replayed.f))
    for name in ("feature", "threshold", "leaf_value"):
        np.testing.assert_array_equal(
            np.asarray(getattr(state.forest, name)),
            np.asarray(getattr(replayed.forest, name)),
        )
