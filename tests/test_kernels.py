"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes/dtypes, plus property tests on the semantics.

The property tests run under hypothesis when it is installed (CI pins it in
requirements.txt); on containers without it they degrade to a fixed-seed
parametrized sweep of the same checks instead of dying at collection
(see tests/_hypothesis_compat.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.histogram import histogram_pallas


def _rand_case(key, n, f, n_bins, n_nodes, grad_dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    node = jax.random.randint(k2, (n,), -1, n_nodes, dtype=jnp.int32)
    grad = jax.random.normal(k3, (n,), grad_dtype)
    hess = jax.random.uniform(k4, (n,), grad_dtype)
    return bins, node, grad, hess


# ---------------------------------------------------------------- histogram
SHAPE_SWEEP = [
    # (N, F, n_bins, n_nodes)
    (64, 4, 8, 1),
    (300, 10, 16, 4),
    (512, 8, 32, 8),
    (1000, 17, 64, 16),  # non-multiple N and F -> exercises padding
    (2048, 32, 64, 32),
]


@pytest.mark.parametrize("n,f,n_bins,n_nodes", SHAPE_SWEEP)
def test_histogram_pallas_matches_ref(key, n, f, n_bins, n_nodes):
    bins, node, grad, hess = _rand_case(key, n, f, n_bins, n_nodes)
    out_ref = ref.histogram_ref(bins, node, grad, hess, n_nodes, n_bins)
    out_pal = ops.build_histogram(
        bins, node, grad, hess, n_nodes, n_bins, backend="pallas"
    )
    np.testing.assert_allclose(out_ref, out_pal, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("sample_block,feature_block", [(128, 4), (256, 8), (512, 16)])
def test_histogram_pallas_block_shapes(key, sample_block, feature_block):
    """Kernel result must be invariant to BlockSpec tiling choices."""
    bins, node, grad, hess = _rand_case(key, 1024, 16, 16, 4)
    base = ref.histogram_ref(bins, node, grad, hess, 4, 16)
    out = histogram_pallas(
        bins, node, grad, hess, 4, 16,
        sample_block=sample_block, feature_block=feature_block, interpret=True,
    )
    np.testing.assert_allclose(base, out, rtol=1e-5, atol=1e-4)


def test_histogram_inactive_samples_ignored(key):
    bins, node, grad, hess = _rand_case(key, 256, 6, 8, 4)
    node_off = jnp.where(jnp.arange(256) % 2 == 0, node, -1)
    out = ref.histogram_ref(bins, node_off, grad, hess, 4, 8)
    # recompute with only active samples
    act = np.asarray(node_off) >= 0
    out2 = ref.histogram_ref(
        jnp.asarray(np.asarray(bins)[act]),
        jnp.asarray(np.asarray(node_off)[act]),
        jnp.asarray(np.asarray(grad)[act]),
        jnp.asarray(np.asarray(hess)[act]),
        4, 8,
    )
    np.testing.assert_allclose(out, out2, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(8, 200),
    f=st.integers(1, 12),
    n_bins=st.sampled_from([4, 8, 16]),
    n_nodes=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_histogram_mass_conservation(n, f, n_bins, n_nodes, seed):
    """Property: summing a histogram over (node, bin) recovers the total
    grad/hess mass of active samples, for every feature."""
    key = jax.random.PRNGKey(seed)
    bins, node, grad, hess = _rand_case(key, n, f, n_bins, n_nodes)
    out = ref.histogram_ref(bins, node, grad, hess, n_nodes, n_bins)
    active = np.asarray(node) >= 0
    tg = float(np.sum(np.asarray(grad)[active]))
    th = float(np.sum(np.asarray(hess)[active]))
    per_feature_g = np.asarray(out[0].sum(axis=(0, 2)))
    per_feature_h = np.asarray(out[1].sum(axis=(0, 2)))
    np.testing.assert_allclose(per_feature_g, tg, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(per_feature_h, th, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------- split gain
@pytest.mark.parametrize("l,f,b", [(1, 4, 8), (4, 8, 16), (8, 16, 64), (16, 7, 32)])
def test_split_gain_pallas_matches_ref(key, l, f, b):
    hist = jax.random.uniform(key, (2, l, f, b), jnp.float32)
    g_ref = ops.split_gain(hist, 1.0, 1e-3, backend="ref")
    g_pal = ops.split_gain(hist, 1.0, 1e-3, backend="pallas")
    ref_m = np.where(np.isfinite(g_ref), np.asarray(g_ref), -1e30)
    pal_m = np.where(np.isfinite(g_pal), np.asarray(g_pal), -1e30)
    np.testing.assert_allclose(ref_m, pal_m, rtol=1e-4, atol=1e-4)


def test_split_gain_last_bin_invalid(key):
    hist = jax.random.uniform(key, (2, 2, 3, 8), jnp.float32)
    gain = ops.split_gain(hist, 1.0, 0.0, backend="ref")
    assert bool(np.all(~np.isfinite(np.asarray(gain)[..., -1])))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([8, 16, 32]))
def test_split_gain_nonnegative_at_optimum(seed, b):
    """Property: gain of the argmax split is >= 0 whenever any split is
    valid (splitting cannot hurt the regularized objective)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (2, 1, 4, b), jnp.float32)
    hist = g.at[1].set(jnp.abs(g[1]) + 0.1)
    best, feat, thr = ref.split_scan_ref(
        hist, jnp.float32(1.0), jnp.float32(1e-6)
    )
    valid = np.isfinite(float(best[0]))
    if valid:
        assert float(best[0]) >= -1e-4


def test_best_split_agrees_with_bruteforce(key):
    hist = jax.random.uniform(key, (2, 3, 5, 16), jnp.float32)
    lam, minh = 0.5, 1e-3
    best, feat, thr = ref.split_scan_ref(hist, jnp.float32(lam), jnp.float32(minh))
    g, h = np.asarray(hist[0]), np.asarray(hist[1])
    for node in range(3):
        best_gain = -np.inf
        for fi in range(5):
            gl = hl = 0.0
            gt, ht = g[node, fi].sum(), h[node, fi].sum()
            for bi in range(15):  # last bin invalid
                gl += g[node, fi, bi]
                hl += h[node, fi, bi]
                gr, hr = gt - gl, ht - hl
                if hl < minh or hr < minh:
                    continue
                gain = gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam)
                best_gain = max(best_gain, gain)
        np.testing.assert_allclose(float(best[node]), best_gain, rtol=1e-4)


# ----------------------------------------------------------- flash attention
FLASH_SWEEP = [
    # (b, sq, sk, h, kv, hd, causal)
    (2, 128, 128, 4, 4, 64, True),
    (2, 128, 128, 4, 4, 64, False),
    (1, 256, 256, 8, 2, 64, True),  # GQA group 4
    (2, 100, 100, 4, 2, 32, True),  # padding path
    (1, 96, 96, 2, 2, 128, False),  # non-causal + padding (kv mask)
    (2, 64, 192, 4, 4, 64, False),  # cross-shaped (Sq != Sk)
]


@pytest.mark.parametrize("b,sq,sk,h,kv,hd,causal", FLASH_SWEEP)
def test_flash_attention_matches_ref(key, b, sq, sk, h, kv, hd, causal):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, hd))
    kk = jax.random.normal(k2, (b, sk, kv, hd))
    v = jax.random.normal(k3, (b, sk, kv, hd))
    o_ref = ops.flash_attention(q, kk, v, causal=causal, backend="ref")
    o_pal = ops.flash_attention(
        q, kk, v, causal=causal, backend="pallas", block_q=64, block_k=64
    )
    np.testing.assert_allclose(o_ref, o_pal, rtol=1e-4, atol=1e-5)


def test_flash_attention_bf16(key):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 128, 4, 64), jnp.bfloat16)
    kk = jax.random.normal(k2, (2, 128, 4, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (2, 128, 4, 64), jnp.bfloat16)
    o_ref = ops.flash_attention(q, kk, v, backend="ref").astype(jnp.float32)
    o_pal = ops.flash_attention(q, kk, v, backend="pallas").astype(jnp.float32)
    np.testing.assert_allclose(o_ref, o_pal, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_invariance(key, bq, bk):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 256, 4, 64))
    kk = jax.random.normal(k2, (1, 256, 4, 64))
    v = jax.random.normal(k3, (1, 256, 4, 64))
    base = ops.flash_attention(q, kk, v, backend="ref")
    out = ops.flash_attention(q, kk, v, backend="pallas", block_q=bq, block_k=bk)
    np.testing.assert_allclose(base, out, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("b,sq,sk,h,kv,hd,causal", FLASH_SWEEP)
def test_flash_attention_backward_matches_ref(key, b, sq, sk, h, kv, hd, causal):
    """The fused Pallas dq/dk/dv kernels vs grads through the oracle."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, hd))
    kk = jax.random.normal(k2, (b, sk, kv, hd))
    v = jax.random.normal(k3, (b, sk, kv, hd))

    def loss(backend):
        def f(q_, k_, v_):
            out = ops.flash_attention(
                q_, k_, v_, causal=causal, backend=backend,
                block_q=64, block_k=64,
            )
            return jnp.sum(jnp.sin(out))
        return f

    gp = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, kk, v)
    gr = jax.grad(loss("ref"), argnums=(0, 1, 2))(q, kk, v)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- forest traversal
def _rand_forest_case(key, n, f, n_bins, n_trees, depth):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_int, n_leaf = (1 << depth) - 1, 1 << depth
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    feat = jax.random.randint(k2, (n_trees, n_int), 0, f, dtype=jnp.int32)
    thr = jax.random.randint(k3, (n_trees, n_int), 0, n_bins, dtype=jnp.int32)
    leaf = jax.random.normal(k4, (n_trees, n_leaf), jnp.float32)
    return bins, feat, thr, leaf


FOREST_SWEEP = [
    # (N, F, n_bins, T, depth, live)
    (64, 4, 8, 1, 2, 1),
    (200, 6, 16, 3, 3, 3),
    (300, 10, 32, 17, 4, 9),  # non-multiple N -> exercises sample padding
    (1000, 17, 64, 40, 6, 25),  # partially filled
    (512, 8, 64, 64, 5, 0),  # nothing live -> exact zeros
]


@pytest.mark.parametrize("n,f,n_bins,n_trees,depth,live", FOREST_SWEEP)
def test_forest_traverse_pallas_matches_ref(key, n, f, n_bins, n_trees, depth, live):
    """Interpret-mode kernel is bitwise-exact vs the oracle (single tree
    block — the serving default for any capacity <= 512)."""
    bins, feat, thr, leaf = _rand_forest_case(key, n, f, n_bins, n_trees, depth)
    nt = jnp.asarray(live, jnp.int32)
    out_ref = ref.forest_traverse_ref(bins, feat, thr, leaf, nt, depth)
    out_pal = ops.forest_traverse(bins, feat, thr, leaf, nt, depth, backend="pallas")
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pal))


def test_forest_traverse_pallas_block_shapes(key):
    """Result must be invariant to tiling; cross-tree-block accumulation is
    float-rounded, so multi-block tilings match to f32 tolerance."""
    from repro.kernels.forest_traversal import forest_traverse_pallas

    bins, feat, thr, leaf = _rand_forest_case(key, 512, 8, 32, 64, 4)
    nt = jnp.asarray(50, jnp.int32)
    base = ref.forest_traverse_ref(bins, feat, thr, leaf, nt, 4)
    for sample_block, tree_block in [(128, 16), (256, 64), (512, 32)]:
        out = forest_traverse_pallas(
            bins, feat, thr, leaf, nt, 4,
            sample_block=sample_block, tree_block=tree_block, interpret=True,
        )
        np.testing.assert_allclose(base, out, rtol=1e-5, atol=1e-5)


def test_forest_traverse_masks_stale_slots(key):
    """Slots >= n_trees must contribute 0 even when they hold garbage —
    the partially-filled / hot-swap serving contract."""
    bins, feat, thr, leaf = _rand_forest_case(key, 256, 6, 16, 12, 3)
    live = 7
    nt = jnp.asarray(live, jnp.int32)
    clean = ref.forest_traverse_ref(
        bins, feat[:live], thr[:live], leaf[:live], nt, 3
    )
    for backend in ("ref", "pallas"):
        out = ops.forest_traverse(bins, feat, thr, leaf, nt, 3, backend=backend)
        np.testing.assert_allclose(clean, out, rtol=1e-6, atol=1e-6)


def test_forest_traverse_ref_matches_apply_forest(key):
    """On zero-padded (training-produced) forests the masked serving sum
    equals the unmasked train-time scan."""
    bins, feat, thr, leaf = _rand_forest_case(key, 400, 8, 16, 10, 4)
    live = 6
    feat = feat.at[live:].set(0)
    thr = thr.at[live:].set(2**30)
    leaf = leaf.at[live:].set(0.0)
    masked = ref.forest_traverse_ref(bins, feat, thr, leaf, live, 4)
    unmasked = ref.apply_forest_ref(bins, feat, thr, leaf, 4)
    np.testing.assert_allclose(masked, unmasked, rtol=1e-6, atol=1e-6)


MULTI_OUT_SWEEP = [
    # (N, F, n_bins, T, depth, live, K) — T and live are slot counts
    (128, 5, 16, 6, 3, 6, 3),
    (300, 8, 32, 20, 4, 12, 4),  # partially-filled, live % K == 0
    (64, 4, 8, 10, 2, 7, 2),  # live mid-round (odd slot count)
    (200, 6, 16, 15, 3, 0, 5),  # nothing live -> exact zeros
]


@pytest.mark.parametrize("n,f,n_bins,n_trees,depth,live,k", MULTI_OUT_SWEEP)
def test_forest_traverse_multi_output_pallas_matches_ref(
    key, n, f, n_bins, n_trees, depth, live, k
):
    """K-output traversal: slot t reduces into column t % K. The kernel's
    per-output masked sums reassociate the reduction vs the oracle's
    segment_sum, so parity is f32-tolerance (bitwise stays a K=1-only
    property of the single-tree-block kernel)."""
    bins, feat, thr, leaf = _rand_forest_case(key, n, f, n_bins, n_trees, depth)
    nt = jnp.asarray(live, jnp.int32)
    out_ref = ref.forest_traverse_ref(bins, feat, thr, leaf, nt, depth, n_outputs=k)
    assert out_ref.shape == (n, k)
    out_pal = ops.forest_traverse(
        bins, feat, thr, leaf, nt, depth, backend="pallas", n_outputs=k
    )
    np.testing.assert_allclose(
        np.asarray(out_ref), np.asarray(out_pal), rtol=1e-6, atol=1e-6
    )
    out_scan = ops.forest_traverse(
        bins, feat, thr, leaf, nt, depth, backend="ref", n_outputs=k
    )
    np.testing.assert_allclose(out_ref, out_scan, rtol=1e-6, atol=1e-6)


def test_forest_traverse_multi_output_columns_are_per_output_sums(key):
    """Column k of the K-output traversal equals a single-output traversal
    over only that output's live slots."""
    k_out, rounds, depth = 3, 4, 3
    bins, feat, thr, leaf = _rand_forest_case(key, 100, 5, 16, k_out * rounds, depth)
    live = k_out * rounds
    out = ref.forest_traverse_ref(
        bins, feat, thr, leaf, jnp.asarray(live), depth, n_outputs=k_out
    )
    for k in range(k_out):
        sel = np.arange(live) % k_out == k
        col = ref.forest_traverse_ref(
            bins, feat[sel], thr[sel], leaf[sel],
            jnp.asarray(int(sel.sum())), depth,
        )
        np.testing.assert_allclose(np.asarray(out[:, k]), np.asarray(col),
                                   rtol=1e-6, atol=1e-6)


# -------------------------------------------------------------- apply_forest
def test_apply_forest_matches_tree_sum(key):
    from repro.trees import LearnerConfig, build_tree, empty_forest, forest_push
    from repro.trees.tree import apply_tree

    bins = jax.random.randint(key, (200, 6), 0, 16, dtype=jnp.int32)
    forest = empty_forest(3, depth=3)
    total = jnp.zeros(200)
    for i in range(3):
        k = jax.random.fold_in(key, i)
        g = jax.random.normal(k, (200,))
        tree = build_tree(
            LearnerConfig(depth=3, n_bins=16, feature_fraction=1.0),
            bins, g, jnp.ones(200), k,
        )
        forest = forest_push(forest, tree, jnp.float32(0.5))
        total = total + 0.5 * apply_tree(tree, bins)
    from repro.trees import forest_predict
    np.testing.assert_allclose(
        np.asarray(forest_predict(forest, bins)),
        np.asarray(forest.base_score + total),
        rtol=1e-5, atol=1e-5,
    )


def test_kernel_interpret_default_autodetects(key):
    """Regression: raw kernel entry points default interpret=None, resolved
    from the backend (interpret off TPU, Mosaic on it) — a direct caller no
    longer silently runs the interpreter on real hardware. On this CPU the
    auto mode must equal an explicit interpret=True run."""
    import inspect

    from repro.kernels.flash_attention import (
        flash_attention_bwd_pallas,
        flash_attention_pallas,
    )
    from repro.kernels.forest_traversal import forest_traverse_pallas
    from repro.kernels.split_scan import split_gain_pallas

    for fn in (
        histogram_pallas,
        split_gain_pallas,
        forest_traverse_pallas,
        flash_attention_pallas,
        flash_attention_bwd_pallas,
    ):
        sig = inspect.signature(fn.__wrapped__)
        assert sig.parameters["interpret"].default is None, fn

    bins, node, grad, hess = _rand_case(key, 512, 8, 16, 4)
    auto = histogram_pallas(bins, node, grad, hess, 4, 16)
    explicit = histogram_pallas(bins, node, grad, hess, 4, 16, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))


# ----------------------------------------------------- quantized traversal
def _quantized_case(key, n, f, n_bins, n_trees, depth, live):
    from repro.trees.forest import Forest

    bins, feat, thr, leaf = _rand_forest_case(key, n, f, n_bins, n_trees, depth)
    forest = Forest(
        feature=feat, threshold=thr, leaf_value=leaf,
        n_trees=jnp.asarray(live, jnp.int32),
        base_score=jnp.asarray(0.0, jnp.float32),
    )
    return bins, forest


@pytest.mark.parametrize("mode", ["int8", "fp16"])
@pytest.mark.parametrize("n,f,n_bins,n_trees,depth,live", FOREST_SWEEP)
def test_quantized_traverse_within_documented_atol(
    key, mode, n, f, n_bins, n_trees, depth, live
):
    """Quantized traversal (both backends) stays within the per-forest
    tolerance ``quantization_atol`` documents: sum over live trees of the
    worst leaf dequantization error."""
    from repro.trees.forest import quantization_atol

    bins, forest = _quantized_case(key, n, f, n_bins, n_trees, depth, live)
    qf = forest.quantize(mode)
    atol = quantization_atol(forest, qf)
    base = np.asarray(
        ref.forest_traverse_ref(
            bins, forest.feature, forest.threshold, forest.leaf_value,
            forest.n_trees, depth,
        )
    )
    for backend in ("ref", "pallas"):
        out = np.asarray(
            ops.forest_traverse(
                bins, qf.feature, qf.threshold, qf.leaf_value, qf.n_trees,
                depth, backend=backend, leaf_scale=qf.leaf_scale,
            )
        )
        assert np.max(np.abs(out - base), initial=0.0) <= atol + 1e-6, backend
    if live == 0:
        np.testing.assert_array_equal(base, np.zeros_like(base))


@pytest.mark.parametrize("mode", ["int8", "fp16"])
def test_quantized_traverse_pallas_bitwise_vs_oracle(key, mode):
    """On the SAME quantized payload the interpret-mode kernel and the
    vectorized oracle dequantize with identical float ops — bitwise."""
    bins, forest = _quantized_case(key, 300, 10, 32, 17, 4, 9)
    qf = forest.quantize(mode)
    q_ref = ref.forest_traverse_ref(
        bins, qf.feature, qf.threshold, qf.leaf_value, qf.n_trees, 4,
        leaf_scale=qf.leaf_scale,
    )
    q_pal = ops.forest_traverse(
        bins, qf.feature, qf.threshold, qf.leaf_value, qf.n_trees, 4,
        backend="pallas", leaf_scale=qf.leaf_scale,
    )
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_pal))


@pytest.mark.parametrize("n,f,n_bins,n_trees,depth,live,k", MULTI_OUT_SWEEP)
def test_quantized_multi_output_parity(key, n, f, n_bins, n_trees, depth, live, k):
    """K-output quantized traversal keeps the per-column t % K contract
    within the documented tolerance on both backends."""
    from repro.trees.forest import quantization_atol

    bins, forest = _quantized_case(key, n, f, n_bins, n_trees, depth, live)
    qf = forest.quantize("int8")
    atol = quantization_atol(forest, qf)
    base = np.asarray(
        ref.forest_traverse_ref(
            bins, forest.feature, forest.threshold, forest.leaf_value,
            forest.n_trees, depth, n_outputs=k,
        )
    )
    for backend in ("ref", "pallas"):
        out = np.asarray(
            ops.forest_traverse(
                bins, qf.feature, qf.threshold, qf.leaf_value, qf.n_trees,
                depth, backend=backend, n_outputs=k, leaf_scale=qf.leaf_scale,
            )
        )
        assert out.shape == (n, k)
        assert np.max(np.abs(out - base), initial=0.0) <= atol + 1e-6, backend


def test_f32_path_ignores_quantization_args(key):
    """The f32 layout must lower the exact historical program: passing a
    leaf_scale alongside f32 leaves changes nothing, bitwise."""
    bins, feat, thr, leaf = _rand_forest_case(key, 256, 8, 32, 16, 4)
    nt = jnp.asarray(11, jnp.int32)
    plain = ops.forest_traverse(bins, feat, thr, leaf, nt, 4, backend="pallas")
    scaled = ops.forest_traverse(
        bins, feat, thr, leaf, nt, 4, backend="pallas",
        leaf_scale=jnp.full((16,), 5.0, jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(scaled))
    plain_r = ops.forest_traverse(bins, feat, thr, leaf, nt, 4, backend="ref")
    scaled_r = ops.forest_traverse(
        bins, feat, thr, leaf, nt, 4, backend="ref",
        leaf_scale=jnp.full((16,), 5.0, jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(plain_r), np.asarray(scaled_r))


def test_quantize_roundtrip_and_mode(key):
    """dequantize() inverts the packing to within the per-tree bound, dead
    slots come back masked-safe, and the mode rides the dtype."""
    _, forest = _quantized_case(key, 8, 6, 64, 10, 3, 7)
    for mode in ("int8", "fp16"):
        qf = forest.quantize(mode)
        assert qf.mode == mode
        deq = qf.dequantize()
        live = np.arange(10) < 7
        np.testing.assert_array_equal(
            np.asarray(deq.feature), np.asarray(forest.feature)
        )
        np.testing.assert_array_equal(
            np.asarray(deq.threshold)[live], np.asarray(forest.threshold)[live]
        )
        np.testing.assert_array_equal(np.asarray(deq.threshold)[~live], 0)
        if mode == "int8":
            bound = np.asarray(qf.leaf_scale)[:, None] / 2 + 1e-7
        else:
            bound = np.abs(np.asarray(forest.leaf_value)) * 2.0**-11 + 1e-7
        assert (
            np.abs(np.asarray(deq.leaf_value) - np.asarray(forest.leaf_value))
            <= bound
        ).all()


def test_quantize_range_checks(key):
    """Bin ids that do not fit the packed threshold dtype must raise, and
    unknown modes must raise — never silently wrap."""
    _, forest = _quantized_case(key, 8, 6, 64, 4, 3, 4)
    with pytest.raises(ValueError, match="int8|fp16"):
        forest.quantize("int4")
    wide = forest._replace(
        threshold=forest.threshold.at[0, 0].set(200)  # n_bins > 128
    )
    with pytest.raises(ValueError, match="int8"):
        wide.quantize("int8")
    wide.quantize("fp16")  # 200 fits int16
    huge = forest._replace(threshold=forest.threshold.at[0, 0].set(40000))
    with pytest.raises(ValueError, match="int16"):
        huge.quantize("fp16")
