"""Fused level-build parity: the one-program level vs the staged pipeline.

``kernels/level_build.py`` runs histogram accumulation, sibling
derivation, the gain scan, argmax, and the row re-route as ONE Pallas
program. Its contracts, pinned here:

  * vs the staged PALLAS pipeline at matched block shapes: BITWISE — the
    fused program issues the same dots in the same order (including the
    K=1 single-sample-block case the acceptance floor names), so
    histograms, split structure, and the row map are exactly equal;
  * vs the jnp REF oracle: split structure and row map exactly equal on
    continuous random data (gains decisively separated), histograms and
    gains to f32 tolerance — rtol 1e-5 / atol 1e-4, the same budget the
    staged kernels carry (one ulp per accumulated O(1..100) cell, dot
    reduction order differs from segment_sum's);
  * through training: the learner consults the committed autotuner table,
    so fused block shapes need NOT match the staged defaults — the
    cross-backend contract there is the same quantitative one the hist
    modes carry (different f32 accumulation orders can flip argmax only
    on near-ties): exact structure at well-populated levels, >= 90% of
    nodes identical overall, and RMS payload drift <= 2% of scale, across
    logistic / multiclass:3 / quantile:0.5 and both hist modes at depths
    1/3/7 (multiclass lanes are the near-tie-prone ones: softmax splits
    each node's gradient mass K ways);
  * the PR-4/5 determinism contracts survive the new backend: threaded
    record -> replay is bit-identical under ``backend='fused'``, and the
    committed golden trace replays to the committed forest (structure
    exact, leaves atol 1e-6 — the corpus was recorded on the ref
    backend, so this calibrates fused-vs-ref drift end to end);
  * levels over the VMEM budget fall back to the staged path with no
    numeric change (fused == pallas stays bitwise when the budget forces
    a mid-tree switch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sgbdt import SGBDTConfig, init_state
from repro.kernels import ops
from repro.kernels.level_build import (
    FUSED_VMEM_BUDGET,
    fused_level_fits,
    fused_level_vmem_bytes,
)
from repro.kernels.ref import level_build_ref
from repro.ps.engine import get_trainer, propose_tree
from repro.ps.runtime import AsyncRuntime
from repro.trees.learner import LearnerConfig, build_tree

DEPTHS = (1, 3, 7)
OBJECTIVES = ("logistic", "multiclass:3", "quantile:0.5")


def _case(seed, n=700, f=9, n_bins=32):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    bins = jax.random.randint(k1, (n, f), 0, n_bins, dtype=jnp.int32)
    g = jax.random.normal(k2, (n,))
    h = (jax.random.uniform(k3, (n,)) < 0.8).astype(jnp.float32)
    return bins, jnp.where(h > 0, g, 0.0), h


def _level_inputs(seed, n, f, n_bins, n_nodes):
    bins, g, h = _case(seed, n=n, f=f, n_bins=n_bins)
    node = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n,), 0, n_nodes, dtype=jnp.int32
    )
    return bins, node, g, h


# ------------------------------------------------------ kernel-level parity
@pytest.mark.parametrize("n_nodes", [1, 2, 4, 8])
@pytest.mark.parametrize("n,f", [(640, 8), (700, 9), (515, 3)])
def test_fused_matches_ref_full_level(n, f, n_nodes):
    """Full-level builds (derive_sibling=False) across ragged geometries
    (515/700 exercise sample padding, 9/3 feature padding)."""
    n_bins = 16
    bins, node, g, h = _level_inputs(5, n, f, n_bins, n_nodes)
    active = jnp.arange(n_nodes, dtype=jnp.int32)
    mask = jnp.ones((f,), jnp.float32)
    args = (bins, node, g, h, active, None, mask, 1.0, 1e-3, n_nodes, n_bins)
    h_r, f_r, t_r, _, n_r = level_build_ref(*args)
    h_f, f_f, t_f, _, n_f = ops.level_build(*args, backend="fused")
    np.testing.assert_array_equal(np.asarray(f_f), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_r))
    np.testing.assert_array_equal(np.asarray(n_f), np.asarray(n_r))
    np.testing.assert_allclose(
        np.asarray(h_f), np.asarray(h_r), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("level", [1, 2, 3])
def test_fused_matches_ref_subtract_level(level):
    """Subtraction levels: only the active children are accumulated, the
    sibling comes from the parent cache inside the kernel."""
    n, f, n_bins = 640, 8, 16
    n_nodes = 1 << level
    bins, node, g, h = _level_inputs(7, n, f, n_bins, n_nodes)
    parent = ops.build_histogram(
        bins, node >> 1, g, h, n_nodes // 2, n_bins, backend="ref"
    )
    active = 2 * jnp.arange(n_nodes // 2, dtype=jnp.int32)
    mask = jnp.ones((f,), jnp.float32)
    args = (bins, node, g, h, active, parent, mask, 1.0, 1e-3, n_nodes, n_bins)
    h_r, f_r, t_r, _, n_r = level_build_ref(*args, derive_sibling=True)
    h_f, f_f, t_f, _, n_f = ops.level_build(
        *args, backend="fused", derive_sibling=True
    )
    np.testing.assert_array_equal(np.asarray(f_f), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_r))
    np.testing.assert_array_equal(np.asarray(n_f), np.asarray(n_r))
    np.testing.assert_allclose(
        np.asarray(h_f), np.asarray(h_r), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("sample_block", [1024, 512, 256])
def test_fused_bitwise_vs_staged_pallas(sample_block):
    """The acceptance floor and beyond: at matched blocks the fused program
    is BITWISE the staged pallas pipeline — 1024 is the K=1 single-block
    case, 512/256 stream 2 and 4 blocks through the same accumulator."""
    n, f, n_bins, n_nodes = 1024, 16, 16, 4
    bins, node, g, h = _level_inputs(9, n, f, n_bins, n_nodes)
    hist_s = ops.build_histogram(
        bins, node, g, h, n_nodes, n_bins, backend="pallas",
        sample_block=sample_block, feature_block=8,
    )
    gain_s = ops.split_gain(hist_s, 1.0, 1e-3, backend="pallas")
    flat = gain_s.reshape(n_nodes, -1)
    idx = jnp.argmax(flat, axis=-1)
    feat_s = (idx // n_bins).astype(jnp.int32)
    thr_s = (idx % n_bins).astype(jnp.int32)

    active = jnp.arange(n_nodes, dtype=jnp.int32)
    mask = jnp.ones((f,), jnp.float32)
    hist_f, feat_f, thr_f, best_f, _ = ops.level_build(
        bins, node, g, h, active, None, mask, 1.0, 1e-3, n_nodes, n_bins,
        backend="fused", sample_block=sample_block, feature_block=8,
    )
    np.testing.assert_array_equal(np.asarray(hist_f), np.asarray(hist_s))
    np.testing.assert_array_equal(np.asarray(feat_f), np.asarray(feat_s))
    np.testing.assert_array_equal(np.asarray(thr_f), np.asarray(thr_s))
    np.testing.assert_array_equal(
        np.asarray(best_f),
        np.asarray(jnp.take_along_axis(flat, idx[:, None], axis=-1)[:, 0]),
    )


def test_feature_mask_respected():
    """Masked features never win a split, matching the staged argmax."""
    n, f, n_bins, n_nodes = 512, 8, 16, 2
    bins, node, g, h = _level_inputs(13, n, f, n_bins, n_nodes)
    mask = jnp.asarray([1, 0, 1, 0, 1, 0, 1, 0], jnp.float32)
    active = jnp.arange(n_nodes, dtype=jnp.int32)
    args = (bins, node, g, h, active, None, mask, 1.0, 1e-3, n_nodes, n_bins)
    _, f_r, t_r, _, _ = level_build_ref(*args)
    _, f_f, t_f, _, _ = ops.level_build(*args, backend="fused")
    np.testing.assert_array_equal(np.asarray(f_f), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(t_f), np.asarray(t_r))
    assert np.all(np.asarray(f_f) % 2 == 0), "a masked feature won a split"


# ------------------------------------------------- learner-level differential
@pytest.mark.parametrize("hist_mode", ["subtract", "rebuild"])
@pytest.mark.parametrize("depth", DEPTHS)
def test_build_tree_fused_parity(key, depth, hist_mode):
    """Whole trees, both hist modes, depths 1/3/7, fused vs pallas AND ref.

    The learner picks the fused program's blocks from the committed
    autotuner table, so its accumulation grouping legitimately differs
    from both staged backends — the cross-backend contract is the
    hist-mode one: bitwise structure on the well-populated heap prefix
    (levels 0..3; decisively separated gains on continuous random data),
    >= 97% of nodes identical overall, and <= 1% RMS prediction drift.
    The BITWISE fused contract lives at matched blocks
    (test_fused_bitwise_vs_staged_pallas)."""
    from repro.trees.tree import apply_tree

    bins, g, h = _case(23)
    trees = {}
    for backend in ("ref", "pallas", "fused"):
        cfg = LearnerConfig(
            depth=depth, n_bins=32, feature_fraction=1.0, backend=backend,
            hist_mode=hist_mode,
        )
        trees[backend] = build_tree(cfg, bins, g, h, key)
    exact_nodes = (1 << min(depth, 4)) - 1  # heap prefix: levels 0..3
    pred = {b: np.asarray(apply_tree(t, bins)) for b, t in trees.items()}
    for other in ("pallas", "ref"):
        for name in ("feature", "threshold"):
            a = np.asarray(getattr(trees["fused"], name))
            b = np.asarray(getattr(trees[other], name))
            np.testing.assert_array_equal(
                a[:exact_nodes], b[:exact_nodes],
                err_msg=f"fused vs {other}: {name} prefix",
            )
            assert np.mean(a == b) >= 0.97, f"fused vs {other}: {name} flips"
        scale = np.sqrt(np.mean(pred[other] ** 2)) + 1e-12
        drift = np.sqrt(np.mean((pred["fused"] - pred[other]) ** 2))
        assert drift <= 0.01 * scale, f"fused vs {other}: drift {drift:.3e}"


def _objective_cfg(objective, backend, hist_mode="subtract"):
    return SGBDTConfig(
        n_trees=8, step_length=0.3, sampling_rate=0.8, objective=objective,
        learner=LearnerConfig(depth=3, n_bins=64, backend=backend,
                              hist_mode=hist_mode),
    )


def _objective_data(objective, sparse_data):
    if objective == "multiclass:3":
        return sparse_data._replace(
            labels=jnp.asarray(np.asarray(sparse_data.labels) % 3, jnp.float32)
        )
    return sparse_data


@pytest.mark.parametrize("hist_mode", ["subtract", "rebuild"])
@pytest.mark.parametrize("objective", OBJECTIVES)
def test_propose_round_fused_parity(objective, hist_mode, sparse_data, key):
    """One worker round per objective x hist mode: the fused backend's
    pushed (tree, delta) payload vs ref (K-output shapes included).

    Multiclass lanes split each node's gradient mass K ways, so deep
    splits near-tie and one ulp of cross-backend accumulation drift can
    flip an argmax (the learner's fused blocks come from the autotuner
    table, not the staged defaults). A flipped near-tie re-routes real
    samples, so the PAYLOAD may differ — what cannot differ is its
    QUALITY: the contract is exact root split per lane, >= 90% of nodes
    identical, and the post-update objective loss within rel 1e-3
    (measured ~5e-5). When structures happen to agree everywhere, the
    floats must too (rtol 1e-5).

    The >=90% bar needs a draw without EXACT gain ties near the root:
    the sparse synthetic data has duplicated columns, and an exactly
    tied split re-routes a whole subtree when the backends break the
    tie in different orders. The shard-invariant PRNG flag (PR 9,
    ``jax_threefry_partitionable``) re-rolled the stream and PRNGKey(0)
    now lands two exact ties at levels 1-2 (verified numerically: equal
    gains to 10 decimals) — fold to a decisive draw instead of
    weakening the assertions."""
    from repro.objectives import get_objective

    key = jax.random.fold_in(key, 1)
    data = _objective_data(objective, sparse_data)
    obj = get_objective(objective)
    out = {}
    for backend in ("ref", "fused"):
        cfg = _objective_cfg(objective, backend, hist_mode)
        state = init_state(cfg, data)
        out[backend] = (state.f, propose_tree(cfg, data, state.f, key))
    (f0, (tree_r, delta_r)), (_, (tree_f, delta_f)) = out["ref"], out["fused"]
    feat_r, feat_f = (np.asarray(t.feature) for t in (tree_r, tree_f))
    thr_r, thr_f = (np.asarray(t.threshold) for t in (tree_r, tree_f))
    # Root split of every output lane is decisively separated.
    np.testing.assert_array_equal(feat_f[..., 0], feat_r[..., 0])
    np.testing.assert_array_equal(thr_f[..., 0], thr_r[..., 0])
    agree = np.mean((feat_f == feat_r) & (thr_f == thr_r))
    assert agree >= 0.90, f"only {agree:.0%} of split nodes identical"
    loss_r = float(obj.loss(data.labels, f0 + delta_r))
    loss_f = float(obj.loss(data.labels, f0 + delta_f))
    assert abs(loss_f - loss_r) <= 1e-3 * abs(loss_r), (
        f"update quality diverged: {loss_f:.6f} vs {loss_r:.6f}"
    )
    if agree == 1.0:
        np.testing.assert_allclose(
            np.asarray(tree_f.leaf_value), np.asarray(tree_r.leaf_value),
            rtol=1e-5, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(delta_f), np.asarray(delta_r), rtol=1e-5, atol=1e-6
        )


@pytest.mark.parametrize("objective", OBJECTIVES)
def test_training_fused_parity(objective, sparse_data):
    """Multi-round scan training per objective: fused and ref loss curves
    agree to accumulated-f32 tolerance and both converge."""
    data = _objective_data(objective, sparse_data)
    losses = {}
    for backend in ("ref", "fused"):
        _, losses[backend] = get_trainer(
            _objective_cfg(objective, backend)
        ).train_scan(data, ("round_robin", 2), seed=0)
    ref_l, fus_l = (np.asarray(losses[b]) for b in ("ref", "fused"))
    assert np.isfinite(ref_l).all() and np.isfinite(fus_l).all()
    np.testing.assert_allclose(fus_l, ref_l, rtol=5e-3, atol=5e-4)
    assert fus_l[-1] < fus_l[0]


# -------------------------------------------------- determinism + golden
def test_threaded_replay_bitwise_fused(sparse_data):
    """The PR-4 record-and-replay contract holds under backend='fused':
    threaded record -> deterministic replay, bit for bit."""
    cfg = SGBDTConfig(
        n_trees=10, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=3, n_bins=64, backend="fused"),
    )
    rt = AsyncRuntime(cfg, sparse_data, n_workers=3)
    state, trace = rt.run(seed=1)
    replayed, _ = rt.replay(trace)
    np.testing.assert_array_equal(np.asarray(state.f), np.asarray(replayed.f))
    for name in ("feature", "threshold", "leaf_value"):
        np.testing.assert_array_equal(
            np.asarray(getattr(state.forest, name)),
            np.asarray(getattr(replayed.forest, name)),
        )


def test_golden_trace_replays_under_fused():
    """The committed PR-5 golden trace replays to the committed forest with
    backend='fused': structure exact, leaves atol 1e-6. The corpus was
    recorded on the ref backend, so this pins fused-vs-ref drift through a
    full threaded schedule, not just one tree."""
    import importlib.util
    import pathlib

    from repro import checkpoint
    from repro.ps.runtime import RunTrace, replay_trace

    golden = pathlib.Path(__file__).resolve().parent / "golden"
    spec = importlib.util.spec_from_file_location(
        "golden_regen_fused", golden / "regen.py"
    )
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)

    cfg, data = regen.golden_config(), regen.golden_data()
    fused_cfg = cfg._replace(
        learner=cfg.learner._replace(backend="fused")
    )
    like = init_state(cfg, data)
    forest = checkpoint.restore_pytree(
        golden / "ckpt", regen.GOLDEN_STEP, like, check_crc=True
    ).forest
    trace = RunTrace.load(golden / "run_trace.json")
    state, _ = replay_trace(fused_cfg, data, trace)
    np.testing.assert_array_equal(
        np.asarray(state.forest.feature), np.asarray(forest.feature)
    )
    np.testing.assert_array_equal(
        np.asarray(state.forest.threshold), np.asarray(forest.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(state.forest.leaf_value), np.asarray(forest.leaf_value),
        rtol=0, atol=1e-6,
    )


# ------------------------------------------------------- VMEM budget gate
def test_vmem_model_monotone_and_gate():
    """The budget model grows with every geometry axis, and the fits gate
    admits small levels while rejecting ones whose resident set cannot fit
    (the staged fallback then runs those levels)."""
    base = fused_level_vmem_bytes(8, 8, 64, 64, 512, 8)
    assert fused_level_vmem_bytes(16, 16, 64, 64, 512, 8) > base
    assert fused_level_vmem_bytes(8, 8, 128, 64, 512, 8) > base
    assert fused_level_vmem_bytes(8, 8, 64, 128, 512, 8) > base
    assert fused_level_vmem_bytes(8, 8, 64, 64, 1024, 8) > base
    assert fused_level_fits(4096, 8, 8, 64, 64)
    # 64 nodes x 800 features x 64 bins: ~100 MiB resident, far over budget.
    assert not fused_level_fits(2000, 64, 64, 800, 64)
    assert fused_level_fits(
        2000, 64, 64, 800, 64, budget=64 * FUSED_VMEM_BUDGET
    )


def test_budget_fallback_is_seamless(key):
    """A tree whose deep levels exceed the budget (F=96 pushes level >= 4
    past a deliberately tiny budget... checked via the public model) still
    builds, and fused == pallas stays bitwise across the switch."""
    n, f, n_bins = 600, 96, 32
    bins = jax.random.randint(key, (n, f), 0, n_bins, dtype=jnp.int32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    h = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) < 0.8).astype(
        jnp.float32
    )
    # At this width the depth-5 tree's last levels are near the real
    # budget's edge; whichever side they land on, parity must hold.
    cfg_f = LearnerConfig(depth=5, n_bins=n_bins, feature_fraction=1.0,
                          backend="fused")
    cfg_p = cfg_f._replace(backend="pallas")
    t_f = build_tree(cfg_f, bins, g, h, key)
    t_p = build_tree(cfg_p, bins, g, h, key)
    for name in ("feature", "threshold", "leaf_value"):
        np.testing.assert_array_equal(
            np.asarray(getattr(t_f, name)), np.asarray(getattr(t_p, name))
        )
