"""repro.analysis: every checker must FIRE on the corpus and stay SILENT
on the repo (modulo the committed baseline).

The corpus under ``tests/analysis_corpus/`` holds one minimal known-bad
snippet per rule; a checker that cannot flag its own corpus file is a
gate that cannot fail, which is no gate at all (the check_bench
``--selftest`` lesson). The clean-side tests then pin the repo itself:
annotations in ``ps/runtime.py`` / ``serving/forest_server.py`` hold, the
kernels' BlockSpecs are SMEM-correct, and the full CLI run agrees with
``analysis_baseline.json`` bit for bit.
"""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import determinism, findings, lints, locks, tuning_schema, vmem

ROOT = pathlib.Path(__file__).resolve().parents[1]
CORPUS = ROOT / "tests" / "analysis_corpus"


def _codes(fs):
    return {f.code for f in fs}


# ------------------------------------------------------------------- locks
def test_locks_flags_corpus():
    fs = locks.check_file(CORPUS / "bad_lock.py", "bad_lock.py")
    assert "unguarded-write" in _codes(fs)  # worker: thread target
    assert "unguarded-read" in _codes(fs)  # reporter: # concurrent opt-in
    idents = {f.ident for f in fs}
    assert "worker:shared" in idents and "reporter:shared" in idents
    # `fine` locks correctly and `main` only touches the Thread object.
    assert not any(f.ident.startswith(("fine:", "main:")) for f in fs)


def test_locks_repo_is_clean():
    assert locks.check_repo(ROOT) == []


def test_locks_catch_delocked_runtime_access():
    """De-indent one locked read in the REAL runtime and the checker must
    notice — proof the annotations there are live, not decorative."""
    src = (ROOT / "src/repro/ps/runtime.py").read_text()
    needle = '                        pulled_version = shared["version"]'
    assert needle in src
    # hoist the read out of `with lock:` (an if-block at the with's own
    # indent keeps the rest of the body parseable)
    broken = src.replace(needle, "                    if True:\n" + needle)
    p = CORPUS / "_runtime_delocked.py"
    try:
        p.write_text(broken)
        fs = locks.check_file(p, "runtime_delocked.py")
        assert "unguarded-read" in _codes(fs)
    finally:
        p.unlink(missing_ok=True)


# ------------------------------------------------------------ determinism
def _import_corpus(name):
    sys.path.insert(0, str(CORPUS))
    try:
        import importlib

        return importlib.import_module(name)
    finally:
        sys.path.pop(0)


@pytest.fixture(scope="module")
def seam_mod():
    return _import_corpus("bad_seam")


def test_seam_unpinned_flagged(seam_mod):
    f = jnp.zeros(8)
    jaxpr = jax.make_jaxpr(seam_mod.unpinned_round)(f, f)
    assert _codes(determinism.audit_seam(jaxpr, "corpus")) == {"seam-unpinned"}


def test_seam_crossing_flagged(seam_mod):
    f = jnp.zeros(8)
    jaxpr = jax.make_jaxpr(seam_mod.leaky_round)(f, f)
    fs = determinism.audit_seam(jaxpr, "corpus")
    assert _codes(fs) == {"seam-crossing"}
    # the leak is the FMA-contractible mul->add pair, named as such
    assert any("FMA-contractible" in f.message for f in fs)


def test_seam_pinned_is_clean(seam_mod):
    f = jnp.zeros(8)
    jaxpr = jax.make_jaxpr(seam_mod.pinned_round)(f, f)
    assert determinism.audit_seam(jaxpr, "corpus") == []


@pytest.mark.filterwarnings("ignore:Explicitly requested dtype")
def test_f64_intermediate_flagged():
    mod = _import_corpus("bad_f64")
    jax.config.update("jax_enable_x64", True)
    try:
        jaxpr = jax.make_jaxpr(mod.double_round)(jnp.zeros(8, jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", False)
    assert "f64-intermediate" in _codes(determinism.audit_f64(jaxpr, "corpus"))
    # the same function traced WITHOUT x64 stays f32 end-to-end: clean
    jaxpr32 = jax.make_jaxpr(mod.double_round)(jnp.zeros(8, jnp.float32))
    assert determinism.audit_f64(jaxpr32, "corpus") == []


def test_staleness_twin_matches():
    assert determinism.audit_staleness_twin() == []


def test_psum_order_flags_premerge_subtract():
    mod = _import_corpus("bad_psum")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    bins = jnp.zeros((8,), jnp.int32)
    g = jnp.zeros((8,), jnp.float32)
    bad = jax.make_jaxpr(mod.make_bad_builder(mesh))(bins, g)
    fs = determinism.audit_psum_order(bad, "corpus")
    assert _codes(fs) == {"premerge-combine"}
    good = jax.make_jaxpr(mod.make_good_builder(mesh))(bins, g)
    assert determinism.audit_psum_order(good, "corpus") == []


def test_psum_order_flags_premerge_argmax():
    """The 2D-mesh inversion: pmax of gains over UNMERGED partial
    histograms must fire; row-psum-then-pmax (the merged-argmax split
    search, DESIGN.md §16) must stay clean."""
    mod = _import_corpus("bad_psum")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    bins = jnp.zeros((8,), jnp.int32)
    g = jnp.zeros((8,), jnp.float32)
    bad = jax.make_jaxpr(mod.make_bad_argmax_builder(mesh))(bins, g)
    fs = determinism.audit_psum_order(bad, "corpus")
    assert _codes(fs) == {"premerge-combine"}
    assert any("pmax" in f.message for f in fs)
    good = jax.make_jaxpr(mod.make_good_argmax_builder(mesh))(bins, g)
    assert determinism.audit_psum_order(good, "corpus") == []


def test_determinism_repo_round_path_is_clean():
    """The real engine honors all three invariants (seam pinned, no f64,
    twin bitwise-equal, subtract after psum)."""
    assert determinism.check_repo(ROOT) == []


# -------------------------------------------------------------------- vmem
def test_vmem_flags_corpus_blockspecs():
    fs = vmem.check_blockspecs(CORPUS / "bad_spec.py", "bad_spec.py")
    assert _codes(fs) == {"blockspec-scalar", "blockspec-any"}
    lines = {f.line for f in fs}
    assert len(lines) == 2  # the SMEM-placed good spec is not flagged


def test_vmem_kernels_are_clean():
    for rel in vmem.KERNEL_FILES:
        assert vmem.check_blockspecs(ROOT / rel, rel) == [], rel


def test_tuning_schema_flags_corpus_table():
    table = json.loads((CORPUS / "bad_table.json").read_text())
    errors = tuning_schema.validate(table)
    joined = "\n".join(errors)
    assert "N128_F8" in joined  # malformed key
    assert "missing field" in joined
    assert "must be > 0" in joined
    assert "unknown fields" in joined


def test_vmem_prices_over_budget_row(tmp_path):
    from repro.kernels.level_build import FUSED_VMEM_BUDGET, fused_level_vmem_bytes

    key = "N16384_F256_B64_L32"
    n, f, b, l = tuning_schema.parse_geometry(key)
    entry = {
        "sample_block": 4096, "feature_block": 8, "node_block": 8,
        "fused_ms": 1.0, "split_ms": 1.0, "host": "test",
    }
    assert (
        fused_level_vmem_bytes(l, l, f, b, 4096, 8) > FUSED_VMEM_BUDGET
    ), "geometry stopped exceeding the budget; pick a bigger corpus row"
    p = tmp_path / "table.json"
    p.write_text(json.dumps({"format": 1, "entries": {key: entry}}))
    fs = vmem.check_tuning_table(p, "table.json")
    assert "tuning-over-budget" in _codes(fs)
    assert any(f.ident == key for f in fs)


# ------------------------------------------------------------------- lints
def test_lints_flag_fake_repo():
    fs = lints.check_repo(CORPUS / "fake_repo")
    by_code = {f.code: f for f in fs}
    assert by_code["hardcoded-interpret"].file == "benchmarks/bad_interpret.py"
    assert by_code["prngkey-outside-ticket"].file == "src/repro/core/bad_rng.py"
    assert by_code["unknown-trace-field"].ident == "staleness"
    # rows["schedule"] IS in the fake schema: exactly one trace finding
    assert sum(f.code == "unknown-trace-field" for f in fs) == 1


def test_lints_repo_is_clean():
    """Clean modulo inline pragmas (the determinism tracer's own keys
    carry `# analysis: ignore[prngkey-outside-ticket]`)."""
    fs = lints.check_repo(ROOT)
    sources = {f.file: (ROOT / f.file).read_text().splitlines() for f in fs}
    assert findings.apply_suppressions(fs, sources) == []


# ------------------------------------------- findings / baseline machinery
def test_fingerprint_survives_line_moves():
    a = findings.Finding("locks", "unguarded-read", "error", "x.py", 10, "m", "f:v")
    b = findings.Finding("locks", "unguarded-read", "error", "x.py", 99, "m", "f:v")
    assert a.fingerprint == b.fingerprint


def test_suppression_pragma():
    f = findings.Finding("lints", "hardcoded-interpret", "error", "a.py", 2, "m")
    pragma = "run(interpret=True)  # analysis: ignore[hardcoded-interpret]"
    sources = {"a.py": ["x = 1", pragma]}
    assert findings.apply_suppressions([f], sources) == []
    assert findings.apply_suppressions([f], {"a.py": ["x", "run(interpret=True)"]}) == [f]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "base.json"
    p.write_text(json.dumps({"findings": [{"fingerprint": "a:b:c:d"}]}))
    with pytest.raises(ValueError, match="justification"):
        findings.load_baseline(p)


def test_split_by_baseline(tmp_path):
    f1 = findings.Finding("locks", "c", "error", "x.py", 1, "m", "i1")
    f2 = findings.Finding("locks", "c", "error", "x.py", 2, "m", "i2")
    base = {f1.fingerprint: "known", "locks:c:gone.py:i9": "fixed long ago"}
    new, old, stale = findings.split_by_baseline([f1, f2], base)
    assert new == [f2] and old == [f1]
    assert stale == ["locks:c:gone.py:i9"]


# --------------------------------------------------------------------- CLI
def _cli(*args, cwd=ROOT):
    import os

    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=env, timeout=600,
    )


def test_cli_selftest_passes():
    r = _cli("--selftest")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "selftest ok" in r.stdout


def test_cli_fails_on_new_and_respects_baseline(tmp_path):
    # a fake repo with one lint violation and no baseline -> exit 1
    root = tmp_path / "repo"
    (root / "benchmarks").mkdir(parents=True)
    (root / "benchmarks" / "b.py").write_text("def r(k):\n    k(interpret=True)\n")
    r = _cli("--only", "lints", "--root", str(root))
    assert r.returncode == 1
    assert "hardcoded-interpret" in r.stdout
    # --no-fail-on-new reports but exits 0
    r = _cli("--only", "lints", "--root", str(root), "--no-fail-on-new")
    assert r.returncode == 0
    # accept into a baseline -> clean run, finding shown as baselined
    base = tmp_path / "base.json"
    r = _cli("--only", "lints", "--root", str(root), "--baseline", str(base),
             "--write-baseline")
    assert r.returncode == 0
    r = _cli("--only", "lints", "--root", str(root), "--baseline", str(base))
    assert r.returncode == 0
    assert "1 baselined" in r.stdout
    # fix the violation -> the baseline entry is reported stale
    (root / "benchmarks" / "b.py").write_text("def r(k):\n    k()\n")
    r = _cli("--only", "lints", "--root", str(root), "--baseline", str(base))
    assert r.returncode == 0
    assert "stale" in r.stdout


def test_cli_stdlib_checkers_match_committed_baseline(tmp_path):
    """The committed repo + committed baseline = green gate (the exact
    invocation the CI analysis job runs, minus the jax-tracing checker
    which test_determinism_repo_round_path_is_clean covers in-process)."""
    report = tmp_path / "report.json"
    r = _cli("--only", "locks", "--only", "vmem", "--only", "lints",
             "--json", str(report))
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(report.read_text())
    assert payload["new"] == []
    assert payload["stale_baseline_entries"] == []
    # the one justified finding: the bench-only over-budget tuning row
    fps = [e["fingerprint"] for e in payload["baselined"]]
    assert fps == [
        "vmem:tuning-over-budget:src/repro/kernels/tuning_table.json:N16384_F256_B64_L32"
    ]
