"""Edge cases of ``ps.schedules.resolve_schedule`` and its providers.

The golden/replay work leans on schedule validation (every committed
trace round-trips through ``resolve_schedule``), so the rejection paths
are load-bearing: a malformed realized array must fail loudly here, not
surface as a silent mis-replay.
"""
import numpy as np
import pytest

from repro.core.simulator import ClusterSpec
from repro.ps.schedules import (
    constant_delay,
    max_staleness,
    resolve_schedule,
    worker_round_robin,
)


def test_realized_array_length_mismatch_rejected():
    good = worker_round_robin(10, 3)
    for bad_len in (9, 11, 0):
        with pytest.raises(ValueError, match="schedule shape"):
            resolve_schedule(good[:bad_len] if bad_len < 10 else
                             np.concatenate([good, [9]]), 10)


def test_realized_array_2d_rejected():
    with pytest.raises(ValueError, match="schedule shape"):
        resolve_schedule(np.zeros((5, 2), np.int32), 10)


def test_causality_violation_rejected():
    sched = worker_round_robin(8, 2)
    sched[3] = 5  # k(3) = 5 > 3: folds a version from the future
    with pytest.raises(ValueError, match="causality"):
        resolve_schedule(sched, 8)


def test_negative_version_rejected():
    sched = constant_delay(8, 1)
    sched[0] = -1
    with pytest.raises(ValueError, match="negative"):
        resolve_schedule(sched, 8)


def test_bad_provider_specs_rejected():
    with pytest.raises(ValueError, match="unknown schedule kind"):
        resolve_schedule(("zigzag", 3), 8)
    with pytest.raises(ValueError, match="tau >= 0"):
        resolve_schedule(("constant", -1), 8)
    with pytest.raises(ValueError, match=">= 1 worker"):
        resolve_schedule(("round_robin", 0), 8)
    with pytest.raises(ValueError, match=">= 1 worker"):
        resolve_schedule(0, 8)  # bare int = round_robin shorthand
    with pytest.raises(TypeError, match="cannot resolve"):
        resolve_schedule(object(), 8)


def test_bare_int_and_tuple_agree():
    np.testing.assert_array_equal(
        resolve_schedule(4, 12), resolve_schedule(("round_robin", 4), 12)
    )


def test_callable_provider_is_validated():
    sched = resolve_schedule(lambda n: np.maximum(0, np.arange(n) - 2), 9)
    assert sched.shape == (9,)
    with pytest.raises(ValueError, match="schedule shape"):
        resolve_schedule(lambda n: np.zeros(n + 1, np.int32), 9)


def test_cluster_spec_degenerate_single_worker():
    """W=1 is the serial trainer: one worker can never outrun the fold
    loop it feeds, so the realized schedule has zero staleness no matter
    what the phase times are — and a zero-staleness schedule needs a
    ring of exactly one version."""
    for t_comm in (0.0, 5.0):  # even absurdly slow comms cannot add staleness
        spec = ClusterSpec(
            n_workers=1, t_build=1e-4, t_comm=t_comm, t_server=1e-4, seed=11
        )
        sched = resolve_schedule(spec, 16)
        np.testing.assert_array_equal(sched, np.arange(16))
        assert max_staleness(sched) == 0


def test_round_robin_steady_state_staleness():
    sched = resolve_schedule(("round_robin", 4), 32)
    tail = np.arange(32)[8:] - sched[8:]
    assert (tail == 3).all()  # steady state: tau = W - 1
    assert max_staleness(sched) == 3


def test_staleness_scales_closed_form():
    """Host twin of the server's adaptive rule: serial schedules scale by
    exactly 1.0; constant-delay schedules by exactly 1/(1+6*rho*tau) with
    the same single f32 rounding of 6*rho the jnp side performs."""
    from repro.ps.schedules import staleness_scales

    serial = staleness_scales(np.arange(20), rho=0.3)
    assert serial.dtype == np.float32
    np.testing.assert_array_equal(serial, np.ones(20, np.float32))
    sched = resolve_schedule(("constant", 4), 32)
    scales = staleness_scales(sched, rho=0.1)
    tau = (np.arange(32) - sched).astype(np.float32)
    expect = np.float32(1.0) / (np.float32(1.0) + np.float32(0.6) * tau)
    np.testing.assert_array_equal(scales, expect)
    # max staleness 4 floors the scale near 1/(1+0.6*4), modulo f32 rounding
    assert scales.min() == pytest.approx(1.0 / (1.0 + 0.6 * 4), rel=1e-6)
