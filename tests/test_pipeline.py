"""Data pipeline: packing invariants + deterministic sharded resumption."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_compat import given, settings, st

from repro.data.pipeline import TokenPipeline, pack_documents


# ------------------------------------------------------------------ packing
@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_docs=st.integers(1, 30),
    seq_len=st.sampled_from([16, 32, 128]),
)
def test_packing_conserves_tokens(seed, n_docs, seq_len):
    """Every non-pad token of every document appears exactly once, in order."""
    rng = np.random.default_rng(seed)
    docs = [
        rng.integers(1, 1000, size=rng.integers(1, 3 * seq_len))
        for _ in range(n_docs)
    ]
    tokens, segments = pack_documents(docs, seq_len)
    flat = tokens[segments > 0]
    want = np.concatenate([d.astype(np.int32) for d in docs])
    # rows are filled greedily in order, so concatenated non-pad tokens
    # reproduce the input stream
    np.testing.assert_array_equal(flat, want)


def test_packing_segments_monotone_within_row():
    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 40)]
    tokens, segments = pack_documents(docs, 16)
    for row in segments:
        nz = row[row > 0]
        assert (np.diff(nz) >= 0).all()
        assert nz[0] == 1  # segment ids restart per row


def test_packing_no_crossdoc_leak_markers():
    docs = [np.full(5, 7), np.full(5, 9)]
    tokens, segments = pack_documents(docs, 16)
    seg_of_7 = set(segments[tokens == 7].tolist())
    seg_of_9 = set(segments[tokens == 9].tolist())
    assert seg_of_7.isdisjoint(seg_of_9)


# ----------------------------------------------------------------- pipeline
def _toy_tokens(n=64, s=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 100, size=(n, s + 1)).astype(np.int32)


def test_batches_are_shifted_pairs():
    pipe = TokenPipeline(_toy_tokens(), batch_size=4)
    b = pipe.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_deterministic_and_resumable():
    pipe = TokenPipeline(_toy_tokens(), batch_size=4, seed=3)
    stream = pipe.iterate(0)
    first = [next(stream) for _ in range(20)]
    resumed = pipe.iterate(12)
    for i in range(8):
        got = next(resumed)
        np.testing.assert_array_equal(got["tokens"], first[12 + i]["tokens"])


def test_epoch_reshuffles():
    pipe = TokenPipeline(_toy_tokens(), batch_size=4, seed=3)
    spe = pipe.steps_per_epoch
    b_e0 = pipe.batch_at(0)
    b_e1 = pipe.batch_at(spe)
    assert not np.array_equal(b_e0["tokens"], b_e1["tokens"])


def test_epoch_covers_every_row_once():
    toks = _toy_tokens(n=64, s=8)
    pipe = TokenPipeline(toks, batch_size=8, seed=1)
    seen = []
    for step in range(pipe.steps_per_epoch):
        seen.append(pipe.batch_at(step)["tokens"])
    seen = np.concatenate(seen)
    # every row of the source appears exactly once in the epoch
    src = {tuple(r) for r in toks[:, :-1].tolist()}
    got = [tuple(r) for r in seen.tolist()]
    assert len(got) == len(src)
    assert set(got) == src


def test_shards_are_disjoint_and_cover():
    toks = _toy_tokens(n=64, s=8)
    rows = set()
    for shard in range(4):
        pipe = TokenPipeline(
            toks, batch_size=4, seed=9, shard_id=shard, num_shards=4
        )
        for step in range(pipe.steps_per_epoch):
            for row in pipe.batch_at(step)["tokens"]:
                rows.add(tuple(row.tolist()))
    assert len(rows) == len({tuple(r) for r in toks[:, :-1].tolist()})


def test_shard_too_small_rejected():
    with pytest.raises(ValueError, match="shard smaller"):
        TokenPipeline(_toy_tokens(n=8), batch_size=4, num_shards=4)
