"""The Objective API: autodiff parity, init scores, the deprecation shim,
and the multiclass/ranking end-to-end contracts (train both ways ->
checkpoint round-trip -> ForestServer serves (rows, K) linked outputs with
the Pallas traversal matching the jnp oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.data as D
from repro.core.sgbdt import (
    SGBDTConfig,
    init_state,
    train_loss,
    train_metrics,
    train_serial,
)
from repro.objectives import (
    BinaryLogistic,
    LambdaRank,
    MulticlassSoftmax,
    Quantile,
    SquaredError,
    get_objective,
    registered_objectives,
)
from repro.trees.learner import LearnerConfig

# One representative instance per registered family (factories that need
# parameters get them here; the parity sweep runs over ALL of these).
PARITY_CASES = [
    get_objective("logistic"),
    get_objective("mse"),
    get_objective("quantile:0.3"),
    get_objective("huber"),
    get_objective("multiclass:4"),
    get_objective("lambdarank"),
    LambdaRank(ndcg_weight=False),  # plain RankNet mode
]


def _case_inputs(obj, n=24, seed=0):
    rng = np.random.default_rng(seed)
    f1 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    qid = jnp.asarray(np.repeat(np.arange(n // 6), 6), jnp.int32)
    if obj.n_outputs > 1:
        y = jnp.asarray(rng.integers(0, obj.n_outputs, n), jnp.float32)
        f = jnp.asarray(rng.standard_normal((n, obj.n_outputs)), jnp.float32)
    elif obj.name == "lambdarank":
        y = jnp.asarray(rng.integers(0, 3, n), jnp.float32)
        f = f1
    else:
        y = jnp.asarray(rng.standard_normal(n), jnp.float32)
        if obj.name == "logistic":
            y = (y > 0).astype(jnp.float32)
        f = f1
    return y, f, qid


def test_every_family_registered():
    names = set(registered_objectives())
    assert {"logistic", "mse", "quantile", "huber", "multiclass", "lambdarank"} <= names


@pytest.mark.parametrize("obj", PARITY_CASES, ids=lambda o: repr(o))
def test_grad_hess_matches_autodiff(obj):
    """grad_hess must be the exact gradient (and, when claimed, the exact
    hessian diagonal) of the objective's own loss_sum potential."""
    y, f, qid = _case_inputs(obj)

    def total(ff):
        return obj.loss_sum(y, ff, qid=qid)

    g, h = obj.grad_hess(y, f, qid=qid)
    if obj.exact_gradient:
        g_ad = jax.grad(total)(f)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(g_ad), rtol=1e-5, atol=1e-5
        )
    if not obj.exact_hessian:
        return
    hess = jax.hessian(total)(f)
    if f.ndim == 1:
        diag = jnp.diagonal(hess)
    else:  # (N, K, N, K) -> per-(sample, output) diagonal
        n, k = f.shape
        diag = hess.reshape(n * k, n * k).diagonal().reshape(n, k)
    np.testing.assert_allclose(np.asarray(h), np.asarray(diag), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- init scores
def test_init_score_squared_error_is_weighted_mean():
    """Regression guard for the old non-logistic init special-case: the
    squared-error prior is the multiplicity-weighted label mean."""
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.standard_normal(50), jnp.float32)
    w = jnp.asarray(rng.integers(1, 5, 50), jnp.float32)
    base = SquaredError().init_score(y, w)
    np.testing.assert_allclose(
        float(base), float(jnp.sum(w * y) / jnp.sum(w)), rtol=1e-6
    )
    data = D.make_sparse_regression(200, 60, 8, seed=1)
    cfg = SGBDTConfig(n_trees=4, objective="mse",
                      learner=LearnerConfig(depth=3, n_bins=64))
    st0 = init_state(cfg, data)
    want = float(jnp.sum(data.multiplicity * data.labels) / jnp.sum(data.multiplicity))
    np.testing.assert_allclose(float(st0.forest.base_score), want, rtol=1e-6)
    assert np.allclose(np.asarray(st0.f), want)


def test_init_score_logistic_unchanged():
    """The shim path must reproduce the historical prior log-odds exactly."""
    rng = np.random.default_rng(4)
    y = jnp.asarray((rng.random(64) > 0.7).astype(np.float32))
    w = jnp.ones(64, jnp.float32)
    ybar = jnp.clip(jnp.mean(y), 1e-6, 1 - 1e-6)
    want = 0.5 * jnp.log(ybar / (1 - ybar))
    got = BinaryLogistic().init_score(y, w)
    assert float(got) == float(want)


def test_init_score_multiclass_log_priors():
    y = jnp.asarray([0, 0, 0, 1, 2, 2], jnp.float32)
    w = jnp.ones(6, jnp.float32)
    base = MulticlassSoftmax(3).init_score(y, w)
    assert base.shape == (3,)
    np.testing.assert_allclose(
        np.asarray(base), np.log(np.array([3, 1, 2]) / 6.0), rtol=1e-5
    )


def test_init_score_quantile_is_weighted_quantile():
    y = jnp.asarray([0.0, 1.0, 2.0, 3.0], jnp.float32)
    w = jnp.asarray([1.0, 1.0, 10.0, 1.0], jnp.float32)
    base = Quantile(alpha=0.5).init_score(y, w)
    assert float(base) == 2.0  # the heavy sample holds the weighted median


# ------------------------------------------------------------ deprecation shim
def test_legacy_loss_strings_resolve():
    assert isinstance(SGBDTConfig(loss="logistic").obj, BinaryLogistic)
    assert isinstance(SGBDTConfig(loss="mse").obj, SquaredError)
    # objective wins over the legacy string when both are set
    cfg = SGBDTConfig(loss="logistic", objective="multiclass:3")
    assert cfg.n_outputs == 3
    with pytest.raises(ValueError, match="unknown objective"):
        SGBDTConfig(loss="hinge").obj


# ------------------------------------------------------- multiclass end-to-end
N_CLASSES = 3


@pytest.fixture(scope="module")
def mc_setup(tmp_path_factory):
    from repro.checkpoint import save_pytree
    from repro.core.async_sgbdt import train_async, worker_round_robin

    data = D.make_multiclass_classification(500, 16, N_CLASSES, seed=2)
    cfg = SGBDTConfig(
        n_trees=24, step_length=0.3, sampling_rate=0.9,
        objective=f"multiclass:{N_CLASSES}",
        learner=LearnerConfig(depth=3, n_bins=64),
    )
    st_serial = train_serial(cfg, data, seed=0)
    st_async = train_async(cfg, data, worker_round_robin(cfg.n_trees, 4), seed=0)
    root = tmp_path_factory.mktemp("mc_ckpt")
    save_pytree(root, cfg.n_trees, st_serial._asdict())
    return cfg, data, st_serial, st_async, root


def test_multiclass_beats_prior_both_trainers(mc_setup):
    """Train accuracy must clearly beat the class prior via train_serial AND
    train_async; loss must drop from the prior's."""
    cfg, data, st_serial, st_async, _ = mc_setup
    prior_acc = max(
        float(jnp.mean(data.labels == k)) for k in range(N_CLASSES)
    )
    l0 = float(train_loss(cfg, data, init_state(cfg, data)))
    for st in (st_serial, st_async):
        m = train_metrics(cfg, data, st)
        assert float(m["accuracy"]) > prior_acc + 0.2, (float(m["accuracy"]), prior_acc)
        assert float(m["loss"]) < 0.7 * l0


def test_multiclass_f_matches_forest_predict(mc_setup):
    """The maintained (N, K) F field equals evaluating the K-output forest."""
    from repro.trees import forest_predict

    cfg, data, st, _, _ = mc_setup
    pred = forest_predict(st.forest, data.bins)
    assert pred.shape == st.f.shape == (data.n_samples, N_CLASSES)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(st.f), atol=1e-4)


def test_multiclass_checkpoint_roundtrip_and_serving(mc_setup):
    """TrainState checkpoint -> load_forest_checkpoint -> ForestServer with
    the objective's link: served rows are (rows, K) softmax probabilities
    matching training semantics, on both traversal backends."""
    from repro.serving import ForestServer, PredictRequest, load_forest_checkpoint

    cfg, data, st, _, root = mc_setup
    forest = load_forest_checkpoint(root, cfg.n_trees, like=st.forest)
    assert forest.n_outputs == N_CLASSES
    assert int(forest.n_trees) == cfg.n_trees * N_CLASSES

    rng = np.random.default_rng(0)
    rows = rng.standard_normal((40, data.n_features)).astype(np.float32)
    want = jax.nn.softmax(
        np.asarray(
            st.forest.base_score
            + np.asarray(
                _traverse_raw(st.forest, rows, data.bin_edges, backend="ref")
            )
        ),
        axis=-1,
    )
    for backend in ("ref", "pallas"):
        server = ForestServer(
            forest, data.bin_edges, max_rows=64, backend=backend,
            objective=cfg.obj,
        )
        out = server.run([PredictRequest(uid=0, x=rows)])[0]
        assert out.scores.shape == (40, N_CLASSES)
        np.testing.assert_allclose(
            out.scores.sum(axis=1), 1.0, rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(out.scores, np.asarray(want), rtol=1e-5, atol=1e-5)


def _traverse_raw(forest, rows, edges, backend):
    from repro.kernels import ops
    from repro.trees.binning import apply_bins

    bins = apply_bins(jnp.asarray(rows), edges)
    return ops.forest_traverse(
        bins, forest.feature, forest.threshold, forest.leaf_value,
        forest.n_trees, forest.depth, backend=backend,
        n_outputs=forest.n_outputs,
    )


def test_forest_server_rejects_output_mismatch(mc_setup):
    """A K-output objective on a single-output forest (or vice versa) must
    error at construction, not softmax across the wave."""
    from repro.serving import ForestServer
    from repro.trees.forest import empty_forest

    cfg, data, st, _, _ = mc_setup
    single = empty_forest(4, 3)
    with pytest.raises(ValueError, match="outputs"):
        ForestServer(single, data.bin_edges, objective=cfg.obj)
    with pytest.raises(ValueError, match="outputs"):
        ForestServer(st.forest, data.bin_edges, objective="logistic")


# ------------------------------------------------------------------ ranking
def test_lambdarank_improves_pairwise_accuracy():
    data = D.make_ranking(30, 12, 10, seed=5)
    cfg = SGBDTConfig(
        n_trees=20, step_length=0.2, sampling_rate=0.9,
        objective="lambdarank",
        learner=LearnerConfig(depth=3, n_bins=64),
    )
    st = train_serial(cfg, data, seed=0)
    m0 = train_metrics(cfg, data, init_state(cfg, data))
    m1 = train_metrics(cfg, data, st)
    assert float(m1["loss"]) < 0.7 * float(m0["loss"])
    assert float(m1["pairwise_acc"]) > 0.8


def test_lambdarank_requires_qid():
    data = D.make_sparse_classification(60, 20, 5, seed=0)  # no qid
    cfg = SGBDTConfig(n_trees=2, objective="lambdarank",
                      learner=LearnerConfig(depth=2, n_bins=64))
    with pytest.raises(ValueError, match="query ids"):
        train_serial(cfg, data, seed=0)


# ------------------------------------------------------------------ quantile
def test_quantile_coverage_moves_toward_alpha():
    data = D.make_sparse_regression(400, 100, 10, seed=6)
    for alpha in (0.25, 0.75):
        cfg = SGBDTConfig(
            n_trees=25, step_length=0.1, sampling_rate=0.9,
            objective=f"quantile:{alpha}",
            learner=LearnerConfig(depth=3, n_bins=64),
        )
        st = train_serial(cfg, data, seed=0)
        cover = float(train_metrics(cfg, data, st)["coverage"])
        assert abs(cover - alpha) < 0.15, (alpha, cover)
