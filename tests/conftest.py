"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; only the dry-run process forces 512 host devices."""
import jax
import numpy as np
import pytest

import repro.data as data_mod
from repro.core.sgbdt import SGBDTConfig
from repro.trees.learner import LearnerConfig


@pytest.fixture(scope="session")
def sparse_data():
    """Small high-diversity sparse classification set (real-sim-like)."""
    return data_mod.make_sparse_classification(600, 150, 8, seed=3)


@pytest.fixture(scope="session")
def dense_lowdiv_data():
    """Low-diversity dense set (Higgs-like, Fig. 4a multiplicities)."""
    return data_mod.make_dense_low_diversity(50, 12, 5_000, seed=5)


@pytest.fixture(scope="session")
def fast_cfg():
    # NOTE: n_bins must match the dataset quantization (synthetic.py bins at
    # 64) — a smaller learner n_bins would alias bins across features.
    return SGBDTConfig(
        n_trees=30,
        step_length=0.3,
        sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
