"""The paper's experiment registry: settings match §VI, and each quick
variant trains."""
import numpy as np
import pytest

from repro.configs import gbdt
from repro.core.sgbdt import init_state, train_loss, train_serial


def test_registry_matches_paper_settings():
    v = gbdt.EXPERIMENTS["validity-realsim"]
    assert v.config.n_trees == 400
    assert v.config.learner.depth == 7  # 100 leaves -> 128 (2^7)
    assert v.config.learner.feature_fraction == 0.8
    assert v.config.step_length == 0.01

    h = gbdt.EXPERIMENTS["validity-higgs"]
    assert h.config.n_trees == 1000
    assert h.config.learner.depth == 5  # 20 leaves -> 32 (2^5)

    e = gbdt.EXPERIMENTS["efficiency-realsim"]
    assert e.config.learner.depth == 9  # 400 leaves -> 512 (2^9)
    assert e.config.sampling_rate == 0.8

    assert gbdt.EXPERIMENTS["efficiency-e2006"].config.loss == "mse"


@pytest.mark.parametrize("name", ["validity-realsim", "efficiency-e2006"])
def test_quick_variant_trains(name):
    cfg, data = gbdt.get(name, quick=True)
    cfg = cfg._replace(n_trees=15, step_length=0.2)  # CI-size
    st = train_serial(cfg, data, seed=0)
    l0 = float(train_loss(cfg, data, init_state(cfg, data)))
    l1 = float(train_loss(cfg, data, st))
    assert np.isfinite(l1) and l1 < l0
