"""Batched serving engine: waves, budgets, EOS, media frontends — and the
GBDT forest server: serve-time binning, traversal parity, checkpoint hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as M
from repro.checkpoint import CheckpointManager
from repro.serving import (
    ForestServer,
    PredictRequest,
    Request,
    ServingEngine,
    load_forest_checkpoint,
)
from repro.trees import apply_bins, forest_predict
from repro.trees.binning import bin_dataset


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, slots=4, max_len=96), cfg


def _req(uid, plen, cfg, budget=8, seed=None):
    rng = np.random.default_rng(seed if seed is not None else uid)
    return Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=budget,
    )


def test_single_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(4)])
    assert [c.uid for c in outs] == [0, 1, 2, 3]
    for c in outs:
        assert c.tokens.shape == (8,)
        assert (c.tokens >= 0).all() and (c.tokens < cfg.vocab_size).all()


def test_overflow_spills_to_second_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(6)])
    assert len(outs) == 6


def test_mixed_lengths_bucketed(engine):
    eng, cfg = engine
    reqs = [_req(0, 16, cfg), _req(1, 32, cfg), _req(2, 16, cfg)]
    outs = eng.run(reqs)
    assert len(outs) == 3


def test_deterministic_across_wave_packing(engine):
    """A request's completion must not depend on its wave-mates (greedy
    decoding + same-length bucketing => per-slot independence)."""
    eng, cfg = engine
    solo = eng.run([_req(0, 16, cfg, seed=42)])[0]
    packed = eng.run(
        [_req(0, 16, cfg, seed=42)] + [_req(i, 16, cfg, seed=100 + i) for i in (1, 2, 3)]
    )[0]
    np.testing.assert_array_equal(solo.tokens, packed.tokens)


def test_budget_respected(engine):
    eng, cfg = engine
    outs = eng.run([_req(0, 16, cfg, budget=3), _req(1, 16, cfg, budget=11)])
    assert outs[0].tokens.shape == (3,)
    assert outs[1].tokens.shape == (11,)


def test_too_long_rejected(engine):
    eng, cfg = engine
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_req(0, 95, cfg, budget=8))


def test_vlm_engine_with_media():
    cfg = configs.get("llama-3.2-vision-90b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            max_new_tokens=4,
            media=rng.standard_normal((cfg.n_media_tokens, cfg.d_model)).astype(
                np.float32
            ) * 0.02,
        )
        for i in range(2)
    ]
    outs = eng.run(reqs)
    assert len(outs) == 2 and all(c.tokens.shape == (4,) for c in outs)


def test_eos_truncates():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=None)
    base = eng.run([_req(0, 16, cfg, budget=8)])[0]
    # pick the token the model actually emits at step 2 as the EOS id
    eos = int(base.tokens[2])
    eng_eos = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=eos)
    out = eng_eos.run([_req(0, 16, cfg, budget=8)])[0]
    assert out.tokens.shape[0] <= 3 or eos in out.tokens[:3]


# ---------------------------------------------------------------- forest GBDT
N_TREES, DEPTH, DIM = 8, 3, 12


@pytest.fixture(scope="module")
def gbdt_setup(tmp_path_factory):
    """Raw data + forest trained on its binned form, checkpointed at steps
    N_TREES/2 (partially-filled) and N_TREES (full)."""
    from repro.core.sgbdt import SGBDTConfig
    from repro.ps import Trainer
    from repro.trees.learner import LearnerConfig

    rng = np.random.default_rng(0)
    x = rng.standard_normal((400, DIM)).astype(np.float32)
    w = rng.standard_normal(DIM).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    data = bin_dataset(x, y, n_bins=64)
    cfg = SGBDTConfig(
        n_trees=N_TREES, step_length=0.3, sampling_rate=0.9,
        learner=LearnerConfig(depth=DEPTH, n_bins=64),
    )
    root = tmp_path_factory.mktemp("gbdt_ckpt")
    ckpt = CheckpointManager(root, save_every=1, keep=4)
    state = Trainer(cfg).train(
        data, ("round_robin", 2), seed=0,
        eval_every=N_TREES // 2, eval_fn=lambda st, j: ckpt.maybe_save(j, st),
    )
    return x, data, state, root


def test_serve_time_binning_matches_training_bins(gbdt_setup):
    """apply_bins over the training edges must reproduce the training bins
    exactly — the serve path sees what training saw."""
    x, data, _, _ = gbdt_setup
    np.testing.assert_array_equal(
        np.asarray(apply_bins(jnp.asarray(x), data.bin_edges)),
        np.asarray(data.bins),
    )


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_forest_server_matches_forest_predict(gbdt_setup, backend):
    """End-to-end server scores on raw rows == forest_predict on the
    training bins, through both traversal backends."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=128,
                          backend=backend)
    rows = x[:100]
    out = server.run([PredictRequest(uid=0, x=rows)])[0]
    want = np.asarray(forest_predict(state.forest, data.bins[:100]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)


def test_forest_server_wave_packing(gbdt_setup):
    """Variable-size requests pack into max_rows waves; results keep uids
    and per-request row counts; malformed feature shapes are rejected."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    sizes = [10, 20, 5, 32, 1]
    reqs = [
        PredictRequest(uid=i, x=x[sum(sizes[:i]) : sum(sizes[: i + 1])])
        for i in range(len(sizes))
    ]
    outs = server.run(reqs)
    assert [r.uid for r in outs] == list(range(len(sizes)))
    assert [len(r.scores) for r in outs] == sizes
    assert server.waves_served == 4  # greedy fill: [10+20], [5], [32], [1]
    solo = server.run([PredictRequest(uid=9, x=x[:10])])[0]
    np.testing.assert_array_equal(solo.scores, outs[0].scores)
    with pytest.raises(ValueError, match="features"):
        server.submit(PredictRequest(uid=99, x=x[:4, :5]))


def test_partially_filled_checkpoint_serves_masked(gbdt_setup):
    """The mid-training checkpoint (n_trees=4 of capacity 8) must predict
    with only its live trees."""
    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2, like=state.forest)
    assert int(half.n_trees) == N_TREES // 2
    server = ForestServer(half, data.bin_edges, max_rows=64)
    out = server.run([PredictRequest(uid=0, x=x[:64])])[0]
    want = np.asarray(forest_predict(half, data.bins[:64]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)
    full = np.asarray(forest_predict(state.forest, data.bins[:64]))
    assert not np.allclose(out.scores, full)  # the swap visibly changes scores


def test_checkpoint_hot_swap_roundtrip(gbdt_setup):
    """Server boots on the old step, polls the root, swaps to the newest
    checkpoint between waves, and serves the new model's scores."""
    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    server = ForestServer(
        half, data.bin_edges, ckpt_root=root, max_rows=64,
        model_step=N_TREES // 2,
    )
    assert server.maybe_reload()
    assert server.model_step == N_TREES
    assert not server.maybe_reload()  # idempotent: nothing newer
    out = server.run([PredictRequest(uid=0, x=x[:64])])[0]
    assert out.model_step == N_TREES
    want = np.asarray(forest_predict(state.forest, data.bins[:64]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)


def test_load_forest_checkpoint_bare_forest(gbdt_setup, tmp_path):
    """Bare-Forest checkpoints (no TrainState wrapper) restore too."""
    from repro.checkpoint import save_pytree

    x, data, state, _ = gbdt_setup
    save_pytree(tmp_path, 3, state.forest)
    forest = load_forest_checkpoint(tmp_path, 3, like=state.forest)
    np.testing.assert_array_equal(
        np.asarray(forest.leaf_value), np.asarray(state.forest.leaf_value)
    )
    assert int(forest.n_trees) == int(state.forest.n_trees)


def test_nonfinite_request_rejected_by_default(gbdt_setup):
    """Serve-time NaN regression: a malformed row must not silently bin
    into the top bin and return a confident garbage score — the default
    server refuses it at submit."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    bad = x[:4].copy()
    bad[1, 3] = np.nan
    bad[2, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(PredictRequest(uid=0, x=bad))
    assert not server._queue  # nothing half-admitted
    with pytest.raises(ValueError):
        ForestServer(state.forest, data.bin_edges, on_nonfinite="drop")


def test_nonfinite_request_flag_mode(gbdt_setup):
    """'flag' mode serves the request deterministically (NaN routed to the
    NaN bin, ±inf clamped) and reports the offending rows; clean rows keep
    their exact clean-request scores."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(
        state.forest, data.bin_edges, max_rows=32, on_nonfinite="flag"
    )
    bad = x[:8].copy()
    bad[1, 3] = np.nan
    bad[5, 0] = -np.inf
    out = server.run([PredictRequest(uid=0, x=bad)])[0]
    assert out.nonfinite_rows.tolist() == [1, 5]
    clean = server.run([PredictRequest(uid=1, x=x[:8])])[0]
    assert clean.nonfinite_rows.size == 0
    good = np.setdiff1d(np.arange(8), [1, 5])
    np.testing.assert_array_equal(out.scores[good], clean.scores[good])
    # the flagged rows still get finite (deterministic) scores
    assert np.isfinite(out.scores).all()
    # NaN-in-top-bin regression: the NaN row's score equals the score of
    # the same row with that feature forced to the NaN bin's range (very
    # small), NOT the score with the feature forced huge.
    forced_small = x[:8].copy()
    forced_small[1, 3] = -1e30
    small = server.run([PredictRequest(uid=2, x=forced_small)])[0]
    np.testing.assert_array_equal(out.scores[1], small.scores[1])


# ------------------------------------------------- latency + chunking + reload
def test_latency_includes_queue_wait(gbdt_setup):
    """Regression: latency_s used to report only wave compute, hiding the
    time a request sat behind earlier traffic. Arrival is stamped in
    submit, so a pre-stuffed queue must show up in queue_s and latency_s."""
    import time

    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    server.run([PredictRequest(uid=0, x=x[:4])])  # warm the jit cache
    server.submit(PredictRequest(uid=1, x=x[:8]))
    server.submit(PredictRequest(uid=2, x=x[8:16]))
    time.sleep(0.05)
    outs = server.run()
    assert len(outs) == 2
    for r in outs:
        assert r.queue_s >= 0.05
        assert r.compute_s > 0
        assert r.latency_s == pytest.approx(r.queue_s + r.compute_s)


@pytest.mark.parametrize("rows_over", ["plus_one", "triple"])
def test_oversized_request_chunked(gbdt_setup, rows_over):
    """Requests wider than max_rows split into sub-waves internally and
    reassemble under the original uid, row order preserved."""
    x, data, state, _ = gbdt_setup
    max_rows = 32
    n = max_rows + 1 if rows_over == "plus_one" else 3 * max_rows
    server = ForestServer(state.forest, data.bin_edges, max_rows=max_rows)
    out = server.run([PredictRequest(uid=5, x=x[:n])])[0]
    assert out.uid == 5 and out.scores.shape == (n,)
    want = np.asarray(forest_predict(state.forest, data.bins[:n]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)
    # a small rider packed behind the oversize request still serves
    outs = server.run(
        [PredictRequest(uid=1, x=x[:n]), PredictRequest(uid=2, x=x[n : n + 3])]
    )
    assert [r.uid for r in outs] == [1, 2]
    np.testing.assert_allclose(outs[0].scores, want, rtol=1e-6, atol=1e-6)
    assert len(outs[1].scores) == 3


def test_oversized_request_preserves_nonfinite_rows(gbdt_setup):
    """nonfinite_rows indices are request-relative even when the bad rows
    land in different sub-waves."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(
        state.forest, data.bin_edges, max_rows=32, on_nonfinite="flag"
    )
    bad = x[:70].copy()
    bad[2, 0] = np.nan  # first chunk
    bad[40, 3] = np.inf  # second chunk
    bad[69, 1] = -np.inf  # third chunk
    out = server.run([PredictRequest(uid=0, x=bad)])[0]
    assert out.nonfinite_rows.tolist() == [2, 40, 69]


def test_empty_request_serves(gbdt_setup):
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    out = server.run([PredictRequest(uid=0, x=x[:0])])[0]
    assert out.scores.shape == (0,)


def test_reload_bound_mid_stream(gbdt_setup, tmp_path):
    """A checkpoint written mid-stream must be serving within
    reload_every_waves waves, even when the caller never polls."""
    from repro.checkpoint import save_pytree

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    save_pytree(tmp_path, 1, half)
    server = ForestServer(
        half, data.bin_edges, ckpt_root=tmp_path, max_rows=32,
        model_step=1, reload_every_waves=2,
    )
    for i in range(8):
        server.submit(PredictRequest(uid=i, x=x[32 * i : 32 * (i + 1)]))
    wave_steps = []
    for _ in range(2):
        wave_steps.append(server.serve_next_wave()[0].model_step)
    save_pytree(tmp_path, 2, state.forest)  # mid-stream checkpoint
    while True:
        res = server.serve_next_wave()
        if not res:
            break
        wave_steps.append(res[0].model_step)
    assert wave_steps[:2] == [1, 1]
    first_new = wave_steps.index(2)
    # the save landed after wave 2; the serving path itself must pick it
    # up within reload_every_waves more waves
    assert first_new <= 2 + server.reload_every_waves
    assert wave_steps[-1] == 2
    want = np.asarray(forest_predict(state.forest, data.bins[224:256]))
    out = server.run([PredictRequest(uid=99, x=x[224:256])])[0]
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)


def test_background_reload_poller_bounds_idle_lag(gbdt_setup, tmp_path):
    """An idle server (no waves) still swaps within the poller interval."""
    import time

    from repro.checkpoint import save_pytree

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    save_pytree(tmp_path, 1, half)
    server = ForestServer(
        half, data.bin_edges, ckpt_root=tmp_path, max_rows=32, model_step=1
    )
    server.start_reload_poller(interval_s=0.01)
    try:
        save_pytree(tmp_path, 2, state.forest)
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with server._lock:
                step = server.model_step
            if step == 2:
                break
            time.sleep(0.01)
        assert step == 2  # swapped with zero waves served
        assert server.waves_served == 0
    finally:
        server.stop_reload_poller()


# --------------------------------------------------------- checkpoint matching
def test_load_forest_checkpoint_prefers_forest_parent(gbdt_setup, tmp_path):
    """When several leaves share a trailing field name, the one under a
    'forest' parent wins — not manifest order."""
    from repro.checkpoint import save_pytree

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    # 'ema' sorts before 'forest': trailing-name-only matching would load
    # the wrong leaves or trip on duplicates.
    save_pytree(tmp_path, 1, {"ema": half, "forest": state.forest})
    got = load_forest_checkpoint(tmp_path, 1, like=state.forest)
    np.testing.assert_array_equal(
        np.asarray(got.leaf_value), np.asarray(state.forest.leaf_value)
    )
    assert int(got.n_trees) == int(state.forest.n_trees)


def test_load_forest_checkpoint_ambiguous_raises(gbdt_setup, tmp_path):
    """Duplicate trailing fields with no 'forest' parent must raise, not
    silently pick one."""
    from repro.checkpoint import save_pytree

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    save_pytree(tmp_path, 1, {"ema": half, "primary": state.forest})
    with pytest.raises(KeyError, match="ambiguous"):
        load_forest_checkpoint(tmp_path, 1)


# ------------------------------------------------------------------- soak test
def test_threaded_soak_no_torn_swap(gbdt_setup, tmp_path):
    """Concurrent submit / wave-serve / hot-swap: every request completes
    exactly once, every result's scores match the forest of its claimed
    model_step (no torn forest/step pair), and each serving thread sees a
    monotone model_step stream."""
    import threading
    import time

    from repro.checkpoint import save_pytree

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    save_pytree(tmp_path, 1, half)
    server = ForestServer(
        half, data.bin_edges, ckpt_root=tmp_path, max_rows=16,
        model_step=1, reload_every_waves=4,
    )
    pred = {
        1: np.asarray(forest_predict(half, data.bins)),
        2: np.asarray(forest_predict(state.forest, data.bins)),
    }
    n_req, chunk = 60, 5
    slices = [(chunk * i % 300, chunk * i % 300 + chunk) for i in range(n_req)]
    done_submitting = threading.Event()
    results: dict[int, list] = {0: [], 1: []}

    def submitter(lo_uid, hi_uid):
        for uid in range(lo_uid, hi_uid):
            lo, hi = slices[uid]
            server.submit(PredictRequest(uid=uid, x=x[lo:hi]))
            time.sleep(0.001)

    def server_thread(tid):
        while True:
            res = server.serve_next_wave()
            results[tid].extend(res)
            if not res:
                if done_submitting.is_set() and server.queued_rows() == 0:
                    return
                time.sleep(0.002)

    def swapper():
        time.sleep(0.05)
        save_pytree(tmp_path, 2, state.forest)

    threads = [
        threading.Thread(target=submitter, args=(0, n_req // 2)),
        threading.Thread(target=submitter, args=(n_req // 2, n_req)),
        threading.Thread(target=server_thread, args=(0,)),
        threading.Thread(target=server_thread, args=(1,)),
        threading.Thread(target=swapper),
    ]
    for t in threads[:2]:
        t.start()
    for t in threads[2:]:
        t.start()
    threads[0].join()
    threads[1].join()
    done_submitting.set()
    for t in threads[2:]:
        t.join()

    everything = results[0] + results[1]
    assert sorted(r.uid for r in everything) == list(range(n_req))
    for r in everything:
        assert r.model_step in (1, 2)
        lo, hi = slices[r.uid]
        np.testing.assert_allclose(
            r.scores, pred[r.model_step][lo:hi], rtol=1e-5, atol=1e-5
        )
    for tid in (0, 1):  # per-thread swap snapshots only move forward
        steps = [r.model_step for r in results[tid]]
        assert steps == sorted(steps)


# ------------------------------------------------------------ continuous engine
@pytest.fixture(scope="module")
def forest_engine_setup(gbdt_setup):
    from repro.serving import ForestEngine

    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2, like=state.forest)
    return x, data, state, half, ForestEngine


def test_engine_ab_routing_and_per_version_steps(forest_engine_setup):
    """Weighted deterministic A/B split over two live versions, each
    result labeled with its version and that version's own model_step."""
    x, data, state, half, ForestEngine = forest_engine_setup
    eng = ForestEngine(data.bin_edges, max_rows=64, slo_s=10.0)
    eng.add_version("old", half, weight=0.5, model_step=N_TREES // 2)
    eng.add_version("new", state.forest, weight=0.5, model_step=N_TREES)
    reqs = [PredictRequest(uid=i, x=x[4 * i : 4 * i + 4]) for i in range(50)]
    routed = {r.uid: eng.submit(r) for r in reqs}
    outs = eng.run()
    assert len(outs) == 50
    by_version = {"old": 0, "new": 0}
    pred = {
        "old": np.asarray(forest_predict(half, data.bins)),
        "new": np.asarray(forest_predict(state.forest, data.bins)),
    }
    want_step = {"old": N_TREES // 2, "new": N_TREES}
    for r in outs:
        assert r.version == routed[r.uid]
        assert r.model_step == want_step[r.version]
        np.testing.assert_allclose(
            r.scores, pred[r.version][4 * r.uid : 4 * r.uid + 4],
            rtol=1e-6, atol=1e-6,
        )
        by_version[r.version] += 1
    assert by_version["old"] > 5 and by_version["new"] > 5  # both sides used
    # routing is uid-deterministic: resubmitting lands identically
    assert {r.uid: eng.submit(r) for r in reqs} == routed
    eng.flush()
    # weight 0 drains a version out of the split
    eng.set_weight("old", 0.0)
    assert all(
        eng.submit(PredictRequest(uid=u, x=x[:2])) == "new" for u in range(20)
    )
    eng.flush()


def test_engine_shadow_traffic(forest_engine_setup):
    """Shadow versions see a copy of every routed request but answer none
    of it; explicit version= pins route directly (even to a shadow)."""
    x, data, state, half, ForestEngine = forest_engine_setup
    eng = ForestEngine(data.bin_edges, max_rows=64, slo_s=10.0)
    eng.add_version("live", state.forest, model_step=N_TREES)
    eng.add_version("cand", half, shadow=True, model_step=N_TREES // 2)
    for i in range(10):
        assert eng.submit(PredictRequest(uid=i, x=x[2 * i : 2 * i + 2])) == "live"
    outs = eng.run()
    assert len(outs) == 10 and all(r.version == "live" for r in outs)
    shadow = eng.shadow_results
    assert sorted(r.uid for r in shadow) == list(range(10))
    pred_half = np.asarray(forest_predict(half, data.bins))
    for r in shadow:
        assert r.version == "cand" and r.model_step == N_TREES // 2
        np.testing.assert_allclose(
            r.scores, pred_half[2 * r.uid : 2 * r.uid + 2], rtol=1e-6, atol=1e-6
        )
    # pinning to the shadow serves it directly — still into the shadow bucket
    assert eng.submit(PredictRequest(uid=77, x=x[:3], version="cand")) == "cand"
    assert eng.run() == []
    assert any(r.uid == 77 for r in eng.shadow_results)
    with pytest.raises(KeyError, match="unknown"):
        eng.submit(PredictRequest(uid=0, x=x[:2], version="nope"))


def test_engine_slo_cutting(forest_engine_setup):
    """Continuous batching: a lone small request is NOT served while its
    deadline budget remains, and IS served once the budget is spent; a
    full wave cuts immediately regardless of deadline."""
    import time

    x, data, state, half, ForestEngine = forest_engine_setup
    eng = ForestEngine(data.bin_edges, max_rows=32, slo_s=0.5)
    eng.add_version("v", state.forest, model_step=N_TREES)
    eng.run([PredictRequest(uid=0, x=x[:4])])  # warm the jit cache
    eng.submit(PredictRequest(uid=1, x=x[:4]))
    assert eng.step() == []  # budget not spent: keep packing
    time.sleep(0.6)
    out = eng.step()
    assert [r.uid for r in out] == [1]
    assert out[0].queue_s >= 0.5  # it genuinely waited for the cut
    # fill cut: max_rows queued serves with no deadline wait
    eng.submit(PredictRequest(uid=2, x=x[:32]))
    out = eng.step()
    assert [r.uid for r in out] == [2]
    assert out[0].queue_s < 0.5


def test_engine_background_loop_meets_slo(forest_engine_setup):
    """The started engine serves a trickle of mixed-size requests without
    caller involvement, and (warm) end-to-end latency honors the SLO."""
    import time

    x, data, state, half, ForestEngine = forest_engine_setup
    from repro.serving import percentile_latencies

    eng = ForestEngine(data.bin_edges, max_rows=64, slo_s=0.5)
    eng.add_version("v", state.forest)
    eng.run([PredictRequest(uid=0, x=x[:64])])  # warm the jit cache
    eng.start(interval_s=0.002)
    try:
        rng = np.random.default_rng(0)
        for uid in range(1, 21):
            n = int(rng.integers(1, 20))
            eng.submit(PredictRequest(uid=uid, x=x[:n]))
            time.sleep(0.003)
        deadline = time.perf_counter() + 10.0
        got = []
        while len(got) < 20 and time.perf_counter() < deadline:
            got.extend(eng.poll())
            time.sleep(0.01)
    finally:
        eng.stop()
    got.extend(eng.poll())
    assert sorted(r.uid for r in got) == list(range(1, 21))
    stats = percentile_latencies(got)
    assert set(stats) == {
        "queue_p50_ms", "queue_p99_ms", "compute_p50_ms",
        "compute_p99_ms", "latency_p50_ms", "latency_p99_ms",
    }
    # generous 2x slack: CI boxes jitter, but a broken cut policy (e.g.
    # waves only cut on fill) would blow far past the 500ms SLO
    assert stats["latency_p99_ms"] <= 2 * 0.5 * 1e3


def test_engine_quantized_version_parity(forest_engine_setup):
    """A quantized version serves within the documented tolerance of its
    f32 twin on identical pinned traffic."""
    from repro.trees import quantization_atol

    x, data, state, half, ForestEngine = forest_engine_setup
    eng = ForestEngine(data.bin_edges, max_rows=64, slo_s=10.0)
    eng.add_version("f32", state.forest)
    eng.add_version("q8", state.forest, quantize="int8", weight=0.0)
    atol = quantization_atol(state.forest, state.forest.quantize("int8"))
    eng.submit(PredictRequest(uid=0, x=x[:50], version="f32"))
    eng.submit(PredictRequest(uid=1, x=x[:50], version="q8"))
    outs = eng.run()
    assert [r.version for r in outs] == ["f32", "q8"]
    np.testing.assert_allclose(
        outs[1].scores, outs[0].scores, atol=atol + 1e-6
    )
