"""Batched serving engine: waves, budgets, EOS, media frontends."""
import jax
import numpy as np
import pytest

import repro.configs as configs
import repro.models as M
from repro.serving import Completion, Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, slots=4, max_len=96), cfg


def _req(uid, plen, cfg, budget=8, seed=None):
    rng = np.random.default_rng(seed if seed is not None else uid)
    return Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=budget,
    )


def test_single_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(4)])
    assert [c.uid for c in outs] == [0, 1, 2, 3]
    for c in outs:
        assert c.tokens.shape == (8,)
        assert (c.tokens >= 0).all() and (c.tokens < cfg.vocab_size).all()


def test_overflow_spills_to_second_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(6)])
    assert len(outs) == 6


def test_mixed_lengths_bucketed(engine):
    eng, cfg = engine
    reqs = [_req(0, 16, cfg), _req(1, 32, cfg), _req(2, 16, cfg)]
    outs = eng.run(reqs)
    assert len(outs) == 3


def test_deterministic_across_wave_packing(engine):
    """A request's completion must not depend on its wave-mates (greedy
    decoding + same-length bucketing => per-slot independence)."""
    eng, cfg = engine
    solo = eng.run([_req(0, 16, cfg, seed=42)])[0]
    packed = eng.run(
        [_req(0, 16, cfg, seed=42)] + [_req(i, 16, cfg, seed=100 + i) for i in (1, 2, 3)]
    )[0]
    np.testing.assert_array_equal(solo.tokens, packed.tokens)


def test_budget_respected(engine):
    eng, cfg = engine
    outs = eng.run([_req(0, 16, cfg, budget=3), _req(1, 16, cfg, budget=11)])
    assert outs[0].tokens.shape == (3,)
    assert outs[1].tokens.shape == (11,)


def test_too_long_rejected(engine):
    eng, cfg = engine
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_req(0, 95, cfg, budget=8))


def test_vlm_engine_with_media():
    cfg = configs.get("llama-3.2-vision-90b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            max_new_tokens=4,
            media=rng.standard_normal((cfg.n_media_tokens, cfg.d_model)).astype(
                np.float32
            ) * 0.02,
        )
        for i in range(2)
    ]
    outs = eng.run(reqs)
    assert len(outs) == 2 and all(c.tokens.shape == (4,) for c in outs)


def test_eos_truncates():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=None)
    base = eng.run([_req(0, 16, cfg, budget=8)])[0]
    # pick the token the model actually emits at step 2 as the EOS id
    eos = int(base.tokens[2])
    eng_eos = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=eos)
    out = eng_eos.run([_req(0, 16, cfg, budget=8)])[0]
    assert out.tokens.shape[0] <= 3 or eos in out.tokens[:3]
