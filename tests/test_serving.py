"""Batched serving engine: waves, budgets, EOS, media frontends — and the
GBDT forest server: serve-time binning, traversal parity, checkpoint hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as M
from repro.checkpoint import CheckpointManager
from repro.serving import (
    ForestServer,
    PredictRequest,
    Request,
    ServingEngine,
    load_forest_checkpoint,
)
from repro.trees import apply_bins, forest_predict
from repro.trees.binning import bin_dataset


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, slots=4, max_len=96), cfg


def _req(uid, plen, cfg, budget=8, seed=None):
    rng = np.random.default_rng(seed if seed is not None else uid)
    return Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=budget,
    )


def test_single_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(4)])
    assert [c.uid for c in outs] == [0, 1, 2, 3]
    for c in outs:
        assert c.tokens.shape == (8,)
        assert (c.tokens >= 0).all() and (c.tokens < cfg.vocab_size).all()


def test_overflow_spills_to_second_wave(engine):
    eng, cfg = engine
    outs = eng.run([_req(i, 16, cfg) for i in range(6)])
    assert len(outs) == 6


def test_mixed_lengths_bucketed(engine):
    eng, cfg = engine
    reqs = [_req(0, 16, cfg), _req(1, 32, cfg), _req(2, 16, cfg)]
    outs = eng.run(reqs)
    assert len(outs) == 3


def test_deterministic_across_wave_packing(engine):
    """A request's completion must not depend on its wave-mates (greedy
    decoding + same-length bucketing => per-slot independence)."""
    eng, cfg = engine
    solo = eng.run([_req(0, 16, cfg, seed=42)])[0]
    packed = eng.run(
        [_req(0, 16, cfg, seed=42)] + [_req(i, 16, cfg, seed=100 + i) for i in (1, 2, 3)]
    )[0]
    np.testing.assert_array_equal(solo.tokens, packed.tokens)


def test_budget_respected(engine):
    eng, cfg = engine
    outs = eng.run([_req(0, 16, cfg, budget=3), _req(1, 16, cfg, budget=11)])
    assert outs[0].tokens.shape == (3,)
    assert outs[1].tokens.shape == (11,)


def test_too_long_rejected(engine):
    eng, cfg = engine
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_req(0, 95, cfg, budget=8))


def test_vlm_engine_with_media():
    cfg = configs.get("llama-3.2-vision-90b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            max_new_tokens=4,
            media=rng.standard_normal((cfg.n_media_tokens, cfg.d_model)).astype(
                np.float32
            ) * 0.02,
        )
        for i in range(2)
    ]
    outs = eng.run(reqs)
    assert len(outs) == 2 and all(c.tokens.shape == (4,) for c in outs)


def test_eos_truncates():
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=None)
    base = eng.run([_req(0, 16, cfg, budget=8)])[0]
    # pick the token the model actually emits at step 2 as the EOS id
    eos = int(base.tokens[2])
    eng_eos = ServingEngine(cfg, params, slots=2, max_len=64, eos_id=eos)
    out = eng_eos.run([_req(0, 16, cfg, budget=8)])[0]
    assert out.tokens.shape[0] <= 3 or eos in out.tokens[:3]


# ---------------------------------------------------------------- forest GBDT
N_TREES, DEPTH, DIM = 8, 3, 12


@pytest.fixture(scope="module")
def gbdt_setup(tmp_path_factory):
    """Raw data + forest trained on its binned form, checkpointed at steps
    N_TREES/2 (partially-filled) and N_TREES (full)."""
    from repro.core.sgbdt import SGBDTConfig
    from repro.ps import Trainer
    from repro.trees.learner import LearnerConfig

    rng = np.random.default_rng(0)
    x = rng.standard_normal((400, DIM)).astype(np.float32)
    w = rng.standard_normal(DIM).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    data = bin_dataset(x, y, n_bins=64)
    cfg = SGBDTConfig(
        n_trees=N_TREES, step_length=0.3, sampling_rate=0.9,
        learner=LearnerConfig(depth=DEPTH, n_bins=64),
    )
    root = tmp_path_factory.mktemp("gbdt_ckpt")
    ckpt = CheckpointManager(root, save_every=1, keep=4)
    state = Trainer(cfg).train(
        data, ("round_robin", 2), seed=0,
        eval_every=N_TREES // 2, eval_fn=lambda st, j: ckpt.maybe_save(j, st),
    )
    return x, data, state, root


def test_serve_time_binning_matches_training_bins(gbdt_setup):
    """apply_bins over the training edges must reproduce the training bins
    exactly — the serve path sees what training saw."""
    x, data, _, _ = gbdt_setup
    np.testing.assert_array_equal(
        np.asarray(apply_bins(jnp.asarray(x), data.bin_edges)),
        np.asarray(data.bins),
    )


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_forest_server_matches_forest_predict(gbdt_setup, backend):
    """End-to-end server scores on raw rows == forest_predict on the
    training bins, through both traversal backends."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=128,
                          backend=backend)
    rows = x[:100]
    out = server.run([PredictRequest(uid=0, x=rows)])[0]
    want = np.asarray(forest_predict(state.forest, data.bins[:100]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)


def test_forest_server_wave_packing(gbdt_setup):
    """Variable-size requests pack into max_rows waves; results keep uids
    and per-request row counts; oversize submits are rejected."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    sizes = [10, 20, 5, 32, 1]
    reqs = [
        PredictRequest(uid=i, x=x[sum(sizes[:i]) : sum(sizes[: i + 1])])
        for i in range(len(sizes))
    ]
    outs = server.run(reqs)
    assert [r.uid for r in outs] == list(range(len(sizes)))
    assert [len(r.scores) for r in outs] == sizes
    assert server.waves_served == 4  # greedy fill: [10+20], [5], [32], [1]
    solo = server.run([PredictRequest(uid=9, x=x[:10])])[0]
    np.testing.assert_array_equal(solo.scores, outs[0].scores)
    with pytest.raises(ValueError, match="max_rows"):
        server.submit(PredictRequest(uid=99, x=x[:33]))
    with pytest.raises(ValueError, match="features"):
        server.submit(PredictRequest(uid=99, x=x[:4, :5]))


def test_partially_filled_checkpoint_serves_masked(gbdt_setup):
    """The mid-training checkpoint (n_trees=4 of capacity 8) must predict
    with only its live trees."""
    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2, like=state.forest)
    assert int(half.n_trees) == N_TREES // 2
    server = ForestServer(half, data.bin_edges, max_rows=64)
    out = server.run([PredictRequest(uid=0, x=x[:64])])[0]
    want = np.asarray(forest_predict(half, data.bins[:64]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)
    full = np.asarray(forest_predict(state.forest, data.bins[:64]))
    assert not np.allclose(out.scores, full)  # the swap visibly changes scores


def test_checkpoint_hot_swap_roundtrip(gbdt_setup):
    """Server boots on the old step, polls the root, swaps to the newest
    checkpoint between waves, and serves the new model's scores."""
    x, data, state, root = gbdt_setup
    half = load_forest_checkpoint(root, N_TREES // 2)
    server = ForestServer(
        half, data.bin_edges, ckpt_root=root, max_rows=64,
        model_step=N_TREES // 2,
    )
    assert server.maybe_reload()
    assert server.model_step == N_TREES
    assert not server.maybe_reload()  # idempotent: nothing newer
    out = server.run([PredictRequest(uid=0, x=x[:64])])[0]
    assert out.model_step == N_TREES
    want = np.asarray(forest_predict(state.forest, data.bins[:64]))
    np.testing.assert_allclose(out.scores, want, rtol=1e-6, atol=1e-6)


def test_load_forest_checkpoint_bare_forest(gbdt_setup, tmp_path):
    """Bare-Forest checkpoints (no TrainState wrapper) restore too."""
    from repro.checkpoint import save_pytree

    x, data, state, _ = gbdt_setup
    save_pytree(tmp_path, 3, state.forest)
    forest = load_forest_checkpoint(tmp_path, 3, like=state.forest)
    np.testing.assert_array_equal(
        np.asarray(forest.leaf_value), np.asarray(state.forest.leaf_value)
    )
    assert int(forest.n_trees) == int(state.forest.n_trees)


def test_nonfinite_request_rejected_by_default(gbdt_setup):
    """Serve-time NaN regression: a malformed row must not silently bin
    into the top bin and return a confident garbage score — the default
    server refuses it at submit."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(state.forest, data.bin_edges, max_rows=32)
    bad = x[:4].copy()
    bad[1, 3] = np.nan
    bad[2, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(PredictRequest(uid=0, x=bad))
    assert not server._queue  # nothing half-admitted
    with pytest.raises(ValueError):
        ForestServer(state.forest, data.bin_edges, on_nonfinite="drop")


def test_nonfinite_request_flag_mode(gbdt_setup):
    """'flag' mode serves the request deterministically (NaN routed to the
    NaN bin, ±inf clamped) and reports the offending rows; clean rows keep
    their exact clean-request scores."""
    x, data, state, _ = gbdt_setup
    server = ForestServer(
        state.forest, data.bin_edges, max_rows=32, on_nonfinite="flag"
    )
    bad = x[:8].copy()
    bad[1, 3] = np.nan
    bad[5, 0] = -np.inf
    out = server.run([PredictRequest(uid=0, x=bad)])[0]
    assert out.nonfinite_rows.tolist() == [1, 5]
    clean = server.run([PredictRequest(uid=1, x=x[:8])])[0]
    assert clean.nonfinite_rows.size == 0
    good = np.setdiff1d(np.arange(8), [1, 5])
    np.testing.assert_array_equal(out.scores[good], clean.scores[good])
    # the flagged rows still get finite (deterministic) scores
    assert np.isfinite(out.scores).all()
    # NaN-in-top-bin regression: the NaN row's score equals the score of
    # the same row with that feature forced to the NaN bin's range (very
    # small), NOT the score with the feature forced huge.
    forced_small = x[:8].copy()
    forced_small[1, 3] = -1e30
    small = server.run([PredictRequest(uid=2, x=forced_small)])[0]
    np.testing.assert_array_equal(out.scores[1], small.scores[1])
