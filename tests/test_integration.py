"""End-to-end flows: NN training loop, GBDT on paper-like data, the paper's
validity claims at test scale, and the delayed-gradient NN bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.data as D
import repro.models as M
import repro.optim as O
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.sgbdt import SGBDTConfig, train_loss
from repro.launch.steps import make_train_step
from repro.launch.train import synthetic_batches
from repro.trees.learner import LearnerConfig


def test_nn_training_loss_decreases(key):
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    opt = O.adamw(3e-3, weight_decay=0.01, max_grad_norm=1.0)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for i, batch in enumerate(synthetic_batches(cfg, 8, 64, 40, seed=1)):
        params, state, m = step(params, state, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9


def test_nn_training_with_sampling_and_delay(key):
    """The full asynch-SGBDT recipe on a NN: Bernoulli-importance batches +
    stale gradients + Prop.-1 step scaling still learns."""
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    delay = 3
    lr = 3e-3 * O.staleness_step_scale(delay, rho=0.3)
    opt = O.delayed_gradient(
        O.adamw(lr, weight_decay=0.01, max_grad_norm=1.0), delay
    )
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, sampling_rate=0.8))
    losses = []
    for i, batch in enumerate(synthetic_batches(cfg, 8, 64, 60, seed=2)):
        params, state, m = step(params, state, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.95


def test_grad_accumulation_matches_full_batch(key):
    """accum=4 must equal accum=1 on the same global batch (up to fp error)
    when sampling is off — the microbatch loop is a pure refactor."""
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    opt = O.sgd(1e-2)
    batch = next(iter(synthetic_batches(cfg, 8, 32, 1, seed=3)))
    s1 = jax.jit(make_train_step(cfg, opt, accum=1))
    s4 = jax.jit(make_train_step(cfg, opt, accum=4))
    p1, _, m1 = s1(params, opt.init(params), batch, key)
    p4, _, m4 = s4(params, opt.init(params), batch, key)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m4["loss"]), rtol=2e-2
    )
    err = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4))
    )
    assert err < 5e-2


# --------------------------------------------------------- paper validity
def _loss_curve(cfg, data, schedule, seed=0, every=5):
    curve = []
    train_async(
        cfg, data, schedule, seed=seed, eval_every=every,
        eval_fn=lambda st, j: curve.append(float(train_loss(cfg, data, st))),
    )
    return np.asarray(curve)


@pytest.mark.slow
def test_paper_c1_sensitivity_ordering():
    """Fig. 5/6 at test scale: the low-diversity (Higgs-like) dataset is
    substantially MORE sensitive to worker count than the high-diversity
    (real-sim-like) dataset — the paper's C1/C2 ordering. The magnitude of
    the W-induced shift is the robust observable at small scale (the sign
    flips with the step/tree budget; see EXPERIMENTS.md §Validity)."""
    cfg = SGBDTConfig(
        n_trees=80, step_length=0.1, sampling_rate=0.5,
        learner=LearnerConfig(depth=5, n_bins=64),
    )
    sparse = D.make_sparse_classification(1_000, 500, 15, seed=1)
    dense = D.make_dense_low_diversity(120, 28, 15_000, seed=1)

    def sensitivity(data, depth):
        c = cfg._replace(learner=cfg.learner._replace(depth=depth))
        l1 = _loss_curve(c, data, worker_round_robin(80, 1))
        l16 = _loss_curve(c, data, worker_round_robin(80, 16))
        return float(np.mean(np.abs(np.asarray(l16) - np.asarray(l1))))

    s_sparse = sensitivity(sparse, 6)
    s_dense = sensitivity(dense, 4)
    assert s_dense > 1.5 * s_sparse, (
        f"dense sensitivity {s_dense:.4f} should exceed sparse {s_sparse:.4f}"
    )


def test_serving_end_to_end(key):
    from repro.serving import Request, ServingEngine

    cfg = configs.get("xlstm-1.3b").reduced()
    params = M.init_params(cfg, key)
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    outs = eng.run(
        [
            Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=6)
            for i in range(3)
        ]
    )
    assert len(outs) == 3
    assert all(len(c.tokens) == 6 for c in outs)
