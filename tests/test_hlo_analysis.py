"""Loop-aware HLO analyzer: validated against programs with known costs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_dot_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    stats = analyze_hlo(_hlo(lambda x, y: x @ y, a, b))
    assert stats.dot_flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_multiplies_flops():
    """A dot inside a scan of length L must count L times (this is the
    correction cost_analysis misses)."""
    w = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(ws, x0):
        def body(h, wi):
            return h @ wi, None

        h, _ = jax.lax.scan(body, x0, ws)
        return h

    stats = analyze_hlo(_hlo(f, w, x))
    want = 16 * 2 * 8 * 64 * 64
    assert stats.dot_flops == pytest.approx(want, rel=0.05)


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((32,), jnp.float32)

    def f(ws, x0):
        def outer(h, wg):
            def inner(hh, wi):
                return wi @ hh, None

            h2, _ = jax.lax.scan(inner, h, wg)
            return h2, None

        h, _ = jax.lax.scan(outer, x0, ws)
        return h

    stats = analyze_hlo(_hlo(f, w, x))
    want = 4 * 3 * 2 * 32 * 32
    assert stats.dot_flops == pytest.approx(want, rel=0.1)


def test_no_collectives_on_single_device():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    stats = analyze_hlo(_hlo(lambda x: x @ x, a))
    assert stats.total_collective_bytes == 0


def test_hbm_bytes_reasonable():
    """The HBM proxy must at least cover inputs + outputs of a memcpy-like
    op and not explode by orders of magnitude."""
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MiB
    stats = analyze_hlo(_hlo(lambda x: x * 2.0 + 1.0, a))
    assert 8e6 <= stats.hbm_bytes <= 1e8


def test_remat_increases_flops():
    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def loss(ws, x0, remat):
        def blk(h, wi):
            return jnp.tanh(h @ wi)

        f = jax.checkpoint(blk) if remat else blk

        def body(h, wi):
            return f(h, wi), None

        h, _ = jax.lax.scan(body, x0, ws)
        return jnp.sum(h * h)

    g_plain = _hlo(lambda w_, x_: jax.grad(lambda a: loss(a, x_, False))(w_), w, x)
    g_remat = _hlo(lambda w_, x_: jax.grad(lambda a: loss(a, x_, True))(w_), w, x)
    assert (
        analyze_hlo(g_remat).dot_flops >= analyze_hlo(g_plain).dot_flops
    )


def test_collectives_counted_inside_loops():
    """all-reduce inside a scanned body on a 1-device 'mesh' lowers away;
    instead validate the loop-aware multiply on a synthetic HLO."""
    hlo = """
HloModule test

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8] all-reduce(%x), to_apply=%add, replica_groups={}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %x)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    stats = analyze_hlo(hlo)
    assert stats.collective_count.get("all-reduce", 0) == 5
    assert stats.collective_bytes["all-reduce"] == 5 * 8 * 4
