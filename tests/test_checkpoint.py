"""Checkpoint round-trips for every state the framework persists."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as M
import repro.optim as O
from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def test_roundtrip_params_and_opt_state(tmp_path, key):
    cfg = configs.get("granite-3-2b").reduced()
    params = M.init_params(cfg, key)
    opt = O.delayed_gradient(O.adamw(1e-3, max_grad_norm=1.0), 2)
    state = opt.init(params)
    save_pytree(tmp_path, 7, {"params": params, "opt": state})
    back = restore_pytree(tmp_path, 7, {"params": params, "opt": state})
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves({"params": params, "opt": state})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_gbdt_state(tmp_path, fast_cfg, sparse_data):
    from repro.core.sgbdt import train_serial
    from repro.trees import forest_predict

    st = train_serial(fast_cfg._replace(n_trees=5), sparse_data, seed=0)
    save_pytree(tmp_path, 1, st._asdict())
    back = restore_pytree(tmp_path, 1, st._asdict())
    np.testing.assert_allclose(np.asarray(back["f"]), np.asarray(st.f))
    # restored forest predicts identically
    from repro.trees.forest import Forest

    f2 = Forest(**back["forest"]._asdict()) if hasattr(back["forest"], "_asdict") else st.forest
    np.testing.assert_allclose(
        np.asarray(forest_predict(st.forest, sparse_data.bins)),
        np.asarray(forest_predict(f2, sparse_data.bins)),
    )


def test_bfloat16_roundtrip(tmp_path):
    tree = {"w": jnp.ones((8, 8), jnp.bfloat16) * 1.5}
    save_pytree(tmp_path, 0, tree)
    back = restore_pytree(tmp_path, 0, tree)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32), 1.5)


def test_shape_mismatch_rejected(tmp_path):
    save_pytree(tmp_path, 0, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        restore_pytree(tmp_path, 0, {"w": jnp.zeros((5,))})


def test_missing_leaf_rejected(tmp_path):
    save_pytree(tmp_path, 0, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        restore_pytree(tmp_path, 0, {"w": jnp.zeros((4,)), "extra": jnp.zeros(1)})


def test_corruption_detected(tmp_path):
    save_pytree(tmp_path, 0, {"w": jnp.arange(16.0)})
    # flip a byte in the payload
    leaf = tmp_path / "step_000000" / "leaf_00000.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        restore_pytree(tmp_path, 0, {"w": jnp.arange(16.0)}, check_crc=True)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=2, keep=2)
    tree = {"x": jnp.zeros(3)}
    for step in range(1, 9):
        mgr.maybe_save(step, tree)
    assert latest_step(tmp_path) == 8
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_000006", "step_000008"]
    got_step, got = mgr.restore_latest(tree)
    assert got_step == 8
    np.testing.assert_array_equal(np.asarray(got["x"]), 0.0)


def test_atomic_overwrite(tmp_path):
    save_pytree(tmp_path, 3, {"w": jnp.zeros(2)})
    save_pytree(tmp_path, 3, {"w": jnp.ones(2)})
    back = restore_pytree(tmp_path, 3, {"w": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(back["w"]), 1.0)


def test_foreign_step_entries_tolerated(tmp_path):
    """A checkpoint root containing foreign step_* entries (step_final/, a
    stray file, an unpadded numeric name) must not crash latest_step, the
    serving hot-swap poll, or CheckpointManager GC."""
    from repro.checkpoint import latest_step

    tree = {"w": np.arange(4, dtype=np.float32)}
    mgr = CheckpointManager(tmp_path, save_every=1, keep=2)
    for step in (1, 2, 3):
        mgr.maybe_save(step, tree)
    # foreign entries: non-numeric dir, stray file, unpadded numeric dir
    (tmp_path / "step_final").mkdir()
    (tmp_path / "step_final" / "manifest.json").write_text("{}")
    (tmp_path / "step_notes.txt").write_text("scratch")
    save_pytree(tmp_path, 7, tree)
    (tmp_path / "step_000007").rename(tmp_path / "step_7")

    assert latest_step(tmp_path) == 7  # unpadded numeric entries count
    # and the loaders can open what latest_step reports: restore_latest
    # resolves the unpadded dir instead of crashing the hot-swap poll
    step, restored = mgr.restore_latest(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    mgr.maybe_save(8, tree)  # triggers _gc over the polluted root
    kept = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    # keep=2 newest numeric steps survive; foreign entries are untouched
    assert "step_final" in kept and "step_notes.txt" in kept
    numeric = [n for n in kept if n[5:].isdigit()]
    assert numeric == ["step_000008", "step_7"]
    assert latest_step(tmp_path) == 8


def test_steps_and_leaf_manifest(tmp_path):
    """The crash-resume loaders: ``steps`` lists only COMPLETE checkpoints
    ascending (a torn write without a manifest is invisible), and
    ``leaf_manifest`` exposes shapes/dtypes so a resume can size its
    ``like`` tree for variable-size leaves before loading any data."""
    from repro.checkpoint import leaf_manifest, steps

    assert steps(tmp_path / "nowhere") == []
    tree = {"f": np.zeros(16, np.float32),
            "held_f": np.zeros((3, 16), np.float32)}
    for s in (12, 4, 20):
        save_pytree(tmp_path, s, tree)
    # a torn checkpoint: directory exists, manifest missing
    (tmp_path / "step_000009").mkdir()
    assert steps(tmp_path) == [4, 12, 20]

    manifest = leaf_manifest(tmp_path, 12)
    held = next(e for p, e in manifest.items() if "held_f" in p)
    assert held["shape"] == [3, 16] and held["dtype"] == "float32"
    # the resume pattern: build `like` from the manifest, restore exactly
    like = {"f": np.zeros(16, np.float32),
            "held_f": np.zeros(tuple(held["shape"]), np.float32)}
    restored = restore_pytree(tmp_path, 12, like)
    assert np.asarray(restored["held_f"]).shape == (3, 16)
