"""The paper's core: serial SGBDT, asynch-SGBDT, and their invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_sgbdt import (
    constant_delay,
    max_staleness,
    train_async,
    train_async_scan,
    worker_round_robin,
)
from repro.core.sgbdt import init_state, sgbdt_round, train_loss, train_serial
from repro.trees import forest_predict


def test_serial_converges(fast_cfg, sparse_data):
    state = train_serial(fast_cfg, sparse_data, seed=0)
    l0 = float(train_loss(fast_cfg, sparse_data, init_state(fast_cfg, sparse_data)))
    l1 = float(train_loss(fast_cfg, sparse_data, state))
    assert l1 < 0.8 * l0, f"no convergence: {l0} -> {l1}"


def test_forest_predict_consistent_with_f(fast_cfg, sparse_data):
    """The maintained F vector must equal evaluating the forest on the
    training bins — the server state is self-consistent."""
    state = train_serial(fast_cfg, sparse_data, seed=1)
    f_eval = forest_predict(state.forest, sparse_data.bins)
    np.testing.assert_allclose(
        np.asarray(f_eval), np.asarray(state.f), rtol=1e-4, atol=1e-4
    )


def test_async_w1_equals_serial(fast_cfg, sparse_data):
    """tau = 0 degeneracy: one worker is exactly the serial trainer."""
    st_serial = train_serial(fast_cfg, sparse_data, seed=0)
    st_async = train_async(
        fast_cfg, sparse_data, worker_round_robin(fast_cfg.n_trees, 1), seed=0
    )
    np.testing.assert_allclose(
        np.asarray(st_serial.f), np.asarray(st_async.f), atol=1e-5
    )


def test_scan_equals_loop(fast_cfg, sparse_data):
    sched = worker_round_robin(fast_cfg.n_trees, 8)
    ring = max_staleness(sched) + 1
    keys = jax.random.split(jax.random.PRNGKey(0), fast_cfg.n_trees)
    st_scan, losses = train_async_scan(
        fast_cfg, sparse_data, jnp.asarray(sched), keys, ring
    )
    st_loop = train_async(fast_cfg, sparse_data, sched, seed=0)
    np.testing.assert_allclose(
        np.asarray(st_scan.f), np.asarray(st_loop.f), atol=1e-5
    )
    assert losses.shape == (fast_cfg.n_trees,)
    assert float(losses[-1]) < float(losses[0])


def test_async_converges_with_staleness(fast_cfg, sparse_data):
    """Prop. 1: asynch-SGBDT still converges under bounded delay (the
    high-diversity dataset regime)."""
    for w in (4, 16):
        st = train_async(
            fast_cfg, sparse_data, worker_round_robin(fast_cfg.n_trees, w), seed=0
        )
        l0 = float(
            train_loss(fast_cfg, sparse_data, init_state(fast_cfg, sparse_data))
        )
        l1 = float(train_loss(fast_cfg, sparse_data, st))
        assert l1 < 0.85 * l0, f"W={w}: {l0} -> {l1}"


def test_constant_delay_schedule():
    s = constant_delay(10, 3)
    assert (s == np.array([0, 0, 0, 0, 1, 2, 3, 4, 5, 6])).all()
    assert max_staleness(s) == 3


def test_round_robin_schedule():
    s = worker_round_robin(8, 1)
    assert (s == np.arange(8)).all()  # serial: zero staleness
    s4 = worker_round_robin(8, 4)
    assert (s4 == np.array([0, 0, 0, 0, 1, 2, 3, 4])).all()
    assert max_staleness(s4) == 4 - 1 + 0 or max_staleness(s4) >= 3


def test_stale_round_uses_stale_target(fast_cfg, sparse_data):
    """sgbdt_round builds the tree against f_target, not state.f."""
    state = init_state(fast_cfg, sparse_data)
    key = jax.random.PRNGKey(7)
    fresh = sgbdt_round(fast_cfg, sparse_data, state, state.f, key)
    stale_target = state.f + 5.0  # wildly different target
    stale = sgbdt_round(fast_cfg, sparse_data, state, stale_target, key)
    assert not np.allclose(np.asarray(fresh.f), np.asarray(stale.f))


def test_newton_step_serial_converges(fast_cfg, sparse_data):
    """xgboost-style Newton leaves: a better serial learner (paper
    conclusion 2 says it's the ASYNC setting where Newton breaks)."""
    cfg = fast_cfg._replace(step_kind="newton")
    st = train_serial(cfg, sparse_data, seed=0)
    l0 = float(train_loss(cfg, sparse_data, init_state(cfg, sparse_data)))
    l1 = float(train_loss(cfg, sparse_data, st))
    assert l1 < 0.8 * l0


def test_newton_more_staleness_sensitive(fast_cfg, sparse_data):
    """Paper conclusion 2: Newton degrades more than gradient under the
    same staleness."""
    res = {}
    for kind in ("gradient", "newton"):
        cfg = fast_cfg._replace(step_kind=kind)
        l1 = float(train_loss(cfg, sparse_data, train_async(
            cfg, sparse_data, worker_round_robin(cfg.n_trees, 1), seed=0)))
        l16 = float(train_loss(cfg, sparse_data, train_async(
            cfg, sparse_data, worker_round_robin(cfg.n_trees, 16), seed=0)))
        res[kind] = l16 - l1
    assert res["newton"] > res["gradient"], res


def test_mse_loss_path(fast_cfg):
    import repro.data as D

    data = D.make_sparse_regression(400, 120, 10, seed=9)
    cfg = fast_cfg._replace(loss="mse")
    st = train_serial(cfg, data, seed=0)
    l0 = float(train_loss(cfg, data, init_state(cfg, data)))
    l1 = float(train_loss(cfg, data, st))
    assert l1 < 0.9 * l0
