"""The real host-async PS runtime: record-and-replay, stragglers, traces.

The contracts under test:
  * record-and-replay — a threaded W=4 run's realized (k(j), ticket) trace,
    replayed through ``Trainer.scan_with``, reproduces the identical forest
    bit for bit (the runtime's debuggability story);
  * the realized schedule is a valid causal k(j) and the tickets are a
    permutation of the rounds;
  * straggler injection — a slow worker's pushes are measurably more stale,
    and training still converges;
  * trace JSON round-trips, and the simulator cross-validation helpers
    compare realized vs. predicted staleness for the measured geometry.
"""
import numpy as np
import pytest

from repro.core.sgbdt import SGBDTConfig, init_state, train_loss
from repro.core.simulator import crossvalidate_schedule, staleness_stats
from repro.ps import AsyncRuntime, RunTrace, replay_trace, resolve_schedule
from repro.trees.learner import LearnerConfig


@pytest.fixture(scope="module")
def rt_cfg():
    return SGBDTConfig(
        n_trees=24, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )


def _forest_identical(a, b) -> bool:
    return (
        np.array_equal(np.asarray(a.forest.feature), np.asarray(b.forest.feature))
        and np.array_equal(
            np.asarray(a.forest.threshold), np.asarray(b.forest.threshold)
        )
        and np.array_equal(
            np.asarray(a.forest.leaf_value), np.asarray(b.forest.leaf_value)
        )
        and np.array_equal(np.asarray(a.f), np.asarray(b.f))
    )


@pytest.fixture(scope="module")
def threaded_run(rt_cfg, sparse_data):
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4)
    state, trace = rt.run(seed=0)
    return rt, state, trace


def test_record_and_replay_identical_forest(rt_cfg, sparse_data, threaded_run):
    """THE runtime contract: the nondeterministic threaded interleaving,
    replayed from its trace through the deterministic fused-scan engine,
    rebuilds the same model exactly."""
    rt, state, trace = threaded_run
    st_replay, losses = rt.replay(trace)
    assert _forest_identical(state, st_replay)
    assert losses.shape == (rt_cfg.n_trees,)
    # and through the module-level entry point (fresh Trainer, same result)
    st_again, _ = replay_trace(rt_cfg, sparse_data, trace)
    assert _forest_identical(state, st_again)


def test_trace_is_valid_schedule(rt_cfg, threaded_run):
    _, _, trace = threaded_run
    # causal, non-negative, right length — resolve_schedule enforces all
    resolve_schedule(trace.schedule, rt_cfg.n_trees)
    assert sorted(trace.key_index.tolist()) == list(range(rt_cfg.n_trees))
    assert set(trace.worker.tolist()) <= set(range(4))
    assert trace.makespan > 0
    assert (trace.t_build > 0).all()
    hist = trace.staleness_histogram()
    assert sum(hist.values()) == rt_cfg.n_trees


def test_trace_json_roundtrip(tmp_path, threaded_run):
    _, _, trace = threaded_run
    path = trace.save(tmp_path / "trace.json")
    back = RunTrace.load(path)
    assert back.n_workers == trace.n_workers and back.seed == trace.seed
    np.testing.assert_array_equal(back.schedule, trace.schedule)
    np.testing.assert_array_equal(back.key_index, trace.key_index)
    np.testing.assert_array_equal(back.worker, trace.worker)
    np.testing.assert_allclose(back.t_build, trace.t_build)
    assert back.makespan == pytest.approx(trace.makespan)


def test_replayed_loaded_trace_matches(rt_cfg, sparse_data, threaded_run, tmp_path):
    """Replay survives serialization: a trace loaded from disk still
    reproduces the threaded forest."""
    _, state, trace = threaded_run
    back = RunTrace.load(trace.save(tmp_path / "t.json"))
    st_replay, _ = replay_trace(rt_cfg, sparse_data, back)
    assert _forest_identical(state, st_replay)


def test_straggler_shifts_staleness(rt_cfg, sparse_data):
    """One slow worker: its pushes are built from older versions than the
    fast workers' (it holds each snapshot longer), and bounded staleness
    still converges — the paper's validity claim under heterogeneity."""
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4, worker_delay={0: 0.25})
    state, trace = rt.run(seed=0)
    stale = trace.staleness
    from_straggler = trace.worker == 0
    assert from_straggler.any(), "straggler never pushed"
    assert from_straggler.sum() < (~from_straggler).sum()
    assert stale[from_straggler].mean() > stale[~from_straggler].mean()
    # still trains: loss strictly improves on the init state
    l0 = float(train_loss(rt_cfg, sparse_data, init_state(rt_cfg, sparse_data)))
    l1 = float(train_loss(rt_cfg, sparse_data, state))
    assert l1 < 0.9 * l0


def test_crossvalidation_helpers(threaded_run):
    _, _, trace = threaded_run
    stats = staleness_stats(trace.schedule)
    assert stats["mean_staleness"] == pytest.approx(float(trace.staleness.mean()))
    assert sum(stats["histogram"].values()) == trace.n_trees
    xval = crossvalidate_schedule(
        trace.schedule, trace.cluster_spec(), makespan=trace.makespan
    )
    assert xval["realized"]["mean_staleness"] == stats["mean_staleness"]
    assert xval["simulated"]["max_staleness"] >= 0
    assert xval["realized_makespan"] == pytest.approx(trace.makespan)
    assert xval["makespan_ratio"] > 0


def test_multioutput_replay():
    """K-output rounds (stacked tree groups, one push each) ride the same
    runtime + replay contract."""
    import repro.data as D

    data = D.make_multiclass_classification(300, 20, 3, seed=11)
    cfg = SGBDTConfig(
        n_trees=10, step_length=0.2, sampling_rate=0.9,
        objective="multiclass:3",
        learner=LearnerConfig(depth=3, n_bins=64),
    )
    rt = AsyncRuntime(cfg, data, n_workers=3)
    state, trace = rt.run(seed=1)
    st_replay, _ = rt.replay(trace)
    assert _forest_identical(state, st_replay)
    assert int(state.forest.n_trees) == 30  # 10 rounds x 3 outputs


def test_runtime_rejects_bad_args(rt_cfg, sparse_data):
    with pytest.raises(ValueError):
        AsyncRuntime(rt_cfg, sparse_data, n_workers=0)
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=2)
    _, trace = rt.run(seed=0)
    wrong = SGBDTConfig(
        n_trees=rt_cfg.n_trees + 1, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )
    with pytest.raises(ValueError):
        replay_trace(wrong, sparse_data, trace)


@pytest.mark.slow
@pytest.mark.parametrize("hist_mode", ["subtract", "rebuild"])
def test_train_cli_threads_verify_replay(hist_mode, tmp_path):
    """Subprocess smoke of the full CLI path: ``launch.train --runtime
    threads --verify-replay`` must hold the bitwise replay contract under
    BOTH histogram modes (the driver asserts it in-process and exits
    nonzero on drift), and must export a loadable trace."""
    import os
    import pathlib
    import subprocess
    import sys

    trace_path = tmp_path / f"trace_{hist_mode}.json"
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train", "--arch", "gbdt",
            "--runtime", "threads", "--steps", "6", "--workers", "2",
            "--hist-mode", hist_mode, "--verify-replay",
            "--trace-out", str(trace_path),
        ],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": str(src), "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "record-and-replay identical forest: True" in proc.stdout
    trace = RunTrace.load(trace_path)
    assert trace.n_trees == 6
    resolve_schedule(trace.schedule, 6)  # valid causal k(j)
